"""Tunable-precision emulation end to end: default-off bit-identity
(golden counters, precision-free trace dumps), forced split2/split3
numerics against the a-priori error bound, escalation on adversarial
inputs, the split pseudo-venue in the adaptive probe/lock, simulator
replay of precision counters (live == replay), the autotune precision
dimension, the fp64 kernel-capability regression, and the apps accuracy
oracle under ``SCILIB_PRECISION=auto``."""
import dataclasses
import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

import repro.core as core  # noqa: E402
from repro.core import blas, callsite  # noqa: E402
from repro.core import precision as prec  # noqa: E402
from repro.core import runtime as rtm  # noqa: E402
from repro.core.config import OffloadConfig  # noqa: E402
from repro.core.policy import host_array  # noqa: E402
from repro.core.trace import Trace  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.memtier.simulator import replay_trace  # noqa: E402
from repro.tools import autotune as at  # noqa: E402

RNG = np.random.default_rng(11)


@pytest.fixture(scope="module", autouse=True)
def x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def no_precision_env(monkeypatch):
    """Tests set precision through explicit configs; the environment
    must not leak a scheme into the legacy install() paths."""
    monkeypatch.delenv("SCILIB_PRECISION", raising=False)
    monkeypatch.delenv("SCILIB_PRECISION_RTOL", raising=False)


def _f64(shape, scale=1.0):
    return RNG.standard_normal(shape) * scale


def _tri64(n):
    a = np.tril(RNG.standard_normal((n, n)) / n)
    np.fill_diagonal(a, 2.0)
    return a


def _pcfg(**kw):
    kw.setdefault("policy", "dfu")
    kw.setdefault("threshold", 1.0)
    kw.setdefault("precision", "split2")
    kw.setdefault("sync", True)
    return OffloadConfig(**kw)


def _cancel_pair(n=48, k=24):
    """A @ B == 0 exactly: the |A|@|B| scale is honest but the forward
    error is unbounded — catastrophic cancellation, the case the
    sampled-residual check exists for."""
    u = RNG.standard_normal((n, k))
    w = RNG.standard_normal((k, n))
    a = np.concatenate([u, u], axis=1)
    b = np.concatenate([w, -w], axis=0)
    return a, b


# --------------------------------------------------------------------- #
# default-off bit-identity                                               #
# --------------------------------------------------------------------- #
def test_precision_off_golden_counters():
    """SCILIB_PRECISION unset reproduces the PR 6 golden counters
    bit-for-bit — the precision stage must be a true no-op on the
    capped eviction workload."""
    rng = np.random.default_rng(42)
    rt = rtm.install("dfu", threshold=10, device_bytes=2 * 128 * 128 * 4,
                     record_trace=False)
    try:
        xs = [host_array(rng.standard_normal((128, 128))
                         .astype("float32")) for _ in range(5)]
        for _ in range(3):
            for x in xs:
                blas.gemm(x, x)
        rt.sync()
        assert rt.stats.evictions == 28
        assert rt.stats.evicted_bytes == 1835008
        st = rt.stats.per_routine["sgemm"]
        assert (st.offloaded, st.on_host) == (15, 0)
        assert (st.cache_hits, st.cache_misses) == (15, 15)
        assert st.split_calls == 0
        assert st.escalations == 0
        assert "split precision" not in rt.stats.report()
    finally:
        rtm.uninstall()


def test_precision_off_trace_dump_is_precision_free(tmp_path):
    """Default-off trace dumps carry no precision keys at all —
    byte-stable against pre-precision readers (and writers)."""
    path = tmp_path / "t.json"
    rt = rtm.install(config=OffloadConfig(policy="dfu", threshold=1.0,
                                          sync=True))
    try:
        a = host_array(_f64((64, 64)) / 64)
        blas.gemm(a, a)
        blas.syrk(a)
        rt.sync()
        assert all(c.precision == "" for c in rt.trace.calls)
        rt.trace.dump(str(path))
    finally:
        rtm.uninstall()
    for call in json.loads(path.read_text())["calls"]:
        assert "precision" not in call
    assert all(c.precision == "" for c in Trace.load(str(path)).calls)


# --------------------------------------------------------------------- #
# forced split schemes: tags, counters, numerics                         #
# --------------------------------------------------------------------- #
def test_split2_tags_counters_and_numerics():
    """A forced split2 run tags every offloaded fp64 call with its
    scheme, the per-routine split counters agree, the report grows the
    precision section, and every accepted result is within rtol."""
    rt = rtm.install(config=_pcfg())
    try:
        a = host_array(_f64((96, 96)) / 96)
        b = host_array(_f64((96, 96)))
        t = host_array(_tri64(96))
        outs = [np.asarray(blas.gemm(a, b)) for _ in range(3)]
        s = np.asarray(blas.syrk(a))
        x = np.asarray(blas.trsm(t, b))
        rt.sync()
        assert [c.precision for c in rt.trace.calls] == ["split2"] * 5
        live = sum(r.split_calls for r in rt.stats.per_routine.values())
        assert live == 5
        assert sum(r.escalations
                   for r in rt.stats.per_routine.values()) == 0
        assert "split precision: 5 calls" in rt.stats.report()
    finally:
        rtm.uninstall()
    an, bn, tn = np.asarray(a), np.asarray(b), np.tril(np.asarray(t))
    rtol = _pcfg().precision_rtol
    for o in outs:
        assert np.max(np.abs(o - an @ bn)) <= rtol * np.max(np.abs(an @ bn))
    ref_s = np.tril(an @ an.T)
    assert np.max(np.abs(s - ref_s)) <= rtol * np.max(np.abs(ref_s))
    ref_x = np.linalg.solve(tn, bn)
    assert np.max(np.abs(x - ref_x)) <= rtol * np.max(np.abs(ref_x))


@pytest.mark.parametrize("scheme", prec.SCHEMES)
def test_split_bound_holds_across_shapes_and_scales(scheme):
    """Deterministic sweep of the hypothesis property: the measured
    error of a split matmul, relative to the |A|@|B| inner-product
    scale, never exceeds error_bound(scheme, k)."""
    for (m, k, n) in ((17, 33, 9), (64, 64, 64), (32, 300, 16)):
        for scale in (1e-6, 1.0, 1e6):
            a = _f64((m, k), scale)
            b = _f64((k, n), scale)
            out = np.asarray(prec.matmul(jnp.asarray(a), jnp.asarray(b),
                                         scheme))
            ref = a @ b
            denom = np.abs(a) @ np.abs(b) + 1e-300
            rel = np.max(np.abs(out - ref) / denom)
            assert rel <= prec.error_bound(scheme, k), (scheme, m, k, n,
                                                        scale, rel)


def test_split_bound_property_hypothesis():
    """Randomized form of the bound sweep (skips when hypothesis is not
    installed, mirroring tests/test_property.py)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(seed=st.integers(0, 2**31 - 1),
                      m=st.integers(1, 48), k=st.integers(1, 96),
                      n=st.integers(1, 48),
                      logscale=st.integers(-6, 6),
                      scheme=st.sampled_from(prec.SCHEMES))
    def check(seed, m, k, n, logscale, scheme):
        rng = np.random.default_rng(seed)
        a = rng.standard_normal((m, k)) * 10.0 ** logscale
        b = rng.standard_normal((k, n)) * 10.0 ** logscale
        out = np.asarray(prec.matmul(jnp.asarray(a), jnp.asarray(b),
                                     scheme))
        denom = np.abs(a) @ np.abs(b) + 1e-300
        rel = np.max(np.abs(out - a @ b) / denom)
        assert rel <= prec.error_bound(scheme, k)

    check()


def test_choose_and_error_bound_units():
    """auto resolves to the cheapest scheme whose bound fits rtol, and
    refuses (native) when none does; explicit schemes are refused up
    front when their own bound cannot fit."""
    assert prec.error_bound("split3", 4096) < prec.error_bound(
        "split2", 4096)
    assert prec.choose("split2", "gemm", 64, 1e-4) == "split2"
    assert prec.choose("split2", "gemm", 64, 1e-9) == ""
    assert prec.choose("auto", "gemm", 64, 1e-4) == "split2"
    big_k = 100_000
    rtol3 = prec.error_bound("split3", big_k, "gemm") * 1.5
    assert prec.error_bound("split2", big_k) > rtol3
    assert prec.choose("auto", "gemm", big_k, rtol3) == "split3"
    assert prec.choose("auto", "gemm", big_k, 1e-12) == ""
    assert prec.choose("native", "gemm", 64, 1e-4) == ""
    assert prec.error_bound("split2", 64, "trsm") == \
        4.0 * prec.error_bound("split2", 64, "gemm")
    assert prec.supported("gemm", jnp.float64)
    assert not prec.supported("gemm", jnp.float32)
    assert not prec.supported("gemm", jnp.complex128)
    assert not prec.supported("trmm", jnp.float64)


# --------------------------------------------------------------------- #
# escalation: bounded degradation, never silent                          #
# --------------------------------------------------------------------- #
def test_escalation_on_catastrophic_cancellation():
    """A @ B == 0 passes the a-priori bound but fails the sampled
    residual: the call escalates to native fp64, the counters and the
    trace event record it, and the result is the native one."""
    a, b = _cancel_pair()
    rt = rtm.install(config=_pcfg())
    try:
        out = np.asarray(blas.gemm(host_array(a), host_array(b)))
        rt.sync()
        st = rt.stats.per_routine["dgemm"]
        assert st.escalations == 1
        assert st.split_calls == 1          # the attempt still counts
        assert rt.trace.event_count("escalate") == 1
        call = rt.trace.calls[-1]
        assert call.precision == "split2"   # attempted scheme is kept
        (site,) = list(rt.callsites)
        assert site.split_bad               # never locks split later
    finally:
        rtm.uninstall()
    # native fp64 rerun: the zeros cancel to rounding level
    assert np.max(np.abs(out)) < 1e-9


def test_trsm_split_well_conditioned_accepts():
    """The trsm residual check estimates *forward* error (back-solved
    through op(A)); a well-conditioned solve accepts without
    escalation and lands within rtol."""
    t = _tri64(96)
    b = _f64((96, 32))
    rt = rtm.install(config=_pcfg())
    try:
        x = np.asarray(blas.trsm(host_array(t), host_array(b)))
        rt.sync()
        assert rt.stats.per_routine["dtrsm"].escalations == 0
        assert rt.trace.calls[-1].precision == "split2"
    finally:
        rtm.uninstall()
    ref = np.linalg.solve(np.tril(t), b)
    assert np.max(np.abs(x - ref)) <= 1e-4 * np.max(np.abs(ref))


def test_trsm_split_ill_conditioned_escalates():
    """A triangle with a 1e16 diagonal range defeats the fp32 solve +
    refinement; the residual check catches it and the native rerun's
    answer is returned."""
    n = 64
    t = np.tril(RNG.standard_normal((n, n)))
    np.fill_diagonal(t, 10.0 ** np.linspace(-16, 0, n))
    b = _f64((n, 8))
    rt = rtm.install(config=_pcfg())
    try:
        x = np.asarray(blas.trsm(host_array(t), host_array(b)))
        rt.sync()
        assert rt.stats.per_routine["dtrsm"].escalations == 1
        assert rt.trace.event_count("escalate") == 1
    finally:
        rtm.uninstall()
    # the returned solution is the native fp64 one
    ref = np.asarray(jax.lax.linalg.triangular_solve(
        jnp.asarray(t), jnp.asarray(b), left_side=True, lower=True))
    np.testing.assert_allclose(x, ref, rtol=1e-12, atol=0)


# --------------------------------------------------------------------- #
# live == replay precision counters                                      #
# --------------------------------------------------------------------- #
def test_precision_counters_live_equals_replay():
    """A split run's trace replays to the same split_calls and
    escalations the runtime reported; a precision-off replay of the
    same trace keeps split_calls at 0."""
    a, b = _cancel_pair()
    rt = rtm.install(config=_pcfg())
    try:
        x = host_array(_f64((96, 96)) / 96)
        for _ in range(4):
            blas.gemm(x, x)
        blas.gemm(host_array(a), host_array(b))   # escalates
        rt.apply_config(_pcfg(precision=""))      # one native sample
        blas.gemm(x, x)                           # for the calibrator
        rt.sync()
        trace = rt.trace
        live_split = sum(r.split_calls
                         for r in rt.stats.per_routine.values())
        live_esc = sum(r.escalations
                       for r in rt.stats.per_routine.values())
        assert live_split == 5 and live_esc == 1
    finally:
        rtm.uninstall()
    on = replay_trace(trace, policies=("dfu",), threshold=1.0,
                      precision="split2")["dfu"]
    assert on.split_calls == live_split
    assert on.escalations == live_esc
    assert on.precision_ratio           # calibrated from the trace
    off = replay_trace(trace, policies=("dfu",), threshold=1.0)["dfu"]
    assert off.split_calls == 0
    assert off.precision_ratio == {}


# --------------------------------------------------------------------- #
# fp64 kernel capability (regression: the venue must not lie)            #
# --------------------------------------------------------------------- #
def test_fp64_gemm_kernel_capability_requires_split():
    """kernel_available must not claim an fp64 gemm kernel it does not
    have: without a split scheme the pallas venue would time the plain
    XLA formulation and could mis-lock."""
    assert not ops.kernel_available("gemm", jnp.float64)
    assert ops.kernel_available("gemm", jnp.float64, precision="split2")
    assert ops.kernel_available("gemm", jnp.float64, precision="split3")
    assert ops.kernel_available("gemm", jnp.float32)
    a = jnp.asarray(_f64((48, 64)))
    b = jnp.asarray(_f64((64, 32)))
    out = np.asarray(ops.kernel_matmul(a, b, precision="split2"))
    ref = np.asarray(a) @ np.asarray(b)
    denom = np.abs(np.asarray(a)) @ np.abs(np.asarray(b)) + 1e-300
    assert np.max(np.abs(out - ref) / denom) <= prec.error_bound(
        "split2", 64)


# --------------------------------------------------------------------- #
# the split pseudo-venue in the adaptive probe/lock                      #
# --------------------------------------------------------------------- #
def test_split_probe_schedule_rotation():
    """probe_venue(2, split=True) appends the split slot to the classic
    host/offload alternation — equal samples per venue."""
    p = callsite.CallSiteProfile("x")
    seen = []
    for _ in range(6):
        v = p.probe_venue(2, split=True)
        seen.append(v)
        if v == "split":
            p.observe_probe(True, 1e-3, venue="xla", precision="split2")
        else:
            p.observe_probe(v != "host", 1e-3)
    assert seen == ["host", "xla", "split"] * 2
    assert p.split_timed == 2 and p.split_scheme == "split2"


def test_lock_prefers_split_on_best_sample():
    """Unit rule: the split pseudo-venue wins the lock iff its best
    probe beats every other venue AND no probe escalated."""
    p = callsite.CallSiteProfile("x")
    p.observe_probe(False, 2e-3)
    p.observe_probe(True, 1e-3, venue="xla")
    p.observe_probe(True, 5e-4, venue="xla", precision="split2")
    assert p.lock() is True
    assert p.locked_precision == "split2"
    assert p.decision_label() == "offload*~split2"
    q = callsite.CallSiteProfile("y")       # an escalated probe blocks
    q.observe_probe(False, 2e-3)
    q.observe_probe(True, 1e-3, venue="xla")
    q.observe_probe(True, 5e-4, venue="xla", precision="split2")
    q.split_bad = True
    assert q.lock() is True
    assert q.locked_venue == "xla" and q.locked_precision == ""
    r = callsite.CallSiteProfile("z")       # slower split never locks
    r.observe_probe(False, 2e-3)
    r.observe_probe(True, 1e-3, venue="xla")
    r.observe_probe(True, 3e-3, venue="xla", precision="split2")
    assert r.lock() is True
    assert r.locked_precision == ""


def _adaptive_site(x, y):
    """One stable call site for the adaptive integration test."""
    return blas.gemm(x, y)


def test_adaptive_probes_split_as_a_venue():
    """With a scheme configured, the warmup round-robins
    host/xla/split (equal samples each) and tags the split probes'
    trace calls with the scheme."""
    rt = rtm.install(config=_pcfg(adaptive=True, adaptive_warmup=6,
                                  threshold=100.0))
    try:
        a = host_array(_f64((64, 64)) / 64)
        for _ in range(6):
            _adaptive_site(a, a)
        rt.sync()
        (prof,) = list(rt.callsites)
        assert (prof.host_timed, prof.device_timed,
                prof.split_timed) == (2, 2, 2)
        assert prof.locked is None
        tags = [c.precision for c in rt.trace.calls]
        assert tags == ["", "", "split2"] * 2
        _adaptive_site(a, a)                # 7th call locks
        assert prof.locked is not None
        if prof.locked_precision:
            assert prof.decision_label().endswith("~split2")
    finally:
        rtm.uninstall()


def test_reconfigure_precision_resets_split_probes():
    """apply_config with a different scheme drops locks and split probe
    samples — they timed the old (scheme, rtol) regime."""
    cfg = _pcfg(adaptive=True, adaptive_warmup=4, threshold=100.0)
    rt = rtm.install(config=cfg)
    try:
        a = host_array(_f64((64, 64)) / 64)
        for _ in range(5):
            _adaptive_site(a, a)
        rt.sync()
        (prof,) = list(rt.callsites)
        assert prof.split_timed > 0 or prof.locked is not None
        rt.apply_config(cfg.replace(precision="split3"))
        assert prof.locked is None
        assert prof.locked_precision == ""
        assert prof.split_timed == 0
        assert prof.split_scheme == ""
    finally:
        rtm.uninstall()


# --------------------------------------------------------------------- #
# autotune precision dimension                                           #
# --------------------------------------------------------------------- #
def _precision_trace(tagged: bool, escalations: int = 0) -> Trace:
    t = Trace()
    a = t.new_buffer(512 * 512 * 8, "A")
    b = t.new_buffer(512 * 512 * 8, "B")
    c = t.new_buffer(512 * 512 * 8, "C")
    for _ in range(8):
        t.gemm("d", 512, 512, 512, a, b, c)
    if tagged:
        t.calls = [dataclasses.replace(
            call, precision="split2" if i % 2 else "",
            seconds=1e-3 if i % 2 else 2e-3)
            for i, call in enumerate(t.calls)]
    for _ in range(escalations):
        t.record_event("escalate", "dev", 0)
    return t


def test_autotune_sweeps_precision_only_on_tagged_traces():
    """The precision grid dimension is gated on split tags: an untagged
    trace has no split timings to calibrate from, so every scheme would
    replay identically and the sweep would only multiply the grid."""
    res = at.autotune(_precision_trace(True), policies=("dfu",),
                      device_counts=(1,))
    assert any(p.precision for p in res.points)
    assert any(not p.precision for p in res.points)
    assert "prec" in at.format_grid(res).splitlines()[0]
    res_off = at.autotune(_precision_trace(False), policies=("dfu",),
                          device_counts=(1,))
    assert not any(p.precision for p in res_off.points)


def test_autotune_refuses_high_escalation_traces():
    """A trace whose escalation rate exceeds 10% of its split-tagged
    calls never gets a precision recommendation — the residual checks
    already said the scheme is wrong for this workload."""
    res = at.autotune(_precision_trace(True, escalations=2),
                      policies=("dfu",), device_counts=(1,))
    assert not any(p.precision for p in res.points)


def test_autotune_precision_point_env_and_config():
    """A split grid point deploys as SCILIB_PRECISION=split2 and as
    OffloadConfig.precision="split2" — the tune->deploy loop carries
    the scheme; with the calibrated 0.5x gemm cost it beats native."""
    res = at.autotune(_precision_trace(True), policies=("dfu",),
                      device_counts=(1,), precisions=("", "split2"))
    p = res.best
    assert p.precision == "split2"
    assert p.env().get("SCILIB_PRECISION") == "split2"
    assert p.to_config().precision == "split2"


# --------------------------------------------------------------------- #
# apps accuracy oracle under SCILIB_PRECISION=auto                       #
# --------------------------------------------------------------------- #
def test_dft_mini_accuracy_under_auto(monkeypatch):
    """PARSEC mini under auto precision: split gemms actually run and
    the converged Ritz drift stays within the split-level tolerance
    (the native test bound is 1e-6; split2's k=512 gemm bound is
    ~3e-5, amplified through Rayleigh-Ritz)."""
    from repro.apps import dft
    monkeypatch.setenv("SCILIB_PRECISION", "auto")
    with core.offload("dfu", threshold=100) as rt:
        out = dft.run_mini(ngrid=512, nstates=16, scf=8)
        rt.sync()
        splits = sum(r.split_calls for r in rt.stats.per_routine.values())
        escs = sum(r.escalations for r in rt.stats.per_routine.values())
    assert splits > 0
    assert out["max_err_low_half"] < 1e-3
    # every accepted split result honored rtol; escalations (if any)
    # reran native, so the drift bound above cannot be violated silently
    assert escs <= splits


def test_lsms_mini_exact_under_auto(monkeypatch):
    """LSMS mini is complex128 — no split formulation exists, auto must
    leave it native and bit-accurate."""
    from repro.apps import lsms
    monkeypatch.setenv("SCILIB_PRECISION", "auto")
    with core.offload("dfu", threshold=100) as rt:
        out = lsms.run_mini(atoms=2, energies=2, scf=1, n=96, nb=32)
        rt.sync()
        splits = sum(r.split_calls for r in rt.stats.per_routine.values())
    assert splits == 0
    assert out["max_resid"] < 1e-10

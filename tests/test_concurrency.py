"""Thread-safe concurrent multi-tenant sessions (PR 7).

The concurrency harness (:func:`run_threads`) drives N worker threads
through mixed gemm/syrk/trsm workloads in independent sessions and
asserts the properties the tentpole promises:

* sessions are context-local — a worker's open/close can never corrupt
  another thread's dispatch target (the seed's global session stack
  failed exactly this way),
* no lost counter updates — the per-session counter sums equal the
  shared pool's totals under a 32-thread storm,
* no cross-session decision-cache bleed — concurrent sessions with
  different thresholds each dispatch per their own config,
* pins survive arbitrary shared-pool pressure,
* N-thread runs stay deterministic: every session's counters and
  results match a single-threaded oracle run of the same workload,
* chaos x concurrency: per-session fault counters under an injected
  fault spec match a serialized replay of that session's trace.
"""
import json
import threading

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import blas  # noqa: E402
from repro.core import faults as flt  # noqa: E402
from repro.core import residency as res  # noqa: E402
from repro.core import runtime as rtm  # noqa: E402
from repro.core import session as ses  # noqa: E402
from repro.core.callsite import CallSiteProfile, CallSiteRegistry  # noqa: E402
from repro.core.config import OffloadConfig  # noqa: E402
from repro.core.policy import host_array  # noqa: E402
from repro.core.residency import ResidencyStore, SharedDevicePool  # noqa: E402
from repro.core.session import Session  # noqa: E402
from repro.memtier.simulator import MemTierSimulator  # noqa: E402

N = 64                       # matrix edge used throughout
NBYTES = N * N * 4


# --------------------------------------------------------------------- #
# the harness                                                            #
# --------------------------------------------------------------------- #
def run_threads(n, fn, *, barrier=True, timeout=120.0):
    """Run ``fn(idx)`` on ``n`` threads; re-raise the first exception.

    With ``barrier=True`` every worker waits at a start barrier so the
    bodies genuinely overlap instead of running in spawn order.  Any
    worker raising aborts the barrier (no deadlocked stragglers) and
    the first exception propagates to the caller.
    """
    start = threading.Barrier(n) if barrier else None
    errors = []
    err_lock = threading.Lock()

    def body(idx):
        try:
            if start is not None:
                start.wait()
            fn(idx)
        except BaseException as exc:   # noqa: BLE001 — harness boundary
            with err_lock:
                errors.append(exc)
            if start is not None:
                start.abort()

    threads = [threading.Thread(target=body, args=(i,),
                                name=f"worker-{i}") for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout)
        assert not t.is_alive(), f"{t.name} did not finish"
    if errors:
        raise errors[0]


def _mats(seed, count=3, n=N):
    rng = np.random.default_rng(seed)
    return [host_array(rng.standard_normal((n, n)).astype("float32"))
            for _ in range(count)]


def _tri(seed, n=N):
    rng = np.random.default_rng(seed)
    return host_array(
        np.tril(rng.standard_normal((n, n)) + n).astype("float32"))


def _mixed_workload(seed, reps=3):
    """The tier-1-style mixed routine chain every stress worker runs:
    gemm -> syrk -> trsm over per-worker deterministic operands."""
    a, b, c = _mats(seed)
    t = _tri(seed + 1000)
    outs = []
    for _ in range(reps):
        g = blas.gemm(a, b)
        s = blas.syrk(c)
        x = blas.trsm(t, g)
        outs.extend((g, s, x))
    return outs


# --------------------------------------------------------------------- #
# the harness itself                                                     #
# --------------------------------------------------------------------- #
def test_run_threads_propagates_first_exception():
    def boom(idx):
        if idx == 3:
            raise ValueError("worker 3 failed")

    with pytest.raises(ValueError, match="worker 3"):
        run_threads(8, boom)


def test_run_threads_barrier_overlaps_all_workers():
    ran = [0] * 8
    gate = threading.Barrier(8)      # passes only if all overlap

    def body(idx):
        gate.wait(timeout=30)
        ran[idx] = 1

    run_threads(8, body)
    assert ran == [1] * 8


# --------------------------------------------------------------------- #
# context-local sessions (the seed's nesting race, fixed)                 #
# --------------------------------------------------------------------- #
def test_sessions_are_context_local():
    """A session opened in a worker thread is not the main thread's
    dispatch target — on the seed's global stack it was."""
    opened = threading.Event()
    done = threading.Event()
    seen = {}

    def worker():
        with ses.session(OffloadConfig(), record_trace=False,
                         intercept=False) as s:
            seen["worker_active"] = ses.active_session() is s
            opened.set()
            done.wait(30)

    t = threading.Thread(target=worker)
    t.start()
    try:
        assert opened.wait(30)
        # the worker's session must be invisible here
        assert ses.active_session() is None
        assert rtm.active() is None
    finally:
        done.set()
        t.join(30)
    assert seen["worker_active"]


def test_session_nesting_race_regression():
    """Seed-failing regression: A opens, B opens, A closes — on a
    shared global stack A's close restored *B's* session as A's
    dispatch target (and B's close then corrupted A's).  Context-local
    stacks keep each thread's nesting its own."""
    a_opened, b_opened, a_closed = (threading.Event(),
                                    threading.Event(), threading.Event())
    state = {}

    def thread_a():
        s = ses.session(OffloadConfig(), record_trace=False,
                        intercept=False)
        a_opened.set()
        assert b_opened.wait(30)
        s.close()
        # after closing its own innermost session this thread must have
        # NO active session — the seed leaked B's here
        state["a_after_close"] = ses.active_session()
        state["a_runtime_after_close"] = rtm.active()
        a_closed.set()

    def thread_b():
        assert a_opened.wait(30)
        with ses.session(OffloadConfig(), record_trace=False,
                         intercept=False) as s:
            b_opened.set()
            assert a_closed.wait(30)
            # A's close must not have stolen B's dispatch target
            state["b_still_active"] = ses.active_session() is s
            state["b_runtime_ok"] = rtm.active() is s.runtime

    ta = threading.Thread(target=thread_a)
    tb = threading.Thread(target=thread_b)
    ta.start(), tb.start()
    ta.join(30), tb.join(30)
    assert state["a_after_close"] is None
    assert state["a_runtime_after_close"] is None
    assert state["b_still_active"] and state["b_runtime_ok"]


def test_scope_adopts_open_session_in_worker_thread():
    """Sessions don't leak across threads, so sharing one is explicit:
    ``with s.scope():`` adopts it; its runtime serializes the calls."""
    with ses.session(OffloadConfig(policy="dfu", threshold=10.0),
                     record_trace=False, intercept=False) as s:

        def worker(idx):
            assert ses.active_session() is None      # not inherited
            with s.scope():
                assert ses.active_session() is s
                a, b, _ = _mats(idx, n=32)
                blas.gemm(a, b)
            assert ses.active_session() is None      # restored

        run_threads(8, worker)
        s.sync()
        total = sum(r.calls for r in s.stats.per_routine.values())
        assert total == 8                            # none lost


def test_scope_restores_workers_own_session():
    """A worker with its own open session that scopes a shared one gets
    its own back on exit (stack discipline per context)."""
    with ses.session(OffloadConfig(threshold=123.0), record_trace=False,
                     intercept=False) as shared:

        def worker(idx):
            with ses.session(OffloadConfig(threshold=77.0),
                             record_trace=False, intercept=False) as own:
                with shared.scope():
                    assert ses.active_session() is shared
                assert ses.active_session() is own
                assert rtm.active() is own.runtime

        run_threads(4, worker)


def test_legacy_install_stack_is_context_local():
    from repro.core import intercept as icp

    def worker(idx):
        rt = rtm.install("dfu", threshold=10, record_trace=False)
        try:
            assert rtm.active() is rt
        finally:
            rtm.uninstall()
        assert rtm.active() is None

    run_threads(4, worker)
    assert rtm.active() is None
    assert icp._PATCHED == 0


# --------------------------------------------------------------------- #
# ResidencyStore under contention                                        #
# --------------------------------------------------------------------- #
def test_concurrent_puts_account_bytes_exactly():
    s = ResidencyStore("t")
    per, nth = 50, 8

    def worker(idx):
        for i in range(per):
            s.put((idx, i), f"p{idx}.{i}", 10)

    run_threads(nth, worker)
    assert len(s) == per * nth
    assert s.resident_bytes == per * nth * 10
    assert s.resident_bytes == sum(s.entry(k).nbytes for k in s.keys())


def test_concurrent_mixed_ops_no_lost_updates():
    """put/get/drop storms keep the byte ledger exactly equal to the
    surviving entries — a torn update breaks the equality."""
    s = ResidencyStore("t")

    def worker(idx):
        for i in range(40):
            s.put((idx, i), i, 7)
            assert s.get((idx, i)) == i
            if i % 3 == 0:
                s.drop((idx, i))

    run_threads(8, worker)
    assert s.resident_bytes == 7 * len(s)
    assert len(s) == 8 * (40 - 14)       # 14 drops per worker


def test_concurrent_eviction_under_cap_pressure():
    s = ResidencyStore("t", cap=200, policy="lru")

    def worker(idx):
        for i in range(60):
            s.put((idx, i), i, 20)

    run_threads(8, worker)
    assert s.resident_bytes <= 200
    assert s.resident_bytes == sum(s.entry(k).nbytes for k in s.keys())
    # conservation: everything placed was either evicted or survives
    assert s.evictions == 8 * 60 - len(s)


def test_pins_never_evicted_under_concurrent_pressure():
    s = ResidencyStore("t", cap=200, policy="lru")
    s.put("pinned", "P", 50, pinned=True)

    def worker(idx):
        for i in range(50):
            s.put((idx, i), i, 30)

    run_threads(8, worker)
    assert "pinned" in s
    assert s.get("pinned") == "P"
    assert s.pinned_bytes() == 50


def test_concurrent_evict_one_terminates_and_accounts():
    s = ResidencyStore("t")
    for i in range(64):
        s.put(i, i, 10)
    freed = []
    lock = threading.Lock()

    def worker(idx):
        for _ in range(16):
            got = s.evict_one()
            with lock:
                freed.append(got)

    run_threads(4, worker)
    assert len(s) == 0 and s.resident_bytes == 0
    assert sum(freed) == 64 * 10         # every byte freed exactly once
    assert s.evictions == 64


# --------------------------------------------------------------------- #
# property test: threaded store ops preserve invariants (hypothesis      #
# optional, gated like the PR 4/6 property suites)                       #
# --------------------------------------------------------------------- #
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    thread_ops = st.lists(
        st.tuples(st.integers(0, 3),             # worker
                  st.sampled_from(["place", "evict", "pin", "refetch"]),
                  st.integers(0, 5),             # key
                  st.integers(1, 40)),           # nbytes
        min_size=4, max_size=48)

    @given(ops=thread_ops)
    @settings(max_examples=25, deadline=None)
    def test_threaded_store_ops_preserve_invariants(ops):
        """Interleaved place/evict/pin/refetch from 4 threads on one
        shared capped store: byte accounting stays exact, the cap
        holds at quiescence, pinned entries stay resident."""
        cap = 100
        s = ResidencyStore("t", cap=cap, policy="lru")
        s.put("pin-a", "PA", 30, pinned=True)
        per_worker = [[op for op in ops if op[0] == w] for w in range(4)]

        def worker(idx):
            for _, kind, key, nbytes in per_worker[idx]:
                if kind == "place":
                    s.put((idx, key), key, min(nbytes, 40))
                elif kind == "evict":
                    s.evict_one()
                elif kind == "pin":
                    s.put((idx, key), key, min(nbytes, 40))
                    s.pin((idx, key))
                    s.unpin((idx, key))
                else:                             # refetch: place again
                    s.put((idx, key), key, min(nbytes, 40))

        run_threads(4, worker)
        assert s.resident_bytes == sum(s.entry(k).nbytes
                                       for k in s.keys())
        assert s.resident_bytes <= cap
        assert "pin-a" in s and s.get("pin-a") == "PA"
else:                                            # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_threaded_store_ops_preserve_invariants():
        pass


# --------------------------------------------------------------------- #
# SharedDevicePool                                                       #
# --------------------------------------------------------------------- #
def test_pool_register_unique_ids_under_contention():
    pool = SharedDevicePool(1 << 20)
    got = []
    lock = threading.Lock()

    def worker(idx):
        sid = pool.register()
        with lock:
            got.append(sid)

    run_threads(32, worker)
    assert len(set(got)) == 32
    assert set(pool.members()) == set(got)


def test_pool_duplicate_name_rejected():
    pool = SharedDevicePool(1 << 20)
    pool.register("a")
    with pytest.raises(ValueError, match="already registered"):
        pool.register("a")


def test_pool_quota_evicts_over_quota_tenant_first():
    """A tenant over its own quota is evicted down before anyone else
    loses a byte, even with pool headroom to spare."""
    pool = SharedDevicePool(10_000)
    sa = ResidencyStore("a-store")
    sb = ResidencyStore("b-store")
    pool.register("a", quota=100)
    pool.register("b", quota=5_000)
    pool.attach("a", sa)
    pool.attach("b", sb)
    for i in range(5):
        sb.put(("b", i), i, 60)
    for i in range(5):
        sa.put(("a", i), i, 60)      # 300B > quota 100 -> evicted down
    assert pool.usage("a") <= 100
    assert pool.usage("b") == 300                # untouched
    assert sb.evictions == 0 and sa.evictions >= 4


def test_pool_fair_eviction_is_quota_proportional():
    """Pool-total pressure picks the tenant with the highest
    usage/quota ratio — the one furthest over its fair share."""
    pool = SharedDevicePool(500)
    sa, sb = ResidencyStore("a-store"), ResidencyStore("b-store")
    pool.register("a", quota=400)
    pool.register("b", quota=400)
    pool.attach("a", sa)
    pool.attach("b", sb)
    for i in range(4):
        sa.put(("a", i), i, 100)     # a: 400B (at quota, over share)
    sb.put(("b", 0), 0, 100)
    sb.put(("b", 1), 1, 100)         # total 600 > 500: a (ratio 1.0)
    #                                # loses before b (ratio 0.5)
    assert pool.usage() <= 500
    assert sa.evictions >= 1 and sb.evictions == 0


def test_pool_pinned_tenant_is_exempted_not_spun():
    """rebalance() must terminate when the only over-quota tenant is
    fully pinned — it is exempted, not retried forever."""
    pool = SharedDevicePool(100)
    sa = ResidencyStore("a-store")
    pool.register("a", quota=50)
    pool.attach("a", sa)
    sa.put("p1", 1, 80, pinned=True)
    sa.put("p2", 2, 80, pinned=True)
    assert pool.usage("a") == 160    # over quota AND pool, all pinned
    assert pool.rebalance() == 0
    assert "p1" in sa and "p2" in sa


def test_pool_unregister_detaches_stores():
    pool = SharedDevicePool(1 << 20)
    s = ResidencyStore("a-store")
    pool.register("a")
    pool.attach("a", s)
    s.put("k", 1, 100)
    assert pool.usage("a") == 100
    pool.unregister("a")
    assert s.pool is None and s.owner == ""
    assert pool.usage() == 0
    s.put("k2", 2, 100)              # no longer charges the pool
    assert pool.usage() == 0
    assert pool.places == 1          # lifetime totals survive


def test_default_pool_config_driven_sessions_share_it():
    """Sessions with ``pool_bytes``/``pool_quota`` set and no explicit
    pool join one process-default pool (first capacity wins)."""
    res.reset_default_pool()
    try:
        cfg = OffloadConfig(policy="dfu", threshold=10.0,
                            pool_bytes=1 << 20, pool_quota=1 << 19)
        with Session(cfg, record_trace=False, intercept=False,
                     name="t1") as s1:
            with Session(cfg, record_trace=False, intercept=False,
                         name="t2") as s2:
                pool = res.default_pool()
                assert s1.runtime.pool is pool
                assert s2.runtime.pool is pool
                assert pool.total_bytes == 1 << 20
                assert pool.quota_of("t1") == 1 << 19
                assert set(pool.members()) == {"t1", "t2"}
        assert pool.members() == ()              # closed -> unregistered
    finally:
        res.reset_default_pool()


def test_pool_totals_equal_tenant_sums_under_32_thread_storm():
    """The headline lost-update detector: 32 sessions hammer one pool
    with mixed gemm/syrk/trsm under real cap pressure; at quiescence
    the independently-maintained pool totals equal the per-tenant sums
    exactly, and the usage ledger equals the stores' resident bytes."""
    nth = 32
    pool = SharedDevicePool(6 * NBYTES, name="storm")
    cfg = OffloadConfig(policy="dfu", threshold=10.0)
    quiesce = threading.Barrier(nth)
    snap = {}

    def worker(idx):
        with ses.session(cfg, record_trace=False, intercept=False,
                         name=f"w{idx}", pool=pool) as s:
            outs = _mixed_workload(idx, reps=2)
            s.sync()
            resident = s.runtime.resident_bytes() + sum(
                s.runtime.block_stores[d].resident_bytes
                for d in range(len(s.runtime.block_stores)))
            quiesce.wait(60)         # everyone done, nobody closed
            if idx == 0:
                snap["tenants"] = pool.tenant_stats()
                snap["totals"] = (pool.places, pool.placed_bytes,
                                  pool.evictions, pool.evicted_bytes,
                                  pool.refetches)
                snap["usage"] = pool.usage()
            snap[f"resident-{idx}"] = resident
            quiesce.wait(60)         # hold tenants until the snapshot
            del outs

    run_threads(nth, worker)
    rows = snap["tenants"].values()
    assert len(rows) == nth
    sums = (sum(r["places"] for r in rows),
            sum(r["placed_bytes"] for r in rows),
            sum(r["evictions"] for r in rows),
            sum(r["evicted_bytes"] for r in rows),
            sum(r["refetches"] for r in rows))
    assert sums == snap["totals"]
    assert snap["totals"][0] > 0                  # work actually ran
    assert sum(r["usage"] for r in rows) == snap["usage"]


def test_pool_pins_survive_cross_tenant_pressure():
    """A pinned placement in one session survives eviction storms
    driven by every other tenant of the pool."""
    pool = SharedDevicePool(4 * NBYTES, name="pinpool")
    cfg = OffloadConfig(policy="dfu", threshold=10.0)
    pinned_sess = ses.session(cfg, record_trace=False, intercept=False,
                              name="pinner", pool=pool)
    try:
        a, b, _ = _mats(999)
        blas.gemm(a, b)
        pinned_sess.pin(a)
        assert pinned_sess.runtime.placements.entry(id(a)).pinned

        def worker(idx):
            with ses.session(cfg, record_trace=False, intercept=False,
                             name=f"evictor-{idx}", pool=pool):
                _mixed_workload(idx, reps=3)

        run_threads(8, worker)
        assert id(a) in pinned_sess.runtime.placements
        assert pinned_sess.runtime.placements.entry(id(a)).pinned
    finally:
        pinned_sess.close()


# --------------------------------------------------------------------- #
# runtime + dispatch under concurrency                                   #
# --------------------------------------------------------------------- #
def test_no_cross_session_decision_cache_bleed():
    """Concurrent sessions with opposite thresholds: each call obeys
    its own session's config — a cached decision from one runtime must
    never serve another (the per-runtime dispatch cache isolates)."""
    lo = OffloadConfig(policy="dfu", threshold=10.0)     # offloads N=64
    hi = OffloadConfig(policy="dfu", threshold=1e6)      # stays host
    results = {}

    def worker(idx):
        cfg = lo if idx % 2 == 0 else hi
        with ses.session(cfg, record_trace=False,
                         intercept=False) as s:
            a, b, _ = _mats(idx)
            for _ in range(4):
                blas.gemm(a, b)
            s.sync()
            st = s.stats.per_routine["sgemm"]
            results[idx] = (st.offloaded, st.on_host)

    run_threads(8, worker)
    for idx, (off, host) in results.items():
        if idx % 2 == 0:
            assert (off, host) == (4, 0), idx
        else:
            assert (off, host) == (0, 4), idx


def test_shared_session_counters_lose_nothing():
    """Many workers scoped into ONE session: the runtime serializes
    them and the counter total is exactly the calls issued."""
    nth, per = 8, 6
    with ses.session(OffloadConfig(policy="dfu", threshold=10.0),
                     record_trace=False, intercept=False) as s:

        def worker(idx):
            with s.scope():
                a, b, _ = _mats(idx)
                for _ in range(per):
                    blas.gemm(a, b)

        run_threads(nth, worker)
        s.sync()
        st = s.stats.per_routine["sgemm"]
        assert st.calls == nth * per
        assert st.offloaded + st.on_host == nth * per


def test_concurrent_sessions_match_single_thread_oracle():
    """Determinism: N threads in independent sessions produce exactly
    the counters and results of the same workloads run one-by-one."""
    nth = 8
    cfg = OffloadConfig(policy="dfu", threshold=10.0)

    def run_one(idx):
        with ses.session(cfg, record_trace=False, intercept=False) as s:
            outs = _mixed_workload(idx, reps=2)
            s.sync()
            counters = {
                name: (r.calls, r.offloaded, r.on_host,
                       r.cache_hits, r.cache_misses, r.bytes_in)
                for name, r in sorted(s.stats.per_routine.items())}
            return counters, [np.asarray(o) for o in outs]

    oracle = {idx: run_one(idx) for idx in range(nth)}
    threaded = {}
    lock = threading.Lock()

    def worker(idx):
        got = run_one(idx)
        with lock:
            threaded[idx] = got

    run_threads(nth, worker)
    for idx in range(nth):
        assert threaded[idx][0] == oracle[idx][0], idx
        for got, ref in zip(threaded[idx][1], oracle[idx][1]):
            np.testing.assert_array_equal(got, ref)


def test_single_threaded_behavior_unchanged():
    """Bit-identity guard: the PR 6 golden counters on the capped
    workload still hold after the locking refactor (same decisions,
    same eviction order, same byte totals)."""
    rng = np.random.default_rng(42)
    rt = rtm.install("dfu", threshold=10, device_bytes=2 * 128 * 128 * 4,
                     record_trace=False)
    try:
        xs = [host_array(rng.standard_normal((128, 128))
                         .astype("float32")) for _ in range(5)]
        outs = []
        for _ in range(3):
            for x in xs:
                outs.append(blas.gemm(x, x))
        rt.sync()
        assert rt.stats.evictions == 28
        assert rt.stats.evicted_bytes == 1835008
        st = rt.stats.per_routine["sgemm"]
        assert (st.offloaded, st.on_host) == (15, 0)
        assert (st.cache_hits, st.cache_misses) == (15, 15)
    finally:
        rtm.uninstall()


# --------------------------------------------------------------------- #
# call-site profiles under concurrency                                   #
# --------------------------------------------------------------------- #
def test_callsite_profile_observations_not_lost():
    prof = CallSiteProfile("gemm@x.py:f:1")
    per, nth = 200, 8

    def worker(idx):
        for i in range(per):
            prof.observe(64.0, 1e6, 1e-4, offload=(i % 2 == 0))
            prof.observe_residency(hit=(i % 3 == 0))

    run_threads(nth, worker)
    assert prof.calls == per * nth
    assert prof.offloaded + prof.on_host == per * nth
    assert prof.lookups == per * nth
    assert prof.n_avg_count == per * nth


def test_callsite_registry_one_profile_per_site_under_race():
    reg = CallSiteRegistry()
    got = []
    lock = threading.Lock()

    def worker(idx):
        p = reg.profile("site-x")
        with lock:
            got.append(p)
        p.observe(10.0, 1.0, 1e-6, offload=False)

    run_threads(16, worker)
    assert len(reg) == 1
    assert all(p is got[0] for p in got)          # no orphaned profile
    assert got[0].calls == 16                     # and no lost counts


# --------------------------------------------------------------------- #
# faults + breaker under concurrency                                     #
# --------------------------------------------------------------------- #
def test_fault_injector_counter_walk_is_atomic():
    """An nth-rule shared by 8 threads fires exactly total//nth times —
    a torn counter under- or over-fires."""
    inj = flt.FaultInjector.from_spec("kernel:nth=5")
    per, nth = 100, 8
    fired = []
    lock = threading.Lock()

    def worker(idx):
        mine = 0
        for _ in range(per):
            try:
                inj.check("kernel")
            except flt.KernelError:
                mine += 1
        with lock:
            fired.append(mine)

    run_threads(nth, worker)
    assert sum(fired) == (per * nth) // 5
    assert inj.injected["kernel"] == (per * nth) // 5


def test_health_tracker_no_lost_failures():
    h = flt.HealthTracker(1, threshold=0)        # disabled: pure tally
    per, nth = 200, 8

    def worker(idx):
        for _ in range(per):
            h.failure(0)

    run_threads(nth, worker)
    assert h.device(0).failures == per * nth


def test_breaker_trips_once_per_quarantine_under_contention():
    """Concurrent failures trip the breaker exactly once (one
    quarantine callback), and ok() recovers it exactly once."""
    trips, recovers = [], []
    h = flt.HealthTracker(1, threshold=3, cooldown_ms=1e9,
                          on_quarantine=trips.append,
                          on_recover=recovers.append)

    def worker(idx):
        for _ in range(10):
            h.failure(0)

    run_threads(8, worker)
    assert h.device(0).quarantines == 1
    assert len(trips) == 1
    assert not h.usable(0)
    h.ok(0)
    assert h.usable(0) and len(recovers) == 1


def test_chaos_and_concurrency_live_matches_serialized_replay():
    """Satellite 4: 8 threads run the tier-1-style workload under the
    injected fault spec; each session's live breaker/fallback counters
    must match a serialized replay of its own trace."""
    nth = 8
    cfg = OffloadConfig(policy="dfu", threshold=10.0,
                        faults="transfer:p=0.05,seed=7",
                        retries=1, backoff_ms=0.0, breaker=0)
    live = {}
    lock = threading.Lock()

    def worker(idx):
        with ses.session(cfg, record_trace=True, intercept=False,
                         name=f"chaos-{idx}") as s:
            # fresh operands each call: every placement rolls the
            # injector's RNG, so the spec actually fires
            mats = _mats(idx, count=24, n=32)
            for i in range(0, 24, 2):
                blas.gemm(mats[i], mats[i + 1])
            s.sync()
            st = s.stats
            with lock:
                live[f"chaos-{idx}"] = (
                    s.runtime.trace,
                    (st.faults, st.retries, st.fallbacks,
                     st.quarantines, st.recoveries))

    run_threads(nth, worker)
    assert len(live) == nth
    assert sum(counts[0] for _, counts in live.values()) > 0
    for name, (trace, counts) in live.items():
        rep = MemTierSimulator.from_config(cfg, session=name).run(trace)
        assert (rep.faults, rep.retries, rep.fallbacks,
                rep.quarantines, rep.recoveries) == counts, name
        assert rep.session == name


# --------------------------------------------------------------------- #
# session-stamped traces                                                 #
# --------------------------------------------------------------------- #
def test_trace_events_carry_session_id():
    cfg = OffloadConfig(policy="dfu", threshold=10.0)
    with ses.session(cfg, record_trace=True, intercept=False,
                     name="tenant-a") as s:
        a, b, _ = _mats(5)
        blas.gemm(a, b)
        s.sync()
        trace = s.runtime.trace
        assert trace.event_count("place") > 0
        assert all(e.session == "tenant-a" for e in trace.events)
        assert trace.event_count("place", session="tenant-a") == \
            trace.event_count("place")
        assert trace.event_count("place", session="other") == 0


def test_unnamed_session_trace_dump_is_pre_tenant_identical(tmp_path):
    """Unnamed sessions serialize with NO session key at all — the
    dumped JSON is byte-compatible with pre-tenant traces."""
    path = str(tmp_path / "t.json")
    cfg = OffloadConfig(policy="dfu", threshold=10.0, trace_path=path)
    with ses.session(cfg, record_trace=True, intercept=False):
        a, b, _ = _mats(6)
        blas.gemm(a, b)
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["events"]
    assert all("session" not in e for e in doc["events"])
    # and named sessions round-trip their stamp through load
    from repro.core.trace import Trace
    t2 = Trace.load(path)
    assert all(e.session == "" for e in t2.events)

"""MuST/PARSEC proxies: physics correctness + paper-claims structure."""
import jax
import numpy as np
import pytest

import repro.core as core
from repro.apps import dft, lsms
from repro.memtier import GH200, replay_trace


@pytest.fixture(scope="module", autouse=True)
def x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def test_lsms_mini_physics_under_offload():
    with core.offload("dfu", threshold=100):
        out = lsms.run_mini(atoms=2, energies=2, scf=1, n=96, nb=32)
    assert out["max_resid"] < 1e-10
    assert out["n_solves"] == 4


def test_parsec_mini_ritz_values():
    out = dft.run_mini(ngrid=512, nstates=16, scf=8)
    assert out["max_err_low_half"] < 1e-6


def test_paper_claims_structure_must():
    """DESIGN.md §8: orderings of Table 3 must reproduce."""
    tr = lsms.production_trace(atoms_per_node=4)   # scaled replay
    reps = replay_trace(tr, spec=GH200,
                        policies=("cpu", "memcopy", "counter", "dfu"))
    cpu, mc = reps["cpu"].total_s, reps["memcopy"].total_s
    ct, dfu = reps["counter"].total_s, reps["dfu"].total_s
    assert dfu < mc < cpu                     # Table 3 ordering
    assert dfu <= ct * 1.05                   # DFU >= counter
    assert cpu / dfu > 2.0                    # ~3x claim (>=2x floor)
    assert reps["dfu"].movement_s < reps["memcopy"].movement_s / 20
    assert reps["dfu"].mean_reuse > 100       # heavy reuse claim


def test_paper_claims_structure_parsec():
    tr = dft.production_trace(filt_per_scf=2)
    reps = replay_trace(tr, spec=GH200,
                        policies=("cpu", "memcopy", "counter", "dfu"))
    # Table 5 orderings: memcopy no better than CPU; counter poor;
    # DFU at least ~2x CPU on the BLAS stream
    assert reps["memcopy"].total_s > reps["cpu"].total_s * 0.8
    assert reps["counter"].total_s > reps["dfu"].total_s * 1.5
    assert reps["cpu"].total_s / reps["dfu"].total_s > 2.0
    # the movement volumes are lopsided exactly as measured
    assert reps["dfu"].movement_s < 1.0
    assert reps["memcopy"].movement_s > 10.0


def test_table6_full_pattern():
    from repro.core.trace import Trace
    from repro.memtier import MemTierSimulator
    want = {(1000, 1000, 1000): ("device", "device", "device"),
            (5000, 5000, 5000): ("device", "device", "host"),
            (20000, 20000, 20000): ("device", "host", "host"),
            (32, 2400, 93536): ("device", "host", "host")}
    for dims, expect in want.items():
        m, n, k = dims
        t = Trace()
        a = t.new_buffer(m * k * 8, "A")
        b = t.new_buffer(k * n * 8, "B")
        c = t.new_buffer(m * n * 8, "C")
        for _ in range(5):
            t.gemm("d", m, n, k, a, b, c)
        sim = MemTierSimulator(GH200, policy="counter", threshold=0,
                               seed=3)
        sim.run(t)
        assert tuple(sim.residency(x) for x in (a, b, c)) == expect

"""Typed OffloadConfig + Session API: the env-knob parity matrix, the
legacy install()/Session equivalence, session isolation/nesting, safe
reconfigure, the gemv interception surface, the atexit trace fallback,
and the autotune --emit-config tune->deploy loop."""
import dataclasses
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

import repro  # noqa: E402
import repro.core as core  # noqa: E402
from repro.core import config as cfg_mod  # noqa: E402
from repro.core import residency as res  # noqa: E402
from repro.core import runtime as rtm  # noqa: E402
from repro.core.config import ENV_FIELDS, OffloadConfig  # noqa: E402
from repro.core.policy import POLICY_CLASSES, host_array  # noqa: E402
from repro.core.trace import Trace  # noqa: E402

RNG = np.random.default_rng(7)
DATA = os.path.join(os.path.dirname(__file__), "data")


def _f32(shape):
    return RNG.standard_normal(shape).astype(np.float32)


@pytest.fixture
def clean_env(monkeypatch):
    """Scrub every SCILIB_* var (incl. the CI stress job's cap) so each
    test controls exactly the knobs it sets."""
    for var in list(os.environ):
        if var.startswith("SCILIB_"):
            monkeypatch.delenv(var)
    return monkeypatch


# --------------------------------------------------------------------- #
# the config <-> env parity matrix                                       #
# --------------------------------------------------------------------- #
#: one sample value per field: (env string, parsed field value)
MATRIX = {
    "policy": ("memcopy", "memcopy"),
    "threshold": ("123.5", 123.5),
    "sync": ("1", True),
    "adaptive": ("1", True),
    "adaptive_warmup": ("4", 4),
    "callsite": ("0", False),
    "dispatch_cache": ("0", False),
    "devices": ("3", 3),
    "device_bytes": ("1048576", 1048576),
    "tile_min": ("32", 32),
    "evict": ("lfu", "lfu"),
    "pin": ("never-evict", True),
    "trace_path": ("/tmp/trace.json", "/tmp/trace.json"),
    "debug": ("2", 2),
    "faults": ("transfer:p=0.5,seed=3", "transfer:p=0.5,seed=3"),
    "retries": ("4", 4),
    "backoff_ms": ("2.5", 2.5),
    "breaker": ("5", 5),
    "breaker_cooldown_ms": ("250", 250.0),
    "pool_bytes": ("4194304", 4194304),
    "pool_quota": ("1048576", 1048576),
    "kernel_path": ("1", True),
    "kernel_block": ("256", 256),
    "precision": ("split2", "split2"),
    "precision_rtol": ("1e-5", 1e-5),
    "lapack": ("1", True),
    "lapack_nb": ("96", 96),
}


def test_matrix_covers_every_field():
    """ENV_FIELDS, the sample matrix, and the dataclass cannot drift."""
    fields = {f.name for f in dataclasses.fields(OffloadConfig)}
    assert set(ENV_FIELDS) == fields
    assert set(MATRIX) == fields


def test_registries_cannot_drift():
    assert sorted(cfg_mod.POLICY_NAMES) == sorted(POLICY_CLASSES)
    assert sorted(cfg_mod.EVICT_NAMES) == sorted(res.EVICTION_POLICIES)


@pytest.mark.parametrize("field", sorted(MATRIX))
def test_env_field_roundtrip(field, clean_env):
    """Every config field <-> env knob round-trips through from_env()
    and save()/load()."""
    raw, want = MATRIX[field]
    clean_env.setenv(ENV_FIELDS[field], raw)
    cfg = OffloadConfig.from_env()
    assert getattr(cfg, field) == want
    # JSON round-trip preserves the parsed value exactly
    path = "/tmp/cfg_roundtrip.json"
    cfg.save(path)
    assert OffloadConfig.load(path) == cfg


def test_env_inverse_roundtrip(clean_env):
    """cfg.env() is the inverse of from_env() for non-default fields."""
    cfg = OffloadConfig(policy="memcopy", threshold=123.5, sync=True,
                        adaptive=True, adaptive_warmup=4, callsite=False,
                        dispatch_cache=False, devices=3,
                        device_bytes=1 << 20, tile_min=32, evict="lfu",
                        pin=True, trace_path="/tmp/t.json", debug=2)
    assert OffloadConfig.from_env(base=OffloadConfig(),
                                  environ=cfg.env()) == cfg


def test_lenient_parsing_falls_back(clean_env):
    clean_env.setenv("SCILIB_THRESHOLD", "not-a-number")
    clean_env.setenv("SCILIB_EVICT", "typo")
    clean_env.setenv("SCILIB_DEVICES", "many")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        cfg = OffloadConfig.from_env(base=OffloadConfig())
    assert cfg.threshold is None
    assert cfg.evict == "lru"
    assert cfg.devices is None


def test_out_of_range_env_values_fall_back_with_warning(clean_env):
    """Parseable-but-invalid values (negative threshold, devices=0)
    must warn and fall back, never escape from_env as a ValueError —
    they would otherwise crash at import time via the blas-layer
    refresh."""
    clean_env.setenv("SCILIB_THRESHOLD", "-5")
    clean_env.setenv("SCILIB_ADAPTIVE_WARMUP", "0")
    cfg_mod._WARNED.discard("SCILIB_THRESHOLD")
    cfg_mod._WARNED.discard("SCILIB_ADAPTIVE_WARMUP")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg = OffloadConfig.from_env(base=OffloadConfig())
    assert cfg.threshold is None
    assert cfg.adaptive_warmup == 2      # legacy clamp to the minimum
    assert any("SCILIB_THRESHOLD" in str(x.message) for x in w)


def test_legacy_shims_honor_set_default_base(clean_env):
    """install() with no arguments must start from the set_default()
    base (the CI config-file job's premise), not re-impose dfu/500."""
    prev = cfg_mod.set_default(OffloadConfig(policy="counter",
                                             threshold=810.7))
    try:
        rt = rtm.install(record_trace=False)
        try:
            assert rt.policy.name == "counter"
            assert rt.threshold == 810.7
        finally:
            rtm.uninstall()
        # an explicit argument still wins over the base
        rt = rtm.install("dfu", threshold=123.0, record_trace=False)
        try:
            assert rt.policy.name == "dfu" and rt.threshold == 123.0
        finally:
            rtm.uninstall()
    finally:
        cfg_mod.set_default(prev)


def test_unknown_env_var_warns_with_nearest_name(clean_env):
    clean_env.setenv("SCILIB_THRESOLD", "99")      # the motivating typo
    cfg_mod._WARNED.discard("SCILIB_THRESOLD")     # warn-once: re-arm
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        OffloadConfig.from_env()
        msgs = [str(x.message) for x in w]
    assert any("SCILIB_THRESOLD" in m and "SCILIB_THRESHOLD" in m
               for m in msgs), msgs
    # ... and only once per process
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        OffloadConfig.from_env()
    assert not [x for x in w if "SCILIB_THRESOLD" in str(x.message)]


def test_validation():
    with pytest.raises(ValueError):
        OffloadConfig(policy="bogus")
    with pytest.raises(ValueError):
        OffloadConfig(evict="bogus")
    with pytest.raises(ValueError):
        OffloadConfig(threshold=-1.0)
    with pytest.raises(ValueError):
        OffloadConfig(adaptive_warmup=1)
    with pytest.raises(ValueError):
        OffloadConfig(devices=0)
    with pytest.raises(ValueError):
        OffloadConfig(tile_min=0)
    # explicit uncapped sentinel normalizes
    assert OffloadConfig(device_bytes=0).device_bytes is None


def test_load_rejects_unknown_field_with_hint(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text('{"treshold": 500}')
    with pytest.raises(ValueError, match="threshold"):
        OffloadConfig.load(str(p))


def test_presets():
    assert OffloadConfig.preset("paper").sync is True
    assert OffloadConfig.preset("paper").threshold == 500.0
    assert OffloadConfig.preset("throughput").adaptive is True
    lm = OffloadConfig.preset("low-memory")
    assert lm.device_bytes == 256 << 20 and lm.evict == "refetch"
    with pytest.raises(ValueError):
        OffloadConfig.preset("bogus")


def test_set_default_is_the_env_free_base(clean_env):
    prev = cfg_mod.set_default(OffloadConfig(threshold=321.0))
    try:
        assert OffloadConfig.from_env().threshold == 321.0
        # env still layers on top of the file-supplied base
        clean_env.setenv("SCILIB_THRESHOLD", "111")
        assert OffloadConfig.from_env().threshold == 111.0
    finally:
        cfg_mod.set_default(prev)


# --------------------------------------------------------------------- #
# legacy install() with each knob  ==  Session(config)                   #
# --------------------------------------------------------------------- #
def _workload():
    """Deterministic mixed workload: super-threshold gemm (reused),
    sub-threshold gemm, a gemv, and an einsum-shaped gemm."""
    big1 = host_array(_f32((520, 520)))
    big2 = host_array(_f32((520, 520)))
    small = host_array(_f32((64, 64)))
    v = host_array(_f32(520))
    keep = [big1, big2, small, v]
    keep.append(jnp.matmul(big1, big2))
    keep.append(jnp.matmul(big1, big2))      # operand reuse
    keep.append(jnp.matmul(small, small))
    keep.append(jnp.matmul(big1, v))         # gemv-shaped
    keep.append(jnp.einsum("ij,jk->ik", big2, big1))
    return keep


def _counters(stats):
    """Every deterministic counter (wall-clock seconds excluded)."""
    per = {n: (r.calls, r.offloaded, r.on_host, r.cache_hits,
               r.cache_misses, r.dispatch_hits, r.dispatch_misses,
               r.bytes_in, r.bytes_out, r.transient_bytes, r.sharded,
               r.tiles)
           for n, r in stats.per_routine.items()}
    dev = {d: (s.tiles, s.moved_bytes, s.affinity_hits, s.evictions,
               s.evicted_bytes)
           for d, s in stats.per_device.items()}
    return {"per": per, "dev": dev,
            "uninstrumented": stats.uninstrumented_calls,
            "evictions": (stats.evictions, stats.evicted_bytes),
            "refetch": (stats.refetches, stats.refetched_bytes)}


def _trace_shape(trace):
    return [(c.routine, c.m, c.n, c.k, c.batch, c.devices)
            for c in trace.calls]


#: (env assignment, equivalent config fields, exact-parity?)
KNOB_CASES = [
    ({}, {}, True),
    ({"SCILIB_THRESHOLD": "123.5"}, dict(threshold=123.5), True),
    ({"SCILIB_SYNC": "1"}, dict(sync=True), True),
    ({"SCILIB_DISPATCH_CACHE": "0"}, dict(dispatch_cache=False), True),
    ({"SCILIB_CALLSITE": "0"}, dict(callsite=False), True),
    ({"SCILIB_POLICY": "memcopy"}, dict(policy="memcopy"), True),
    ({"SCILIB_POLICY": "cpu"}, dict(policy="cpu"), True),
    ({"SCILIB_DEVICES": "2", "SCILIB_TILE_MIN": "128"},
     dict(devices=2, tile_min=128), True),
    ({"SCILIB_DEVICE_BYTES": "524288"}, dict(device_bytes=524288), True),
    ({"SCILIB_DEVICE_BYTES": "524288", "SCILIB_EVICT": "lfu"},
     dict(device_bytes=524288, evict="lfu"), True),
    ({"SCILIB_DEVICE_BYTES": "524288", "SCILIB_PIN": "never-evict"},
     dict(device_bytes=524288, pin=True), True),
    # adaptive locks on measured wall time: decisions are by design not
    # reproducible run-to-run, so assert call/probe structure only
    ({"SCILIB_ADAPTIVE": "1", "SCILIB_ADAPTIVE_WARMUP": "4",
      "SCILIB_SYNC": "1"},
     dict(adaptive=True, adaptive_warmup=4, sync=True), False),
]


@pytest.mark.parametrize("env,fields,exact", KNOB_CASES,
                         ids=[" ".join(e) or "defaults"
                              for e, _, _ in KNOB_CASES])
def test_legacy_env_install_matches_session_config(env, fields, exact,
                                                   clean_env):
    """The acceptance invariant: legacy install() with each documented
    SCILIB_* knob produces decisions, counters and trace identical to
    the equivalent Session(config)."""
    # warm the jit caches first: compile-time tracer pass-throughs are
    # counted as uninstrumented calls and must not differ between the
    # two measured runs below
    with repro.session(OffloadConfig(**fields)):
        _workload()

    for var, val in env.items():
        clean_env.setenv(var, val)
    rt = core.install()
    keep = _workload()
    rt.sync()
    legacy_counters = _counters(rt.stats)
    legacy_trace = _trace_shape(rt.trace)
    legacy_report = rt.stats.report()
    del keep
    core.uninstall()
    for var in env:
        clean_env.delenv(var)

    with repro.session(OffloadConfig(**fields)) as s:
        keep = _workload()
        s.sync()
        session_counters = _counters(s.stats)
        session_trace = _trace_shape(s.trace)
        session_report = s.stats.report()
        del keep

    assert session_trace == legacy_trace
    if exact:
        assert session_counters == legacy_counters
        # the report differs only in measured seconds: compare shape
        assert len(session_report.splitlines()) == \
            len(legacy_report.splitlines())
    else:
        assert {k: v[0] for k, v in session_counters["per"].items()} == \
            {k: v[0] for k, v in legacy_counters["per"].items()}


def test_runtime_reads_no_env_with_explicit_config(clean_env):
    """A session with an explicit config is immune to ambient env: the
    single ingestion boundary is from_env(), which explicit configs
    never pass through."""
    clean_env.setenv("SCILIB_THRESHOLD", "10")
    clean_env.setenv("SCILIB_POLICY", "cpu")
    clean_env.setenv("SCILIB_DEVICE_BYTES", "4096")
    with repro.session(OffloadConfig(threshold=800.0)) as s:
        assert s.runtime.threshold == 800.0
        assert s.runtime.policy.name == "dfu"
        assert s.runtime.device_bytes_cap is None


# --------------------------------------------------------------------- #
# sessions: isolation, nesting, lifecycle                                #
# --------------------------------------------------------------------- #
def test_sequential_sessions_do_not_leak_state(clean_env):
    a_np = _f32((520, 520))
    with repro.session(OffloadConfig(threshold=100.0)) as s1:
        a = host_array(a_np)
        jnp.matmul(a, a)
        assert s1.stats.per_routine["sgemm"].offloaded == 1
        assert len(s1.runtime.placements) > 0
    with repro.session(OffloadConfig(policy="cpu",
                                     threshold=100.0)) as s2:
        # fresh counters, fresh placement registry, different decisions
        assert "sgemm" not in s2.stats.per_routine
        assert len(s2.runtime.placements) == 0
        a = host_array(a_np)
        jnp.matmul(a, a)
        st = s2.stats.per_routine["sgemm"]
        assert (st.calls, st.offloaded, st.on_host) == (1, 0, 1)
    assert rtm.active() is None


def test_nested_session_close_restores_module_state(clean_env):
    """Closing an inner session must restore the outer session's
    module-level state too: the blas-layer cache flag and the resolved
    memspace mapping, not just the active runtime."""
    from repro.core import blas, memspace
    with repro.session(OffloadConfig(dispatch_cache=False, devices=2)):
        assert blas._CACHE_ON is False
        assert memspace.active().n_devices == 2
        with repro.session(OffloadConfig(dispatch_cache=True,
                                         devices=2)):
            assert blas._CACHE_ON is True
        # outer restored: uncached baseline stays uncached
        assert blas._CACHE_ON is False
        assert memspace.active().n_devices == 2
    assert blas._CACHE_ON is True        # env default (no vars set)


def test_mixed_level_install_uninstall_share_one_stack(clean_env):
    """intercept-level install() + runtime-level uninstall() (and vice
    versa) drain the same legacy stack — no stale session is left."""
    orig_matmul = jnp.matmul
    core.install("dfu", threshold=100)
    stats = rtm.uninstall()              # runtime-level uninstall
    assert stats is not None
    assert jnp.matmul is orig_matmul     # symbols restored
    assert rtm.active() is None
    assert core.uninstall() is None      # nothing left to pop


def test_repeated_install_nests_documented_semantics(clean_env):
    """Repeated install() nests (documented divergence from the old
    orphaning globals): each uninstall() restores the previous
    runtime; the last one tears everything down."""
    orig_matmul = jnp.matmul
    r1 = core.install("dfu", threshold=500)
    r2 = core.install("dfu", threshold=100)
    assert rtm.active() is r2
    core.uninstall()
    assert rtm.active() is r1            # outer install restored
    assert jnp.matmul is not orig_matmul   # still intercepting
    core.uninstall()
    assert rtm.active() is None
    assert jnp.matmul is orig_matmul


def test_nested_sessions_inner_config_wins(clean_env):
    with repro.session(OffloadConfig(threshold=100.0)) as outer:
        assert rtm.active() is outer.runtime
        with repro.session(OffloadConfig(threshold=900.0)) as inner:
            assert rtm.active() is inner.runtime
            a = host_array(_f32((520, 520)))
            jnp.matmul(a, a)           # 520 < 900: stays host inside
            assert inner.stats.per_routine["sgemm"].on_host == 1
            assert "sgemm" not in outer.stats.per_routine
        # outer restored on exit
        assert rtm.active() is outer.runtime
        a = host_array(_f32((520, 520)))
        jnp.matmul(a, a)               # 520 > 100: offloads outside
        assert outer.stats.per_routine["sgemm"].offloaded == 1
    assert rtm.active() is None


def test_session_close_is_idempotent_and_guards(clean_env):
    s = repro.session(OffloadConfig(threshold=100.0))
    assert s.close() is not None
    assert s.close() is None
    with pytest.raises(RuntimeError):
        s.report()
    with pytest.raises(RuntimeError):
        s.reconfigure(threshold=200.0)


def test_install_uninstall_restore_symbols(clean_env):
    orig_matmul, orig_dot = jnp.matmul, jnp.dot
    core.install("dfu", threshold=100)
    assert jnp.matmul is not orig_matmul
    core.uninstall()
    assert jnp.matmul is orig_matmul and jnp.dot is orig_dot


def test_reconfigure_flushes_invalidated_state(clean_env):
    with repro.session(OffloadConfig(threshold=100.0)) as s:
        a = host_array(_f32((520, 520)))
        jnp.matmul(a, a)
        assert s.stats.per_routine["sgemm"].offloaded == 1
        assert len(s.runtime._decisions) > 0
        s.reconfigure(threshold=900.0, device_bytes=1 << 20,
                      evict="refetch")
        # dispatch cache flushed, threshold applied, caps live
        assert len(s.runtime._decisions) == 0
        assert s.runtime.threshold == 900.0
        assert s.runtime.placements.cap == 1 << 20
        assert s.runtime.placements.policy.name == "refetch"
        assert s.config.threshold == 900.0
        jnp.matmul(a, a)               # same shape, new decision: host
        assert s.stats.per_routine["sgemm"].on_host == 1
        # topology is fixed: devices cannot change mid-run
        with pytest.raises(ValueError):
            s.reconfigure(devices=s.runtime.n_devices + 1)


def test_reconfigure_pin_off_makes_residents_evictable(clean_env):
    """Turning pin-all off mid-run must unpin existing placements, or a
    newly-set cap could never evict anything."""
    with repro.session(OffloadConfig(threshold=100.0, pin=True)) as s:
        mats = [host_array(_f32((520, 520))) for _ in range(3)]
        for m in mats:
            jnp.matmul(m, m)
        store = s.runtime.placements
        assert store.pinned_bytes() == store.resident_bytes > 0
        s.reconfigure(pin=False, device_bytes=520 * 520 * 4)
        assert store.pinned_bytes() == 0
        assert store.resident_bytes <= 520 * 520 * 4   # cap enforced
        assert s.stats.evictions > 0
        # ... and pin=True re-pins what currently resides
        s.reconfigure(pin=True)
        assert store.pinned_bytes() == store.resident_bytes


def test_reconfigure_resets_adaptive_locks_on_policy_change(clean_env):
    with repro.session(OffloadConfig(threshold=100.0, adaptive=True,
                                     adaptive_warmup=2,
                                     sync=True)) as s:
        a = host_array(_f32((256, 256)))
        for _ in range(4):
            jnp.matmul(a, a)
        locked = [p for p in s.runtime.callsites if p.locked is not None]
        assert locked
        s.reconfigure(policy="memcopy")
        assert all(p.locked is None for p in s.runtime.callsites)
        assert all(p.probes_done == 0 for p in s.runtime.callsites)


# --------------------------------------------------------------------- #
# gemv interception (satellite): mat-vec no longer bypasses the runtime  #
# --------------------------------------------------------------------- #
def test_gemv_intercepted_counted_and_host_below_threshold(clean_env):
    A_np, x_np, z_np = _f32((200, 300)), _f32(300), _f32(200)
    with repro.session(OffloadConfig(threshold=500.0)) as s:
        A = host_array(A_np)
        x = host_array(x_np)
        z = host_array(z_np)
        y1 = jnp.matmul(A, x)          # A @ x
        y2 = jnp.dot(A, x)
        y3 = jnp.dot(z, A)             # x @ A == A.T @ x
        st = s.stats.per_routine["sgemv"]
        assert st.calls == 3
        assert st.on_host == 3 and st.offloaded == 0   # below threshold
        trace_routines = [c.routine for c in s.trace.calls]
        assert trace_routines.count("sgemv") == 3
        # the trace replays through the simulator (flops defined)
        from repro.memtier.simulator import MemTierSimulator
        MemTierSimulator(policy="dfu").run(s.trace)
    want1 = A_np @ x_np
    np.testing.assert_allclose(np.asarray(y1), want1, rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), want1, rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(y3), z_np @ A_np, rtol=1e-3,
                               atol=1e-4)


def test_gemv_respects_threshold_dispatch(clean_env):
    with repro.session(OffloadConfig(threshold=30.0)) as s:
        A = host_array(_f32((200, 300)))      # N_avg = (200*300)^(1/3)
        x = host_array(_f32(300))             # ~ 39 > 30: offloads
        jnp.matmul(A, x)
        st = s.stats.per_routine["sgemv"]
        assert st.offloaded == 1


# --------------------------------------------------------------------- #
# atexit trace-dump fallback (satellite)                                 #
# --------------------------------------------------------------------- #
def _run_subprocess(code):
    src = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(repro.__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(src, "src")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)


def test_atexit_dumps_trace_of_unclosed_session(tmp_path):
    path = str(tmp_path / "session_trace.json")
    proc = _run_subprocess(f"""
import numpy as np, jax.numpy as jnp
import repro
from repro import OffloadConfig
from repro.core.policy import host_array
s = repro.session(OffloadConfig(threshold=100.0, trace_path={path!r}))
a = host_array(np.ones((128, 128), np.float32))
jnp.matmul(a, a)
# no close(), no uninstall(): the atexit fallback must dump the trace
""")
    assert proc.returncode == 0, proc.stderr
    t = Trace.load(path)
    assert len(t) == 1 and t.calls[0].routine == "sgemm"


def test_atexit_dumps_trace_of_legacy_env_install(tmp_path):
    path = str(tmp_path / "legacy_trace.json")
    proc = _run_subprocess(f"""
import os
os.environ["SCILIB_TRACE"] = {path!r}
import numpy as np, jax.numpy as jnp
import repro.core as core
from repro.core.policy import host_array
core.install("dfu", threshold=100)
a = host_array(np.ones((128, 128), np.float32))
jnp.matmul(a, a)
# no uninstall(): abnormal teardown used to lose the trace
""")
    assert proc.returncode == 0, proc.stderr
    t = Trace.load(path)
    assert len(t) == 1 and t.calls[0].routine == "sgemm"


def test_close_dump_not_duplicated_by_atexit(tmp_path, clean_env):
    """A session closed normally dumps exactly once (close wins)."""
    path = str(tmp_path / "t.json")
    with repro.session(OffloadConfig(threshold=100.0,
                                     trace_path=path)) as s:
        a = host_array(_f32((128, 128)))
        jnp.matmul(a, a)
    t = Trace.load(path)
    assert len(t) == 1
    from repro.core import session as ses
    ses._atexit_dump()                  # would double-dump if unguarded
    assert len(Trace.load(path)) == 1


# --------------------------------------------------------------------- #
# autotune --emit-config: the tune->deploy loop (satellite + acceptance) #
# --------------------------------------------------------------------- #
def test_autotune_emit_config_loads_and_predicts(tmp_path, capsys,
                                                 clean_env):
    from repro.memtier.simulator import MemTierSimulator
    from repro.tools import autotune as at
    trace_path = os.path.join(DATA, "mini_trace.json")
    out = str(tmp_path / "tuned.json")
    assert at.main([trace_path, "--emit-config", out]) == 0
    printed = capsys.readouterr().out
    assert f"config written to {out}" in printed
    cfg = OffloadConfig.load(out)
    # the emitted config realizes exactly the printed recommendation:
    # replaying it through the simulator predicts the same outcome
    trace = Trace.load(trace_path)
    result = at.autotune(trace)
    rep = MemTierSimulator.from_config(cfg).run(Trace.load(trace_path))
    assert rep.total_s == pytest.approx(result.best.total_s)
    assert rep.moved_bytes == result.best.moved_bytes
    assert cfg.policy == result.best.policy
    assert cfg.resolved_threshold() == pytest.approx(
        result.best.threshold)
    # the tuned device count is explicit, never "resolve on deploy"
    assert cfg.devices == result.best.n_devices
    # ... and a session can run the file directly
    with repro.session(cfg) as s:
        a = host_array(_f32((128, 128)))
        jnp.matmul(a, a)
        assert s.stats.per_routine["sgemm"].calls == 1

"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.attention import flash_attention
from repro.kernels.gemm import gemm as pallas_gemm
from repro.kernels.syrk import syrk as pallas_syrk
from repro.kernels.trsm import trsm as pallas_trsm

RNG = np.random.default_rng(0)


def _tri(n, uplo, dtype=np.float32):
    a = RNG.standard_normal((n, n)).astype(dtype) / n
    a = np.tril(a) if uplo == "L" else np.triu(a)
    np.fill_diagonal(a, 1.0 + np.abs(np.diag(a)))
    return a


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (300, 200, 150),
                                   (64, 257, 100), (33, 65, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gemm_shapes_dtypes(m, k, n, dtype):
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype)
    out = pallas_gemm(a, b, bm=128, bk=128, bn=128, interpret=True)
    want = ref.matmul(a, b)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert out.dtype == a.dtype


def test_gemm_f64():
    jax.config.update("jax_enable_x64", True)
    try:
        a = jnp.asarray(RNG.standard_normal((130, 70)))
        b = jnp.asarray(RNG.standard_normal((70, 90)))
        out = pallas_gemm(a, b, bm=128, bk=128, bn=128, interpret=True)
        np.testing.assert_allclose(out, np.asarray(a) @ np.asarray(b),
                                   rtol=1e-12, atol=1e-12)
        assert out.dtype == jnp.float64
    finally:
        jax.config.update("jax_enable_x64", False)


@pytest.mark.parametrize("side", ["L", "R"])
@pytest.mark.parametrize("uplo", ["L", "U"])
@pytest.mark.parametrize("trans", ["N", "T"])
@pytest.mark.parametrize("diag", ["N", "U"])
def test_trsm_variants(side, uplo, trans, diag):
    m, n = 160, 96
    a = _tri(m if side == "L" else n, uplo)
    b = RNG.standard_normal((m, n)).astype(np.float32)
    got = pallas_trsm(jnp.asarray(a), jnp.asarray(b), side=side,
                      uplo=uplo, trans=trans, diag=diag, interpret=True)
    want = ref.trsm(jnp.asarray(a), jnp.asarray(b), side=side, uplo=uplo,
                    trans=trans, diag=diag)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_trsm_batched():
    a = np.stack([_tri(96, "L") for _ in range(3)])
    b = RNG.standard_normal((3, 96, 32)).astype(np.float32)
    got = pallas_trsm(jnp.asarray(a), jnp.asarray(b), interpret=True)
    want = ref.trsm(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("uplo", ["L", "U"])
@pytest.mark.parametrize("trans", ["N", "T"])
@pytest.mark.parametrize("n,k", [(200, 130), (128, 256), (65, 33)])
def test_syrk(uplo, trans, n, k):
    shape = (n, k) if trans == "N" else (k, n)
    a = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    got = pallas_syrk(a, uplo=uplo, trans=trans, interpret=True)
    want = ref.syrk(a, uplo=uplo, trans=trans)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("kwargs", [
    dict(causal=True), dict(causal=False),
    dict(causal=True, window=32),
    dict(causal=True, softcap=30.0),
    dict(causal=True, window=48, softcap=20.0),
])
def test_flash_attention(kwargs):
    q = jnp.asarray(RNG.standard_normal((2, 4, 128, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2, 128, 64)), jnp.float32)
    got = flash_attention(q, k, v, bq=64, bk=64, interpret=True, **kwargs)
    want = ref.attention(q, k, v, **kwargs)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_ragged_tq():
    q = jnp.asarray(RNG.standard_normal((1, 2, 100, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 2, 100, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 2, 100, 32)), jnp.float32)
    got = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_complex_matmul_via_ops():
    import os
    os.environ["SCILIB_PALLAS"] = "1"
    try:
        from repro.kernels import ops
        a = (RNG.standard_normal((96, 64))
             + 1j * RNG.standard_normal((96, 64))).astype(np.complex64)
        b = (RNG.standard_normal((64, 80))
             + 1j * RNG.standard_normal((64, 80))).astype(np.complex64)
        got = ops.matmul(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_allclose(got, a @ b, rtol=1e-3, atol=1e-3)
    finally:
        os.environ.pop("SCILIB_PALLAS", None)


@pytest.mark.parametrize("kvlen", [1, 37, 128, 256])
def test_decode_attention_kernel(kvlen):
    from repro.kernels.decode_attention import decode_attention
    q = jnp.asarray(RNG.standard_normal((2, 8, 1, 64)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((2, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((2, 2, 256, 64)), jnp.float32)
    got = decode_attention(q, k, v, jnp.asarray(kvlen), bk=64,
                           interpret=True)
    want = ref.attention(q, k, v, causal=True,
                         kv_len=jnp.asarray(kvlen))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------- #
# the `pallas` venue entry points (kernels/ops.kernel_*)                  #
# --------------------------------------------------------------------- #
def test_kernel_capability_matrix():
    """The venue capability registry: which (base, dtype) pairs the
    kernel path can execute.  Complex syrk/trsm need complex VPU ops
    the kernels lack; complex gemm decomposes onto real gemms (4M);
    fp64 gemm has no MXU path, so it needs a split-precision scheme
    (without one the venue would time the plain XLA formulation and
    could mis-lock)."""
    from repro.kernels import ops
    assert ops.KERNEL_BASES == ("gemm", "syrk", "trsm")
    for base in ops.KERNEL_BASES:
        assert ops.kernel_available(base, jnp.float32)
    assert not ops.kernel_available("gemm", jnp.float64)
    assert ops.kernel_available("gemm", jnp.float64, precision="split2")
    assert ops.kernel_available("syrk", jnp.float64)
    assert ops.kernel_available("trsm", jnp.float64)
    assert ops.kernel_available("gemm", jnp.complex64)
    assert not ops.kernel_available("syrk", jnp.complex64)
    assert not ops.kernel_available("trsm", jnp.complex64)
    for base in ("trmm", "symm", "herk", "gemv"):
        assert not ops.kernel_available(base, jnp.float32)


@pytest.fixture
def interpreted_kernels(monkeypatch):
    """Force ops.kernel_* onto the interpreted Pallas kernels — the same
    code the compiled venue runs on the TPU target, minus the MXU."""
    import functools

    from repro.kernels import ops
    monkeypatch.setattr(ops, "_kernel_compiled", lambda: True)
    monkeypatch.setattr(ops, "pallas_gemm",
                        functools.partial(pallas_gemm, interpret=True))
    monkeypatch.setattr(ops, "pallas_syrk",
                        functools.partial(pallas_syrk, interpret=True))
    monkeypatch.setattr(ops, "pallas_trsm",
                        functools.partial(pallas_trsm, interpret=True))
    return ops


@pytest.mark.parametrize("m,k,n", [(48, 32, 40), (1, 32, 16),
                                   (16, 0, 8), (5, 7, 3)])
@pytest.mark.parametrize("dtype", ["float32", "complex64"])
def test_kernel_matmul_parity(interpreted_kernels, dtype, m, k, n):
    """kernel_matmul == ref == XLA across dtypes and degenerate shapes
    (k=0 must skip the kernel — its K grid axis would launch nothing)."""
    if dtype == "complex64":
        a = (RNG.standard_normal((m, k))
             + 1j * RNG.standard_normal((m, k))).astype(np.complex64)
        b = (RNG.standard_normal((k, n))
             + 1j * RNG.standard_normal((k, n))).astype(np.complex64)
    else:
        a = RNG.standard_normal((m, k)).astype(dtype)
        b = RNG.standard_normal((k, n)).astype(dtype)
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    got = interpreted_kernels.kernel_matmul(aj, bj)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul(aj, bj)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got), a @ b, rtol=2e-4, atol=2e-4)
    assert got.dtype == aj.dtype


def test_kernel_matmul_f64_stays_on_xla(interpreted_kernels):
    """No f64 MXU path: the venue's f64 gemm is the XLA reference."""
    jax.config.update("jax_enable_x64", True)
    try:
        a = jnp.asarray(RNG.standard_normal((40, 24)))
        b = jnp.asarray(RNG.standard_normal((24, 32)))
        got = interpreted_kernels.kernel_matmul(a, b)
        np.testing.assert_allclose(got, np.asarray(a) @ np.asarray(b),
                                   rtol=1e-12, atol=1e-12)
        assert got.dtype == jnp.float64
    finally:
        jax.config.update("jax_enable_x64", False)


@pytest.mark.parametrize("uplo", ["L", "U"])
@pytest.mark.parametrize("trans", ["N", "T"])
def test_kernel_syrk_parity(interpreted_kernels, uplo, trans):
    shape = (48, 24) if trans == "N" else (24, 48)
    a = jnp.asarray(RNG.standard_normal(shape), jnp.float32)
    got = interpreted_kernels.kernel_syrk(a, uplo=uplo, trans=trans)
    np.testing.assert_allclose(got, ref.syrk(a, uplo=uplo, trans=trans),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("side,uplo,trans,diag",
                         [("L", "L", "N", "N"), ("R", "U", "T", "U")])
def test_kernel_trsm_parity(interpreted_kernels, side, uplo, trans, diag):
    m, n = 48, 24
    a = _tri(m if side == "L" else n, uplo)
    b = RNG.standard_normal((m, n)).astype(np.float32)
    got = interpreted_kernels.kernel_trsm(
        jnp.asarray(a), jnp.asarray(b), side=side, uplo=uplo,
        trans=trans, diag=diag)
    want = ref.trsm(jnp.asarray(a), jnp.asarray(b), side=side, uplo=uplo,
                    trans=trans, diag=diag)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_kernel_complex_syrk_trsm_fall_back_to_ref(interpreted_kernels):
    """The dtypes the capability registry rejects still compute right —
    kernel_syrk/kernel_trsm degrade to ref rather than fail."""
    a = jnp.asarray((RNG.standard_normal((24, 16))
                     + 1j * RNG.standard_normal((24, 16)))
                    .astype(np.complex64))
    np.testing.assert_allclose(
        np.asarray(interpreted_kernels.kernel_syrk(a)),
        np.asarray(ref.syrk(a)), rtol=1e-4, atol=1e-4)
    t = jnp.asarray(_tri(24, "L").astype(np.complex64))
    b = jnp.asarray((RNG.standard_normal((24, 8))
                     + 1j * RNG.standard_normal((24, 8)))
                    .astype(np.complex64))
    np.testing.assert_allclose(
        np.asarray(interpreted_kernels.kernel_trsm(t, b)),
        np.asarray(ref.trsm(t, b)), rtol=1e-4, atol=1e-4)


def test_kernel_block_override(interpreted_kernels):
    """SCILIB_KERNEL_BLOCK plumbing: an explicit block edge reaches the
    kernel (and an off-size one still pads correctly)."""
    a = jnp.asarray(RNG.standard_normal((40, 24)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((24, 32)), jnp.float32)
    got = interpreted_kernels.kernel_matmul(a, b, block=16)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(a) @ np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_softcap_and_bf16():
    from repro.kernels.decode_attention import decode_attention
    q = jnp.asarray(RNG.standard_normal((1, 4, 1, 32)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 4, 128, 32)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 4, 128, 32)), jnp.bfloat16)
    got = decode_attention(q, k, v, jnp.asarray(100), softcap=20.0,
                           bk=64, interpret=True)
    want = ref.attention(q, k, v, causal=True, softcap=20.0,
                         kv_len=jnp.asarray(100))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2)

"""Exactness of the beyond-paper performance knobs (EXPERIMENTS.md §Perf).
Every optimization must be bit-compatible (within fp tolerance) with the
baseline formulation — these tests are the guard rail for the hillclimb.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models import get_config
from repro.models.registry import Model

KEY = jax.random.PRNGKey(0)


def test_chunked_attention_matches_full():
    q = jax.random.normal(KEY, (2, 4, 128, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 2, 128, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 2, 128, 32))
    full = ref.attention(q, k, v, causal=True)
    for cq in (16, 32, 64):
        chk = ref.attention_chunked(q, k, v, chunk_q=cq)
        np.testing.assert_allclose(np.asarray(full), np.asarray(chk),
                                   rtol=2e-5, atol=2e-5)


def test_chunked_attention_grads_match():
    q = jax.random.normal(KEY, (1, 2, 64, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 2, 64, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 2, 64, 16))
    g1 = jax.grad(lambda q_: ref.attention(q_, k, v, causal=True)
                  .sum())(q)
    g2 = jax.grad(lambda q_: ref.attention_chunked(q_, k, v, chunk_q=16)
                  .sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-4, atol=1e-5)


def test_head_padding_exact_logits():
    cfg0 = get_config("qwen2_5_32b").reduced()
    cfg1 = dataclasses.replace(cfg0, pad_heads_to=8)
    assert cfg1.padded_heads == 8
    m1 = Model.from_config(cfg1)
    p1 = m1.init(KEY)
    hd, hq0, hq1 = cfg0.head_dim, cfg0.n_heads, cfg1.padded_heads
    hkv = max(1, cfg0.n_kv_heads)
    g1, g0 = hq1 // hkv, hq0 // hkv
    real = np.concatenate([np.arange(g * g1 * hd, (g * g1 + g0) * hd)
                           for g in range(hkv)])

    def strip(block):
        att = dict(block["attn"])
        att["wq"] = block["attn"]["wq"][..., real]
        att["wo"] = block["attn"]["wo"][..., real, :]
        if "bq" in att:
            att["bq"] = block["attn"]["bq"][..., real]
        return {**block, "attn": att}

    p0 = {**p1, "blocks": tuple(strip(b) for b in p1["blocks"])}
    m0 = Model.from_config(cfg0)
    tok = jax.random.randint(KEY, (2, 16), 0, cfg0.vocab)
    l1, _, _ = m1.forward(p1, tok)
    l0, _, _ = m0.forward(p0, tok)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=2e-4, atol=2e-4)


def test_head_padding_pads_receive_zero_grad():
    cfg = dataclasses.replace(get_config("qwen2_5_32b").reduced(),
                              pad_heads_to=8)
    m = Model.from_config(cfg)
    params = m.init(KEY)
    tok = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)

    def loss(p):
        lg, _, _ = m.forward(p, tok)
        return jnp.mean(lg.astype(jnp.float32) ** 2)

    g = jax.grad(loss)(params)
    hd = cfg.head_dim
    hkv = max(1, cfg.n_kv_heads)
    group = cfg.padded_heads // hkv
    rpg = cfg.n_heads // hkv
    pad_cols = np.concatenate(
        [np.arange((gq * group + rpg) * hd, (gq + 1) * group * hd)
         for gq in range(hkv)])
    for blk in g["blocks"]:
        wq_pad = np.asarray(blk["attn"]["wq"])[..., pad_cols]
        wo_pad = np.asarray(blk["attn"]["wo"])[..., pad_cols, :]
        assert np.allclose(wq_pad, 0.0)
        assert np.allclose(wo_pad, 0.0)


def test_vocab_parallel_ce_matches_gather():
    from repro.train.loop import cross_entropy
    logits = jax.random.normal(KEY, (4, 8, 100), jnp.float32) * 5
    labels = jax.random.randint(KEY, (4, 8), 0, 100)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = float(jnp.mean(lse - gold))
    got = float(cross_entropy(logits, labels, 0.0))
    assert abs(want - got) < 1e-6


def test_last_only_prefill():
    cfg = get_config("qwen1_5_4b").reduced()
    m = Model.from_config(cfg)
    params = m.init(KEY)
    tok = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    full, _, _ = m.forward(params, tok)
    last, _, _ = m.forward(params, tok, last_only=True)
    assert last.shape[1] == 1
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(last),
                               rtol=1e-5, atol=1e-5)


def test_sorted_dispatch_fifo_drop_semantics():
    """When capacity binds, the FIFO (first-token-wins) drop order of the
    cumsum formulation must be preserved by the sorted formulation."""
    from repro.models import moe as MO
    cfg = dataclasses.replace(get_config("granite_moe_1b_a400m").reduced(),
                              capacity_factor=0.10, top_k=1)
    p = MO.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model), jnp.float32)
    out1, _ = MO.moe_fwd(p, cfg, x, impl="scatter")
    out2, _ = MO.moe_fwd(p, cfg, x, impl="scatter")
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # dropped tokens produce zero expert output rows (gather of zeros)
    assert np.isfinite(np.asarray(out1)).all()

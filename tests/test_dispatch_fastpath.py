"""The fast dispatch path: memspace tier mapping, dispatch cache,
byte-capped LRU placement registry, async-vs-sync equivalence."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import blas, memspace
from repro.core import runtime as rtm
from repro.core import threshold as thr
from repro.core.policy import host_array

RNG = np.random.default_rng(7)


def _f32(shape):
    return RNG.standard_normal(shape).astype("float32")


# --------------------------------------------------------------------- #
# memspace tier mapping                                                  #
# --------------------------------------------------------------------- #
def test_memspace_probe_matches_backend():
    ms = memspace.probe()
    import jax
    kinds = [m.kind for m in jax.devices()[0].addressable_memories()]
    assert ms.device_kind in kinds
    assert ms.host_kind in kinds
    # simulated exactly when the backend can't express two tiers
    assert ms.simulated == (ms.host_kind == ms.device_kind)


def test_simulated_tiers_track_identity_and_movement():
    ms = memspace.active()
    x = host_array(_f32((32, 32)))
    assert memspace.tier_of(x) == memspace.HOST
    y = memspace.put(x, memspace.DEVICE)
    assert memspace.tier_of(y) == memspace.DEVICE
    # the source keeps its own tier: Mem-Copy round trips stay observable
    assert memspace.tier_of(x) == memspace.HOST
    if ms.simulated:
        assert y is not x
    # same-tier put is the identity (no spurious copies on the fast path)
    assert memspace.put(y, memspace.DEVICE) is y
    # untagged fresh arrays behave device-resident, like on accelerators
    assert memspace.tier_of(jnp.ones((4, 4))) == memspace.DEVICE


def test_single_kind_backend_runs_all_policies():
    """On this container the backend has one memory kind; every policy
    must still run and count movement (the 51-failing-seed-tests fix)."""
    a_np, b_np = _f32((300, 300)), _f32((300, 300))
    for pol in ("cpu", "memcopy", "counter", "dfu", "pinned"):
        with core.offload(pol, threshold=100) as rt:
            a, b = host_array(a_np), host_array(b_np)
            out = jnp.matmul(a, b)
        assert np.isfinite(np.asarray(out)).all(), pol
        st = rt.stats.per_routine["sgemm"]
        assert st.calls == 1, pol
        if pol in ("memcopy", "dfu", "pinned"):
            assert st.bytes_in == a.nbytes + b.nbytes, pol


# --------------------------------------------------------------------- #
# dispatch cache                                                         #
# --------------------------------------------------------------------- #
def test_dispatch_cache_one_threshold_derivation(monkeypatch):
    calls = []
    real = thr.should_offload

    def counting(*a, **kw):
        calls.append(a)
        return real(*a, **kw)

    monkeypatch.setattr(thr, "should_offload", counting)
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(_f32((256, 256)))
        b = host_array(_f32((256, 256)))
        for _ in range(4):
            jnp.matmul(a, b)
        st = rt.stats.per_routine["sgemm"]
        assert len(calls) == 1          # derived once, cached thereafter
        assert st.dispatch_misses == 1
        assert st.dispatch_hits == 3
        # a different call-site shape is a fresh decision
        c = host_array(_f32((128, 256)))
        jnp.matmul(c, b)
        assert len(calls) == 2


def test_dispatch_cache_reuses_scalars_and_kernels():
    blas.clear_caches()
    with core.offload("dfu", threshold=100):
        a = host_array(_f32((256, 256)))
        blas.gemm(a, a, alpha=2.0)
        n_scalars = len(blas._SCALARS)
        n_bound = len(blas._BOUND)
        blas.gemm(a, a, alpha=2.0)
        # steady state: no new device scalars, no new bound kernels
        assert len(blas._SCALARS) == n_scalars
        assert len(blas._BOUND) == n_bound
        assert n_bound >= 1


def test_dispatch_cache_env_disable(monkeypatch):
    monkeypatch.setenv("SCILIB_DISPATCH_CACHE", "0")
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(_f32((256, 256)))
        jnp.matmul(a, a)
        jnp.matmul(a, a)
        st = rt.stats.per_routine["sgemm"]
        assert st.dispatch_hits == 0
        assert st.dispatch_misses == 2
    monkeypatch.setenv("SCILIB_DISPATCH_CACHE", "1")
    core.install("dfu")  # refresh the blas-level flag
    core.uninstall()


def test_unhashable_alpha_still_correct():
    """Array-valued alpha can't key the cache; the call must fall back to
    per-call binding, not crash or corrupt the cache."""
    with core.offload("dfu", threshold=100):
        a = host_array(_f32((128, 128)))
        al = jnp.asarray(3.0, jnp.float32)
        out = blas.gemm(a, a, alpha=al)
    want = 3.0 * (np.asarray(a) @ np.asarray(a))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4,
                               atol=1e-4)


# --------------------------------------------------------------------- #
# byte-capped LRU placement registry                                     #
# --------------------------------------------------------------------- #
def test_lru_eviction_at_byte_cap():
    nbytes = 256 * 256 * 4
    rt = rtm.install("dfu", threshold=10, record_trace=False,
                     device_bytes=2 * nbytes)
    try:
        xs = [host_array(_f32((256, 256))) for _ in range(3)]
        outs = [blas.gemm(x, x) for x in xs]
        assert rt.stats.evictions >= 1
        assert rt.stats.evicted_bytes >= nbytes
        assert rt.resident_bytes() <= 2 * nbytes
        st = rt.stats.per_routine["sgemm"]
        # the first operand was evicted; re-using it re-migrates (pays
        # bytes again) instead of silently reading a stale placement
        before = st.bytes_in
        blas.gemm(xs[0], xs[0])
        assert st.bytes_in == before + xs[0].nbytes
        del outs
    finally:
        rtm.uninstall()


def test_lru_cap_env_knob(monkeypatch):
    monkeypatch.setenv("SCILIB_DEVICE_BYTES", str(512 * 1024))
    rt = rtm.install("dfu", threshold=10, record_trace=False)
    try:
        assert rt.device_bytes_cap == 512 * 1024
    finally:
        rtm.uninstall()


def test_no_cap_means_no_eviction():
    rt = rtm.install("dfu", threshold=10, record_trace=False)
    try:
        for _ in range(4):
            blas.gemm(host_array(_f32((128, 128))),
                      host_array(_f32((128, 128))))
        assert rt.stats.evictions == 0
    finally:
        rtm.uninstall()


# --------------------------------------------------------------------- #
# async execution                                                        #
# --------------------------------------------------------------------- #
def test_async_vs_sync_numerically_identical(monkeypatch):
    a_np, b_np = _f32((300, 300)), _f32((300, 300))
    outs = {}
    for sync in ("", "1"):
        monkeypatch.setenv("SCILIB_SYNC", sync)
        for pol in ("cpu", "memcopy", "counter", "dfu", "pinned"):
            with core.offload(pol, threshold=100):
                a, b = host_array(a_np), host_array(b_np)
                outs[(pol, sync)] = np.asarray(jnp.matmul(a, b))
    ref = outs[("cpu", "1")]
    for key, out in outs.items():
        np.testing.assert_array_equal(out, ref, err_msg=str(key))


def test_sync_drains_pending():
    rt = rtm.install("dfu", threshold=10, record_trace=False)
    try:
        assert not rt.sync_mode
        a = host_array(_f32((256, 256)))
        blas.gemm(a, a)
        assert len(rt._pending) == 1
        rt.sync()
        assert len(rt._pending) == 0
    finally:
        rtm.uninstall()


def test_sync_mode_env(monkeypatch):
    monkeypatch.setenv("SCILIB_SYNC", "1")
    rt = rtm.install("dfu", threshold=10, record_trace=False)
    try:
        assert rt.sync_mode
        a = host_array(_f32((256, 256)))
        blas.gemm(a, a)
        assert len(rt._pending) == 0    # sync mode never defers
    finally:
        rtm.uninstall()


# --------------------------------------------------------------------- #
# threshold backend detection + batched einsum interception              #
# --------------------------------------------------------------------- #
def test_threshold_backend_detection():
    assert thr.detect_device_key("tpu", "TPU v5e") == "tpu-v5e"
    assert thr.detect_device_key("tpu", "TPU v4") == "tpu"
    assert thr.detect_device_key("gpu", "NVIDIA GH200 480GB") == "gh200"
    assert thr.detect_device_key("gpu", "NVIDIA H100") == "gpu"
    assert thr.detect_device_key("cpu", "cpu") == "cpu"
    assert thr.DEVICE_DEFAULTS["tpu-v5e"] == 384.0
    assert thr.DEVICE_DEFAULTS[thr.detect_device_key()] == \
        thr.default_threshold()


def test_threshold_env_override_still_wins(monkeypatch):
    monkeypatch.setenv("SCILIB_THRESHOLD", "123.5")
    rt = rtm.install("dfu", record_trace=False)
    try:
        assert rt.threshold == 123.5
    finally:
        rtm.uninstall()


@pytest.mark.parametrize("spec,ta,tb", [
    ("bij,bjk->bik", "N", "N"),
    ("bji,bjk->bik", "T", "N"),
    ("bij,bkj->bik", "N", "T"),
    ("bji,bkj->bik", "T", "T"),
])
def test_batched_einsum_intercepted(spec, ta, tb):
    sa = (3, 48, 32) if ta == "N" else (3, 32, 48)
    sb = (3, 32, 24) if tb == "N" else (3, 24, 32)
    a = jnp.asarray(_f32(sa))
    b = jnp.asarray(_f32(sb))
    with core.offload("dfu", threshold=10) as rt:
        out = jnp.einsum(spec, a, b)
        st = rt.stats.per_routine["sgemm"]
        assert st.calls == 1
    want = np.einsum(spec, np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4,
                               atol=1e-4)


def test_matmul_benign_kwargs_still_offload():
    """precision=None / preferred_element_type == operand dtype are
    no-ops; NumPy-style callers passing them must still hit the offload
    path instead of bailing to the original symbol."""
    a_np = _f32((256, 256))
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(a_np)
        out1 = jnp.matmul(a, a, precision=None)
        out2 = jnp.matmul(a, a, preferred_element_type=jnp.float32)
        out3 = jnp.dot(a, a, precision=None,
                       preferred_element_type=jnp.float32)
        # explicit None defaults (what NumPy-style wrappers forward)
        jnp.matmul(a, a, precision=None, preferred_element_type=None)
        st = rt.stats.per_routine["sgemm"]
        assert st.calls == 4             # all four routed to offload
        # (uninstrumented may be nonzero from jit-compile pass-throughs
        # of the kernels themselves — count deltas, not absolutes)
        before = rt.stats.uninstrumented_calls
        # a genuine accumulation-type change is NOT benign: fall through
        out4 = jnp.matmul(a, a, preferred_element_type=jnp.float64)
        assert st.calls == 4
        assert rt.stats.uninstrumented_calls == before + 1
    want = np.asarray(a) @ np.asarray(a)
    # out4 went through the original symbol (x64 may be disabled, so
    # dtype promotion is backend-dependent; the routing is what matters)
    for out in (out1, out2, out3, out4):
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4,
                                   atol=1e-4)


def test_non_gemm_batched_einsum_falls_through():
    a = jnp.asarray(_f32((3, 8, 8)))
    with core.offload("dfu", threshold=10) as rt:
        jnp.einsum("bii->b", a)             # trace: not a gemm
        jnp.einsum("bij,bij->b", a, a)      # inner product: not a gemm
        assert "sgemm" not in rt.stats.per_routine
        assert rt.stats.uninstrumented_calls == 2


def test_mismatched_batch_dims_fall_through():
    a = jnp.asarray(_f32((2, 8, 8)))
    b = jnp.asarray(_f32((1, 8, 8)))       # broadcasting batch: fall back
    with core.offload("dfu", threshold=10) as rt:
        out = jnp.einsum("bij,bjk->bik", a, b)
        assert "sgemm" not in rt.stats.per_routine
    want = np.einsum("bij,bjk->bik", np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4,
                               atol=1e-4)

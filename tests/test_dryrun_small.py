"""Sharding machinery on a small fake mesh (subprocess: own XLA_FLAGS)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_debug_mesh
from repro.launch.specs import axis_env_for, build_cell
from repro.models.registry import Model, get_config
from repro.models.sharding import axis_env
from repro.launch import shardings as shd

mesh = make_debug_mesh(8, model=2)
assert mesh.devices.size == 8

# sanitize: drops non-divisible, honors fallback
spec = shd.sanitize(P("model", None), (7, 4), mesh)
assert spec == P(None, None), spec
spec = shd.sanitize(P(None, None, "model", None, None),
                    (2, 2, 3, 8, 16), mesh, fallbacks={2: 4})
assert spec == P(None, None, None, None, "model"), spec

# a reduced arch lowers + compiles on the debug mesh
cfg = get_config("qwen1_5_4b").reduced()
model = Model.from_config(cfg)
with mesh, axis_env(axis_env_for(mesh)):
    cell = build_cell(model, "q", "train_4k", mesh)
    # shrink the batch spec shapes for the debug run
    import repro.launch.specs as S
    jitted = jax.jit(cell.fn, out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate_argnums)
    lowered = jitted.lower(*cell.args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax 0.4.x returns [dict]
        cost = cost[0] if cost else {}
print(json.dumps({"ok": True, "flops": float(cost.get("flops", 0))}))
"""


def test_small_mesh_dryrun():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["flops"] > 0

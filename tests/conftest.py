"""Shared fixtures. NOTE: no XLA_FLAGS here by design — tests see the
real single CPU device; only the dry-run subprocess gets 512."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)

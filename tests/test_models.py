"""Per-arch smoke tests (reduced configs): fwd/train step, no NaNs, and
the prefill==decode consistency invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCHS, get_config
from repro.models.registry import Model

KEY = jax.random.PRNGKey(0)


def _extra(cfg, b):
    if cfg.family == "encdec":
        return {"frames": jnp.ones((b, cfg.encoder_seq, cfg.d_model),
                                   jnp.float32)}
    if cfg.family == "vlm" and cfg.patch_prefix:
        return {"patch_embeds": jnp.ones(
            (b, cfg.patch_prefix, cfg.d_model), jnp.float32)}
    return {}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).reduced()
    m = Model.from_config(cfg)
    params = m.init(KEY)
    b, t = 2, 16
    tokens = jax.random.randint(KEY, (b, m.text_len(t)), 0, cfg.vocab)
    logits, aux, _ = m.forward(params, tokens, moe_impl="dense",
                               **_extra(cfg, b))
    assert logits.shape == (b, m.text_len(t), cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert float(aux) >= 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    from repro.optim import AdamW, init_compression
    from repro.train.loop import TrainConfig, make_train_step
    cfg = get_config(arch).reduced()
    m = Model.from_config(cfg)
    params = m.init(KEY)
    opt = AdamW()
    tcfg = TrainConfig(n_micro=1, remat="none", moe_impl="dense")
    step = jax.jit(make_train_step(m, tcfg, opt))
    b, t = 2, 16
    tokens = jax.random.randint(KEY, (b, m.text_len(t)), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    batch.update(_extra(cfg, b))
    params2, _, _, metrics = step(params, opt.init(params),
                                  init_compression(params), batch,
                                  jnp.asarray(1, jnp.int32))
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ["qwen1_5_4b", "gemma2_9b",
                                  "mamba2_1_3b",
                                  "jamba_1_5_large_398b",
                                  "whisper_tiny"])
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    m = Model.from_config(cfg)
    params = m.init(KEY)
    b, t = 2, 16
    tokens = jax.random.randint(KEY, (b, t), 0, cfg.vocab)
    extra = _extra(cfg, b)
    if cfg.family == "encdec":
        full, _, _ = m.forward(params, tokens, **extra)
        cache = m.init_cache(b, t, jnp.float32)
        lg, _, cache = m.forward(params, tokens[:, :8], cache=cache,
                                 cache_pos=jnp.asarray(0, jnp.int32),
                                 **extra)
    else:
        full, _, _ = m.forward(params, tokens, moe_impl="dense")
        cache = m.init_cache(b, t, jnp.float32)
        lg, _, cache = m.forward(params, tokens[:, :8], cache=cache,
                                 cache_pos=jnp.asarray(0, jnp.int32),
                                 moe_impl="dense")
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :8]),
                               rtol=3e-3, atol=3e-3)
    outs = [lg]
    for i in range(8, t):
        lg, _, cache = m.forward(params, tokens[:, i:i + 1], cache=cache,
                                 cache_pos=jnp.asarray(i, jnp.int32),
                                 moe_impl="dense")
        outs.append(lg)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_unroll_matches_scan():
    cfg = get_config("qwen1_5_4b").reduced()
    m = Model.from_config(cfg)
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    a, _, _ = m.forward(params, tokens)
    b, _, _ = m.forward(params, tokens, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


def test_moe_scatter_matches_dense():
    import dataclasses
    from repro.models import moe as MO
    cfg = dataclasses.replace(
        get_config("granite_moe_1b_a400m").reduced(), capacity_factor=8.0)
    p = MO.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)
    out_s, _ = MO.moe_fwd(p, cfg, x, impl="scatter")
    out_d, _ = MO.moe_fwd(p, cfg, x, impl="dense")
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               rtol=1e-4, atol=1e-4)


def test_gemma2_window_and_softcap_active():
    cfg = get_config("gemma2_9b").reduced()
    assert cfg.layer_window(0) > 0 and cfg.layer_window(1) == 0
    assert cfg.attn_softcap > 0 and cfg.final_softcap > 0


def test_param_count_sane():
    total, active = get_config("qwen2_5_32b").param_count()
    assert 30e9 < total < 36e9
    t2, a2 = get_config("moonshot_v1_16b_a3b").param_count()
    assert a2 < t2 / 3  # MoE: active far below total


def test_moe_a2a_matches_dense_subprocess():
    """a2a expert parallelism == dense oracle (runs on a fake 8-dev mesh
    in a subprocess so the fake device count cannot leak into this
    session)."""
    import json
    import os
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, jax, jax.numpy as jnp, numpy as np
from repro.models import get_config
from repro.models import moe as MO
from repro.models.sharding import AxisEnv, axis_env
key = jax.random.PRNGKey(0)
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(get_config("granite_moe_1b_a400m").reduced(),
                          capacity_factor=8.0)
p = MO.init_moe(key, cfg)
x = jax.random.normal(key, (4, 8, cfg.d_model), jnp.float32)
out_d, _ = MO.moe_fwd(p, cfg, x, impl="dense")
env = AxisEnv(batch=("data",), model="model",
              sizes=tuple(mesh.shape.items()), mesh=mesh)
with mesh, axis_env(env):
    out_a, _ = jax.jit(lambda pp, xx: MO.moe_fwd(pp, cfg, xx,
                                                 impl="a2a"))(p, x)
ok = bool(np.allclose(np.asarray(out_a), np.asarray(out_d),
                      rtol=1e-4, atol=1e-4))
print(json.dumps({"ok": ok}))
'''
    env = dict(os.environ)
    env["PYTHONPATH"] = src
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]

"""Multi-device sharded dispatch: SCILIB_DEVICES simulated tiers, tile
decomposition correctness vs the single-device path, round-robin-with-
affinity scheduling, per-device byte-cap eviction, trace + simulator
coverage of the device dimension."""
import contextlib
import os

import numpy as np
import pytest

import repro.core as core
from repro.core import blas, memspace
from repro.core import runtime as rtm
from repro.core.policy import host_array
from repro.core.trace import Trace
from repro.memtier.simulator import MemTierSimulator

RNG = np.random.default_rng(11)


def _mat(n, dtype="float32", m=None):
    m = n if m is None else m
    x = RNG.standard_normal((m, n))
    if np.dtype(dtype).kind == "c":
        x = x + 1j * RNG.standard_normal((m, n))
    return x.astype(dtype)


@contextlib.contextmanager
def devices(n):
    """Force an n-tier simulated device layout for the enclosed runtime."""
    old = os.environ.get("SCILIB_DEVICES")
    os.environ["SCILIB_DEVICES"] = str(n)
    memspace.install()              # re-probe the tier layout now
    try:
        yield
    finally:
        if old is None:
            os.environ.pop("SCILIB_DEVICES", None)
        else:
            os.environ["SCILIB_DEVICES"] = old
        memspace.install()          # re-probe with the restored env


# --------------------------------------------------------------------- #
# tier enumeration                                                       #
# --------------------------------------------------------------------- #
def test_scilib_devices_enumerates_simulated_tiers():
    with devices(4):
        rt = rtm.install("dfu", record_trace=False)
        try:
            assert memspace.active().n_devices == 4
            assert rt.n_devices == 4
        finally:
            rtm.uninstall()
    rt = rtm.install("dfu", record_trace=False)
    try:
        assert rt.n_devices == len(__import__("jax").devices())
    finally:
        rtm.uninstall()


def test_put_block_tags_device_index():
    with devices(3):
        x = host_array(_mat(64))
        y = memspace.put_block(x, 2)
        assert memspace.tier_of(y) == memspace.DEVICE
        assert memspace.device_of(y) == 2
        assert memspace.device_of(x) is None      # host-resident source
        assert memspace.put_block(y, 2) is y      # same-home is identity


# --------------------------------------------------------------------- #
# tile decomposition correctness vs the single-device path               #
# --------------------------------------------------------------------- #
def _single_then_sharded(fn, n_dev=4):
    """Run fn() under a 1-device runtime and an n-device runtime."""
    with core.offload("dfu", threshold=50):
        ref = np.asarray(fn())
    with devices(n_dev):
        with core.offload("dfu", threshold=50) as rt:
            got = np.asarray(fn())
    return ref, got, rt


@pytest.mark.parametrize("dtype", ["float32", "complex64"])
@pytest.mark.parametrize("trans_a,trans_b", [("N", "N"), ("T", "N"),
                                             ("N", "T")])
def test_gemm_tiles_match_single_device(dtype, trans_a, trans_b):
    a_np, b_np, c_np = _mat(384, dtype), _mat(384, dtype), _mat(384, dtype)

    def fn():
        return blas.gemm(host_array(a_np), host_array(b_np),
                         host_array(c_np), alpha=1.5, beta=0.5,
                         trans_a=trans_a, trans_b=trans_b)

    ref, got, rt = _single_then_sharded(fn)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)
    pre = "s" if dtype == "float32" else "c"
    st = rt.stats.per_routine[pre + "gemm"]
    assert st.sharded == 1 and st.tiles >= 4
    assert len(rt.stats.per_device) == 4
    assert all(d.tiles >= 1 for d in rt.stats.per_device.values())
    assert all(d.moved_bytes > 0 for d in rt.stats.per_device.values())


@pytest.mark.parametrize("dtype,conj", [("float32", False),
                                        ("complex64", False),
                                        ("complex64", True)])
@pytest.mark.parametrize("uplo,trans", [("L", "N"), ("U", "T")])
def test_syrk_tiles_match_single_device(dtype, conj, uplo, trans):
    a_np, c_np = _mat(360, dtype), _mat(360, dtype)
    routine = blas.herk if conj else blas.syrk

    def fn():
        return routine(host_array(a_np), host_array(c_np), uplo=uplo,
                       trans=trans, alpha=1.25, beta=0.75)

    ref, got, rt = _single_then_sharded(fn)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)
    pre = "s" if dtype == "float32" else "c"
    st = rt.stats.per_routine[pre + ("herk" if conj else "syrk")]
    assert st.sharded == 1 and st.tiles >= 4   # g=3: 6 stored-tri tiles


@pytest.mark.parametrize("dtype,conj", [("float32", False),
                                        ("complex64", False),
                                        ("complex64", True)])
@pytest.mark.parametrize("uplo,trans", [("L", "N"), ("U", "T")])
def test_syr2k_tiles_match_single_device(dtype, conj, uplo, trans):
    """syr2k/her2k ride the syrk triangle grid (the last level-3 gap in
    the tile scheduler): sharded result must match the single-device
    path bit-for-bit in structure and within tolerance in values."""
    a_np, b_np, c_np = _mat(360, dtype), _mat(360, dtype), _mat(360, dtype)
    routine = blas.her2k if conj else blas.syr2k

    def fn():
        return routine(host_array(a_np), host_array(b_np),
                       host_array(c_np), uplo=uplo, trans=trans,
                       alpha=1.25, beta=0.75)

    ref, got, rt = _single_then_sharded(fn)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)
    pre = "s" if dtype == "float32" else "c"
    st = rt.stats.per_routine[pre + ("her2k" if conj else "syr2k")]
    assert st.sharded == 1 and st.tiles >= 4   # g=3: 6 stored-tri tiles
    assert len(rt.stats.per_device) == 4


def test_syr2k_no_c_tiles_match_single_device():
    a_np, b_np = _mat(360), _mat(360)

    def fn():
        return blas.syr2k(host_array(a_np), host_array(b_np), uplo="L",
                          alpha=0.5)

    ref, got, rt = _single_then_sharded(fn)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)
    assert rt.stats.per_routine["ssyr2k"].sharded == 1


@pytest.mark.parametrize("dtype", ["float32", "complex64"])
@pytest.mark.parametrize("side", ["L", "R"])
def test_trsm_tiles_match_single_device(dtype, side):
    n = 384
    l_np = np.tril(_mat(n, dtype)) + n * np.eye(n, dtype=dtype)
    b_np = _mat(n, dtype)

    def fn():
        return blas.trsm(host_array(l_np), host_array(b_np), side=side,
                         uplo="L", alpha=2.0)

    ref, got, rt = _single_then_sharded(fn)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)
    pre = "s" if dtype == "float32" else "c"
    st = rt.stats.per_routine[pre + "trsm"]
    assert st.sharded == 1 and st.tiles == 4   # 4 independent panels


def test_symm_trmm_tiles_match_single_device():
    a_np, b_np = _mat(384), _mat(384)

    def fn_symm():
        return blas.symm(host_array(a_np), host_array(b_np), side="L",
                         uplo="U", alpha=1.5)

    def fn_trmm():
        return blas.trmm(host_array(np.tril(a_np)), host_array(b_np),
                         side="R", uplo="L", alpha=0.5)

    for fn, name in ((fn_symm, "ssymm"), (fn_trmm, "strmm")):
        ref, got, rt = _single_then_sharded(fn)
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)
        assert rt.stats.per_routine[name].sharded == 1


def test_small_matrix_falls_back_to_single_device():
    """Below SCILIB_TILE_MIN per tile edge the plan builder declines and
    the call takes the unsharded offload path."""
    a_np = _mat(96)
    with devices(4):
        with core.offload("dfu", threshold=10) as rt:
            out = blas.gemm(host_array(a_np), host_array(a_np))
    st = rt.stats.per_routine["sgemm"]
    assert st.offloaded == 1 and st.sharded == 0
    np.testing.assert_allclose(np.asarray(out), a_np @ a_np,
                               rtol=2e-3, atol=2e-3)


def test_batched_calls_not_sharded():
    a_np = RNG.standard_normal((3, 256, 256)).astype("float32")
    with devices(4):
        with core.offload("dfu", threshold=10) as rt:
            blas.gemm(a_np, a_np)
    st = rt.stats.per_routine["sgemm"]
    assert st.offloaded == 1 and st.sharded == 0


def test_singleton_batch_axis_not_sharded():
    """ndim==3 with batch 1 uses the batched kernels: 2-D tile coords
    must not address it (this crashed before the ndim gate)."""
    a_np = RNG.standard_normal((1, 256, 256)).astype("float32")
    with devices(2):
        with core.offload("dfu", threshold=10) as rt:
            out = blas.gemm(a_np, a_np)
    st = rt.stats.per_routine["sgemm"]
    assert st.offloaded == 1 and st.sharded == 0
    np.testing.assert_allclose(np.asarray(out), a_np @ a_np,
                               rtol=2e-3, atol=2e-3)


def test_counter_policy_never_sharded():
    """R1-R4 are per-operand host-vs-device rules: sharding would turn
    the access-counter model into DFU, so it stays single-device."""
    a_np = _mat(512)
    with devices(4):
        with core.offload("counter", threshold=50) as rt:
            blas.gemm(a_np, a_np, a_np)   # written C qualifies nothing
    st = rt.stats.per_routine["sgemm"]
    assert st.offloaded == 1 and st.sharded == 0
    assert rt.stats.per_device == {}


# --------------------------------------------------------------------- #
# round-robin with affinity                                              #
# --------------------------------------------------------------------- #
def test_first_call_spreads_round_robin():
    with devices(4):
        with core.offload("dfu", threshold=50) as rt:
            a = host_array(_mat(512))
            blas.gemm(a, a)
    assert sorted(rt.stats.per_device) == [0, 1, 2, 3]
    assert [d.tiles for _, d in sorted(rt.stats.per_device.items())] == \
        [1, 1, 1, 1]
    assert rt.trace.calls[0].devices == (0, 1, 2, 3)


def test_affinity_reuses_resident_blocks():
    with devices(4):
        with core.offload("dfu", threshold=50) as rt:
            a, b = host_array(_mat(512)), host_array(_mat(512))
            blas.gemm(a, b)
            st = rt.stats.per_routine["sgemm"]
            moved_first = st.bytes_in
            blas.gemm(a, b)
            # every block of every tile was already resident on the tile's
            # device: zero new movement, one schedule per prior placement
            assert st.bytes_in == moved_first
            assert all(d.affinity_hits >= 2
                       for d in rt.stats.per_device.values())
            # and the schedule is stable: same device per tile
            assert rt.trace.calls[0].devices == rt.trace.calls[1].devices


def test_tie_break_spreads_chained_grid():
    """Chained 2-D grids replicate A row blocks across devices; the
    scheduled-load tie-breaker must keep all devices busy rather than
    funneling each grid row onto its lowest-scoring device."""
    with devices(4):
        with core.offload("dfu", threshold=50) as rt:
            a = host_array(_mat(512).astype("float32") / 512)
            c = a
            for _ in range(4):
                c = blas.gemm(a, c)
    tiles = [d.tiles for _, d in sorted(rt.stats.per_device.items())]
    assert len(tiles) == 4
    assert all(t >= 2 for t in tiles), tiles   # 16 tiles, nobody idle


def test_memcopy_stages_every_call_round_robin():
    """Non-persistent staging: no affinity, movement every call."""
    with devices(4):
        with core.offload("memcopy", threshold=50) as rt:
            a, b = host_array(_mat(512)), host_array(_mat(512))
            blas.gemm(a, b)
            st = rt.stats.per_routine["sgemm"]
            moved_first = st.bytes_in
            blas.gemm(a, b)
    assert st.bytes_in == 2 * moved_first
    assert all(d.affinity_hits == 0 for d in rt.stats.per_device.values())
    assert st.bytes_out > 0           # gathered outputs bounce to host


# --------------------------------------------------------------------- #
# per-device byte caps                                                   #
# --------------------------------------------------------------------- #
def test_per_device_byte_cap_evicts_lru_blocks():
    cap = int(1.8e6)
    with devices(2):
        rt = rtm.install("dfu", threshold=50, record_trace=False,
                         device_bytes=cap)
        try:
            a = host_array(_mat(512).astype("float32") / 512)
            c = a
            for _ in range(8):
                c = blas.gemm(a, c)
            assert any(d.evictions > 0
                       for d in rt.stats.per_device.values())
            for dev in range(rt.n_devices):
                assert rt.device_resident_bytes(dev) <= cap
        finally:
            rtm.uninstall()


def test_no_cap_no_device_evictions():
    with devices(2):
        rt = rtm.install("dfu", threshold=50, record_trace=False)
        try:
            a = host_array(_mat(512))
            blas.gemm(a, a)
            assert all(d.evictions == 0
                       for d in rt.stats.per_device.values())
        finally:
            rtm.uninstall()


# --------------------------------------------------------------------- #
# stats report / single-device invariance                                #
# --------------------------------------------------------------------- #
def test_report_shows_per_device_counters():
    with devices(4):
        with core.offload("dfu", threshold=50) as rt:
            a = host_array(_mat(512))
            blas.gemm(a, a)
    rep = rt.stats.report()
    for frag in ("device", "dev0", "dev3", "GB moved", "affinity"):
        assert frag in rep, rep


def test_single_device_path_has_no_shard_state():
    with core.offload("dfu", threshold=50) as rt:
        a = host_array(_mat(512))
        blas.gemm(a, a)
    st = rt.stats.per_routine["sgemm"]
    assert rt.n_devices == 1
    assert st.sharded == 0 and st.tiles == 0
    assert rt.stats.per_device == {}
    assert rt.trace.calls[0].devices == ()


# --------------------------------------------------------------------- #
# trace + simulator device dimension                                     #
# --------------------------------------------------------------------- #
def test_trace_devices_roundtrip(tmp_path):
    with devices(4):
        with core.offload("dfu", threshold=50) as rt:
            a = host_array(_mat(512))
            blas.gemm(a, a)
    path = str(tmp_path / "trace.json")
    rt.trace.dump(path)
    loaded = Trace.load(path)
    assert loaded.calls[0].devices == rt.trace.calls[0].devices
    assert len(loaded.calls[0].devices) == 4


def _big_trace():
    t = Trace()
    a = t.new_buffer(4000 * 4000 * 8, "A")
    b = t.new_buffer(4000 * 4000 * 8, "B")
    c = t.new_buffer(4000 * 4000 * 8, "C")
    for _ in range(3):
        t.gemm("d", 4000, 4000, 4000, a, b, c)
    return t


def test_simulator_multidevice_dfu_scales():
    t = _big_trace()
    one = MemTierSimulator(policy="dfu", threshold=500).run(t)
    four = MemTierSimulator(policy="dfu", threshold=500,
                            n_devices=4).run(t)
    assert four.n_devices == 4
    # concurrent tiles: device BLAS time shrinks with the device count
    assert four.blas_device_s < one.blas_device_s
    assert four.total_s < one.total_s
    # each buffer still migrates exactly once, onto one device
    assert four.bytes_host_to_dev == one.bytes_host_to_dev
    assert sum(four.per_device_h2d.values()) == four.bytes_host_to_dev
    assert set(four.per_device_h2d) <= set(range(4))
    assert len(four.per_device_h2d) >= 2    # round-robin spread buffers


def test_simulator_single_device_unchanged_by_field():
    t = _big_trace()
    rep = MemTierSimulator(policy="dfu", threshold=500).run(t)
    assert rep.n_devices == 1 and rep.per_device_h2d == {}


def test_simulator_multidevice_honors_evict_lru():
    """A working set beyond one device's HBM: without evict_lru the
    overflow buffer stays remote; with it, LRU residents bounce to host
    (same contract as the single-device path)."""
    from repro.memtier.spec import GH200
    tiny = GH200.with_(device_capacity=96 << 20)     # 96 MB HBM
    t = Trace()
    bufs = [t.new_buffer(60 << 20, f"B{i}") for i in range(3)]
    for i in range(3):
        t.gemm("d", 3000, 3000, 3000, bufs[i], bufs[i],
               bufs[(i + 1) % 3])
    keep = MemTierSimulator(tiny, policy="dfu", threshold=100,
                            n_devices=2).run(t)
    evict = MemTierSimulator(tiny, policy="dfu", threshold=100,
                             n_devices=2, evict_lru=True).run(t)
    assert keep.bytes_dev_to_host == 0
    assert evict.bytes_dev_to_host > 0
    assert evict.bytes_host_to_dev > keep.bytes_host_to_dev


# --------------------------------------------------------------------- #
# mesh integration                                                       #
# --------------------------------------------------------------------- #
def test_offload_mesh_over_device_tiers():
    from repro.launch import mesh
    with devices(4):
        devs = mesh.offload_devices()
        assert len(devs) == 4          # logical tiers wrap real devices
        m = mesh.make_offload_mesh()
        assert m.axis_names == ("blas",)
        assert m.shape["blas"] >= 1

"""The residency engine: ResidencyStore invariants (property-tested),
eviction policies, pinning, refetch accounting, PR3-HEAD behavior
identity in lru mode, residency events in the trace, and the
live-capped-run vs simulator-replay eviction-count match the autotuner
relies on."""
import gc
import os

import numpy as np
import pytest

import repro.core as core
from repro.core import blas
from repro.core import runtime as rtm
from repro.core.policy import host_array
from repro.core.residency import (EVICTION_POLICIES, ResidencyStore,
                                  evict_policy_from_env, pin_all_from_env)
from repro.core.trace import Trace
from repro.memtier.simulator import MemTierSimulator, replay_trace

RNG = np.random.default_rng(21)

MINI_TRACE = os.path.join(os.path.dirname(__file__), "data",
                          "mini_trace.json")


def _f32(shape):
    return RNG.standard_normal(shape).astype("float32")


# --------------------------------------------------------------------- #
# store unit behavior                                                    #
# --------------------------------------------------------------------- #
def test_lru_eviction_order_matches_pre_refactor_semantics():
    """lru mode must reproduce the old OrderedDict registries exactly:
    evict from the front, newest registration protected, a get() hit
    refreshes recency."""
    s = ResidencyStore("t", cap=300, policy="lru")
    s.put("a", "A", 100)
    s.put("b", "B", 100)
    s.put("c", "C", 100)
    assert s.evictions == 0
    s.get("a")                       # refresh: b is now LRU
    s.put("d", "D", 100)             # over cap: b evicted, not a
    assert s.evictions == 1
    assert "b" not in s and "a" in s and "d" in s
    assert s.resident_bytes == 300


def test_oversized_entry_admitted_once():
    """The just-registered entry is protected: one oversized buffer is
    admitted (evicting everyone else) and the next registration pushes
    it out — the old _evict_over_cap contract."""
    s = ResidencyStore("t", cap=100, policy="lru")
    s.put("small", "S", 80)
    s.put("big", "B", 500)
    assert "big" in s and "small" not in s
    assert s.resident_bytes == 500   # over cap, but protected
    s.put("next", "N", 80)
    assert "big" not in s and "next" in s


def test_lfu_evicts_least_used():
    s = ResidencyStore("t", cap=300, policy="lfu")
    s.put("a", "A", 100)
    s.put("b", "B", 100)
    s.put("c", "C", 100)
    s.get("a"), s.get("a"), s.get("c")
    s.put("d", "D", 100)             # b has 0 uses -> victim
    assert "b" not in s and "a" in s and "c" in s


def test_refetch_policy_evicts_cheapest_bytes_per_use():
    """Cost-aware: the victim is the entry with the smallest
    nbytes/uses — a big block used once outlives a small hot one only
    if re-fetching the small one is cheaper per use."""
    s = ResidencyStore("t", cap=1000, policy="refetch")
    s.put("big_once", "X", 800)              # 800 B / 1 use = 800
    s.put("small_hot", "Y", 100)
    for _ in range(9):
        s.get("small_hot")                   # 100 B / 10 uses = 10
    s.get("big_once")
    s.put("new", "Z", 200)                   # small_hot is cheapest
    assert "small_hot" not in s and "big_once" in s


def test_pinned_entries_survive_pressure():
    s = ResidencyStore("t", cap=200, policy="lru")
    s.put("p", "P", 150, pinned=True)
    for i in range(10):
        s.put(f"x{i}", "X", 150)
    assert "p" in s                  # survived ten rounds of pressure
    assert s.entry("p").pinned
    s.unpin("p")
    s.put("y", "Y", 150)
    assert "p" not in s              # unpinned: evictable again


def test_refetch_counters_track_evicted_then_replaced():
    s = ResidencyStore("t", cap=100, policy="lru")
    s.put("a", "A", 80)
    s.put("b", "B", 80)              # a evicted
    assert s.evictions == 1
    s.put("a", "A", 80)              # refetch of a
    assert s.refetches == 1 and s.refetched_bytes == 80
    s.put("fresh", "F", 80)          # b evicted... then a fresh place
    assert s.refetches == 1          # fresh was never evicted


def test_reserve_refusal_semantics():
    """The simulator's HBM-capacity admission: refuse (not thrash) when
    eviction is off, make room when it is on."""
    s = ResidencyStore("t", policy="lru")
    s.put("a", "A", 80)
    assert not s.reserve(50, limit=100, evict=False)
    assert "a" in s                  # refusal evicted nothing
    assert s.reserve(50, limit=100, evict=True)
    assert "a" not in s and s.evictions == 1
    assert not s.reserve(500, limit=100)     # can never fit: refused


def test_weakref_lifecycle_drops_entries():
    class Anchor:
        pass
    s = ResidencyStore("t")
    a = Anchor()
    s.put(id(a), "payload", 64, anchor=a)
    assert s.resident_bytes == 64
    del a
    gc.collect()
    assert len(s) == 0 and s.resident_bytes == 0


def test_env_knob_parsing(monkeypatch):
    monkeypatch.setenv("SCILIB_EVICT", "refetch")
    assert evict_policy_from_env() == "refetch"
    monkeypatch.setenv("SCILIB_EVICT", "typo")
    assert evict_policy_from_env() == "lru"   # unknown: safe default
    monkeypatch.setenv("SCILIB_PIN", "never-evict")
    assert pin_all_from_env()
    monkeypatch.delenv("SCILIB_PIN")
    assert not pin_all_from_env()
    assert sorted(EVICTION_POLICIES) == ["lfu", "lru", "refetch"]


# --------------------------------------------------------------------- #
# property tests (hypothesis optional: unit + integration tests above   #
# and below must run even where it is not installed)                     #
# --------------------------------------------------------------------- #
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    ops = st.lists(
        st.tuples(st.integers(0, 7),             # key
                  st.integers(1, 100),           # nbytes (<= cap)
                  st.booleans()),                # get-after-put?
        min_size=1, max_size=60)

    @given(ops=ops, policy=st.sampled_from(sorted(EVICTION_POLICIES)))
    @settings(max_examples=60, deadline=None)
    def test_resident_bytes_never_exceed_cap(ops, policy):
        """With every entry no larger than the cap and no pins, the
        store is never over cap after any put (the protected entry
        fits, so the sweep always gets back under)."""
        cap = 100
        s = ResidencyStore("t", cap=cap, policy=policy)
        for key, nbytes, touch in ops:
            s.put(key, f"p{key}", nbytes)
            assert s.resident_bytes <= cap
            assert sum(s.entry(k).nbytes
                       for k in s.keys()) == s.resident_bytes
            if touch:
                assert s.get(key) == f"p{key}"

    @given(ops=ops, policy=st.sampled_from(sorted(EVICTION_POLICIES)),
           pinned_key=st.integers(100, 101))
    @settings(max_examples=60, deadline=None)
    def test_pins_survive_arbitrary_pressure(ops, policy, pinned_key):
        cap = 100
        s = ResidencyStore("t", cap=cap, policy=policy)
        s.put(pinned_key, "PIN", 60, pinned=True)
        for key, nbytes, touch in ops:
            s.put(key, f"p{key}", nbytes)
            assert pinned_key in s
            # unpinned residency still honors the cap up to the
            # protected entry (which may exceed the headroom by itself)
            unpinned = [s.entry(k) for k in s.keys()
                        if not s.entry(k).pinned]
            if len(unpinned) > 1:
                assert s.resident_bytes <= cap + max(e.nbytes
                                                     for e in unpinned)
        assert s.get(pinned_key) == "PIN"
else:                                            # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_resident_bytes_never_exceed_cap():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_pins_survive_arbitrary_pressure():
        pass


# --------------------------------------------------------------------- #
# runtime integration                                                    #
# --------------------------------------------------------------------- #
def _capped_workload(cap_mats, n=128, mats=5, reps=3, **install_kw):
    """The scripted capped DFU workload whose PR3-HEAD counters are the
    identity baseline: round-robin gemms over `mats` buffers under a
    cap of `cap_mats` matrices."""
    nbytes = n * n * 4
    rng = np.random.default_rng(42)
    rt = rtm.install("dfu", threshold=10,
                     device_bytes=cap_mats * nbytes, **install_kw)
    try:
        xs = [host_array(rng.standard_normal((n, n)).astype("float32"))
              for _ in range(mats)]
        outs = []
        for _ in range(reps):
            for x in xs:
                outs.append(blas.gemm(x, x))
        rt.sync()
        return rt, xs, outs
    finally:
        rtm.uninstall()


def test_lru_counters_match_pr3_head_live():
    """Golden identity: with SCILIB_EVICT=lru and no pins the refactored
    runtime's decisions and eviction counters are exactly what PR3 HEAD
    produced on this workload (captured before the refactor)."""
    rt, xs, outs = _capped_workload(2, record_trace=False)
    st = rt.stats.per_routine["sgemm"]
    assert rt.stats.evictions == 28
    assert rt.stats.evicted_bytes == 1835008
    assert st.bytes_in == 983040
    assert (st.cache_hits, st.cache_misses) == (15, 15)
    assert (st.offloaded, st.on_host) == (15, 0)
    # anchors (xs/outs) still alive here, so no lifecycle drops yet
    assert rt.resident_bytes() == 131072
    del xs, outs
    rt, xs, outs = _capped_workload(3, record_trace=False)
    assert rt.stats.evictions == 27
    assert rt.stats.evicted_bytes == 1769472


def test_lru_replay_matches_pr3_head_on_mini_trace():
    """Golden identity for the simulator half: the uncapped lru replay
    of the bundled mini trace reproduces PR3 HEAD's Tables-3/5 numbers
    for every policy (captured before the refactor)."""
    reports = replay_trace(Trace.load(MINI_TRACE), threshold=500.0)
    want = {
        "cpu": (0.0, 0, 40),
        "memcopy": (1925760000, 30, 10),
        "counter": (54460416, 30, 10),
        "dfu": (822804480, 30, 10),
        "pinned": (0, 30, 10),
    }
    for policy, (h2d, off, host) in want.items():
        r = reports[policy]
        assert r.bytes_host_to_dev == h2d, policy
        assert (r.offloaded_calls, r.host_calls) == (off, host), policy
        assert r.evictions == 0, policy       # uncapped: engine is idle
    assert abs(reports["dfu"].total_s - 0.026482285318641288) < 1e-12
    assert abs(reports["pinned"].total_s - 0.008685036968682825) < 1e-12


def test_live_capped_run_matches_simulator_replay():
    """The acceptance loop: a live capped run records residency events;
    replaying its trace through the simulator at the same cap and
    eviction policy reproduces the eviction AND refetch counts — live
    and simulation share one accounting implementation."""
    cap = 2 * 128 * 128 * 4
    rt, _, _ = _capped_workload(2, record_trace=True)
    trace = rt.trace
    assert rt.stats.evictions == trace.event_count("evict") == 28
    assert rt.stats.refetches == trace.event_count("refetch") == 10
    rep = MemTierSimulator(policy="dfu", threshold=10,
                           device_bytes=cap, evict="lru").run(trace)
    assert rep.evictions == rt.stats.evictions
    assert rep.refetches == rt.stats.refetches
    assert rep.device_bytes == cap and rep.evict == "lru"


def test_live_capped_match_with_written_operands():
    """The count-for-count guarantee must hold for routines whose
    output aliases a written operand (syrk's C): the live registry
    keeps both the operand's placed copy and the output entry, and the
    replay mirrors that with a synthetic twin of the same size."""
    cap = 2 * 128 * 128 * 4
    rng = np.random.default_rng(42)
    rt = rtm.install("dfu", threshold=10, record_trace=True,
                     device_bytes=cap)
    try:
        xs = [host_array(rng.standard_normal((128, 128))
                         .astype("float32")) for _ in range(5)]
        outs = []
        for _ in range(3):
            for x in xs:
                outs.append(blas.syrk(x, x))
        rt.sync()
    finally:
        rtm.uninstall()
    rep = MemTierSimulator(policy="dfu", threshold=10,
                           device_bytes=cap, evict="lru").run(rt.trace)
    assert rep.evictions == rt.stats.evictions == 28
    assert rep.refetches == rt.stats.refetches == 10


def test_trace_events_roundtrip(tmp_path):
    rt, _, _ = _capped_workload(2, record_trace=True)
    path = str(tmp_path / "trace.json")
    rt.trace.dump(path)
    loaded = Trace.load(path)
    assert len(loaded.events) == len(rt.trace.events)
    assert loaded.event_count("evict") == rt.trace.event_count("evict")
    assert loaded.events[0] == rt.trace.events[0]
    # calls carry the fresh-output buffer for replay accounting
    assert all(c.out_buf > 0 and c.out_nbytes == 128 * 128 * 4
               for c in loaded.calls)


def test_pin_survives_pressure_live():
    """runtime.pin(x): the pinned placement outlives arbitrary cap
    pressure and keeps serving hits."""
    nbytes = 128 * 128 * 4
    rt = rtm.install("dfu", threshold=10, record_trace=False,
                     device_bytes=2 * nbytes)
    try:
        hot = host_array(_f32((128, 128)))
        rt.pin(hot)
        for _ in range(6):
            blas.gemm(host_array(_f32((128, 128))),
                      host_array(_f32((128, 128))))
        st = rt.stats.per_routine["sgemm"]
        before_in = st.bytes_in
        blas.gemm(hot, hot)
        # both operand lookups hit the pinned placement: nothing moved
        assert st.bytes_in == before_in
        assert rt.stats.evictions > 0         # pressure was real
    finally:
        rtm.uninstall()


def test_pin_env_never_evict(monkeypatch):
    """SCILIB_PIN=never-evict pins every placement: the cap stops
    evicting entirely (residency only grows, the paper's plain DFU)."""
    monkeypatch.setenv("SCILIB_PIN", "never-evict")
    rt, _, _ = _capped_workload(2, record_trace=False)
    assert rt.stats.evictions == 0
    assert rt.resident_bytes() > rt.placements.cap


def test_post_eviction_refetch_bit_identical():
    """An evicted-then-refetched operand must produce bit-identical
    results — eviction is an accounting event, never a data hazard."""
    nbytes = 128 * 128 * 4
    a_np = _f32((128, 128))
    with core.offload("dfu", threshold=10) as rt:
        a = host_array(a_np)
        want = np.asarray(blas.gemm(a, a))
    rt = rtm.install("dfu", threshold=10, record_trace=False,
                     device_bytes=2 * nbytes)
    try:
        a = host_array(a_np)
        first = np.asarray(blas.gemm(a, a))
        for _ in range(4):                    # flush a out of residency
            blas.gemm(host_array(_f32((128, 128))),
                      host_array(_f32((128, 128))))
        assert id(a) not in rt.placements     # it was really evicted
        again = np.asarray(blas.gemm(a, a))   # refetch
        np.testing.assert_array_equal(first, again)
        np.testing.assert_array_equal(first, want)
        assert rt.stats.refetches >= 1
    finally:
        rtm.uninstall()


def test_evict_env_selects_policy(monkeypatch):
    monkeypatch.setenv("SCILIB_EVICT", "refetch")
    rt = rtm.install("dfu", threshold=10, record_trace=False,
                     device_bytes=1 << 20)
    try:
        assert rt.evict_policy == "refetch"
        assert rt.placements.policy.name == "refetch"
        assert all(s.policy.name == "refetch" for s in rt.block_stores)
    finally:
        rtm.uninstall()


# --------------------------------------------------------------------- #
# autotune sweep over cap x eviction policy                              #
# --------------------------------------------------------------------- #
def test_autotune_sweeps_cap_and_evict_dimensions():
    from repro.tools import autotune as at
    trace = Trace.load(MINI_TRACE)
    result = at.autotune(trace)
    caps = {p.device_bytes for p in result.points}
    evicts = {p.evict for p in result.points}
    assert len(caps) >= 3                 # None + auto-derived fractions
    assert evicts == {"lru", "lfu", "refetch"}
    # the original acceptance invariants survive the wider grid
    assert result.speedup > 1.5
    assert result.best.moved_bytes < result.baseline.moved_bytes
    # env rendering includes the new knobs on capped points
    capped = next(p for p in result.points
                  if p.device_bytes is not None and p.evict != "lru")
    env = capped.env()
    assert env["SCILIB_DEVICE_BYTES"] == str(capped.device_bytes)
    assert env["SCILIB_EVICT"] == capped.evict


def test_autotune_replayed_evictions_match_live_capped_run():
    """End-to-end acceptance: record a live capped run, hand its trace
    to the autotuner sweeping the same cap — the grid point at the live
    configuration reports the same eviction count the live run paid."""
    from repro.tools import autotune as at
    cap = 2 * 128 * 128 * 4
    rt, _, _ = _capped_workload(2, record_trace=True)
    live_evictions = rt.stats.evictions
    result = at.autotune(rt.trace, thresholds=(10.0,),
                         policies=("dfu",), device_counts=(1,),
                         device_bytes=(0, cap), evicts=("lru",))
    point = next(p for p in result.points
                 if p.device_bytes == cap and p.evict == "lru"
                 and p.threshold == 10.0 and p.n_devices == 1)
    assert point.report.evictions == live_evictions == 28

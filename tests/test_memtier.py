"""Tiered-memory model: Table 6 pattern, policy ordering, page table."""
import numpy as np
import pytest

from repro.core.trace import Trace
from repro.memtier import (GH200, GH200_4K, TPU_V5E, MemKind,
                           MemTierSimulator, PageTable, replay_trace)


def _gemm_trace(m, n, k, reps=5, prec="d"):
    t = Trace()
    el = 16 if prec == "z" else 8
    a = t.new_buffer(m * k * el, "A")
    b = t.new_buffer(k * n * el, "B")
    c = t.new_buffer(m * n * el, "C")
    for _ in range(reps):
        t.gemm(prec, m, n, k, a, b, c)
    return t, (a, b, c)


TABLE6 = {
    (1000, 1000, 1000): ("device", "device", "device"),
    (5000, 5000, 5000): ("device", "device", "host"),
    (20000, 20000, 20000): ("device", "host", "host"),
    (32, 2400, 93536): ("device", "host", "host"),
}


@pytest.mark.parametrize("dims,want", TABLE6.items())
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_table6_counter_pattern(dims, want, seed):
    t, bufs = _gemm_trace(*dims)
    sim = MemTierSimulator(GH200, policy="counter", threshold=0,
                           seed=seed)
    sim.run(t)
    assert tuple(sim.residency(x) for x in bufs) == want


def test_policy_ordering_reuse_heavy():
    """On a reuse-heavy stream: dfu < memcopy < cpu total time.
    (aligned allocations: Table 8 shows aligned system memory matches
    cudaMalloc, isolating the movement-policy effect.)"""
    t, _ = _gemm_trace(2000, 2000, 2000, reps=200, prec="z")
    reps = replay_trace(t, spec=GH200, aligned_alloc=True)
    assert reps["dfu"].total_s < reps["memcopy"].total_s
    assert reps["memcopy"].total_s < reps["cpu"].total_s
    assert reps["dfu"].movement_s < reps["memcopy"].movement_s / 10


def test_dfu_moves_each_buffer_once():
    t, bufs = _gemm_trace(3000, 3000, 3000, reps=50)
    sim = MemTierSimulator(GH200, policy="dfu", threshold=0)
    rep = sim.run(t)
    assert rep.n_migrated_buffers == 3
    assert rep.mean_reuse >= 49


def test_pagetable_move_pages_accounting():
    pt = PageTable(GH200)
    buf = pt.malloc(10 << 20, "x")
    assert buf.fully_on(MemKind.HOST)
    moved, secs = pt.move_pages(buf, MemKind.DEVICE)
    assert moved >= 10 << 20 and secs > 0
    assert buf.fully_on(MemKind.DEVICE)
    moved2, _ = pt.move_pages(buf, MemKind.DEVICE)
    assert moved2 == 0  # idempotent


def test_unaligned_penalty_applies():
    t1, _ = _gemm_trace(2000, 2000, 2000, reps=2)
    fast = MemTierSimulator(GH200, policy="dfu", threshold=0,
                            aligned_alloc=True).run(t1)
    t2, _ = _gemm_trace(2000, 2000, 2000, reps=2)
    slow = MemTierSimulator(GH200, policy="dfu", threshold=0,
                            aligned_alloc=False).run(t2)
    assert slow.blas_device_s > fast.blas_device_s


def test_capacity_eviction_lru():
    spec = GH200.with_(device_capacity=1 << 30)
    t = Trace()
    bufs = [t.new_buffer(600 << 20, f"b{i}") for i in range(3)]
    out = t.new_buffer(8 << 10, "out")
    for i in range(3):
        t.gemm("d", 1000, 1000, 1000, bufs[i], bufs[i], out)
    sim = MemTierSimulator(spec, policy="dfu", threshold=0,
                           evict_lru=True)
    rep = sim.run(t)
    assert rep.bytes_dev_to_host > 0       # something was evicted
    assert sim.residency(bufs[2]) == "device"


def test_getf2_never_offloaded():
    t = Trace()
    a = t.new_buffer(1000 * 1000 * 16, "A")
    t.panel("z", 1000, 128, a)
    sim = MemTierSimulator(GH200, policy="dfu", threshold=0)
    rep = sim.run(t)
    assert rep.host_calls == 1 and rep.offloaded_calls == 0

"""Offload runtime, policies, interception, threshold, serving placement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import blas, memspace
from repro.core.policy import host_array
from repro.core.threshold import n_avg, should_offload

RNG = np.random.default_rng(2)


def test_threshold_navg_gemm():
    assert n_avg("zgemm", 600, 600, 600) == pytest.approx(600.0)
    off, nav = should_offload("dgemm", 32, 2400, 93536, threshold=500)
    assert off and nav > 1900  # the PARSEC skinny shape offloads


def test_threshold_below_stays_host():
    with core.offload("dfu", threshold=500) as rt:
        a = jnp.ones((64, 64), jnp.float32)
        jnp.matmul(a, a)
    assert rt.stats.per_routine["sgemm"].on_host == 1


def test_dfu_migrates_once_and_reuses(monkeypatch):
    # asserts uncapped move-once semantics: pin the cap off so the CI
    # eviction-stress job's global SCILIB_DEVICE_BYTES can't evict here
    monkeypatch.delenv("SCILIB_DEVICE_BYTES", raising=False)
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(RNG.standard_normal((512, 512)).astype("float32"))
        b = host_array(RNG.standard_normal((512, 512)).astype("float32"))
        c = jnp.matmul(a, b)
        for _ in range(5):
            c = jnp.matmul(a, c)
        st = rt.stats.per_routine["sgemm"]
        assert st.offloaded == 6
        # a and b moved once; a hit 5 more times, outputs chain for free
        assert st.bytes_in == a.nbytes + b.nbytes
        assert st.cache_hits >= 5
    assert memspace.tier_of(c) == memspace.DEVICE


def test_memcopy_roundtrips_every_call():
    with core.offload("memcopy", threshold=100) as rt:
        a = host_array(RNG.standard_normal((512, 512)).astype("float32"))
        b = host_array(RNG.standard_normal((512, 512)).astype("float32"))
        out = None
        for _ in range(3):
            out = jnp.matmul(a, b)
        st = rt.stats.per_routine["sgemm"]
        assert st.bytes_in == 3 * (a.nbytes + b.nbytes)
        assert st.bytes_out == 3 * out.nbytes
    assert memspace.tier_of(out) == memspace.HOST


def test_policies_numerically_identical():
    a_np = RNG.standard_normal((300, 300)).astype("float32")
    b_np = RNG.standard_normal((300, 300)).astype("float32")
    outs = {}
    for pol in ("cpu", "memcopy", "counter", "dfu", "pinned"):
        with core.offload(pol, threshold=100):
            a, b = host_array(a_np), host_array(b_np)
            outs[pol] = np.asarray(jnp.matmul(a, b))
    for pol, out in outs.items():
        np.testing.assert_allclose(out, outs["cpu"], rtol=1e-5,
                                   atol=1e-5, err_msg=pol)


def test_einsum_interception_transposes():
    with core.offload("dfu", threshold=10) as rt:
        a = jnp.asarray(RNG.standard_normal((128, 64)), jnp.float32)
        b = jnp.asarray(RNG.standard_normal((128, 96)), jnp.float32)
        out = jnp.einsum("ji,jk->ik", a, b)
        assert rt.stats.per_routine["sgemm"].calls == 1
    np.testing.assert_allclose(out, np.asarray(a).T @ np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_interception_restores_symbols():
    orig = jnp.matmul
    with core.offload("dfu"):
        assert jnp.matmul is not orig
    assert jnp.matmul is orig


def test_jit_tracing_passes_through():
    with core.offload("dfu", threshold=10) as rt:
        @jax.jit
        def f(x):
            return jnp.matmul(x, x)

        x = jnp.asarray(RNG.standard_normal((64, 64)), jnp.float32)
        f(x)
        # traced calls pass through to the original symbol: they are
        # counted as uninstrumented, never as offloaded BLAS calls
        assert "sgemm" not in rt.stats.per_routine
        assert rt.stats.uninstrumented_calls >= 1


def test_trace_recorded_and_replayable():
    from repro.memtier import GH200, replay_trace
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(RNG.standard_normal((512, 512)).astype("float32"))
        for _ in range(4):
            a_out = jnp.matmul(a, a)
        trace = rt.trace
    assert len(trace) == 4
    reports = replay_trace(trace, spec=GH200, policies=("cpu", "dfu"))
    assert reports["dfu"].total_s < reports["cpu"].total_s

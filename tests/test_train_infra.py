"""Training loop, checkpoint/restart, data determinism, serving."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, TokenPipeline
from repro.models import get_config
from repro.models.registry import Model
from repro.train import Server, ServeConfig, Trainer, TrainConfig


def _mk(steps=6, ckpt=None, **kw):
    cfg = get_config("qwen1_5_4b").reduced()
    m = Model.from_config(cfg)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=24,
                                    global_batch=4))
    tcfg = TrainConfig(steps=steps, ckpt_every=3, log_every=100,
                      warmup=2, moe_impl="dense", **kw)
    return Trainer(m, pipe, tcfg, ckpt_dir=ckpt), m


def test_loss_decreases():
    tr, _ = _mk(steps=10)
    hist = tr.fit(verbose=False)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_restart_exact():
    with tempfile.TemporaryDirectory() as d:
        # same schedule horizon (steps=9) everywhere; interrupt at 6
        tr1, _ = _mk(steps=9, ckpt=d)
        tr1.fit(steps=6, verbose=False)
        # fresh trainer resumes from the step-6 checkpoint; run to 9
        tr2, _ = _mk(steps=9, ckpt=d)
        tr2.fit(verbose=False)
        assert tr2.step == 9
        # compare against an uninterrupted 9-step run
        with tempfile.TemporaryDirectory() as d2:
            tr3, _ = _mk(steps=9, ckpt=d2)
            tr3.fit(verbose=False)
        for a, b in zip(jax.tree.leaves(tr2.params),
                        jax.tree.leaves(tr3.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


def test_microbatch_equivalence():
    tr1, _ = _mk(steps=3, n_micro=1)
    tr2, _ = _mk(steps=3, n_micro=2)
    h1 = tr1.fit(verbose=False)
    h2 = tr2.fit(verbose=False)
    assert abs(h1[-1]["loss"] - h2[-1]["loss"]) < 1e-3


def test_remat_equivalence():
    tr1, _ = _mk(steps=2, remat="none")
    tr2, _ = _mk(steps=2, remat="full")
    h1 = tr1.fit(verbose=False)
    h2 = tr2.fit(verbose=False)
    assert abs(h1[-1]["loss"] - h2[-1]["loss"]) < 1e-4


def test_grad_compress_close_but_not_exact():
    tr1, _ = _mk(steps=4, grad_compress=False)
    tr2, _ = _mk(steps=4, grad_compress=True)
    h1 = tr1.fit(verbose=False)
    h2 = tr2.fit(verbose=False)
    assert abs(h1[-1]["loss"] - h2[-1]["loss"]) < 0.1


def test_data_determinism_and_sharding():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    p1 = TokenPipeline(cfg, num_shards=1)
    p4 = TokenPipeline(cfg, num_shards=4)
    b1a = p1.batch(5)
    b1b = p1.batch(5)
    np.testing.assert_array_equal(b1a["tokens"], b1b["tokens"])
    # shards are disjoint slices of the same deterministic stream
    g = p4.global_batch(5)
    assert g["tokens"].shape == (8, 16)
    # labels are next-token shifted
    full = p1.batch(3)
    assert full["tokens"].shape == (8, 16)


def test_checkpoint_store_atomic_and_prune():
    from repro.checkpoint import CheckpointStore
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d, keep=2)
        tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
        for s in (1, 2, 3):
            store.save(s, tree, blocking=True)
        assert store.latest_step() == 3
        assert sorted(os.listdir(d)) == ["step_2", "step_3"]
        restored, manifest = store.restore(tree)
        np.testing.assert_array_equal(restored["a"], tree["a"])
        assert manifest["step"] == 3


def test_serve_policies_identical_output():
    cfg = get_config("qwen1_5_4b").reduced()
    m = Model.from_config(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = jnp.ones((2, 8), jnp.int32)
    outs = {}
    for pol in ("dfu", "memcopy", "pinned"):
        srv = Server(m, params, ServeConfig(max_len=32,
                                            offload_policy=pol,
                                            cache_dtype=jnp.float32))
        outs[pol] = np.asarray(srv.generate(prompt, 8))
        if pol == "dfu":
            assert srv.stats.migrations == 1
            assert srv.stats.cache_reuses >= 6
    np.testing.assert_array_equal(outs["dfu"], outs["memcopy"])
    np.testing.assert_array_equal(outs["dfu"], outs["pinned"])
    srv_mc_bytes = True

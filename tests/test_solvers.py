"""LAPACK solver tier: drivers vs oracles, interception, spans,
live==replay counters, factor pinning, and default-off bit-identity."""
import json

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl
import numpy as np
import pytest
import scipy.linalg as sla

import repro
from repro.core import blas, lapack
from repro.core import runtime as rtm
from repro.core.config import OffloadConfig
from repro.core.policy import host_array
from repro.core.trace import Trace
from repro.memtier.simulator import MemTierSimulator
from repro.memtier.spec import SPECS
from repro.solvers import drivers
from repro.solvers import eigen
import repro.tools.autotune as at

RNG = np.random.default_rng(7)

DTYPES = ("float32", "float64", "complex64", "complex128")


def _tol(dtype) -> float:
    return 5e-3 if jnp.dtype(dtype).itemsize <= 8 and \
        np.finfo(np.dtype(dtype)).eps > 1e-10 else 1e-9


def _rand(shape, dtype):
    x = RNG.standard_normal(shape)
    if np.issubdtype(np.dtype(dtype), np.complexfloating):
        x = x + 1j * RNG.standard_normal(shape)
    return np.asarray(x, dtype=dtype)


def _diag_dominant(n, dtype):
    a = _rand((n, n), dtype) / n
    return np.asarray(a + np.eye(n), dtype=dtype)


def _hpd(n, dtype):
    g = _rand((n, n), dtype) / n
    return np.asarray(g @ g.conj().T + np.eye(n), dtype=dtype)


def _hermitian(n, dtype):
    g = _rand((n, n), dtype)
    return np.asarray((g + g.conj().T) / 2, dtype=dtype)


@pytest.fixture(scope="module", autouse=True)
def x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


# --------------------------------------------------------------------- #
# getrf: rectangular / partial-block regressions                         #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("shape,nb", [
    ((7, 7), 3), ((10, 6), 4), ((6, 10), 4),
    ((130, 70), 48), ((70, 130), 48), ((100, 100), 48),
])
def test_getrf_rectangular_and_partial_blocks(shape, nb):
    """Non-square inputs and ragged final blocks factor correctly:
    A[piv] == L @ U with unit-lower L of shape (m, k) and U (k, n)."""
    m, n = shape
    a = jnp.asarray(_rand(shape, "float64"))
    lu, piv = lapack.getrf(a, nb=nb)
    k = min(m, n)
    low = np.tril(np.asarray(lu)[:, :k], -1) + np.eye(m, k)
    up = np.triu(np.asarray(lu)[:k, :])
    np.testing.assert_allclose(np.asarray(a)[np.asarray(piv)],
                               low @ up, atol=1e-10)


# --------------------------------------------------------------------- #
# drivers vs oracles (no runtime: plain blocked kernels)                 #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", DTYPES)
def test_gesv_oracle(dtype):
    a = _diag_dominant(96, dtype)
    b = _rand((96, 7), dtype)
    x = drivers.gesv(jnp.asarray(a), jnp.asarray(b), nb=32)
    np.testing.assert_allclose(np.asarray(x), np.linalg.solve(a, b),
                               atol=_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("uplo", ("L", "U"))
def test_potrf_potrs_oracle(dtype, uplo):
    a = _hpd(80, dtype)
    f = drivers.potrf(jnp.asarray(a), nb=32, uplo=uplo)
    fn = np.asarray(f)
    if uplo == "L":
        np.testing.assert_allclose(np.tril(fn) @ np.tril(fn).conj().T,
                                   a, atol=_tol(dtype))
    else:
        np.testing.assert_allclose(np.triu(fn).conj().T @ np.triu(fn),
                                   a, atol=_tol(dtype))
    b = _rand((80, 5), dtype)
    x = drivers.potrs(f, jnp.asarray(b), uplo=uplo)
    np.testing.assert_allclose(np.asarray(x), np.linalg.solve(a, b),
                               atol=_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
def test_syev_oracle(dtype):
    a = _hermitian(67, dtype)
    w, s = drivers.syev(jnp.asarray(a), nb=24)
    ww = sla.eigh(a, eigvals_only=True)
    np.testing.assert_allclose(np.asarray(w), ww, atol=_tol(dtype))
    # residual: A S == S diag(w), and S orthonormal
    sn, wn = np.asarray(s), np.asarray(w)
    np.testing.assert_allclose(a @ sn, sn * wn, atol=20 * _tol(dtype))
    np.testing.assert_allclose(sn.conj().T @ sn, np.eye(67),
                               atol=_tol(dtype))


def test_syev_uplo_u_ignores_lower_garbage():
    """uplo="U" reads only the upper triangle — LAPACK convention: the
    strictly-lower part may hold arbitrary values."""
    a = _hermitian(40, "complex128")
    dirty = np.array(a)
    dirty[np.tril_indices(40, -1)] = RNG.standard_normal(
        len(np.tril_indices(40, -1)[0])) * 1e3
    w, _ = drivers.syev(jnp.asarray(dirty), nb=16, uplo="U")
    np.testing.assert_allclose(np.asarray(w),
                               sla.eigh(a, eigvals_only=True), atol=1e-9)


# --------------------------------------------------------------------- #
# symbol interception (SCILIB_LAPACK)                                    #
# --------------------------------------------------------------------- #
def test_lapack_session_patches_and_restores_symbols():
    orig = (jsl.lu_factor, jsl.lu_solve, jnp.linalg.cholesky,
            jnp.linalg.solve, jsl.eigh)
    with repro.session(OffloadConfig(lapack=True, threshold=64.0)):
        assert jsl.lu_factor is not orig[0]
        assert jnp.linalg.solve is not orig[3]
    assert (jsl.lu_factor, jsl.lu_solve, jnp.linalg.cholesky,
            jnp.linalg.solve, jsl.eigh) == orig


def test_lapack_unset_touches_no_symbols():
    """The default-off guarantee starts here: SCILIB_LAPACK unset means
    these symbols are never even reassigned."""
    orig = (jsl.lu_factor, jsl.cho_solve, jnp.linalg.cholesky)
    with repro.session(OffloadConfig(threshold=64.0)):
        assert (jsl.lu_factor, jsl.cho_solve,
                jnp.linalg.cholesky) == orig


def test_reconfigure_flips_solver_patch():
    orig = jsl.lu_factor
    with repro.session(OffloadConfig(threshold=64.0)) as s:
        assert jsl.lu_factor is orig
        s.reconfigure(lapack=True)
        assert jsl.lu_factor is not orig
        s.reconfigure(lapack=False)
        assert jsl.lu_factor is orig


def test_intercepted_solve_records_span_and_is_correct():
    a = _diag_dominant(150, "complex128")
    b = _rand((150, 6), "complex128")
    with repro.session(OffloadConfig(lapack=True, threshold=32.0,
                                     lapack_nb=48)) as s:
        x = jnp.linalg.solve(host_array(jnp.asarray(a)),
                             host_array(jnp.asarray(b)))
        rt = s.runtime
        st = rt.stats.solvers["gesv"]
        assert st.spans == 1
        assert st.panel_calls == 4          # ceil(150/48) panels
        assert st.calls > st.panel_calls    # + trsms and gemms
        assert rt.trace.event_count("solver_begin") == 1
        assert rt.trace.event_count("solver_end") == 1
        assert all(c.solver == "gesv" for c in rt.trace
                   if c.solver_id)
        assert "solvers (LAPACK tier)" in rt.stats.report()
    np.testing.assert_allclose(np.asarray(x), np.linalg.solve(a, b),
                               atol=1e-9)


def test_subthreshold_solve_falls_through_native():
    a = _diag_dominant(48, "float64")
    with repro.session(OffloadConfig(lapack=True,
                                     threshold=1000.0)) as s:
        x = jnp.linalg.solve(host_array(jnp.asarray(a)),
                             host_array(jnp.asarray(_rand((48, 3),
                                                          "float64"))))
        assert not s.runtime.stats.solvers
        assert s.runtime.trace.event_count("solver_begin") == 0
    assert x.shape == (48, 3)


def test_intercepted_scipy_surface_matches_oracles():
    """cho_factor/cho_solve, solve_triangular and eigh all route
    through the tier and stay numerically faithful."""
    n = 72
    spd = _hpd(n, "float64")
    b = _rand((n, 4), "float64")
    herm = _hermitian(n, "float64")
    tri = np.tril(_rand((n, n), "float64")) + n * np.eye(n)
    with repro.session(OffloadConfig(lapack=True, threshold=32.0,
                                     lapack_nb=24)) as s:
        c = jsl.cho_factor(host_array(jnp.asarray(spd)))
        x = jsl.cho_solve(c, host_array(jnp.asarray(b)))
        y = jsl.solve_triangular(host_array(jnp.asarray(tri)),
                                 host_array(jnp.asarray(b)), lower=True)
        w = jsl.eigh(host_array(jnp.asarray(herm)), eigvals_only=True)
        names = set(s.runtime.stats.solvers)
        assert {"potrf", "potrs", "syev"} <= names
    np.testing.assert_allclose(np.asarray(x), np.linalg.solve(spd, b),
                               atol=1e-9)
    np.testing.assert_allclose(np.asarray(y), sla.solve_triangular(
        tri, b, lower=True), atol=1e-9)
    np.testing.assert_allclose(np.asarray(w),
                               sla.eigh(herm, eigvals_only=True),
                               atol=1e-8)


# --------------------------------------------------------------------- #
# spans: trace round-trip and simulator replay                           #
# --------------------------------------------------------------------- #
def _lapack_workload(sess) -> None:
    a = _diag_dominant(120, "float64")
    spd = _hpd(96, "float64")
    jnp.linalg.solve(host_array(jnp.asarray(a)),
                     host_array(jnp.asarray(_rand((120, 5), "float64"))))
    jnp.linalg.cholesky(host_array(jnp.asarray(spd)))
    jsl.eigh(host_array(jnp.asarray(_hermitian(48, "float64"))),
             eigvals_only=True)


def test_span_trace_roundtrip(tmp_path):
    path = tmp_path / "t.json"
    with repro.session(OffloadConfig(lapack=True, threshold=32.0,
                                     lapack_nb=32)) as s:
        _lapack_workload(s)
        trace = s.runtime.trace
        tagged = [(c.routine, c.solver_id) for c in trace if c.solver_id]
        begins = trace.event_count("solver_begin")
        trace.dump(str(path))
    loaded = Trace.load(str(path))
    assert [(c.routine, c.solver_id) for c in loaded
            if c.solver_id] == tagged
    assert loaded.event_count("solver_begin") == begins == 3
    assert loaded.event_count("solver_end") == 3


def test_live_equals_replay_per_solver(tmp_path):
    """The acceptance bar: simulator-replayed per-solver counters match
    the live session's exactly, span for span and call for call."""
    with repro.session(OffloadConfig(lapack=True, threshold=32.0,
                                     lapack_nb=32)) as s:
        _lapack_workload(s)
        live = {name: (st.spans, st.calls, st.panel_calls)
                for name, st in s.runtime.stats.solvers.items()}
        trace = s.runtime.trace
    sim = MemTierSimulator(SPECS["gh200"], policy="dfu", threshold=32.0)
    rep = sim.run(trace)
    replay = {name: (d["spans"], d["calls"], d["panel_calls"])
              for name, d in rep.per_solver.items()}
    assert replay == live
    assert rep.solver_spans == sum(v[0] for v in live.values()) == 3


# --------------------------------------------------------------------- #
# residency: the span pins its factor                                    #
# --------------------------------------------------------------------- #
def test_span_pins_factor_under_cap_pressure():
    n = 96
    el = 8
    rt = rtm.install("dfu", threshold=10, device_bytes=3 * n * n * el,
                     record_trace=False)
    try:
        factor = host_array(jnp.asarray(_diag_dominant(n, "float64")))
        span = rt.solver_begin("getrf", factor)
        ent = rt.placements.entry(id(factor))
        assert ent is not None and ent.pinned
        # stream a working set larger than the cap: evictions must
        # happen, but never to the pinned factor
        others = [host_array(jnp.asarray(_rand((n, n), "float64")))
                  for _ in range(6)]
        for x in others:
            blas.gemm(x, x)
        rt.sync()
        assert rt.stats.evictions > 0
        ent = rt.placements.entry(id(factor))
        assert ent is not None and ent.pinned
        rt.solver_end(span)
        ent = rt.placements.entry(id(factor))
        assert ent is not None and not ent.pinned
    finally:
        rtm.uninstall()


def test_cpu_policy_span_does_not_pin():
    rt = rtm.install(config=OffloadConfig(policy="cpu"),
                     record_trace=False)
    try:
        factor = host_array(jnp.asarray(_diag_dominant(32, "float64")))
        span = rt.solver_begin("getrf", factor)
        assert not span.pinned
        assert rt.placements.entry(id(factor)) is None
        rt.solver_end(span)
    finally:
        rtm.uninstall()


# --------------------------------------------------------------------- #
# default-off bit-identity                                               #
# --------------------------------------------------------------------- #
def test_lapack_off_golden_counters(monkeypatch):
    """SCILIB_LAPACK unset reproduces the pre-solver golden counters
    bit-for-bit on the capped eviction workload (same goldens the
    kernel-venue and precision stages preserve)."""
    monkeypatch.delenv("SCILIB_LAPACK", raising=False)
    rng = np.random.default_rng(42)
    rt = rtm.install("dfu", threshold=10, device_bytes=2 * 128 * 128 * 4,
                     record_trace=False)
    try:
        xs = [host_array(jnp.asarray(rng.standard_normal((128, 128)),
                                     jnp.float32)) for _ in range(5)]
        for _ in range(3):
            for x in xs:
                blas.gemm(x, x)
        rt.sync()
        assert rt.stats.evictions == 28
        assert rt.stats.evicted_bytes == 1835008
        st = rt.stats.per_routine["sgemm"]
        assert (st.offloaded, st.on_host) == (15, 0)
        assert (st.cache_hits, st.cache_misses) == (15, 15)
        assert not rt.stats.solvers
        assert "solvers (LAPACK tier)" not in rt.stats.report()
    finally:
        rtm.uninstall()


def test_lapack_off_trace_dump_has_no_solver_keys(tmp_path):
    """Default-off dumps carry no solver_id keys and no solver events —
    byte-stable against pre-solver readers and writers."""
    path = tmp_path / "t.json"
    with repro.session(OffloadConfig(threshold=1.0, sync=True)) as s:
        a = host_array(jnp.asarray(RNG.standard_normal((64, 64)),
                                   jnp.float32))
        blas.gemm(a, a)
        s.runtime.trace.dump(str(path))
    raw = json.loads(path.read_text())
    assert all("solver_id" not in c for c in raw["calls"])
    assert not any(e["kind"].startswith("solver")
                   for e in raw.get("events", ()))


def test_note_panel_is_noop_outside_spans():
    rt = rtm.install("dfu", threshold=10, record_trace=True)
    try:
        a = host_array(jnp.asarray(_rand((32, 32), "float64")))
        rt.note_panel("d", 32, 8, a)
        assert len(rt.trace) == 0
        assert "dgetf2" not in rt.stats.per_routine
        assert not rt.stats.solvers
    finally:
        rtm.uninstall()


# --------------------------------------------------------------------- #
# lsms mini-app through the tier                                         #
# --------------------------------------------------------------------- #
def test_run_mini_matches_host_under_lapack():
    from repro.apps.lsms import run_mini
    kw = dict(atoms=2, energies=2, scf=1, n=96, nb=32)
    ref = run_mini(**kw)
    with repro.session(OffloadConfig(lapack=True, threshold=48.0,
                                     lapack_nb=32)) as s:
        out = run_mini(**kw)
        assert {"getrf", "getrs"} <= set(s.runtime.stats.solvers)
        spans = s.runtime.trace.event_count("solver_begin")
        assert spans == 2 * kw["atoms"] * kw["energies"] * kw["scf"]
    assert out["n_solves"] == ref["n_solves"]
    assert out["max_resid"] < 1e-10
    np.testing.assert_allclose(out["energy"], ref["energy"], rtol=1e-9)


# --------------------------------------------------------------------- #
# autotuner: the lapack_nb grid dimension                                #
# --------------------------------------------------------------------- #
def _solver_trace(spans: int = 2, n: int = 512, nb: int = 64) -> Trace:
    t = Trace()
    el = 16
    tau = t.new_buffer(n * n * el, "tau")
    tm = t.new_buffer(n * 32 * el, "tmat")
    for s in range(spans):
        sid = f"gesv#{s}"
        t.record_event("solver_begin", sid, 0)
        for j0 in range(0, n, nb):
            jb = min(nb, n - j0)
            t.panel("z", n - j0, jb, tau, solver=sid)
            rem = n - j0 - jb
            if rem:
                t.trsm("z", jb, rem, tau, tau, solver=sid)
                t.gemm("z", rem, rem, jb, tau, tau, tau, solver=sid)
        t.trsm("z", n, 32, tau, tm, solver=sid)
        t.trsm("z", n, 32, tau, tm, solver=sid)
        t.record_event("solver_end", sid, 0)
    return t


def test_retile_lapack_regenerates_lu_spans():
    trace = _solver_trace(spans=2, n=512, nb=64)
    out = at.retile_lapack(trace, 128)
    assert at.retile_lapack(trace, 0) is trace
    per_span = 512 // 128
    panels = [c for c in out if c.routine.endswith("getf2")]
    assert len(panels) == 2 * per_span
    # solve-phase trsms (m == matrix n) survive verbatim
    solves = [c for c in out if c.routine.endswith("trsm")
              and c.m == 512]
    assert len(solves) == 4
    # buffers and span events are preserved
    assert out.buffer_sizes == trace.buffer_sizes
    assert out.event_count("solver_begin") == 2
    # the re-tiled stream stays span-tagged
    assert all(c.solver == "gesv" for c in out if c.solver_id)


def test_retile_leaves_spanfree_traces_alone():
    t = Trace()
    a = t.new_buffer(64 * 64 * 4, "A")
    t.gemm("s", 64, 64, 64, a, a, a)
    assert at.retile_lapack(t, 128) is t


def test_autotune_sweeps_nb_only_on_solver_traces():
    res = at.autotune(_solver_trace(), policies=("dfu",),
                      device_counts=(1,), device_bytes=None)
    assert {p.lapack_nb for p in res.points} == {0, 64, 128, 256}
    assert "nb" in at.format_grid(res).splitlines()[0]
    plain = Trace()
    a = plain.new_buffer(512 * 512 * 4, "A")
    for _ in range(4):
        plain.gemm("s", 512, 512, 512, a, a, a)
    res_off = at.autotune(plain, policies=("dfu",), device_counts=(1,),
                          device_bytes=None)
    assert all(p.lapack_nb == 0 for p in res_off.points)


def test_autotune_nb_point_env_and_config():
    res = at.autotune(_solver_trace(), policies=("dfu",),
                      device_counts=(1,), device_bytes=None,
                      lapack_nbs=(0, 128))
    p = next(p for p in res.points if p.lapack_nb == 128)
    assert p.env().get("SCILIB_LAPACK") == "1"
    assert p.env().get("SCILIB_LAPACK_NB") == "128"
    cfg = p.to_config()
    assert cfg.lapack is True and cfg.lapack_nb == 128
    base = next(p for p in res.points if p.lapack_nb == 0)
    assert "SCILIB_LAPACK" not in base.env()


# --------------------------------------------------------------------- #
# config plumbing                                                        #
# --------------------------------------------------------------------- #
def test_lapack_env_fields(monkeypatch):
    monkeypatch.setenv("SCILIB_LAPACK", "1")
    monkeypatch.setenv("SCILIB_LAPACK_NB", "96")
    cfg = OffloadConfig.from_env()
    assert cfg.lapack is True and cfg.lapack_nb == 96
    monkeypatch.delenv("SCILIB_LAPACK")
    monkeypatch.delenv("SCILIB_LAPACK_NB")
    cfg = OffloadConfig.from_env()
    assert cfg.lapack is False and cfg.lapack_nb == 0
    with pytest.raises(ValueError):
        OffloadConfig(lapack_nb=-1)

"""The `pallas` dispatch venue end to end: default-off bit-identity
(golden counters, venue-free trace dumps), forced kernel-path venue
tagging, the 3-venue adaptive probe/lock, sharded tiles and fault
injection through the venue, simulator replay of kernel_calls, and the
autotune grid's kernel dimension."""
import dataclasses
import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import blas, callsite  # noqa: E402
from repro.core import runtime as rtm  # noqa: E402
from repro.core.config import OffloadConfig  # noqa: E402
from repro.core.policy import host_array  # noqa: E402
from repro.core.trace import Trace  # noqa: E402
from repro.memtier.simulator import replay_trace  # noqa: E402
from repro.tools import autotune as at  # noqa: E402

RNG = np.random.default_rng(3)


def _f32(shape):
    return RNG.standard_normal(shape).astype(np.float32)


def _tri(n):
    a = np.tril(RNG.standard_normal((n, n)).astype(np.float32) / n)
    np.fill_diagonal(a, 2.0)
    return a


def _kcfg(**kw):
    kw.setdefault("policy", "dfu")
    kw.setdefault("threshold", 1.0)
    kw.setdefault("kernel_path", True)
    kw.setdefault("sync", True)
    return OffloadConfig(**kw)


def _site(x, y):
    """One stable call site for the adaptive tests."""
    return blas.gemm(x, y)


# --------------------------------------------------------------------- #
# default-off bit-identity                                               #
# --------------------------------------------------------------------- #
def test_kernels_off_golden_counters(monkeypatch):
    """SCILIB_KERNELS=0 (the default) reproduces the PR 6 golden
    counters bit-for-bit — the venue stage must be a true no-op on the
    capped eviction workload."""
    monkeypatch.setenv("SCILIB_KERNELS", "0")
    rng = np.random.default_rng(42)
    rt = rtm.install("dfu", threshold=10, device_bytes=2 * 128 * 128 * 4,
                     record_trace=False)
    try:
        xs = [host_array(rng.standard_normal((128, 128))
                         .astype("float32")) for _ in range(5)]
        for _ in range(3):
            for x in xs:
                blas.gemm(x, x)
        rt.sync()
        assert rt.stats.evictions == 28
        assert rt.stats.evicted_bytes == 1835008
        st = rt.stats.per_routine["sgemm"]
        assert (st.offloaded, st.on_host) == (15, 0)
        assert (st.cache_hits, st.cache_misses) == (15, 15)
        assert st.kernel_calls == 0
        assert "pallas" not in rt.stats.report()
    finally:
        rtm.uninstall()


def test_kernels_off_trace_dump_is_venue_free(tmp_path, monkeypatch):
    """Default-off trace dumps carry no venue keys at all — byte-stable
    against pre-venue readers (and writers)."""
    monkeypatch.setenv("SCILIB_KERNELS", "0")
    path = tmp_path / "t.json"
    rt = rtm.install(config=OffloadConfig(policy="dfu", threshold=1.0,
                                          sync=True))
    try:
        a = host_array(_f32((64, 64)))
        blas.gemm(a, a)
        blas.syrk(a)
        rt.sync()
        assert all(c.venue == "" for c in rt.trace.calls)
        rt.trace.dump(str(path))
    finally:
        rtm.uninstall()
    for call in json.loads(path.read_text())["calls"]:
        assert "venue" not in call
    # and the round-trip restores the empty-venue default
    assert all(c.venue == "" for c in Trace.load(str(path)).calls)


# --------------------------------------------------------------------- #
# forced kernel path: venue tags, counters, numerics                     #
# --------------------------------------------------------------------- #
def test_venue_tags_counters_and_replay_match():
    """A kernel-path run tags every offloaded call with its venue, the
    per-routine kernel counters agree, and the simulator replays the
    same kernel_calls from the recorded trace (live == replay)."""
    rt = rtm.install(config=_kcfg())
    try:
        a = host_array(_f32((96, 96)))
        t = host_array(_tri(96))
        for _ in range(4):
            blas.gemm(a, a)
        blas.syrk(a)
        blas.trsm(t, a)
        rt.sync()
        trace = rt.trace
        assert [c.venue for c in trace.calls] == ["pallas"] * 6
        live = sum(r.kernel_calls for r in rt.stats.per_routine.values())
        assert live == 6
        assert "pallas venue: 6 calls" in rt.stats.report()
    finally:
        rtm.uninstall()
    on = replay_trace(trace, policies=("dfu",), threshold=1.0,
                      kernel_path=True)["dfu"]
    assert on.kernel_calls == live
    off = replay_trace(trace, policies=("dfu",), threshold=1.0)["dfu"]
    assert off.kernel_calls == 0


def test_capability_registry_routes_venues():
    """Routines without a kernel fall back to the generic XLA venue —
    per dtype (complex syrk) and per base (trmm) — and still compute
    the right answer."""
    rt = rtm.install(config=_kcfg())
    try:
        a = _f32((64, 48))
        ca = host_array((a + 1j * _f32((64, 48))).astype(np.complex64))
        out = blas.gemm(ca, ca, trans_b="C")
        rt.sync()
        assert rt.trace.calls[-1].venue == "pallas"  # cgemm: 4M kernel
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ca) @ np.asarray(ca).conj().T,
            rtol=1e-3, atol=1e-3)
        blas.syrk(ca)                                # csyrk: no kernel
        rt.sync()
        assert rt.trace.calls[-1].venue == "xla"
        t = host_array(_tri(64))
        b = host_array(_f32((64, 32)))
        blas.trmm(t, b)                              # trmm: no kernel
        rt.sync()
        assert rt.trace.calls[-1].venue == "xla"
        blas.trsm(t, b)                              # trsm: kernel
        rt.sync()
        assert rt.trace.calls[-1].venue == "pallas"
    finally:
        rtm.uninstall()


def test_generic_epilogue_numerics_on_pallas_venue():
    """alpha/beta/C/transpose epilogues through the kernel venue match
    the BLAS definition (the lean fast path only covers the bare
    alpha=1, beta=0, no-C case)."""
    rt = rtm.install(config=_kcfg())
    try:
        a = host_array(_f32((48, 32)))
        b = host_array(_f32((32, 40)))
        c = host_array(_f32((48, 40)))
        out = blas.gemm(a, b, c, alpha=0.5, beta=2.0)
        want = 0.5 * (np.asarray(a) @ np.asarray(b)) + 2.0 * np.asarray(c)
        np.testing.assert_allclose(np.asarray(out), want,
                                   rtol=1e-4, atol=1e-4)
        out2 = blas.gemm(a, a, alpha=3.0, trans_b="T")
        np.testing.assert_allclose(
            np.asarray(out2), 3.0 * (np.asarray(a) @ np.asarray(a).T),
            rtol=1e-4, atol=1e-4)
        out3 = blas.syrk(a, alpha=2.0, uplo="U")
        np.testing.assert_allclose(
            np.asarray(out3),
            np.triu(2.0 * (np.asarray(a) @ np.asarray(a).T)),
            rtol=1e-4, atol=1e-4)
        rt.sync()
        assert all(cl.venue == "pallas" for cl in rt.trace.calls)
    finally:
        rtm.uninstall()


def test_sharded_tiles_route_through_pallas_venue():
    """Multi-device tile plans execute their per-tile kernels on the
    selected venue: same numerics, venue tag recorded, tiles spread
    over the device tiers."""
    rt = rtm.install(config=_kcfg(devices=4, tile_min=32))
    try:
        a = host_array(_f32((256, 256)))
        b = host_array(_f32((256, 256)))
        out = blas.gemm(a, b)
        rt.sync()
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(a) @ np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
        call = rt.trace.calls[-1]
        assert call.venue == "pallas"
        assert len(set(call.devices)) > 1      # actually sharded
    finally:
        rtm.uninstall()


def test_fault_injection_covers_kernel_venue():
    """The `kernel` fault site wraps the venue's compute units too:
    injected faults retry and the results stay correct."""
    rt = rtm.install(config=_kcfg(faults="kernel:p=0.5,seed=7",
                                  retries=3))
    try:
        a = host_array(_f32((64, 64)))
        outs = [blas.gemm(a, a) for _ in range(8)]
        rt.sync()
        want = np.asarray(a) @ np.asarray(a)
        for o in outs:
            np.testing.assert_allclose(np.asarray(o), want,
                                       rtol=1e-4, atol=1e-4)
        assert rt.stats.faults > 0
        assert rt.stats.retries > 0
        assert sum(r.kernel_calls
                   for r in rt.stats.per_routine.values()) > 0
    finally:
        rtm.uninstall()


# --------------------------------------------------------------------- #
# 3-venue adaptive warmup                                                #
# --------------------------------------------------------------------- #
def test_three_venue_probe_schedule_and_lock():
    """With kernel_path on, the warmup round-robins host/xla/pallas
    (equal samples each), records the probe venue in the trace, and
    locks the best-sample venue with an explanatory why-string."""
    rt = rtm.install(config=_kcfg(adaptive=True, adaptive_warmup=6,
                                  threshold=100.0))
    try:
        a = host_array(_f32((64, 64)))
        for _ in range(6):
            _site(a, a)
        (prof,) = list(rt.callsites)
        assert (prof.host_timed, prof.device_timed,
                prof.kernel_timed) == (2, 2, 2)
        assert prof.locked is None             # warmup not over yet
        assert [c.venue for c in rt.trace.calls] == \
            ["host", "xla", "pallas"] * 2
        _site(a, a)                            # 7th call locks
        assert prof.locked is not None
        assert prof.locked_venue in callsite.VENUES
        assert "probes" in prof.locked_why
        if prof.locked_venue == "pallas":
            assert prof.decision_label() == "pallas*"
            assert prof.locked is True
    finally:
        rtm.uninstall()


def test_lock_prefers_pallas_on_best_sample():
    """Unit rule: the kernel venue wins the lock iff its best probe
    beats both classic venues; untimed venues never win."""
    p = callsite.CallSiteProfile("x")
    p.observe_probe(False, 2e-3)
    p.observe_probe(True, 1e-3, venue="xla")
    p.observe_probe(True, 5e-4, venue="pallas")
    assert p.lock() is True
    assert p.locked_venue == "pallas"
    assert p.decision_label() == "pallas*"
    q = callsite.CallSiteProfile("y")
    q.observe_probe(False, 1e-4)
    q.observe_probe(True, 1e-3, venue="xla")
    q.observe_probe(True, 5e-4, venue="pallas")
    assert q.lock() is False
    assert q.locked_venue == "host"
    r = callsite.CallSiteProfile("z")          # 2-venue mode: no kernel
    r.observe_probe(False, 2e-3)
    r.observe_probe(True, 1e-3, venue="xla")
    assert r.lock() is True
    assert r.locked_venue == "xla"


def test_two_venue_schedule_unchanged_without_kernel_path():
    """probe_venue(2) reproduces the classic host/offload alternation —
    the probe schedule the default pipeline has always used."""
    p = callsite.CallSiteProfile("x")
    seen = []
    for _ in range(4):
        v = p.probe_venue(2)
        seen.append(v)
        assert (v != "host") == p.probe_path()
        p.observe_probe(v != "host", 1e-3,
                        venue=v if v == "pallas" else "")
    assert seen == ["host", "xla", "host", "xla"]


# --------------------------------------------------------------------- #
# autotune kernel dimension                                              #
# --------------------------------------------------------------------- #
def _venue_trace(tagged: bool) -> Trace:
    t = Trace()
    a = t.new_buffer(512 * 512 * 4, "A")
    b = t.new_buffer(512 * 512 * 4, "B")
    c = t.new_buffer(512 * 512 * 4, "C")
    for _ in range(8):
        t.gemm("s", 512, 512, 512, a, b, c)
    if tagged:
        t.calls = [dataclasses.replace(
            call, venue="pallas" if i % 2 else "xla",
            seconds=1e-3 if i % 2 else 2e-3)
            for i, call in enumerate(t.calls)]
    return t


def test_autotune_sweeps_kernel_only_on_venue_traces():
    """The kernel grid dimension is gated on venue tags: a venue-free
    trace has no probe timings to calibrate from, so both settings
    would replay identically and the sweep would only double the grid."""
    res = at.autotune(_venue_trace(True), policies=("dfu",),
                      device_counts=(1,))
    assert any(p.kernel for p in res.points)
    assert any(not p.kernel for p in res.points)
    grid = at.format_grid(res)
    assert "kern" in grid.splitlines()[0]
    res_off = at.autotune(_venue_trace(False), policies=("dfu",),
                          device_counts=(1,))
    assert not any(p.kernel for p in res_off.points)


def test_autotune_kernel_point_env_and_config():
    """A kernel-on grid point deploys as SCILIB_KERNELS=1 and as
    OffloadConfig.kernel_path=True — the tune->deploy loop carries the
    venue choice."""
    res = at.autotune(_venue_trace(True), policies=("dfu",),
                      device_counts=(1,), kernels=(True,))
    p = res.best
    assert p.kernel
    assert p.env().get("SCILIB_KERNELS") == "1"
    assert p.to_config().kernel_path is True
    # the calibrated pallas model (0.5x gemm time) must beat kernel-off
    both = at.autotune(_venue_trace(True), policies=("dfu",),
                       device_counts=(1,), kernels=(False, True))
    assert both.best.kernel

"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.threshold import n_avg, should_offload
from repro.core.trace import Trace
from repro.memtier import GH200, MemTierSimulator, replay_trace
from repro.optim.grad_compress import (_dequantize, _quantize,
                                       compress_decompress,
                                       init_compression)

dims = st.integers(min_value=1, max_value=5000)
# movement comparisons need super-page matrices (a page-granular
# migration of an 8-byte matrix rightly costs more than copying it)
big_dims = st.integers(min_value=128, max_value=5000)


@given(m=dims, n=dims, k=dims)
def test_navg_scale_invariance(m, n, k):
    """N_avg of gemm is the geometric mean: symmetric + monotone."""
    assert n_avg("dgemm", m, n, k) == n_avg("dgemm", n, m, k)
    assert n_avg("dgemm", m, n, k) <= n_avg("dgemm", m + 1, n, k)
    off_lo, _ = should_offload("dgemm", m, n, k, threshold=1e12)
    assert not off_lo  # infinite threshold never offloads


@given(m=dims, n=dims, k=dims, reps=st.integers(1, 10))
@settings(max_examples=25, deadline=None)
def test_dfu_movement_bounded_by_working_set(m, n, k, reps):
    """DFU never moves more than one pass over the distinct buffers."""
    t = Trace()
    a = t.new_buffer(m * k * 8, "A")
    b = t.new_buffer(k * n * 8, "B")
    c = t.new_buffer(m * n * 8, "C")
    for _ in range(reps):
        t.gemm("d", m, n, k, a, b, c)
    sim = MemTierSimulator(GH200, policy="dfu", threshold=0)
    rep = sim.run(t)
    working = sum(t.buffer_sizes.values())
    assert rep.bytes_host_to_dev <= working * 1.01 + 3 * GH200.page_size


@given(m=big_dims, n=big_dims, k=big_dims, reps=st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_memcopy_movement_scales_with_calls(m, n, k, reps):
    """Mem-Copy movement is linear in calls; DFU's is not."""
    def run(policy):
        t = Trace()
        a = t.new_buffer(m * k * 8, "A")
        b = t.new_buffer(k * n * 8, "B")
        c = t.new_buffer(m * n * 8, "C")
        for _ in range(reps):
            t.gemm("d", m, n, k, a, b, c)
        return MemTierSimulator(GH200, policy=policy, threshold=0).run(t)

    mc, dfu = run("memcopy"), run("dfu")
    # memcopy counts exact operand bytes; DFU migrates page-rounded
    tol = reps * 3 * GH200.page_size
    assert abs(mc.bytes_host_to_dev - reps * dfu.bytes_host_to_dev) <= tol
    # (total-time ordering is shape-dependent at small sizes — the very
    # reason the offload threshold exists — and is asserted at realistic
    # scale in test_memtier.test_policy_ordering_reuse_heavy)


@given(st.lists(st.floats(min_value=-100, max_value=100,
                          allow_nan=False, width=32),
                min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_int8_quantize_error_bound(vals):
    x = jnp.asarray(np.array(vals, np.float32))
    q, s = _quantize(x)
    err = np.max(np.abs(np.asarray(_dequantize(q, s)) - np.asarray(x)))
    assert err <= float(s) * 0.5 + 1e-6   # half-ULP of the int8 grid


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_error_feedback_conserves_mass(seed):
    """grads_out + residual_new == grads_in + residual_old exactly."""
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)}
    state = init_compression(g)
    out, new_state = compress_decompress(g, state)
    lhs = np.asarray(out["w"]) + np.asarray(new_state.residual["w"])
    rhs = np.asarray(g["w"])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)


@given(step=st.integers(0, 10_000), shard=st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_pipeline_pure_function_of_step(step, shard):
    from repro.data import DataConfig, TokenPipeline
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=8)
    p = TokenPipeline(cfg, num_shards=4)
    b1 = p.batch(step, shard)
    b2 = p.batch(step, shard)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert int(b1["tokens"].max()) < 100

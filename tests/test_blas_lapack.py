"""BLAS surface semantics + LAPACK drivers vs numpy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blas, lapack

RNG = np.random.default_rng(1)


def test_gemm_alpha_beta_trans():
    a = jnp.asarray(RNG.standard_normal((64, 48)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((64, 32)), jnp.float32)
    c = jnp.asarray(RNG.standard_normal((48, 32)), jnp.float32)
    out = blas.gemm(a, b, c, alpha=2.0, beta=0.5, trans_a="T")
    want = 2.0 * np.asarray(a).T @ np.asarray(b) + 0.5 * np.asarray(c)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_gemm_batched():
    a = jnp.asarray(RNG.standard_normal((3, 32, 16)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((3, 16, 24)), jnp.float32)
    out = blas.gemm(a, b)
    np.testing.assert_allclose(out, np.asarray(a) @ np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_symm_references_one_triangle():
    a_full = RNG.standard_normal((32, 32)).astype(np.float32)
    a_garbage_upper = np.tril(a_full) + np.triu(
        RNG.standard_normal((32, 32)).astype(np.float32), 1)
    b = jnp.asarray(RNG.standard_normal((32, 16)), jnp.float32)
    out = blas.symm(jnp.asarray(a_garbage_upper), b, uplo="L")
    sym = np.tril(a_garbage_upper) + np.tril(a_garbage_upper, -1).T
    np.testing.assert_allclose(out, sym @ np.asarray(b), rtol=1e-4,
                               atol=1e-4)


def test_syrk_beta_triangle_semantics():
    a = jnp.asarray(RNG.standard_normal((24, 48)), jnp.float32)
    c = jnp.asarray(RNG.standard_normal((24, 24)), jnp.float32)
    out = blas.syrk(a, c, uplo="L", alpha=1.0, beta=2.0)
    want_l = np.tril(np.asarray(a) @ np.asarray(a).T
                     + 2.0 * np.asarray(c))
    np.testing.assert_allclose(np.tril(np.asarray(out)), want_l,
                               rtol=1e-4, atol=1e-4)
    # upper triangle must be untouched C values
    np.testing.assert_allclose(np.triu(np.asarray(out), 1),
                               np.triu(np.asarray(c), 1), rtol=1e-6)


def test_her2k_hermitian():
    a = jnp.asarray((RNG.standard_normal((16, 24))
                     + 1j * RNG.standard_normal((16, 24))), jnp.complex64)
    b = jnp.asarray((RNG.standard_normal((16, 24))
                     + 1j * RNG.standard_normal((16, 24))), jnp.complex64)
    out = blas.her2k(a, b, uplo="L", alpha=1.0)
    full = np.asarray(a) @ np.asarray(b).conj().T \
        + np.asarray(b) @ np.asarray(a).conj().T
    np.testing.assert_allclose(np.tril(np.asarray(out)), np.tril(full),
                               rtol=1e-4, atol=1e-4)


def test_trmm_trsm_roundtrip():
    lt = np.tril(RNG.standard_normal((48, 48)).astype(np.float32) / 48)
    np.fill_diagonal(lt, 1.5)
    b = jnp.asarray(RNG.standard_normal((48, 20)), jnp.float32)
    prod = blas.trmm(jnp.asarray(lt), b, side="L", uplo="L")
    back = blas.trsm(jnp.asarray(lt), prod, side="L", uplo="L")
    np.testing.assert_allclose(back, b, rtol=1e-3, atol=1e-3)


@pytest.fixture(scope="module", autouse=True)
def x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def test_getrf_gesv_f64():
    n = 200
    a = RNG.standard_normal((n, n)) + np.eye(n) * 3
    b = RNG.standard_normal((n, 5))
    x = lapack.gesv(jnp.asarray(a), jnp.asarray(b), nb=64)
    np.testing.assert_allclose(x, np.linalg.solve(a, b), rtol=1e-8,
                               atol=1e-8)


def test_gesv_complex():
    n = 150
    a = (RNG.standard_normal((n, n))
         + 1j * RNG.standard_normal((n, n))) + np.eye(n) * 4
    b = RNG.standard_normal((n, 3)) + 1j * RNG.standard_normal((n, 3))
    x = lapack.gesv(jnp.asarray(a), jnp.asarray(b), nb=48)
    np.testing.assert_allclose(x, np.linalg.solve(a, b), rtol=1e-8,
                               atol=1e-8)


def test_potrf():
    n = 160
    a = RNG.standard_normal((n, n))
    s = a @ a.T + n * np.eye(n)
    l = lapack.potrf(jnp.asarray(s), nb=64)
    np.testing.assert_allclose(l, np.linalg.cholesky(s), rtol=1e-8,
                               atol=1e-8)


def test_getrf_pivoting_hard_case():
    # leading zeros force pivoting
    a = np.array([[0.0, 1.0, 2.0], [1.0, 0.0, 1.0], [2.0, 3.0, 0.0]])
    b = np.array([1.0, 2.0, 3.0])
    x = lapack.gesv(jnp.asarray(a), jnp.asarray(b), nb=2)
    np.testing.assert_allclose(x, np.linalg.solve(a, b), rtol=1e-10)

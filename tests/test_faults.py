"""Fault tolerance: spec parsing, exception classification, the
deterministic injector, retry/backoff, the per-device circuit breaker
state machine, host fallback bit-identity, quarantine -> re-shard ->
recover on a multi-device layout, live == replay fault counters, and
the exception-safe sync() drain."""
import contextlib
import os
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import blas, memspace  # noqa: E402
from repro.core import faults as flt  # noqa: E402
from repro.core import runtime as rtm  # noqa: E402
from repro.core.config import OffloadConfig  # noqa: E402
from repro.core.policy import host_array  # noqa: E402
from repro.core.session import Session  # noqa: E402
from repro.memtier.simulator import MemTierSimulator  # noqa: E402

RNG = np.random.default_rng(23)


def _mat(n, m=None):
    return RNG.standard_normal((n if m is None else m, n)).astype(
        np.float32)


@contextlib.contextmanager
def _devices(n):
    old = os.environ.get("SCILIB_DEVICES")
    os.environ["SCILIB_DEVICES"] = str(n)
    memspace.install()
    try:
        yield
    finally:
        if old is None:
            os.environ.pop("SCILIB_DEVICES", None)
        else:
            os.environ["SCILIB_DEVICES"] = old
        memspace.install()


# --------------------------------------------------------------------- #
# spec grammar                                                           #
# --------------------------------------------------------------------- #
def test_parse_spec_full_grammar():
    rules = flt.parse_spec("transfer:p=0.05,device=1,seed=7;kernel:nth=13")
    assert rules == (
        flt.FaultRule(kind="transfer", p=0.05, device=1, seed=7),
        flt.FaultRule(kind="kernel", nth=13))


def test_parse_spec_empty_is_no_rules():
    assert flt.parse_spec("") == ()
    assert flt.parse_spec("  ") == ()
    assert flt.FaultInjector.from_spec("") is None


@pytest.mark.parametrize("bad", [
    "bogus:p=1",           # unknown fault kind
    "transfer:q=1",        # unknown parameter
    "transfer:p=1.5",      # probability out of range
    "transfer:nth=0",      # nth counts from 1
    "transfer:device=-1",  # negative device index
    "transfer",            # non-latency rule with no trigger
    "kernel:p=x",          # unparseable value
])
def test_parse_spec_rejects(bad):
    with pytest.raises(ValueError):
        flt.parse_spec(bad)


def test_config_validates_fault_knobs():
    with pytest.raises(ValueError):
        OffloadConfig(faults="bogus:p=1")
    with pytest.raises(ValueError):
        OffloadConfig(retries=-1)
    with pytest.raises(ValueError):
        OffloadConfig(backoff_ms=-0.5)
    cfg = OffloadConfig(faults="transfer:p=0.5,seed=3", retries=4)
    assert cfg.retries == 4


# --------------------------------------------------------------------- #
# exception classification                                               #
# --------------------------------------------------------------------- #
def test_classify_maps_absorbable_errors():
    oom = flt.classify("transfer", MemoryError("boom"), device=1,
                       nbytes=64)
    assert isinstance(oom, flt.DeviceOOMError) and not oom.transient
    assert oom.device == 1 and oom.nbytes == 64
    oom2 = flt.classify("kernel", RuntimeError("RESOURCE_EXHAUSTED: hbm"))
    assert isinstance(oom2, flt.DeviceOOMError)
    tr = flt.classify("transfer", OSError("link reset"))
    assert isinstance(tr, flt.TransferError) and tr.transient
    kr = flt.classify("kernel", RuntimeError("launch failed"))
    assert isinstance(kr, flt.KernelError) and kr.transient


def test_classify_leaves_bugs_alone():
    # bugs in our own stack must keep their type and traceback
    assert flt.classify("kernel", TypeError("bad arg")) is None
    assert flt.classify("transfer", ValueError("shape")) is None
    # already-typed errors pass through unchanged
    e = flt.TransferError("x", device=2)
    assert flt.classify("transfer", e) is e


# --------------------------------------------------------------------- #
# the injector                                                           #
# --------------------------------------------------------------------- #
def _injected_pattern(spec, n=200, site="transfer", device=None):
    inj = flt.FaultInjector.from_spec(spec)
    out = []
    for _ in range(n):
        try:
            inj.check(site, device=device, nbytes=8)
            out.append(0)
        except flt.OffloadError:
            out.append(1)
    return out


def test_injector_is_deterministic():
    a = _injected_pattern("transfer:p=0.2,seed=11")
    b = _injected_pattern("transfer:p=0.2,seed=11")
    assert a == b and sum(a) > 0
    c = _injected_pattern("transfer:p=0.2,seed=12")
    assert a != c


def test_injector_nth_fires_periodically():
    hits = _injected_pattern("transfer:nth=5", n=20)
    assert hits == [0, 0, 0, 0, 1] * 4


def test_injector_device_filter():
    # device-filtered rule fires only on its device, never on device=None
    assert sum(_injected_pattern("transfer:p=1,device=1", device=0)) == 0
    assert sum(_injected_pattern("transfer:p=1,device=1", device=None)) == 0
    assert sum(_injected_pattern("transfer:p=1,device=1", device=1,
                                 n=5)) == 5


def test_injector_site_and_kind_mapping():
    with pytest.raises(flt.DeviceOOMError):
        flt.FaultInjector.from_spec("oom:p=1").check("transfer")
    with pytest.raises(flt.KernelError):
        flt.FaultInjector.from_spec("kernel:p=1").check("kernel")
    # kernel rules never fire at transfer sites and vice versa
    inj = flt.FaultInjector.from_spec("kernel:p=1")
    inj.check("transfer")
    inj = flt.FaultInjector.from_spec("transfer:p=1")
    inj.check("kernel")
    # latency injects a stall, not an error
    t0 = time.perf_counter()
    flt.FaultInjector.from_spec("latency:p=1,ms=5").check("transfer")
    assert time.perf_counter() - t0 >= 0.004


# --------------------------------------------------------------------- #
# retry policy + breaker state machine                                   #
# --------------------------------------------------------------------- #
def test_retry_backoff_is_exponential():
    rp = flt.RetryPolicy(attempts=3, backoff_ms=8.0)
    assert [rp.delay_s(a) for a in range(3)] == [0.008, 0.016, 0.032]


def test_breaker_state_machine_with_fake_clock():
    now = [0.0]
    events = []
    ht = flt.HealthTracker(
        2, threshold=3, cooldown_ms=100.0, clock=lambda: now[0],
        on_quarantine=lambda d: events.append(("q", d)),
        on_recover=lambda d: events.append(("r", d)))
    # two failures then a success: consecutive count resets, no trip
    assert not ht.failure(1) and not ht.failure(1)
    ht.ok(1)
    assert ht.device(1).state == flt.CLOSED
    # three consecutive failures trip the breaker
    assert [ht.failure(1) for _ in range(3)] == [False, False, True]
    assert ht.device(1).state == flt.OPEN
    assert not ht.usable(1) and ht.usable(0)
    assert ht.usable_count() == 1 and ht.usable_devices() == [0]
    assert events == [("q", 1)]
    # cooldown elapses -> half-open probe allowed
    now[0] = 0.2
    assert ht.usable(1) and ht.device(1).state == flt.HALF_OPEN
    # a failed probe re-opens immediately (no threshold accumulation)
    assert ht.failure(1)
    assert ht.device(1).state == flt.OPEN and events[-1] == ("q", 1)
    # next probe succeeds -> closed again, recover hook fires
    now[0] = 0.4
    assert ht.usable(1)
    ht.ok(1)
    assert ht.device(1).state == flt.CLOSED and events[-1] == ("r", 1)
    assert ht.usable_count() == 2


def test_breaker_disabled_never_trips():
    ht = flt.HealthTracker(1, threshold=0)
    for _ in range(50):
        ht.failure(0)
    assert ht.usable(0) and ht.device(0).quarantines == 0


# --------------------------------------------------------------------- #
# runtime integration                                                    #
# --------------------------------------------------------------------- #
def _workload(n_calls=6, n=96, seed=5):
    rng = np.random.default_rng(seed)
    mats = [(host_array(rng.standard_normal((n, n)).astype(np.float32)),
             host_array(rng.standard_normal((n, n)).astype(np.float32)))
            for _ in range(n_calls)]
    outs = [np.asarray(blas.gemm(a, b)) for a, b in mats]
    refs = [np.asarray(jnp.asarray(np.asarray(a))
                       @ jnp.asarray(np.asarray(b))) for a, b in mats]
    return outs, refs


def _run(cfg):
    with Session(cfg, record_trace=True, intercept=False) as s:
        outs, refs = _workload()
        st = s.runtime.stats
        sg = st.per_routine["sgemm"]
        snap = dict(faults=st.faults, retries=st.retries,
                    fallbacks=st.fallbacks, bytes_in=sg.bytes_in,
                    cache_hits=sg.cache_hits, offloaded=sg.offloaded,
                    on_host=sg.on_host)
        trace = s.runtime.trace
    return outs, refs, snap, trace


def test_retry_absorbs_transient_faults_exactly():
    """A retried fault is a perfect no-op: every byte/hit/offload
    counter matches the unfaulted run, and results are bit-identical."""
    base = dict(policy="dfu", threshold=10.0)
    o0, r0, clean, _ = _run(OffloadConfig(**base))
    o1, _, chaotic, _ = _run(OffloadConfig(
        **base, faults="transfer:nth=2", retries=2, backoff_ms=0.0))
    assert chaotic["faults"] > 0
    assert chaotic["retries"] == chaotic["faults"]
    assert chaotic["fallbacks"] == 0
    for key in ("bytes_in", "cache_hits", "offloaded", "on_host"):
        assert chaotic[key] == clean[key], key
    for a, b in zip(o0, o1):
        assert np.array_equal(a, b)


def test_kernel_fault_exhaustion_falls_back_bit_identically():
    outs, refs, snap, _ = _run(OffloadConfig(
        policy="dfu", threshold=10.0, faults="kernel:p=1,seed=5",
        retries=0, breaker=0))
    assert snap["fallbacks"] == 6 and snap["on_host"] == 6
    assert snap["offloaded"] == 0
    for got, ref in zip(outs, refs):
        assert np.array_equal(got, ref)     # same jit on same values


def test_oom_is_permanent_no_retries():
    _, _, snap, _ = _run(OffloadConfig(
        policy="dfu", threshold=10.0, faults="oom:p=1", retries=3,
        breaker=0, backoff_ms=0.0))
    assert snap["faults"] > 0 and snap["retries"] == 0
    assert snap["fallbacks"] == 6


def test_real_bugs_still_propagate():
    """classify() must not absorb caller errors into fallbacks."""
    with Session(OffloadConfig(policy="dfu", threshold=10.0, retries=3),
                 record_trace=False, intercept=False):
        with pytest.raises((TypeError, ValueError)):
            blas.gemm(host_array(_mat(8)), host_array(_mat(16)))


def test_degraded_mode_serves_from_host():
    """Breaker tripped on every device -> host-only degraded mode keeps
    serving with correct results (no exception escapes)."""
    cfg = OffloadConfig(policy="dfu", threshold=10.0,
                        faults="transfer:p=1,seed=2", retries=0,
                        breaker=2, breaker_cooldown_ms=60_000.0)
    with Session(cfg, record_trace=False, intercept=False) as s:
        outs, refs = _workload()
        st = s.runtime.stats
        assert st.quarantines == 1
        assert st.fallbacks == 6
        assert not s.runtime.health.any_usable()
    for got, ref in zip(outs, refs):
        assert np.array_equal(got, ref)


def test_report_shows_health_only_under_faults():
    with Session(OffloadConfig(policy="dfu", threshold=10.0),
                 record_trace=False, intercept=False) as s:
        _workload(n_calls=1)
        assert "health:" not in s.runtime.stats.report()
    with Session(OffloadConfig(policy="dfu", threshold=10.0,
                               faults="kernel:nth=1", retries=1,
                               backoff_ms=0.0),
                 record_trace=False, intercept=False) as s:
        _workload(n_calls=1)
        rep = s.runtime.stats.report()
        assert "health:" in rep and "dev0:" in rep


# --------------------------------------------------------------------- #
# quarantine -> re-shard -> recover (multi-device)                       #
# --------------------------------------------------------------------- #
def test_quarantine_reshard_recover():
    with _devices(3):
        cfg = OffloadConfig(policy="dfu", threshold=10.0, devices=3,
                            faults="transfer:p=1,device=1,seed=1",
                            retries=0, breaker=2,
                            breaker_cooldown_ms=50.0)
        with Session(cfg, record_trace=False, intercept=False) as s:
            rt = s.runtime
            refs, outs = [], []

            def call():
                a, b = _mat(384), _mat(384)
                refs.append(a @ b)
                outs.append(np.asarray(
                    blas.gemm(host_array(a), host_array(b))))

            # two sharded calls hit dev1 tiles -> 2 consecutive unit
            # failures -> quarantine (each call itself falls back)
            call()
            call()
            assert rt.stats.quarantines == 1
            assert rt.stats.fallbacks == 2
            assert not rt.health.usable(1)
            assert rt.block_stores[1].resident_bytes == 0  # invalidated
            # next call re-shards across the healthy pair
            call()
            assert rt.stats.per_routine["sgemm"].sharded >= 1
            assert rt.stats.fallbacks == 2                 # no new ones
            assert rt.stats.per_device[1].tiles == 0       # dev1 idle
            # clear the injector, wait out the cooldown: the half-open
            # probe succeeds and dev1 rejoins the fleet
            s.reconfigure(faults="")
            time.sleep(0.06)
            call()
            assert rt.health.usable(1)
            assert rt.stats.recoveries == 1
            assert rt.health.device(1).state == flt.CLOSED
            for got, ref in zip(outs, refs):
                np.testing.assert_allclose(got, ref, rtol=2e-3,
                                           atol=2e-3)


# --------------------------------------------------------------------- #
# live == replay                                                         #
# --------------------------------------------------------------------- #
def test_faulted_live_run_matches_replay_counters():
    # kernel faults get absorbed by the retry; oom faults are permanent
    # and fall back — the trace must carry both accurately
    cfg = OffloadConfig(policy="dfu", threshold=10.0,
                        faults="kernel:nth=3;oom:nth=5", retries=1,
                        backoff_ms=0.0, breaker=0)
    with Session(cfg, record_trace=True, intercept=False) as s:
        _workload(n_calls=8)
        st = s.runtime.stats
        trace = s.runtime.trace
        live = (st.faults, st.retries, st.fallbacks, st.quarantines,
                st.recoveries)
    assert st.retries > 0 and st.fallbacks > 0      # both paths exercised
    rep = MemTierSimulator.from_config(cfg).run(trace)
    assert (rep.faults, rep.retries, rep.fallbacks, rep.quarantines,
            rep.recoveries) == live
    # the forced-host set really moved calls off the device path
    assert rep.host_calls >= st.fallbacks


def test_fault_events_roundtrip_through_dump(tmp_path):
    from repro.core.trace import Trace
    path = str(tmp_path / "t.json")
    cfg = OffloadConfig(policy="dfu", threshold=10.0,
                        faults="kernel:nth=2", retries=0, breaker=0,
                        trace_path=path)
    with Session(cfg, record_trace=True, intercept=False):
        _workload(n_calls=4)
    loaded = Trace.load(path)
    assert loaded.event_count("fault") > 0
    assert loaded.event_count("fallback") == loaded.event_count("fault")
    rep = MemTierSimulator.from_config(cfg).run(loaded)
    assert rep.fallbacks == loaded.event_count("fallback")


def test_trace_dump_is_atomic(tmp_path):
    """A dump that cannot serialize leaves no partial file behind."""
    from repro.core.trace import Trace
    t = Trace()
    t.gemm("s", 8, 8, 8, t.new_buffer(256), t.new_buffer(256),
           t.new_buffer(256))
    target = tmp_path / "out.json"
    t.dump(str(target))
    good = target.read_bytes()
    t.calls.append(object())           # unserializable: dump must fail
    with pytest.raises(Exception):
        t.dump(str(target))
    assert target.read_bytes() == good          # old file intact
    assert list(tmp_path.iterdir()) == [target]  # no tmp litter


# --------------------------------------------------------------------- #
# exception-safe sync()                                                  #
# --------------------------------------------------------------------- #
def test_sync_drains_everything_and_reraises_first():
    class _Buf:
        def __init__(self, log, fail=None):
            self.log, self.fail = log, fail

        def block_until_ready(self):
            self.log.append(self)
            if self.fail is not None:
                raise self.fail

    with Session(OffloadConfig(policy="dfu"), record_trace=False,
                 intercept=False) as s:
        rt = s.runtime
        log = []
        first = RuntimeError("first failure")
        bufs = [_Buf(log), _Buf(log, first), _Buf(log),
                _Buf(log, RuntimeError("second failure")), _Buf(log)]
        rt._pending.extend(bufs)
        with pytest.raises(RuntimeError) as exc_info:
            rt.sync()
        assert exc_info.value is first
        assert log == bufs                  # every buffer was awaited
        assert not rt._pending              # queue fully drained
        if hasattr(first, "__notes__"):     # py3.11+
            assert any("second failure" in n for n in first.__notes__)
        rt._pending.append(_Buf(log))
        rt.sync()                           # clean sync still works


# --------------------------------------------------------------------- #
# property: any fault spec leaves results bit-identical                  #
# --------------------------------------------------------------------- #
def _check_bit_identity(spec, retries):
    """The robustness contract: under ANY injected fault pattern the
    numerical results equal the unfaulted host-path run bit for bit."""
    a = RNG.standard_normal((64, 64)).astype(np.float32)
    b = RNG.standard_normal((64, 64)).astype(np.float32)
    with Session(OffloadConfig(policy="cpu"), record_trace=False,
                 intercept=False):
        want = np.asarray(blas.gemm(host_array(a), host_array(b)))
    cfg = OffloadConfig(policy="dfu", threshold=10.0, faults=spec,
                        retries=retries, backoff_ms=0.0, breaker=2,
                        breaker_cooldown_ms=60_000.0)
    with Session(cfg, record_trace=False, intercept=False):
        got = np.asarray(blas.gemm(host_array(a), host_array(b)))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("spec,retries", [
    ("", 2),
    ("transfer:p=1,seed=0", 0),
    ("transfer:nth=1", 2),
    ("kernel:p=1,seed=9", 1),
    ("oom:p=1", 3),
    ("latency:p=1,ms=1", 0),
    ("transfer:p=0.62,seed=4;kernel:nth=2", 1),
])
def test_fault_specs_are_bit_identical_to_host(spec, retries):
    _check_bit_identity(spec, retries)


try:                                    # hypothesis widens the sweep
    from hypothesis import given, settings
    from hypothesis import strategies as st_
except ImportError:                     # pragma: no cover — CI has it
    given = None

if given is not None:
    _SPECS = st_.one_of(
        st_.just(""),
        st_.builds(lambda k, p, s: f"{k}:p={p:.2f},seed={s}",
                   st_.sampled_from(["transfer", "kernel", "oom"]),
                   st_.floats(0.0, 1.0), st_.integers(0, 99)),
        st_.builds(lambda k, n: f"{k}:nth={n}",
                   st_.sampled_from(["transfer", "kernel"]),
                   st_.integers(1, 5)),
        st_.builds(
            lambda p, s, n: f"transfer:p={p:.2f},seed={s};kernel:nth={n}",
            st_.floats(0.0, 1.0), st_.integers(0, 99),
            st_.integers(1, 5)),
    )

    @settings(max_examples=12, deadline=None)
    @given(spec=_SPECS, retries=st_.integers(0, 2))
    def test_any_fault_spec_is_bit_identical_to_host(spec, retries):
        _check_bit_identity(spec, retries)

"""Elastic fault tolerance: a checkpoint written under one mesh restores
onto a different device count/topology (subprocess with fake devices)."""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointStore
from repro.launch import shardings as shd
from repro.launch.mesh import make_debug_mesh
from repro.models.registry import Model, get_config

cfg = get_config("qwen1_5_4b").reduced()
model = Model.from_config(cfg)
params = model.init(jax.random.PRNGKey(0))

with tempfile.TemporaryDirectory() as d:
    # save under an 8-device (4x2) mesh
    mesh8 = make_debug_mesh(8, model=2)
    sh8 = shd.param_shardings(params, mesh8, cfg)
    p8 = jax.device_put(params, sh8)
    store = CheckpointStore(d)
    store.save(1, p8, blocking=True)

    # restore onto a DIFFERENT mesh: 4 devices (2x2)
    import numpy as _np
    devs = _np.array(jax.devices()[:4]).reshape(2, 2)
    from jax.sharding import Mesh
    mesh4 = Mesh(devs, ("data", "model"))
    sh4 = shd.param_shardings(params, mesh4, cfg)
    restored, manifest = store.restore(params, shardings=sh4)

    ok = True
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        if not np.allclose(np.asarray(a), np.asarray(b)):
            ok = False
    # and the restored leaves actually live on the new mesh
    lead = jax.tree.leaves(restored)[0]
    on_new = lead.sharding.mesh.devices.size == 4
print(json.dumps({"ok": ok, "on_new_mesh": bool(on_new),
                  "step": manifest["step"]}))
"""


def test_elastic_reshard_restore():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["on_new_mesh"] and rec["step"] == 1

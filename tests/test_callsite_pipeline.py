"""Per-call-site dispatch pipeline: fingerprints, adaptive lock-in,
trace round-trip with the new fields, tensordot interception, the
SCILIB_TRACE dump knob, and the trace-replay autotuner."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import blas, callsite
from repro.core import runtime as rtm
from repro.core import threshold as thr
from repro.core.policy import host_array
from repro.core.trace import BlasCall, Trace

RNG = np.random.default_rng(11)

MINI_TRACE = os.path.join(os.path.dirname(__file__), "data",
                          "mini_trace.json")


def _f32(shape):
    return RNG.standard_normal(shape).astype("float32")


def _gemm_site_a(a, b):
    return blas.gemm(a, b)


def _gemm_site_b(a, b):
    return blas.gemm(a, b)


# --------------------------------------------------------------------- #
# call-site fingerprints                                                 #
# --------------------------------------------------------------------- #
def test_fingerprint_distinguishes_call_sites():
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(_f32((64, 64)))
        for _ in range(3):
            _gemm_site_a(a, a)
        _gemm_site_b(a, a)
    sites = {p.site: p for p in rt.callsites}
    assert len(sites) == 2
    (sa,) = [p for s, p in sites.items() if "_gemm_site_a" in s]
    (sb,) = [p for s, p in sites.items() if "_gemm_site_b" in s]
    assert sa.calls == 3 and sb.calls == 1
    # entry point (routine) prefixes the id; machinery frames are skipped
    assert sa.site.startswith("sgemm@")
    assert "blas.py" not in sa.site and "runtime.py" not in sa.site


def test_site_profile_distribution_and_hits():
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(_f32((256, 256)))
        for _ in range(4):
            _gemm_site_a(a, a)       # DFU: first call moves, rest hit
    (prof,) = [p for p in rt.callsites if "_gemm_site_a" in p.site]
    assert prof.calls == 4
    assert prof.offloaded == 4
    assert prof.n_avg_min == pytest.approx(256.0)
    assert prof.n_avg_max == pytest.approx(256.0)
    assert prof.lookups == 8          # 2 operands x 4 calls
    assert prof.hits == 7             # all but the first A(=B) placement
    assert 0.8 < prof.hit_rate <= 1.0
    assert prof.flops == pytest.approx(4 * 2.0 * 256 ** 3)


def test_report_contains_callsite_table():
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(_f32((128, 128)))
        _gemm_site_a(a, a)
    rep = rt.stats.report()
    assert "call sites" in rep
    # long ids truncate in the table; the file prefix must survive
    assert "sgemm@test_callsite_pipeline.py" in rep


def test_callsite_disable_env(monkeypatch):
    monkeypatch.setenv("SCILIB_CALLSITE", "0")
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(_f32((128, 128)))
        _gemm_site_a(a, a)
    assert len(rt.callsites) == 0
    assert rt.trace.calls[-1].callsite_id == ""


# --------------------------------------------------------------------- #
# pipeline equivalence with SCILIB_ADAPTIVE=0 (the default)              #
# --------------------------------------------------------------------- #
def test_pipeline_decisions_match_threshold_rule():
    """The staged pipeline must reproduce the flat dispatch exactly:
    same decisions, same dispatch counters."""
    with core.offload("dfu", threshold=200) as rt:
        small = host_array(_f32((64, 64)))
        big = host_array(_f32((300, 300)))
        for _ in range(3):
            _gemm_site_a(small, small)     # n_avg 64  < 200 -> host
        for _ in range(3):
            _gemm_site_b(big, big)         # n_avg 300 > 200 -> offload
    st = rt.stats.per_routine["sgemm"]
    assert st.calls == 6
    assert st.on_host == 3 and st.offloaded == 3
    assert st.dispatch_misses == 2         # one derivation per shape
    assert st.dispatch_hits == 4


# --------------------------------------------------------------------- #
# adaptive per-site mode                                                 #
# --------------------------------------------------------------------- #
def test_adaptive_probe_schedule_deterministic(monkeypatch):
    """Warmup alternates host/offload deterministically and locks after
    exactly SCILIB_ADAPTIVE_WARMUP probes — run twice, same schedule."""
    monkeypatch.setenv("SCILIB_ADAPTIVE", "1")
    monkeypatch.setenv("SCILIB_ADAPTIVE_WARMUP", "4")
    monkeypatch.setenv("SCILIB_SYNC", "1")
    # this test documents the classic 2-venue schedule; pin the kernel
    # path off so the CI kernel-path job (SCILIB_KERNELS=1) can't turn
    # the warmup into the 3-venue rotation
    monkeypatch.setenv("SCILIB_KERNELS", "0")
    counts = []
    for _ in range(2):
        with core.offload("dfu", threshold=100) as rt:
            a = host_array(_f32((64, 64)))
            for _ in range(4):
                _gemm_site_a(a, a)
            (prof,) = list(rt.callsites)
            counts.append((prof.host_timed, prof.device_timed,
                           prof.locked))
            st = rt.stats.per_routine["sgemm"]
            assert (st.on_host, st.offloaded) == (2, 2)
            assert st.dispatch_misses == 4     # every probe derives
    assert counts[0][:2] == counts[1][:2] == (2, 2)
    assert counts[0][2] is None                # not locked mid-warmup


def test_adaptive_locks_faster_path_and_stays(monkeypatch):
    monkeypatch.setenv("SCILIB_ADAPTIVE", "1")
    monkeypatch.setenv("SCILIB_ADAPTIVE_WARMUP", "2")
    monkeypatch.setenv("SCILIB_SYNC", "1")
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(_f32((64, 64)))
        _gemm_site_a(a, a)                     # probe host
        _gemm_site_a(a, a)                     # probe offload
        (prof,) = list(rt.callsites)
        # force the measurement so the lock decision is deterministic
        prof.host_best = 1e-6
        prof.device_best = 1e-3
        for _ in range(5):
            _gemm_site_a(a, a)                 # locks host on first call
        assert prof.locked is False
        assert "device" in prof.locked_why
        st = rt.stats.per_routine["sgemm"]
        # 1 host probe + 5 locked host calls; 1 offload probe
        assert st.on_host == 6 and st.offloaded == 1
        assert st.dispatch_hits == 5           # locked calls are hits
        assert prof.decision_label() == "host*"


def test_adaptive_lock_rule_unit():
    p = callsite.CallSiteProfile("x")
    p.observe_probe(False, 2e-3)
    p.observe_probe(True, 1e-3)
    assert p.lock() is True                    # device min wins
    q = callsite.CallSiteProfile("y")
    q.observe_probe(False, 1e-3)
    q.observe_probe(True, 2e-3)
    assert q.lock() is False
    r = callsite.CallSiteProfile("z")          # no probes: fallback
    assert r.lock(fallback=True) is True


def test_adaptive_off_is_default():
    with core.offload("dfu", threshold=100) as rt:
        assert rt.adaptive is False


# --------------------------------------------------------------------- #
# trace round-trip with the new fields                                   #
# --------------------------------------------------------------------- #
def test_trace_roundtrip_callsite_timing_devices(tmp_path):
    t = Trace()
    a = t.new_buffer(1024, "A")
    b = t.new_buffer(1024, "B")
    c = t.new_buffer(1024, "C")
    t.gemm("s", 16, 16, 16, a, b, c, site="sgemm@app.py:f:1")
    t.calls.append(BlasCall(
        routine="dgemm", m=512, n=512, k=512,
        operands=(("A", a, 512 * 512 * 8, 512.0, False),
                  ("C", c, 512 * 512 * 8, 1.0, True)),
        devices=(0, 1, 1, 0), callsite_id="dgemm@app.py:g:2",
        seconds=0.125))
    path = tmp_path / "trace.json"
    t.dump(str(path))
    back = Trace.load(str(path))
    assert len(back) == 2
    assert back.calls[0].callsite_id == "sgemm@app.py:f:1"
    assert back.calls[0].seconds == 0.0
    assert back.calls[1].devices == (0, 1, 1, 0)
    assert back.calls[1].callsite_id == "dgemm@app.py:g:2"
    assert back.calls[1].seconds == 0.125
    assert back.total_flops == pytest.approx(t.total_flops)


def test_trace_load_pre_callsite_format(tmp_path):
    """Traces dumped before the callsite/timing/devices fields existed
    must still load (defaults fill in)."""
    raw = {"buffers": {"1": [64, "A"]},
           "calls": [{"routine": "sgemm", "m": 8, "n": 8, "k": 8,
                      "batch": 1,
                      "operands": [["A", 1, 256, 8.0, False]]}]}
    path = tmp_path / "old.json"
    path.write_text(json.dumps(raw))
    t = Trace.load(str(path))
    assert t.calls[0].devices == ()
    assert t.calls[0].callsite_id == ""
    assert t.calls[0].seconds == 0.0


def test_runtime_trace_records_site_and_seconds():
    with core.offload("dfu", threshold=100) as rt:
        a = host_array(_f32((128, 128)))
        _gemm_site_a(a, a)
    call = rt.trace.calls[-1]
    assert "_gemm_site_a" in call.callsite_id
    assert call.seconds > 0.0


# --------------------------------------------------------------------- #
# SCILIB_TRACE auto-dump                                                 #
# --------------------------------------------------------------------- #
def test_scilib_trace_dump_at_uninstall(tmp_path, monkeypatch):
    path = tmp_path / "auto.json"
    monkeypatch.setenv("SCILIB_TRACE", str(path))
    core.install("dfu", threshold=100)
    a = host_array(_f32((128, 128)))
    jnp.matmul(a, a)
    core.uninstall()
    assert path.exists()
    back = Trace.load(str(path))
    assert len(back) == 1
    assert back.calls[0].routine == "sgemm"
    assert back.calls[0].callsite_id  # fingerprint survived the dump


# --------------------------------------------------------------------- #
# tensordot interception                                                 #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("axes", [1, (1, 0), ([1], [0]), (0, 0),
                                  (1, 1), (0, 1), ((-1,), (0,))])
def test_tensordot_intercepted(axes):
    a = jnp.asarray(_f32((48, 48)))
    b = jnp.asarray(_f32((48, 48)))
    with core.offload("dfu", threshold=10) as rt:
        out = jnp.tensordot(a, b, axes=axes)
        st = rt.stats.per_routine["sgemm"]
        assert st.calls == 1
    want = np.tensordot(np.asarray(a), np.asarray(b), axes=axes)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4,
                               atol=1e-4)


def test_tensordot_non_gemm_falls_through():
    a = jnp.asarray(_f32((8, 8)))
    t3 = jnp.asarray(_f32((4, 8, 8)))
    with core.offload("dfu", threshold=10) as rt:
        jnp.tensordot(a, a, axes=2)            # full contraction: scalar
        jnp.tensordot(t3, a, axes=(2, 0))      # rank-3 operand
        assert "sgemm" not in rt.stats.per_routine
        assert rt.stats.uninstrumented_calls == 2


def test_tensordot_flags_unit():
    assert blas.tensordot_flags(1) == ("N", "N")
    assert blas.tensordot_flags((1, 0)) == ("N", "N")
    assert blas.tensordot_flags((0, 0)) == ("T", "N")
    assert blas.tensordot_flags((1, 1)) == ("N", "T")
    assert blas.tensordot_flags((0, 1)) == ("T", "T")
    assert blas.tensordot_flags(((-1,), (-2,))) == ("N", "N")
    assert blas.tensordot_flags(2) is None
    assert blas.tensordot_flags(([0, 1], [0, 1])) is None
    assert blas.tensordot_flags((3, 0)) is None
    # numpy integer axes (common when axes come from computed indices)
    assert blas.tensordot_flags(
        (np.int64(1), np.int64(0))) == ("N", "N")
    assert blas.tensordot_flags((np.int32(0), [np.int64(1)])) == ("T", "T")
    assert blas.tensordot_flags(("x", 0)) is None


def test_site_flops_match_trace_model():
    """Per-site flops must agree with BlasCall.flops — including the
    syrk family (lstrip('sdcz') used to mangle 'dsyrk' to 'yrk') and
    the 4x complex multiplier."""
    with core.offload("dfu", threshold=10) as rt:
        a = host_array(_f32((96, 64)))
        blas.syrk(a)
        z = host_array((_f32((64, 64)) + 1j * _f32((64, 64)))
                       .astype("complex64"))
        blas.gemm(z, z)
    profs = {p.site: p for p in rt.callsites}
    (syrk_p,) = [p for s, p in profs.items() if s.startswith("ssyrk@")]
    assert syrk_p.flops == pytest.approx(1.0 * 96 * 96 * 64)
    (zg_p,) = [p for s, p in profs.items() if s.startswith("cgemm@")]
    assert zg_p.flops == pytest.approx(4.0 * 2.0 * 64 ** 3)
    for call in rt.trace.calls:
        site = profs[call.callsite_id]
        assert site.flops == pytest.approx(call.flops)


def test_tensordot_uninstall_restores():
    orig = jnp.tensordot
    with core.offload("dfu", threshold=10):
        assert jnp.tensordot is not orig
    assert jnp.tensordot is orig


# --------------------------------------------------------------------- #
# threshold grid + autotuner                                             #
# --------------------------------------------------------------------- #
def test_threshold_grid_flips_decisions():
    grid = thr.threshold_grid([128.0, 621.4, 1000.0])
    assert thr.DEFAULT_THRESHOLD in grid
    assert any(621.4 < t < 1000.0 for t in grid)   # the useful midpoint
    assert grid == tuple(sorted(grid))
    assert len(thr.threshold_grid(range(1, 100), limit=8)) <= 8
    assert thr.threshold_grid([]) == (thr.DEFAULT_THRESHOLD,)


def test_autotune_mini_trace_recommends_fewer_moved_bytes():
    """The bundled workload's acceptance check: the recommended
    threshold beats the paper-default baseline on predicted time AND
    moved bytes (the skinny-gemm site stops offloading)."""
    from repro.tools import autotune as at
    trace = Trace.load(MINI_TRACE)
    result = at.autotune(trace)
    assert result.best.threshold > thr.DEFAULT_THRESHOLD
    assert result.speedup > 1.5
    assert result.best.moved_bytes < result.baseline.moved_bytes
    env = result.best.env()
    assert set(env) >= {"SCILIB_POLICY", "SCILIB_THRESHOLD"}
    # per-site accounting flowed through the simulator
    assert "dgemm@parsec_dft.py:update_rho:88" in \
        result.baseline.report.per_site_s


def test_autotune_cli_runs(capsys):
    from repro.tools.autotune import main
    assert main([MINI_TRACE, "--devices", "1,2"]) == 0
    out = capsys.readouterr().out
    assert "recommended: SCILIB_POLICY=" in out
    assert "<- baseline" in out
    assert "call sites" in out

"""PARSEC proxy: tall-skinny dgemm offload (paper Table 5).

    PYTHONPATH=src python examples/parsec_dft.py

Runs a real Chebyshev-filtered subspace iteration (Ritz values verified
against dense eigh) under the interception layer, then replays the
production tall-skinny dgemm stream through the GH200 model: Mem-Copy
drowns in transfers, the access counter strands the 1.8 GB panel on the
host, Device First-Use moves it once.
"""
import jax
jax.config.update("jax_enable_x64", True)

import repro.core as scilib
from repro.apps import dft
from repro.memtier import GH200, replay_trace


def main():
    print("== runnable mini-PARSEC (subspace iteration) ==")
    runtime = scilib.install(policy="dfu", threshold=200)
    out = dft.run_mini(ngrid=1024, nstates=32)
    stats = scilib.uninstall()
    print(f"ritz_min={out['ritz_min']:.6f} exact={out['exact_min']:.6f} "
          f"max_err(low half)={out['max_err_low_half']:.2e}")
    assert out["max_err_low_half"] < 1e-6
    print(stats.report())

    print("\n== production-scale trace replay (GH200 constants) ==")
    trace = dft.production_trace()
    reports = replay_trace(trace, spec=GH200,
                           policies=("cpu", "memcopy", "counter", "dfu"))
    print(f"{'policy':10s}{'total_s':>10s}{'dgemm_s':>10s}"
          f"{'movement_s':>12s}{'reuse':>8s}")
    for p, r in reports.items():
        print(f"{p:10s}{r.total_s:10.1f}"
              f"{r.blas_device_s + r.blas_host_s:10.1f}"
              f"{r.movement_s:12.2f}{r.mean_reuse:8.1f}")
    print(f"\nDFU speedup vs CPU: "
          f"{reports['cpu'].total_s / reports['dfu'].total_s:.2f}x "
          f"(paper Table 5: ~1.9x total, ~10x on dgemm)")


if __name__ == "__main__":
    main()

"""LM serving with Device-First-Use cache placement (DESIGN.md §4).

    PYTHONPATH=src python examples/serve_offload.py

The paper's policies applied to the decode cache of a small LM: DFU
migrates the cache once at prefill; Mem-Copy round-trips it per token.
"""
import jax
import jax.numpy as jnp

from repro.models import get_config
from repro.models.registry import Model
from repro.train import Server, ServeConfig


def main():
    cfg = get_config("mamba2_1_3b").reduced()
    model = Model.from_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                0, cfg.vocab)
    outs = {}
    for policy in ("dfu", "memcopy", "pinned"):
        srv = Server(model, params,
                     ServeConfig(max_len=96, offload_policy=policy,
                                 cache_dtype=jnp.float32))
        outs[policy] = srv.generate(prompt, 32)
        s = srv.stats
        print(f"{policy:8s} decode={s.decode_s:6.2f}s "
              f"h->d={s.bytes_host_to_dev/1e6:8.2f}MB "
              f"d->h={s.bytes_dev_to_host/1e6:8.2f}MB "
              f"migrations={s.migrations} reuses={s.cache_reuses}")
    import numpy as np
    np.testing.assert_array_equal(outs["dfu"], outs["memcopy"])
    np.testing.assert_array_equal(outs["dfu"], outs["pinned"])
    print("identical generations under all policies: OK")


if __name__ == "__main__":
    main()

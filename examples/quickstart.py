"""Quickstart: automatic BLAS offload on unmodified JAX code.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's usage model: `install()` is the LD_PRELOAD analogue —
after it, plain jnp.matmul/jnp.dot/jnp.einsum calls are intercepted,
placed per the Device First-Use policy, and counted.
"""
import numpy as np
import jax.numpy as jnp

import repro.core as scilib


def application_code(a, b):
    """Completely ordinary JAX code — no scilib imports, no changes."""
    c = jnp.matmul(a, b)                 # offloaded (large)
    for _ in range(5):
        c = jnp.matmul(a, c)             # reuses device-resident a, c
    d = jnp.einsum("ij,kj->ik", c, b)    # transposed gemm, intercepted
    small = jnp.dot(a[:64, :64], b[:64, :64])   # stays on host (N_avg)
    return c, d, small


def main():
    rng = np.random.default_rng(0)
    # host_array = the malloc() analogue: inputs are CPU-first-touched
    a = scilib.host_array(rng.standard_normal((768, 768)).astype("float32"))
    b = scilib.host_array(rng.standard_normal((768, 768)).astype("float32"))

    runtime = scilib.install(policy="dfu", threshold=500)
    c, d, small = application_code(a, b)
    stats = scilib.uninstall()

    print(stats.report())
    ms = scilib.memspace.active()
    print(f"\nresult tier: {scilib.memspace.tier_of(c)} "
          f"(memory kind {ms.kind_of(scilib.memspace.tier_of(c))}"
          f"{', simulated' if ms.simulated else ''})")
    print(f"mean buffer reuse: {runtime.mean_buffer_reuse():.1f}")
    # verify against plain execution
    c2, d2, small2 = application_code(a, b)
    np.testing.assert_allclose(c, c2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(d, d2, rtol=2e-3, atol=2e-3)
    print("results identical with offload enabled: OK")


if __name__ == "__main__":
    main()

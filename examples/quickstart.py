"""Quickstart: automatic BLAS offload on unmodified JAX code.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's usage model as a first-class session: inside
`repro.session(config)`, plain jnp.matmul/jnp.dot/jnp.einsum calls are
intercepted, placed per the Device First-Use policy, and counted.  The
config is a typed `OffloadConfig` — env `SCILIB_*` vars still layer in
through `OffloadConfig.from_env()` (so `SCILIB_DEVICES=4` exercises the
multi-device tile scheduler on any backend), and the legacy
`scilib.install()/uninstall()` surface remains as a shim.
"""
import numpy as np
import jax.numpy as jnp

import repro
import repro.core as scilib
from repro import OffloadConfig


def application_code(a, b):
    """Completely ordinary JAX code — no scilib imports, no changes."""
    c = jnp.matmul(a, b)                 # offloaded (large)
    for _ in range(5):
        c = jnp.matmul(a, c)             # reuses device-resident a, c
    d = jnp.einsum("ij,kj->ik", c, b)    # transposed gemm, intercepted
    small = jnp.dot(a[:64, :64], b[:64, :64])   # stays on host (N_avg)
    y = jnp.matmul(a, b[:, 0])           # gemv-shaped: counted, host
    return c, d, small, y


def main():
    rng = np.random.default_rng(0)
    # host_array = the malloc() analogue: inputs are CPU-first-touched
    a = scilib.host_array(rng.standard_normal((768, 768)).astype("float32"))
    b = scilib.host_array(rng.standard_normal((768, 768)).astype("float32"))

    # the script's defaults, with env knobs (SCILIB_THRESHOLD=10,
    # SCILIB_DEVICES=4, ...) layering over them — same precedence as
    # the legacy install(policy="dfu", threshold=500) this replaces
    config = OffloadConfig.legacy(policy="dfu", threshold=500.0)
    with repro.session(config) as s:
        c, d, small, y = application_code(a, b)
        print(s.report())
        reuse = s.runtime.mean_buffer_reuse()
    ms = scilib.memspace.active()
    print(f"\nresult tier: {scilib.memspace.tier_of(c)} "
          f"(memory kind {ms.kind_of(scilib.memspace.tier_of(c))}"
          f"{', simulated' if ms.simulated else ''})")
    print(f"mean buffer reuse: {reuse:.1f}")
    # verify against plain execution (the session is closed: these run
    # through the original, un-intercepted symbols)
    c2, d2, small2, y2 = application_code(a, b)
    np.testing.assert_allclose(c, c2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(d, d2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(y, y2, rtol=2e-3, atol=2e-3)
    print("results identical with offload enabled: OK")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-parameter LM.

    PYTHONPATH=src python examples/train_100m.py --steps 300

Full substrate in play: deterministic data pipeline, AdamW + cosine,
microbatching, remat, async checkpointing, straggler watchdog. On a
laptop CPU use --steps 20; on real accelerators run the full few
hundred steps.
"""
import argparse

from repro.configs.base import ModelConfig
from repro.data import DataConfig, TokenPipeline
from repro.models.registry import Model
from repro.train import Trainer, TrainConfig

CONFIG_100M = ModelConfig(
    name="repro-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=32000,
    tie_embeddings=True, dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    total, active = CONFIG_100M.param_count()
    print(f"model: {CONFIG_100M.name}  params={total/1e6:.1f}M")
    model = Model.from_config(CONFIG_100M)
    pipe = TokenPipeline(DataConfig(vocab=CONFIG_100M.vocab,
                                    seq_len=args.seq,
                                    global_batch=args.batch))
    tcfg = TrainConfig(steps=args.steps, n_micro=2, remat="dots",
                       ckpt_every=100, log_every=10)
    trainer = Trainer(model, pipe, tcfg, ckpt_dir=args.ckpt_dir)
    hist = trainer.fit()
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"over {len(hist)} steps")


if __name__ == "__main__":
    main()

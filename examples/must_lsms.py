"""MuST/LSMS proxy under every data-movement policy (paper Table 3).

    PYTHONPATH=src python examples/must_lsms.py [--atoms 4 --energies 4]

Runs REAL multiple-scattering solves (zgetrf/zgetrs through the
intercepted BLAS) under cpu / memcopy / dfu policies, verifies the
physics is identical, then replays the production-scale trace through
the GH200 memtier model to reproduce the paper's Table 3 structure.
"""
import argparse

import jax
jax.config.update("jax_enable_x64", True)

import repro.core as scilib
from repro.apps import lsms
from repro.memtier import GH200, replay_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--atoms", type=int, default=3)
    ap.add_argument("--energies", type=int, default=3)
    ap.add_argument("--scf", type=int, default=2)
    ap.add_argument("--n", type=int, default=160)
    args = ap.parse_args()

    print("== runnable mini-LSMS through the interception layer ==")
    results = {}
    for policy in ("cpu", "memcopy", "dfu"):
        runtime = scilib.install(policy=policy, threshold=100)
        out = lsms.run_mini(atoms=args.atoms, energies=args.energies,
                            scf=args.scf, n=args.n)
        stats = scilib.uninstall()
        results[policy] = out
        g = stats.per_routine.get("zgemm")
        print(f"policy={policy:8s} energy={out['energy']:+.6f} "
              f"resid={out['max_resid']:.2e} solves={out['n_solves']} "
              f"zgemm calls={g.calls if g else 0}")
    e0 = results["cpu"]["energy"]
    for p, r in results.items():
        assert abs(r["energy"] - e0) < 1e-8, (p, r["energy"], e0)
    print("energies identical across policies: OK\n")

    print("== production-scale trace replay (GH200 constants) ==")
    trace = lsms.production_trace()
    reports = replay_trace(trace, spec=GH200,
                           policies=("cpu", "memcopy", "counter", "dfu"))
    print(f"{'policy':10s}{'total_s':>10s}{'blas_s':>10s}"
          f"{'movement_s':>12s}{'reuse':>8s}")
    for p, r in reports.items():
        print(f"{p:10s}{r.total_s:10.1f}"
              f"{r.blas_device_s + r.blas_host_s:10.1f}"
              f"{r.movement_s:12.2f}{r.mean_reuse:8.1f}")
    speedup = reports["cpu"].total_s / reports["dfu"].total_s
    print(f"\nDFU speedup vs CPU: {speedup:.2f}x "
          f"(paper Table 3: ~2.8x on zgemm+ztrsm-dominated runtime)")


if __name__ == "__main__":
    main()

"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
prints one row per (arch x shape x mesh) cell with the three terms,
dominant bottleneck and roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str]

ART_DIR = os.environ.get("DRYRUN_ART", "experiments/dryrun")


def load_records(art_dir: str = ART_DIR) -> list:
    recs = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        try:
            recs.append(json.load(open(f)))
        except Exception:
            pass
    return recs


def report(art_dir: str = ART_DIR) -> List[Row]:
    rows: List[Row] = []
    recs = load_records(art_dir)
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    n_err = sum(r["status"] == "error" for r in recs)
    rows.append(("roofline.cells_ok", n_ok, f"skip={n_skip} err={n_err}"))
    for r in recs:
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        name = f"{r['arch']}.{r['shape']}.{r['mesh']}"
        dom = rl["dominant"]
        rows.append((f"rl.{name}.frac", rl["roofline_fraction"],
                     f"dom={dom} tc={rl['t_compute_s']:.4f} "
                     f"tm={rl['t_memory_s']:.4f} "
                     f"tx={rl['t_collective_s']:.4f}"))
    return rows


def markdown_table(art_dir: str = ART_DIR) -> str:
    recs = [r for r in load_records(art_dir)]
    lines = ["| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s)"
             " | dominant | useful | roofline frac | fits HBM |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                         " — | — | — | skipped (quadratic attn @500k) |"
                         " — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
                         f" ERROR {r.get('error', '')[:60]} |" + " |" * 6)
            continue
        rl = r["roofline"]
        mem = r.get("memory", {})
        tot = sum(mem.get(k, 0) for k in ("argument_size_in_bytes",
                                          "temp_size_in_bytes",
                                          "output_size_in_bytes"))
        fits = "yes" if tot and tot / 1e9 < 16 else f"NO ({tot/1e9:.0f}G)"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['t_compute_s']:.4f} | {rl['t_memory_s']:.4f} "
            f"| {rl['t_collective_s']:.4f} | {rl['dominant']} "
            f"| {rl['useful_flops_ratio']:.2f} "
            f"| {rl['roofline_fraction']:.3f} | {fits} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())

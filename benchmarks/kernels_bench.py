"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode
(Python semantics — correctness, not speed), so the honest numbers are:
(a) wall time of the XLA reference op (what the CPU fallback costs),
(b) the kernel's arithmetic model on the v5e target (MXU-bound bound),
and (c) the venue-comparison rows — the same BLAS call dispatched
through each of the runtime's three execution venues (host / generic
XLA offload / pallas kernel path), which is what the `SCILIB_KERNELS`
knob actually races per call site.

    PYTHONPATH=src python -m benchmarks.kernels_bench [--quick] [--out F]

``--quick`` (or ``SCILIB_BENCH_QUICK=1``) shrinks shapes and reps for
CI smoke runs; ``--out`` also writes the CSV rows to a file.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]

V5E_FLOPS = 197.0e12

_QUICK = os.environ.get("SCILIB_BENCH_QUICK", "") == "1"

#: execution venues the comparison rows sweep, in VENUES order
_VENUE_CONFIGS = ("host", "xla", "pallas")


def _wall(fn, *args, reps=3) -> float:
    warm = fn(*args)                       # evaluate the warmup once
    (warm[0] if isinstance(warm, tuple) else warm).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench(quick: bool = False) -> List[Row]:
    from repro.kernels import ref
    quick = quick or _QUICK
    n = 256 if quick else 512
    reps = 1 if quick else 3
    rows = []
    rng = np.random.default_rng(0)

    # gemm: n^3 f32
    a = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    mm = jax.jit(ref.matmul)
    us = _wall(mm, a, b, reps=reps)
    flops = 2 * n**3
    rows.append((f"kern.gemm{n}.ref_us", round(us, 1),
                 f"v5e_mxu_bound_us={flops / V5E_FLOPS * 1e6:.2f}"))

    # trsm nxn on n/2 rhs
    l = np.tril(rng.standard_normal((n, n)).astype(np.float32) / n)
    np.fill_diagonal(l, 1.0)
    bb = jnp.asarray(rng.standard_normal((n, n // 2)), jnp.float32)
    ts = jax.jit(lambda aa, cc: ref.trsm(aa, cc))
    us = _wall(ts, jnp.asarray(l), bb, reps=reps)
    rows.append((f"kern.trsm{n}.ref_us", round(us, 1),
                 f"v5e_bound_us={n * n * (n // 2) / V5E_FLOPS * 1e6:.2f}"))

    # flash attention 1x8x1024x64 causal
    t = 512 if quick else 1024
    q = jnp.asarray(rng.standard_normal((1, 8, t, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, t, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 8, t, 64)), jnp.float32)
    at = jax.jit(lambda *xs: ref.attention(*xs, causal=True))
    us = _wall(at, q, k, v, reps=reps)
    aflops = 4 * 1 * 8 * t * t * 64 / 2
    rows.append((f"kern.attn{t}.ref_us", round(us, 1),
                 f"v5e_bound_us={aflops / V5E_FLOPS * 1e6:.2f}"))

    # interpret-mode correctness spot check counts as the kernel row
    from repro.kernels.gemm import gemm as pallas_gemm
    out = pallas_gemm(a[:256, :256], b[:256, :256], bm=128, bk=128,
                      bn=128, interpret=True)
    err = float(jnp.max(jnp.abs(out - a[:256, :256] @ b[:256, :256])))
    rows.append(("kern.gemm.pallas_interpret_maxerr", round(err, 6),
                 "correctness via interpret mode"))
    return rows


def _venue_config(venue: str):
    """The typed config that forces one execution venue end to end."""
    from repro.core.config import OffloadConfig
    if venue == "host":
        return OffloadConfig(policy="cpu")
    return OffloadConfig(policy="dfu", threshold=1.0,
                         kernel_path=(venue == "pallas"))


def _venue_cps(venue: str, routine: str, n: int, calls: int,
               reps: int) -> float:
    """calls/sec for one routine at one shape through one venue."""
    from repro.core import blas
    from repro.core.policy import host_array
    from repro.core.session import Session
    rng = np.random.default_rng(11)
    blas.clear_caches()
    with Session(_venue_config(venue), record_trace=False) as s:
        with s.scope():
            a = host_array(rng.standard_normal((n, n))
                           .astype("float32") / n)
            b = host_array(rng.standard_normal((n, n)).astype("float32"))
            tri = host_array(
                (np.tril(rng.standard_normal((n, n))) / n
                 + 2.0 * np.eye(n)).astype("float32"))

            def loop():
                if routine == "gemm":
                    for _ in range(calls):
                        blas.gemm(a, b)
                elif routine == "syrk":
                    for _ in range(calls):
                        blas.syrk(a)
                else:
                    for _ in range(calls):
                        blas.trsm(tri, b)

            best = 0.0
            for _ in range(reps + 1):      # first rep warms jit caches
                t0 = time.perf_counter()
                loop()
                s.sync()
                best = max(best, calls / (time.perf_counter() - t0))
            return best


def venue_rows(quick: bool = False) -> List[Row]:
    """host / xla / pallas calls-per-second per routine and shape —
    the comparison the kernel path's per-site racing automates."""
    quick = quick or _QUICK
    shapes = (128,) if quick else (128, 512)
    calls = 10 if quick else 40
    reps = 1 if quick else 3
    rows: List[Row] = []
    for n in shapes:
        for routine in ("gemm", "syrk", "trsm"):
            cps = {v: _venue_cps(v, routine, n, calls, reps)
                   for v in _VENUE_CONFIGS}
            for v in _VENUE_CONFIGS:
                rows.append((f"kern.venue.{routine}{n}.{v}_cps",
                             round(cps[v], 0),
                             f"{routine} {n}^2 f32 via the {v} venue"))
            rows.append((f"kern.venue.{routine}{n}.pallas_vs_xla",
                         round(cps["pallas"] / max(1e-9, cps["xla"]), 3),
                         ">1 means the pallas venue wins this shape"))
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.kernels_bench",
        description="Kernel micro-benchmarks + venue comparison rows.")
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / single rep (CI smoke)")
    ap.add_argument("--out", default="",
                    help="also write the CSV rows to this file")
    ap.add_argument("--no-venues", action="store_true",
                    help="skip the dispatch venue comparison rows")
    args = ap.parse_args(argv)
    rows = bench(quick=args.quick)
    if not args.no_venues:
        rows += venue_rows(quick=args.quick)
    lines = ["name,value,derived"]
    lines += [f"{name},{value},{derived}" for name, value, derived in rows]
    print("\n".join(lines))
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(lines) + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode
(Python semantics — correctness, not speed), so the honest numbers are:
(a) wall time of the XLA reference op (what the CPU fallback costs) and
(b) the kernel's arithmetic model on the v5e target (MXU-bound bound).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]

V5E_FLOPS = 197.0e12


def _wall(fn, *args, reps=3) -> float:
    warm = fn(*args)                       # evaluate the warmup once
    (warm[0] if isinstance(warm, tuple) else warm).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench() -> List[Row]:
    from repro.kernels import ref
    rows = []
    rng = np.random.default_rng(0)

    # gemm: 512^3 f32
    a = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    mm = jax.jit(ref.matmul)
    us = _wall(mm, a, b)
    flops = 2 * 512**3
    rows.append(("kern.gemm512.ref_us", round(us, 1),
                 f"v5e_mxu_bound_us={flops / V5E_FLOPS * 1e6:.2f}"))

    # trsm 512x512 on 256 rhs
    l = np.tril(rng.standard_normal((512, 512)).astype(np.float32) / 512)
    np.fill_diagonal(l, 1.0)
    bb = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    ts = jax.jit(lambda aa, cc: ref.trsm(aa, cc))
    us = _wall(ts, jnp.asarray(l), bb)
    rows.append(("kern.trsm512.ref_us", round(us, 1),
                 f"v5e_bound_us={512 * 512 * 256 / V5E_FLOPS * 1e6:.2f}"))

    # flash attention 1x8x1024x64 causal
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), jnp.float32)
    at = jax.jit(lambda *xs: ref.attention(*xs, causal=True))
    us = _wall(at, q, k, v)
    aflops = 4 * 1 * 8 * 1024 * 1024 * 64 / 2
    rows.append(("kern.attn1k.ref_us", round(us, 1),
                 f"v5e_bound_us={aflops / V5E_FLOPS * 1e6:.2f}"))

    # interpret-mode correctness spot check counts as the kernel row
    from repro.kernels.gemm import gemm as pallas_gemm
    out = pallas_gemm(a[:256, :256], b[:256, :256], bm=128, bk=128,
                      bn=128, interpret=True)
    err = float(jnp.max(jnp.abs(out - a[:256, :256] @ b[:256, :256])))
    rows.append(("kern.gemm.pallas_interpret_maxerr", round(err, 6),
                 "correctness via interpret mode"))
    return rows

"""Generate the checked-in miniature autotune trace.

    PYTHONPATH=src python -m benchmarks.make_mini_trace [out.json]

The workload is three call sites chosen so the paper's default
threshold (500) is measurably wrong for one of them — the situation the
trace-replay autotuner exists to catch:

* ``dgemm@parsec_dft.py:update_rho:88`` — six movement-bound skinny
  dgemms (4000 x 4000 x 15, N_avg ~= 621) on *fresh* buffers every call:
  above the default threshold, so the baseline offloads them and pays
  ~130 MB of one-way migration per call for ~0.5 GFLOP of work.  Any
  threshold above ~621 keeps them host and deletes that movement.
* ``zgemm@must_lsms.py:greens:214`` — twenty-four reuse-heavy 1000^3
  zgemms on the *same* buffers (N_avg = 1000): genuinely worth
  offloading at any sensible threshold; DFU moves the operands once,
  Mem-Copy restages ~64 MB per call.
* ``sgemm@train_step.py:mlp_forward:57`` — ten tiny 128^3 sgemms:
  below every candidate threshold, host everywhere.

The expected recommendation is therefore a threshold between ~621 and
1000 (the autotuner's N_avg-midpoint grid lands on ~811), which both
speeds up the replay and cuts moved bytes versus the 500 default —
the acceptance check in ``tests/test_callsite_pipeline.py`` and the CI
autotune smoke step assert exactly that.
"""
from __future__ import annotations

import sys

from repro.core.trace import Trace

DEFAULT_OUT = "tests/data/mini_trace.json"

SITE_SKINNY = "dgemm@parsec_dft.py:update_rho:88"
SITE_REUSE = "zgemm@must_lsms.py:greens:214"
SITE_SMALL = "sgemm@train_step.py:mlp_forward:57"


def build() -> Trace:
    t = Trace()
    # reuse-heavy zgemm site: one buffer triple, 24 calls
    za = t.new_buffer(1000 * 1000 * 16, "G_k")
    zb = t.new_buffer(1000 * 1000 * 16, "tau")
    zc = t.new_buffer(1000 * 1000 * 16, "G_out")
    # small sgemm site: one buffer triple, 10 calls
    sa = t.new_buffer(128 * 128 * 4, "act")
    sb = t.new_buffer(128 * 128 * 4, "w")
    sc = t.new_buffer(128 * 128 * 4, "out")
    # interleave the sites roughly how an application would issue them
    for step in range(6):
        # skinny dgemm on fresh buffers every call (no reuse to exploit)
        da = t.new_buffer(4000 * 15 * 8, f"rho_a{step}")
        db = t.new_buffer(15 * 4000 * 8, f"rho_b{step}")
        dc = t.new_buffer(4000 * 4000 * 8, f"rho_c{step}")
        t.gemm("d", 4000, 4000, 15, da, db, dc, site=SITE_SKINNY)
        for _ in range(4):
            t.gemm("z", 1000, 1000, 1000, za, zb, zc, site=SITE_REUSE)
        t.gemm("s", 128, 128, 128, sa, sb, sc, site=SITE_SMALL)
    for _ in range(4):
        t.gemm("s", 128, 128, 128, sa, sb, sc, site=SITE_SMALL)
    return t


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_OUT
    trace = build()
    trace.dump(out)
    print(f"wrote {len(trace)} calls / "
          f"{len(trace.buffer_sizes)} buffers -> {out}")


if __name__ == "__main__":
    main()

"""Serving-state placement benchmark: the paper's Table 3/5 accounting
applied to LM decode state (DESIGN.md §4).

Measures, per policy, the real bytes moved between the host and device
tiers while generating with a small LM, plus a GH200-modeled cost of
that movement for a production-sized cache (qwen2.5-32b at 32k context,
batch 128 — the decode_32k cell's cache).

:func:`load_bench` adds the multi-tenant serving axis: a closed-loop
request load generator at 1/8/32/128 concurrent streams, each stream an
independent session drawing on one shared device pool, reporting
p50/p95/p99 request latency and aggregate calls/sec per stream count
(``SCILIB_BENCH_QUICK=1`` shrinks the request counts for CI).
"""
from __future__ import annotations

import os
import threading
import time
from typing import List, Tuple

Row = Tuple[str, float, str]

_QUICK = os.environ.get("SCILIB_BENCH_QUICK", "") == "1"

#: closed-loop concurrency levels (streams = concurrent sessions)
STREAMS = (1, 8, 32, 128)
REQUESTS_PER_STREAM = 4 if _QUICK else 16
POOL_MB = 64          # shared pool capacity across all tenants


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted sample."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1,
              max(0, int(round(q / 100.0 * (len(sorted_vals) - 1)))))
    return sorted_vals[idx]


def _request(blas, arrays) -> None:
    """One serving request: a small decode-step-shaped BLAS chain
    (gemm attention-score shape, syrk state update, trsm solve)."""
    a, b, s, t = arrays
    out = blas.gemm(a, b)
    blas.syrk(s)
    blas.trsm(t, out)


def load_bench() -> List[Row]:
    """Request-level closed-loop load generator over concurrent
    multi-tenant sessions sharing one device pool."""
    import numpy as np

    from repro.core import blas
    from repro.core import residency as res
    from repro.core import session as ses
    from repro.core.config import OffloadConfig
    from repro.core.policy import host_array

    n = 96
    rng = np.random.default_rng(0)
    a = host_array(rng.standard_normal((n, n)).astype("float32"))
    b = host_array(rng.standard_normal((n, n)).astype("float32"))
    s = host_array(rng.standard_normal((n, n)).astype("float32"))
    t = host_array(np.tril(rng.standard_normal((n, n)) + n)
                   .astype("float32"))
    arrays = (a, b, s, t)
    cfg = OffloadConfig(policy="dfu", threshold=1.0, sync=True)

    rows: List[Row] = []
    for n_streams in STREAMS:
        pool = res.SharedDevicePool(POOL_MB << 20,
                                    name=f"load-{n_streams}")
        latencies_ms: List[List[float]] = [[] for _ in range(n_streams)]
        barrier = threading.Barrier(n_streams + 1)
        errors: List[BaseException] = []

        def worker(idx: int) -> None:
            try:
                with ses.session(cfg, record_trace=False,
                                 intercept=False,
                                 name=f"stream-{idx}", pool=pool):
                    _request(blas, arrays)      # warm compile caches
                    barrier.wait()
                    for _ in range(REQUESTS_PER_STREAM):
                        t0 = time.perf_counter()
                        _request(blas, arrays)
                        latencies_ms[idx].append(
                            (time.perf_counter() - t0) * 1e3)
            except BaseException as exc:        # propagate to the row
                errors.append(exc)
                try:
                    barrier.abort()
                except Exception:
                    pass

        threads = [threading.Thread(target=worker, args=(i,),
                                    name=f"load-{n_streams}-{i}")
                   for i in range(n_streams)]
        for th in threads:
            th.start()
        barrier.wait()
        t0 = time.perf_counter()
        for th in threads:
            th.join()
        wall = time.perf_counter() - t0
        if errors:
            raise errors[0]
        lat = sorted(ms for per in latencies_ms for ms in per)
        calls = len(lat)
        tag = f"serve.load.{n_streams}str"
        note = f"{calls} reqs, {n_streams} sessions, shared pool"
        rows.append((f"{tag}.p50_ms",
                     round(_percentile(lat, 50), 3), note))
        rows.append((f"{tag}.p95_ms",
                     round(_percentile(lat, 95), 3), note))
        rows.append((f"{tag}.p99_ms",
                     round(_percentile(lat, 99), 3), note))
        rows.append((f"{tag}.req_per_s",
                     round(calls / max(wall, 1e-9), 1), note))
    return rows


def main() -> None:
    """CLI for the load generator (CI artifact): ``--out`` writes the
    CSV rows to a file in addition to stdout."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="", help="also write CSV here")
    args = ap.parse_args()
    lines = ["name,value,derived"]
    for name, value, derived in load_bench():
        lines.append(f"{name},{value},{derived}")
    text = "\n".join(lines) + "\n"
    print(text, end="")
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(text)


def bench() -> List[Row]:
    import jax
    import jax.numpy as jnp

    from repro.memtier import GH200
    from repro.models import get_config
    from repro.models.registry import Model
    from repro.train import Server, ServeConfig

    cfg = get_config("mamba2_1_3b").reduced()
    model = Model.from_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                0, cfg.vocab)
    rows: List[Row] = []
    moved = {}
    for policy in ("dfu", "memcopy", "pinned"):
        srv = Server(model, params,
                     ServeConfig(max_len=80, offload_policy=policy,
                                 cache_dtype=jnp.float32))
        srv.generate(prompt, 32)
        s = srv.stats
        moved[policy] = s.bytes_host_to_dev + s.bytes_dev_to_host
        rows.append((f"serve.{policy}.moved_MB",
                     round(moved[policy] / 1e6, 2),
                     f"migrations={s.migrations} reuses={s.cache_reuses}"))
    rows.append(("serve.memcopy_vs_dfu_traffic",
                 round(moved["memcopy"] / max(1, moved["dfu"]), 1),
                 "per-token roundtrips vs one first-use migration"))

    # production-scale projection: qwen2.5-32b decode_32k cache
    big = get_config("qwen2_5_32b")
    cache_bytes = (big.n_layers * 2 * big.n_kv_heads * big.head_dim
                   * 32768 * 128 * 2)          # bf16, batch 128
    link = GH200.link_bw
    tokens = 1024
    t_dfu = cache_bytes / GH200.effective_migrate_bw()
    t_memcopy = 2 * cache_bytes * tokens / link
    rows.append(("serve.proj32k.cache_GB", round(cache_bytes / 1e9, 1),
                 "qwen2.5-32b kv cache @32k x128"))
    rows.append(("serve.proj32k.dfu_move_s", round(t_dfu, 2),
                 "one first-use migration"))
    rows.append(("serve.proj32k.memcopy_move_s", round(t_memcopy, 1),
                 f"2 transfers/token x {tokens} tokens"))
    return rows


if __name__ == "__main__":
    main()

"""Serving-state placement benchmark: the paper's Table 3/5 accounting
applied to LM decode state (DESIGN.md §4).

Measures, per policy, the real bytes moved between the host and device
tiers while generating with a small LM, plus a GH200-modeled cost of
that movement for a production-sized cache (qwen2.5-32b at 32k context,
batch 128 — the decode_32k cell's cache).
"""
from __future__ import annotations

from typing import List, Tuple

Row = Tuple[str, float, str]


def bench() -> List[Row]:
    import jax
    import jax.numpy as jnp

    from repro.memtier import GH200
    from repro.models import get_config
    from repro.models.registry import Model
    from repro.train import Server, ServeConfig

    cfg = get_config("mamba2_1_3b").reduced()
    model = Model.from_config(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                0, cfg.vocab)
    rows: List[Row] = []
    moved = {}
    for policy in ("dfu", "memcopy", "pinned"):
        srv = Server(model, params,
                     ServeConfig(max_len=80, offload_policy=policy,
                                 cache_dtype=jnp.float32))
        srv.generate(prompt, 32)
        s = srv.stats
        moved[policy] = s.bytes_host_to_dev + s.bytes_dev_to_host
        rows.append((f"serve.{policy}.moved_MB",
                     round(moved[policy] / 1e6, 2),
                     f"migrations={s.migrations} reuses={s.cache_reuses}"))
    rows.append(("serve.memcopy_vs_dfu_traffic",
                 round(moved["memcopy"] / max(1, moved["dfu"]), 1),
                 "per-token roundtrips vs one first-use migration"))

    # production-scale projection: qwen2.5-32b decode_32k cache
    big = get_config("qwen2_5_32b")
    cache_bytes = (big.n_layers * 2 * big.n_kv_heads * big.head_dim
                   * 32768 * 128 * 2)          # bf16, batch 128
    link = GH200.link_bw
    tokens = 1024
    t_dfu = cache_bytes / GH200.effective_migrate_bw()
    t_memcopy = 2 * cache_bytes * tokens / link
    rows.append(("serve.proj32k.cache_GB", round(cache_bytes / 1e9, 1),
                 "qwen2.5-32b kv cache @32k x128"))
    rows.append(("serve.proj32k.dfu_move_s", round(t_dfu, 2),
                 "one first-use migration"))
    rows.append(("serve.proj32k.memcopy_move_s", round(t_memcopy, 1),
                 f"2 transfers/token x {tokens} tokens"))
    return rows

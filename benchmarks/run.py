"""Benchmark harness: one function per paper table + kernels + roofline.

Prints ``name,value,derived`` CSV (the derived column carries the
paper's measured number for the same quantity where one exists).

    PYTHONPATH=src python -m benchmarks.run [--only t3,t5]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: dispatch,t1,t3,t4,t5,t6,t7,t8,"
                         "kern,serve,roofline")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))

    from benchmarks import (dispatch_bench, kernels_bench,
                            roofline_report, serve_bench, tables)
    suites = [
        ("dispatch", dispatch_bench.bench),
        ("t1", tables.table1_stream),
        ("t3", tables.table3_must),
        ("t4", tables.table4_scaling),
        ("t5", tables.table5_parsec),
        ("t6", tables.table6_counter),
        ("t7", tables.table7_pagesize),
        ("t8", tables.table8_alignment),
        ("kern", kernels_bench.bench),
        ("serve", serve_bench.bench),
        ("roofline", roofline_report.report),
    ]
    print("name,value,derived")
    failures = 0
    for tag, fn in suites:
        if only and tag not in only:
            continue
        t0 = time.time()
        try:
            for name, value, derived in fn():
                print(f"{name},{value},{derived}")
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{tag}.ERROR,nan,{type(e).__name__}: {e}")
        print(f"#{tag} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Dispatch fast-path benchmark: seed (sync, uncached) vs fast (async,
cached) runtime, on the two workloads the tentpole targets.

* ``smallgemm`` — a loop of sub-threshold 64^3 sgemms from one call
  site.  The paper's point: interception overhead must be ~zero for
  calls that *stay on the host*; the seed runtime spent ~200us/call on
  re-created device scalars, re-derived thresholds and a mandatory
  ``block_until_ready``.
* ``dfuchain`` — a 100-call chained DFU workload (``C = A @ C``) above
  the threshold: placement-registry hits plus async submission.

Modes are selected with the runtime's own knobs so the comparison runs
the *same* code path the library ships:

* seed: ``SCILIB_SYNC=1`` + ``SCILIB_DISPATCH_CACHE=0`` (per-call
  blocking + per-call re-derivation, the seed's behaviour),
* fast: the defaults (async + dispatch cache).

    PYTHONPATH=src python -m benchmarks.dispatch_bench
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]

SMALL_N = 64
SMALL_CALLS = 400
CHAIN_N = 256
CHAIN_CALLS = 100
REPS = 3


def _install(mode: str):
    from repro.core import runtime as rtm
    if mode == "seed":
        os.environ["SCILIB_SYNC"] = "1"
        os.environ["SCILIB_DISPATCH_CACHE"] = "0"
    else:
        os.environ.pop("SCILIB_SYNC", None)
        os.environ["SCILIB_DISPATCH_CACHE"] = "1"
    from repro.core import blas
    blas.clear_caches()
    return rtm


def _sweep(fn, runtime, calls: int) -> float:
    """calls/sec, best of REPS (first rep also warms compile caches)."""
    best = 0.0
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn()
        runtime.sync()
        best = max(best, calls / (time.perf_counter() - t0))
    return best


def _bench_smallgemm(mode: str) -> float:
    rtm = _install(mode)
    from repro.core import blas
    from repro.core.policy import host_array
    rng = np.random.default_rng(0)
    rt = rtm.install("dfu", record_trace=False)   # default threshold: host
    try:
        a = host_array(rng.standard_normal((SMALL_N, SMALL_N))
                       .astype("float32"))
        b = host_array(rng.standard_normal((SMALL_N, SMALL_N))
                       .astype("float32"))

        def loop():
            for _ in range(SMALL_CALLS):
                blas.gemm(a, b, alpha=1.0, beta=0.0)

        return _sweep(loop, rt, SMALL_CALLS)
    finally:
        rtm.uninstall()


def _bench_dfuchain(mode: str) -> float:
    rtm = _install(mode)
    from repro.core import blas
    from repro.core.policy import host_array
    rng = np.random.default_rng(1)
    rt = rtm.install("dfu", threshold=100, record_trace=False)
    try:
        a = host_array(rng.standard_normal((CHAIN_N, CHAIN_N))
                       .astype("float32") / CHAIN_N)

        def loop():
            c = a
            for _ in range(CHAIN_CALLS):
                c = blas.gemm(a, c)
            return c

        return _sweep(loop, rt, CHAIN_CALLS)
    finally:
        rtm.uninstall()


def bench() -> List[Row]:
    rows: List[Row] = []
    saved = {k: os.environ.get(k)
             for k in ("SCILIB_SYNC", "SCILIB_DISPATCH_CACHE")}
    try:
        small = {m: _bench_smallgemm(m) for m in ("seed", "fast")}
        chain = {m: _bench_dfuchain(m) for m in ("seed", "fast")}
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    rows.append(("dispatch.smallgemm64.seed_cps", round(small["seed"], 0),
                 "sync + uncached (seed runtime)"))
    rows.append(("dispatch.smallgemm64.fast_cps", round(small["fast"], 0),
                 "async + dispatch cache"))
    rows.append(("dispatch.smallgemm64.speedup",
                 round(small["fast"] / small["seed"], 2),
                 "acceptance: >= 2x"))
    rows.append(("dispatch.dfuchain100.seed_cps", round(chain["seed"], 0),
                 "sync + uncached (seed runtime)"))
    rows.append(("dispatch.dfuchain100.fast_cps", round(chain["fast"], 0),
                 "async + dispatch cache"))
    rows.append(("dispatch.dfuchain100.speedup",
                 round(chain["fast"] / chain["seed"], 2),
                 "chained DFU workload"))
    return rows


def main() -> None:
    print("name,value,derived")
    for name, value, derived in bench():
        print(f"{name},{value},{derived}")


if __name__ == "__main__":
    main()

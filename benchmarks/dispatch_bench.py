"""Dispatch fast-path benchmark: seed (sync, uncached) vs fast (async,
cached) runtime, on the two workloads the tentpole targets.

* ``smallgemm`` — a loop of sub-threshold 64^3 sgemms from one call
  site.  The paper's point: interception overhead must be ~zero for
  calls that *stay on the host*; the seed runtime spent ~200us/call on
  re-created device scalars, re-derived thresholds and a mandatory
  ``block_until_ready``.
* ``dfuchain`` — a 100-call chained DFU workload (``C = A @ C``) above
  the threshold: placement-registry hits plus async submission.
* ``shardscale`` — the same chained workload under the multi-device
  tile scheduler (``devices`` in 1/2/4): tiles/sec, per-device
  moved bytes and byte-cap eviction counters.  On this CPU container
  every logical device tier shares one physical CPU, so the numbers
  measure scheduler overhead and movement accounting, not speedup.
* ``adaptive`` — the small-gemm loop under ``adaptive=True``: the
  per-site warmup probes both paths, locks, and steady state should
  approach the fast path (the lock costs two dict hops per call).
* ``evict`` — eviction pressure: a round-robin working set sized at
  2x the ``device_bytes`` cap, run once per eviction policy
  (``evict`` in lru/lfu/refetch).  Reports calls/sec plus the
  refetched GB the cap cost — how each policy's victim choice trades
  throughput against link traffic under constant pressure.
* ``kernel`` — the pallas dispatch venue (``SCILIB_KERNELS``): the
  chained offloaded gemm loop at two shape classes with the kernel
  path off (generic XLA offload) vs on (kernel-backed closures), plus
  an adaptive run that round-robins host/XLA/pallas probes and reports
  which venue the call site locked.
* ``precision`` — split fp64 emulation (``SCILIB_PRECISION``): the
  offloaded fp64 gemm loop at two shape classes, native vs ``split2``
  vs ``split3``, reporting calls/sec *and* the measured max relative
  error of each scheme — the speedup column is only meaningful next to
  the accuracy column it was bought with.
* ``solver`` — the LAPACK solver tier (``SCILIB_LAPACK``): one
  factorization per timing for gesv/potrf/syev in three modes — host
  (the span-wrapped drivers under ``policy=cpu``), offload (the raw
  blocked kernels under DFU, no spans), and offload+pin (the drivers
  under DFU: spans pin the factor buffer for their lifetime).  gesv
  and potrf run at n=512/1024; syev runs one size class down
  (256/512) because its per-column tridiagonalization is python-
  dispatch-bound at laptop scale and the rank-2k updates it feeds the
  runtime are what the comparison is about.
* ``faults`` — fault-tolerance overhead: the chained workload under
  the Mem-Copy policy (every call stages transfers, so every call is
  exposed to injection) at 5% transfer faults.  Three configs: clean
  (no injection — the guard's fixed cost), default retries (faults
  absorbed in place), and retries=0 (every fault becomes a host
  fallback).  Reports calls/sec and the fallback percentage.

Modes are selected with the runtime's own knobs — typed
``OffloadConfig`` objects, no env mutation — so the comparison runs
the *same* code path the library ships:

* seed: ``sync=True`` + ``dispatch_cache=False`` (per-call blocking +
  per-call re-derivation, the seed's behaviour),
* fast: the defaults (async + dispatch cache).

    PYTHONPATH=src python -m benchmarks.dispatch_bench

``SCILIB_BENCH_QUICK=1`` shrinks every loop for CI smoke runs, and
``--record-trace PATH`` dumps the dfuchain workload's BLAS trace for
the autotuner walkthrough (``python -m repro.tools.autotune PATH``).
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]

_QUICK = os.environ.get("SCILIB_BENCH_QUICK", "") == "1"

SMALL_N = 64
SMALL_CALLS = 40 if _QUICK else 400
CHAIN_N = 256
CHAIN_CALLS = 20 if _QUICK else 100
SHARD_N = 512
SHARD_CALLS = 6 if _QUICK else 30
#: eviction-pressure working set: a hot set of small matrices reused
#: every phase + a cold set of big matrices streamed once per phase.
#: Uniform sizes/frequencies make every policy degenerate to LRU order;
#: this mix makes recency (lru), frequency (lfu) and refetch cost
#: (refetch) rank victims differently, which is the comparison's point.
EVICT_HOT_N, EVICT_HOT = 160, 4
EVICT_COLD_N, EVICT_COLD = 320, 6
EVICT_PHASES = 2 if _QUICK else 8
EVICT_CALLS = EVICT_PHASES * (3 * EVICT_HOT + EVICT_COLD)
PREC_NS = (256,) if _QUICK else (256, 1024)
PREC_CALLS = 4 if _QUICK else 10
PREC_ROUNDS = 2 if _QUICK else 4
SOLVER_NS = (192,) if _QUICK else (512, 1024)
SOLVER_EIG_NS = (128,) if _QUICK else (256, 512)
SOLVER_NRHS = 32
SOLVER_NB = 128
REPS = 1 if _QUICK else 3


def _mode_config(mode: str, **fields):
    """The typed config for one benchmark mode (plus extra fields);
    resets the blas-level caches so reps start cold."""
    from repro.core import blas
    from repro.core.config import OffloadConfig
    blas.clear_caches()
    if mode == "seed":
        base = OffloadConfig(sync=True, dispatch_cache=False)
    elif mode == "adaptive":
        base = OffloadConfig(adaptive=True)
    else:
        base = OffloadConfig()
    return base.replace(**fields)


def _sweep(fn, runtime, calls: int) -> float:
    """calls/sec, best of REPS (first rep also warms compile caches)."""
    best = 0.0
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn()
        runtime.sync()
        best = max(best, calls / (time.perf_counter() - t0))
    return best


def _bench_smallgemm(mode: str) -> float:
    from repro.core import blas
    from repro.core import runtime as rtm
    from repro.core.policy import host_array
    rng = np.random.default_rng(0)
    # default threshold: every call stays host
    rt = rtm.install(config=_mode_config(mode), record_trace=False)
    try:
        a = host_array(rng.standard_normal((SMALL_N, SMALL_N))
                       .astype("float32"))
        b = host_array(rng.standard_normal((SMALL_N, SMALL_N))
                       .astype("float32"))

        def loop():
            for _ in range(SMALL_CALLS):
                blas.gemm(a, b, alpha=1.0, beta=0.0)

        return _sweep(loop, rt, SMALL_CALLS)
    finally:
        rtm.uninstall()


def _bench_dfuchain(mode: str) -> float:
    from repro.core import blas
    from repro.core import runtime as rtm
    from repro.core.policy import host_array
    rng = np.random.default_rng(1)
    rt = rtm.install(config=_mode_config(mode, threshold=100.0),
                     record_trace=False)
    try:
        a = host_array(rng.standard_normal((CHAIN_N, CHAIN_N))
                       .astype("float32") / CHAIN_N)

        def loop():
            c = a
            for _ in range(CHAIN_CALLS):
                c = blas.gemm(a, c)
            return c

        return _sweep(loop, rt, CHAIN_CALLS)
    finally:
        rtm.uninstall()


def _bench_shardscale(n_dev: int) -> Tuple[float, float, int, int]:
    """Chained DFU gemms under ``devices=n_dev`` with a per-device
    byte cap sized to put the block LRU under pressure.  Returns
    (calls/sec, tiles/sec, evictions, moved bytes) summed over devices."""
    from repro.core import blas
    from repro.core import runtime as rtm
    from repro.core.policy import host_array
    rng = np.random.default_rng(2)
    rt = rtm.install(config=_mode_config(
        "fast", threshold=100.0, devices=n_dev,
        device_bytes=3 * SHARD_N * SHARD_N * 4), record_trace=False)
    try:
        a = host_array(rng.standard_normal((SHARD_N, SHARD_N))
                       .astype("float32") / SHARD_N)

        def loop():
            c = a
            for _ in range(SHARD_CALLS):
                c = blas.gemm(a, c)
            return c

        cps = _sweep(loop, rt, SHARD_CALLS)
        st = rt.stats.per_routine["sgemm"]
        tiles_per_call = st.tiles / max(1, st.calls)
        evs = sum(d.evictions for d in rt.stats.per_device.values())
        moved = sum(d.moved_bytes for d in rt.stats.per_device.values())
        return cps, cps * tiles_per_call, evs, moved
    finally:
        rtm.uninstall()


def _bench_eviction(evict_policy: str) -> Tuple[float, int, int]:
    """Round-robin gemms over a working set 2x the ``device_bytes``
    cap: constant pressure, every policy choosing different victims.
    Returns (calls/sec, evictions, refetched bytes) summed over reps."""
    from repro.core import blas
    from repro.core import runtime as rtm
    from repro.core.policy import host_array
    working = (EVICT_HOT * EVICT_HOT_N ** 2
               + EVICT_COLD * EVICT_COLD_N ** 2) * 4
    rng = np.random.default_rng(5)
    rt = rtm.install(config=_mode_config(
        "fast", threshold=100.0, device_bytes=working // 2,
        evict=evict_policy), record_trace=False)
    try:
        hot = [host_array(rng.standard_normal((EVICT_HOT_N, EVICT_HOT_N))
                          .astype("float32")) for _ in range(EVICT_HOT)]
        cold = [host_array(rng.standard_normal(
            (EVICT_COLD_N, EVICT_COLD_N)).astype("float32"))
            for _ in range(EVICT_COLD)]

        def loop():
            for _ in range(EVICT_PHASES):
                for _ in range(3):          # hot phase: reuse to exploit
                    for h in hot:
                        blas.gemm(h, h)
                for c in cold:              # cold scan: streams through
                    blas.gemm(c, c)

        cps = _sweep(loop, rt, EVICT_CALLS)
        return cps, rt.stats.evictions, rt.stats.refetched_bytes
    finally:
        rtm.uninstall()


def _bench_kernelpath(n: int, kernel: bool) -> float:
    """Chained offloaded gemms at shape n with the pallas venue off/on.
    Returns calls/sec."""
    from repro.core import blas
    from repro.core import runtime as rtm
    from repro.core.policy import host_array
    rng = np.random.default_rng(6)
    rt = rtm.install(config=_mode_config(
        "fast", threshold=100.0, kernel_path=kernel), record_trace=False)
    try:
        a = host_array(rng.standard_normal((n, n))
                       .astype("float32") / n)

        def loop():
            c = a
            for _ in range(CHAIN_CALLS):
                c = blas.gemm(a, c)
            return c

        return _sweep(loop, rt, CHAIN_CALLS)
    finally:
        rtm.uninstall()


def _bench_kernel_adaptive(n: int) -> Tuple[str, float]:
    """Adaptive warmup racing all three venues at shape n: returns the
    locked venue and the locked steady-state calls/sec."""
    from repro.core import blas
    from repro.core import runtime as rtm
    from repro.core.policy import host_array
    rng = np.random.default_rng(7)
    rt = rtm.install(config=_mode_config(
        "adaptive", threshold=100.0, kernel_path=True,
        adaptive_warmup=9), record_trace=False)
    try:
        a = host_array(rng.standard_normal((n, n))
                       .astype("float32") / n)

        def loop():
            c = a
            for _ in range(CHAIN_CALLS):
                c = blas.gemm(a, c)
            return c

        cps = _sweep(loop, rt, CHAIN_CALLS)
        venue = next((p.locked_venue for p in rt.callsites
                      if p.locked is not None), "")
        return venue or "unlocked", cps
    finally:
        rtm.uninstall()


def _bench_precision(n: int):
    """Offloaded fp64 gemm chain, native vs split2 vs split3 at shape n.

    The schemes run in *interleaved* short rounds on one runtime
    (``apply_config`` flips ``precision`` between rounds) rather than
    one-scheme-at-a-time sweeps: on shared/burstable containers a
    sequential sweep hands whichever scheme runs first the cold-burst
    clocks and every later scheme the throttled ones, which reads as a
    fake native win.  Best round per scheme is reported, like
    everywhere else in this bench.

    Returns ``{scheme: (calls/sec, max relative error vs native
    fp64)}`` — the accuracy each scheme's throughput was bought with.
    """
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.core import blas
    from repro.core import runtime as rtm
    from repro.core.policy import host_array
    schemes = ("", "split2", "split3")
    rng = np.random.default_rng(8)
    cfg = _mode_config("fast", threshold=100.0)
    rt = rtm.install(config=cfg, record_trace=False)
    best = {s: 0.0 for s in schemes}
    err = {}
    try:
        a = host_array(rng.standard_normal((n, n)) / n)
        b = host_array(rng.standard_normal((n, n)))
        for _ in range(PREC_ROUNDS):
            for s in schemes:
                rt.apply_config(cfg.replace(precision=s))
                c = a
                t0 = time.perf_counter()
                for _ in range(PREC_CALLS):
                    c = blas.gemm(a, c)
                rt.sync()
                best[s] = max(best[s],
                              PREC_CALLS / (time.perf_counter() - t0))
        ref = np.asarray(a) @ np.asarray(b)
        for s in schemes:
            rt.apply_config(cfg.replace(precision=s))
            out = np.asarray(blas.gemm(a, b))
            rt.sync()
            err[s] = float(np.max(np.abs(out - ref))
                           / np.max(np.abs(ref)))
        return {s: (best[s], err[s]) for s in schemes}
    finally:
        rtm.uninstall()


def _bench_solver(kind: str, n: int, mode: str) -> float:
    """One LAPACK-tier factorization, three ways.  ``host`` runs the
    span-wrapped drivers under ``policy=cpu`` (spans open but nothing
    pins or offloads), ``offload`` runs the raw blocked kernels under
    DFU (no spans, so the factor competes in the LRU like any buffer),
    ``pin`` runs the drivers under DFU (the span pins the factor for
    its lifetime).  Returns solves/sec, best rep."""
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.core import lapack
    from repro.core import runtime as rtm
    from repro.core.policy import host_array
    from repro.solvers import drivers
    from repro.solvers import eigen
    rng = np.random.default_rng(9)
    fields = ({"policy": "cpu"} if mode == "host"
              else {"threshold": 100.0})
    rt = rtm.install(config=_mode_config("fast", **fields),
                     record_trace=False)
    raw = mode == "offload"
    try:
        if kind == "gesv":
            a = host_array(jnp.asarray(
                rng.standard_normal((n, n)) / n + np.eye(n)))
            b = host_array(jnp.asarray(
                rng.standard_normal((n, SOLVER_NRHS))))
            if raw:
                def run():
                    lu, piv = lapack.getrf(a, nb=SOLVER_NB)
                    return lapack.getrs(lu, piv, b)
            else:
                def run():
                    return drivers.gesv(a, b, nb=SOLVER_NB)
        elif kind == "potrf":
            g = rng.standard_normal((n, n)) / n
            a = host_array(jnp.asarray(g @ g.T + np.eye(n)))
            if raw:
                def run():
                    return lapack.potrf(a, SOLVER_NB)
            else:
                def run():
                    return drivers.potrf(a, SOLVER_NB)
        else:
            g = rng.standard_normal((n, n))
            a = host_array(jnp.asarray((g + g.T) / 2))
            if raw:
                def run():
                    return eigen.syev(a, nb=SOLVER_NB)
            else:
                def run():
                    return drivers.syev(a, SOLVER_NB)
        best = 0.0
        for _ in range(REPS):       # first rep warms the compile caches
            t0 = time.perf_counter()
            jax.block_until_ready(run())
            rt.sync()
            best = max(best, 1.0 / (time.perf_counter() - t0))
        return best
    finally:
        rtm.uninstall()


def _bench_faults(spec: str, retries: int) -> Tuple[float, float, int]:
    """Chained Mem-Copy gemms under an injected transfer-fault rate.
    Returns (calls/sec, fallback %, retries) over all reps."""
    from repro.core import blas
    from repro.core import runtime as rtm
    from repro.core.policy import host_array
    rng = np.random.default_rng(4)
    rt = rtm.install(config=_mode_config(
        "fast", policy="memcopy", threshold=100.0, faults=spec,
        retries=retries, backoff_ms=0.0, breaker=0),
        record_trace=False)
    try:
        a = host_array(rng.standard_normal((CHAIN_N, CHAIN_N))
                       .astype("float32") / CHAIN_N)

        def loop():
            c = a
            for _ in range(CHAIN_CALLS):
                c = blas.gemm(a, c)
            return c

        cps = _sweep(loop, rt, CHAIN_CALLS)
        st = rt.stats.per_routine["sgemm"]
        return (cps, 100.0 * st.fallbacks / max(1, st.calls),
                rt.stats.retries)
    finally:
        rtm.uninstall()


def _record_chain_trace(path: str) -> None:
    """Run the dfuchain workload with trace recording on and dump the
    trace for the autotuner walkthrough (docs/PERF.md)."""
    from repro.core import blas
    from repro.core import runtime as rtm
    from repro.core.policy import host_array
    rng = np.random.default_rng(3)
    rt = rtm.install(config=_mode_config("fast", threshold=100.0),
                     record_trace=True)
    try:
        a = host_array(rng.standard_normal((CHAIN_N, CHAIN_N))
                       .astype("float32") / CHAIN_N)
        c = a
        for _ in range(CHAIN_CALLS):
            c = blas.gemm(a, c)
        rt.sync()
        rt.trace.dump(path)
        print(f"# trace: {len(rt.trace)} calls -> {path}")
    finally:
        rtm.uninstall()


def bench() -> List[Row]:
    rows: List[Row] = []
    # each bench builds its own typed OffloadConfig: no env mutation,
    # nothing to save/restore
    small = {m: _bench_smallgemm(m)
             for m in ("seed", "fast", "adaptive")}
    chain = {m: _bench_dfuchain(m) for m in ("seed", "fast")}
    shard = {n: _bench_shardscale(n) for n in (1, 2, 4)}
    evict = {p: _bench_eviction(p)
             for p in ("lru", "lfu", "refetch")}
    faults = {
        "clean": _bench_faults("", 2),
        "retry": _bench_faults("transfer:p=0.05,seed=7", 2),
        "fallback": _bench_faults("transfer:p=0.05,seed=7", 0),
    }
    rows.append(("dispatch.smallgemm64.seed_cps", round(small["seed"], 0),
                 "sync + uncached (seed runtime)"))
    rows.append(("dispatch.smallgemm64.fast_cps", round(small["fast"], 0),
                 "async + dispatch cache"))
    rows.append(("dispatch.smallgemm64.speedup",
                 round(small["fast"] / small["seed"], 2),
                 "acceptance: >= 2x"))
    rows.append(("dispatch.smallgemm64.adaptive_cps",
                 round(small["adaptive"], 0),
                 "adaptive=True: warmup probes + locked steady state"))
    rows.append(("dispatch.dfuchain100.seed_cps", round(chain["seed"], 0),
                 "sync + uncached (seed runtime)"))
    rows.append(("dispatch.dfuchain100.fast_cps", round(chain["fast"], 0),
                 "async + dispatch cache"))
    rows.append(("dispatch.dfuchain100.speedup",
                 round(chain["fast"] / chain["seed"], 2),
                 "chained DFU workload"))
    for n, (cps, tps, evs, moved) in sorted(shard.items()):
        rows.append((f"dispatch.shard.gemm512.d{n}_cps", round(cps, 0),
                     f"chained gemm, devices={n}"))
        rows.append((f"dispatch.shard.gemm512.d{n}_tiles_ps",
                     round(tps, 0),
                     "tile kernels/sec across device tiers"))
        rows.append((f"dispatch.shard.gemm512.d{n}_evictions", evs,
                     "per-device byte-cap LRU evictions (summed)"))
        rows.append((f"dispatch.shard.gemm512.d{n}_moved_mb",
                     round(moved / 1e6, 1),
                     "block bytes moved to device tiers (summed)"))
    for n in (128, 512):
        xla_cps = _bench_kernelpath(n, False)
        pal_cps = _bench_kernelpath(n, True)
        rows.append((f"dispatch.kernel.gemm{n}.xla_cps",
                     round(xla_cps, 0),
                     "offloaded chain, generic XLA venue"))
        rows.append((f"dispatch.kernel.gemm{n}.pallas_cps",
                     round(pal_cps, 0),
                     "offloaded chain, SCILIB_KERNELS=1"))
        rows.append((f"dispatch.kernel.gemm{n}.pallas_speedup",
                     round(pal_cps / max(1e-9, xla_cps), 3),
                     ">1 means the pallas venue wins this shape class"))
    venue, cps = _bench_kernel_adaptive(128)
    rows.append(("dispatch.kernel.adaptive128_cps", round(cps, 0),
                 f"3-venue warmup locked: {venue}"))
    for n in PREC_NS:
        prec = _bench_precision(n)
        nat_cps, nat_err = prec[""]
        rows.append((f"dispatch.precision.dgemm{n}.native_cps",
                     round(nat_cps, 0), "offloaded fp64 chain, native"))
        for s in ("split2", "split3"):
            s_cps, s_err = prec[s]
            rows.append((f"dispatch.precision.dgemm{n}.{s}_cps",
                         round(s_cps, 0),
                         f"offloaded fp64 chain, SCILIB_PRECISION={s}"))
            rows.append((f"dispatch.precision.dgemm{n}.{s}_maxrel",
                         float(f"{s_err:.3g}"),
                         "measured max relative error vs native fp64"))
            rows.append((f"dispatch.precision.dgemm{n}.{s}_speedup",
                         round(s_cps / max(1e-9, nat_cps), 3),
                         ">1 means the split scheme wins this shape"))
    for pol, (cps, evs, refetched) in evict.items():
        rows.append((f"dispatch.evict.mixed.{pol}_cps", round(cps, 0),
                     f"working set 2x cap, evict={pol}"))
        rows.append((f"dispatch.evict.mixed.{pol}_evictions", evs,
                     "cap-pressure evictions (all reps)"))
        rows.append((f"dispatch.evict.mixed.{pol}_refetched_gb",
                     round(refetched / 1e9, 3),
                     "GB re-moved for evicted-then-reused buffers"))
    for kind, ns in (("gesv", SOLVER_NS), ("potrf", SOLVER_NS),
                     ("syev", SOLVER_EIG_NS)):
        for n in ns:
            sps = {m: _bench_solver(kind, n, m)
                   for m in ("host", "offload", "pin")}
            rows.append((f"dispatch.solver.{kind}{n}.host_sps",
                         round(sps["host"], 3),
                         "span-wrapped drivers, policy=cpu"))
            rows.append((f"dispatch.solver.{kind}{n}.offload_sps",
                         round(sps["offload"], 3),
                         "raw blocked kernels under DFU (no spans)"))
            rows.append((f"dispatch.solver.{kind}{n}.pin_sps",
                         round(sps["pin"], 3),
                         "drivers under DFU: span pins the factor"))
            rows.append((f"dispatch.solver.{kind}{n}.pin_speedup",
                         round(sps["pin"] / max(1e-9, sps["host"]), 3),
                         ">1 means offload+pin beats the host path"))
    labels = {"clean": "no injection (guard fixed cost)",
              "retry": "5% transfer faults, retries=2 (absorbed)",
              "fallback": "5% transfer faults, retries=0 (host falls)"}
    for key, (cps, fb_pct, nretries) in faults.items():
        rows.append((f"dispatch.faults.{key}_cps", round(cps, 0),
                     labels[key]))
        rows.append((f"dispatch.faults.{key}_fallback_pct",
                     round(fb_pct, 2), "calls served on the host path"))
    rows.append(("dispatch.faults.retry_retries", faults["retry"][2],
                 "transient faults absorbed in place (all reps)"))
    return rows


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--record-trace", default="",
                    help="also dump the dfuchain workload's BLAS trace "
                         "here (autotuner input)")
    args = ap.parse_args()
    print("name,value,derived")
    for name, value, derived in bench():
        print(f"{name},{value},{derived}")
    if args.record_trace:
        _record_chain_trace(args.record_trace)


if __name__ == "__main__":
    main()

"""One benchmark per paper table. Each function returns CSV-ready rows:
(name, value, derived/paper-reference). Model numbers come from the
calibrated GH200 memtier replay; where the paper printed a measured
value, it is carried alongside for direct comparison.
"""
from __future__ import annotations

from typing import List, Tuple

Row = Tuple[str, float, str]


# ----------------------------------------------------------------------- #
# Table 1: STREAM bandwidths (spec constants echoed + key ratios)          #
# ----------------------------------------------------------------------- #
def table1_stream() -> List[Row]:
    from repro.memtier import GH200
    g = GH200
    rows = [
        ("t1.cpu_lpddr_GBs", g.cpu_local_bw / 1e9, "paper=418.2"),
        ("t1.cpu_hbm_GBs", g.cpu_remote_bw / 1e9, "paper=141.9"),
        ("t1.gpu_hbm_GBs", g.gpu_local_bw / 1e9, "paper=3679.5"),
        ("t1.gpu_lpddr_GBs", g.gpu_remote_bw / 1e9, "paper=610.4"),
        ("t1.gpu_vs_cpu_hbm_ratio", g.gpu_local_bw / g.cpu_remote_bw,
         "locality matters: ~26x"),
    ]
    return rows


# ----------------------------------------------------------------------- #
# Table 3: MuST 50-node policy comparison                                  #
# ----------------------------------------------------------------------- #
MUST_NONBLAS_S = 238.0     # paper: 2318.4 total - 2080 zgemm+ztrsm
PARSEC_NONBLAS_S = 145.0   # paper: 415.1 total - 270.1 dgemm


def _must_reports():
    from repro.apps import lsms
    from repro.memtier import GH200, replay_trace
    trace = lsms.production_trace()
    return replay_trace(trace, spec=GH200,
                        policies=("cpu", "memcopy", "counter", "dfu"))


def table3_must() -> List[Row]:
    reps = _must_reports()
    paper_total = {"cpu": 2318.4, "memcopy": 1098.0, "counter": 858.0,
                   "dfu": 824.0}
    rows = []
    for p, r in reps.items():
        total = r.total_s + MUST_NONBLAS_S
        rows.append((f"t3.{p}.total_s", round(total, 1),
                     f"paper={paper_total[p]}"))
        rows.append((f"t3.{p}.movement_s", round(r.movement_s, 1),
                     {"memcopy": "paper=291.7", "dfu": "paper=4.8"}.get(
                         p, "")))
    rows.append(("t3.dfu_speedup_vs_cpu",
                 round((reps["cpu"].total_s + MUST_NONBLAS_S)
                       / (reps["dfu"].total_s + MUST_NONBLAS_S), 2),
                 "paper=2.8x"))
    rows.append(("t3.dfu_mean_reuse", round(reps["dfu"].mean_reuse, 0),
                 "paper~780 (per-matrix; ours counts block-level calls)"))
    return rows


# ----------------------------------------------------------------------- #
# Table 4 / Figure 3: strong scaling 25..200 nodes                         #
# ----------------------------------------------------------------------- #
def table4_scaling() -> List[Row]:
    from repro.apps import lsms
    from repro.memtier import GH200, replay_trace
    paper = {25: (4598.1, 1550.9), 50: (2318.4, 823.8),
             75: (1842.6, 623.1), 100: (1192.2, 446.8),
             150: (947.0, 357.5), 200: (None, 253.3)}
    rows = []
    for nodes, (p_cpu, p_dfu) in paper.items():
        atoms = max(1, 5600 // nodes)
        # replay a few atoms and scale linearly (atom solves independent)
        probe = min(atoms, 8)
        trace = lsms.production_trace(atoms_per_node=probe)
        reps = replay_trace(trace, spec=GH200, policies=("cpu", "dfu"))
        scale = atoms / probe
        nonblas = MUST_NONBLAS_S * (50.0 / nodes)
        cpu = reps["cpu"].total_s * scale + nonblas
        dfu = reps["dfu"].total_s * scale + nonblas
        rows.append((f"t4.n{nodes}.cpu_s", round(cpu, 1),
                     f"paper={p_cpu}"))
        rows.append((f"t4.n{nodes}.dfu_s", round(dfu, 1),
                     f"paper={p_dfu}"))
        if p_cpu:
            rows.append((f"t4.n{nodes}.speedup", round(cpu / dfu, 2),
                         f"paper={round(p_cpu / p_dfu, 2)}"))
    return rows


# ----------------------------------------------------------------------- #
# Table 5: PARSEC single-node policy comparison                            #
# ----------------------------------------------------------------------- #
def table5_parsec() -> List[Row]:
    from repro.apps import dft
    from repro.memtier import GH200, replay_trace
    trace = dft.production_trace()
    reps = replay_trace(trace, spec=GH200,
                        policies=("cpu", "memcopy", "counter", "dfu"))
    paper_total = {"cpu": 415.1, "memcopy": 425.7, "counter": 470.0,
                   "dfu": 220.3}
    rows = []
    for p, r in reps.items():
        total = r.total_s + PARSEC_NONBLAS_S
        rows.append((f"t5.{p}.total_s", round(total, 1),
                     f"paper={paper_total[p]}"))
    rows.append(("t5.memcopy.movement_s",
                 round(reps["memcopy"].movement_s, 1), "paper=220.7"))
    rows.append(("t5.dfu.movement_s",
                 round(reps["dfu"].movement_s, 2), "paper=1.3"))
    rows.append(("t5.dfu.dgemm_s",
                 round(reps["dfu"].blas_device_s
                       + reps["dfu"].blas_host_s, 1), "paper=29.1"))
    rows.append(("t5.dfu_speedup_vs_cpu",
                 round((reps["cpu"].total_s + PARSEC_NONBLAS_S)
                       / (reps["dfu"].total_s + PARSEC_NONBLAS_S), 2),
                 "paper=1.9x"))
    return rows


# ----------------------------------------------------------------------- #
# Table 6: access-counter migration behaviour                              #
# ----------------------------------------------------------------------- #
def table6_counter() -> List[Row]:
    from repro.core.trace import Trace
    from repro.memtier import GH200, MemTierSimulator
    cases = {
        "1000^3": ((1000, 1000, 1000), ("device", "device", "device")),
        "5000^3": ((5000, 5000, 5000), ("device", "device", "host")),
        "20000^3": ((20000, 20000, 20000), ("device", "host", "host")),
        "skinny": ((32, 2400, 93536), ("device", "host", "host")),
    }
    rows = []
    for name, ((m, n, k), want) in cases.items():
        t = Trace()
        a = t.new_buffer(m * k * 8, "A")
        b = t.new_buffer(k * n * 8, "B")
        c = t.new_buffer(m * n * 8, "C")
        for _ in range(5):
            t.gemm("d", m, n, k, a, b, c)
        sim = MemTierSimulator(GH200, policy="counter", threshold=0,
                               seed=1)
        sim.run(t)
        got = tuple(sim.residency(x) for x in (a, b, c))
        rows.append((f"t6.{name}.match_paper", float(got == want),
                     f"A,B,C -> {','.join(got)} (paper: {','.join(want)})"))
    return rows


# ----------------------------------------------------------------------- #
# Table 7: page-size impact                                                #
# ----------------------------------------------------------------------- #
def table7_pagesize() -> List[Row]:
    """CPU dgemm on remote (HBM) memory under 4K vs 64K pages.

    The model charges remote traffic at the measured bandwidths with the
    64K penalty; compute-bound cases clip at chip FLOPs. Absolute paper
    milliseconds carried for reference.
    """
    from repro.memtier import GH200, GH200_4K
    rows = []
    # passes = remote re-streaming factor of blocked dgemm: the square
    # case re-reads tiles ~8x (small cache share per core); the skinny
    # case streams the big panel once (each element reused M=32 times
    # from cache within a pass)
    workloads = {
        "2000^3": (2.0 * 2000**3, 3 * 2000 * 2000 * 8, 8.0),
        "skinny": (2.0 * 32 * 2400 * 93536, (32 * 93536 + 93536 * 2400
                                             + 32 * 2400) * 8, 1.0),
    }
    for name, (flops, nbytes, passes) in workloads.items():
        for spec, tag in ((GH200_4K, "4K"), (GH200, "64K")):
            chip_flops = spec.cpu_flops / 2  # Table 7 is one 72c chip
            remote = spec.cpu_remote_bw
            if spec.page_size >= 64 * 1024:
                remote /= spec.cpu_remote_64k_penalty
            # blocked dgemm re-streams operands ~`passes` times remotely
            t = max(flops / (chip_flops * 0.85),
                    passes * nbytes / remote) * 1e3
            paper = {("2000^3", "4K"): 5.3, ("2000^3", "64K"): 10.0,
                     ("skinny", "4K"): 15.5, ("skinny", "64K"): 23.2}[
                         (name, tag)]
            rows.append((f"t7.cpu_hbm.{name}.{tag}_ms", round(t, 2),
                         f"paper={paper}"))
    return rows


# ----------------------------------------------------------------------- #
# Table 8: page-alignment impact on device kernels                         #
# ----------------------------------------------------------------------- #
def table8_alignment() -> List[Row]:
    from repro.core.trace import Trace
    from repro.memtier import GH200, MemTierSimulator
    # Table 8 is an isolated cublasDgemm microbench: clean square shapes
    # run at full cuBLAS efficiency (unlike the LU-stream calibration)
    spec = GH200.with_(gpu_eff=(("gemm", 1.0),))
    rows = []
    for name, (m, n, k), paper_un, paper_al in (
            ("2000^3", (2000, 2000, 2000), 0.39, 0.29),
            ("skinny", (32, 2400, 93536), 0.94, 0.64)):
        for aligned, paper in ((False, paper_un), (True, paper_al)):
            t = Trace()
            a = t.new_buffer(m * k * 8, "A")
            b = t.new_buffer(k * n * 8, "B")
            c = t.new_buffer(m * n * 8, "C")
            t.gemm("d", m, n, k, a, b, c)
            t.gemm("d", m, n, k, a, b, c)   # steady state (resident)
            sim = MemTierSimulator(spec, policy="dfu", threshold=0,
                                   aligned_alloc=aligned)
            rep = sim.run(t)
            t_ms = (rep.blas_device_s / 2) * 1e3   # steady-state per call
            tag = "aligned" if aligned else "unaligned"
            rows.append((f"t8.{name}.{tag}_ms", round(t_ms, 3),
                         f"paper={paper}"))
    return rows

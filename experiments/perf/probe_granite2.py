import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses, jax
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import axis_env_for, build_cell
from repro.models.registry import Model, get_config
from repro.models.sharding import axis_env

cfg0 = get_config("granite_moe_1b_a400m")
mesh = make_production_mesh()
def probe(cfg, tag):
    model = Model.from_config(cfg)
    with mesh, axis_env(axis_env_for(mesh)):
        cell = build_cell(model, tag, "train_4k", mesh, unroll=True)
        compiled = jax.jit(cell.fn, out_shardings=cell.out_shardings,
                           donate_argnums=cell.donate_argnums).lower(*cell.args).compile()
        c = compiled.cost_analysis()
        print(f"{tag:24s} flops={c.get('flops',0):.3e} bytes={c.get('bytes accessed',0):.3e}")

probe(dataclasses.replace(cfg0, n_layers=2, d_ff_expert=8), "L2_tinyff")   # dispatch only
probe(dataclasses.replace(cfg0, n_layers=2, top_k=1), "L2_top1")           # k-scaling
probe(dataclasses.replace(cfg0, n_layers=2, n_experts=8, top_k=8), "L2_e8k8")  # E-scaling

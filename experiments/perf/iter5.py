import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
OUT = "experiments/perf"
run_cell("moonshot_v1_16b_a3b", "train_4k", False, moe_impl="a2a",
         out_dir=OUT, tag="D4_a2a")
print("ITER5 DONE")

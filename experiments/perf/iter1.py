import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
OUT = "experiments/perf"
run_cell("qwen2_5_32b", "prefill_32k", False, out_dir=OUT, tag="A1_lastonly")
run_cell("qwen2_5_32b", "train_4k", False, out_dir=OUT, tag="B2_vpce")
run_cell("qwen2_5_32b", "train_4k", False, overrides={"pad_heads_to": 48}, out_dir=OUT, tag="B12_pad48_vpce")
run_cell("granite_moe_1b_a400m", "train_4k", False, overrides={"attn_chunk_q": 512}, out_dir=OUT, tag="C1_chunk512")
print("ITER1 DONE")

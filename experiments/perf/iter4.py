import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
OUT = "experiments/perf"
# sorted dispatch (now default) on the other MoE/hybrid cells
run_cell("moonshot_v1_16b_a3b", "train_4k", False, out_dir=OUT, tag="D1_sortdisp")
run_cell("jamba_1_5_large_398b", "train_4k", False, out_dir=OUT, tag="D2_sortdisp")
run_cell("moonshot_v1_16b_a3b", "prefill_32k", False, out_dir=OUT, tag="D3_sortdisp")
# ZeRO-1 optimizer sharding: capacity effect on the paper-rep cell
run_cell("qwen2_5_32b", "train_4k", False, overrides={"pad_heads_to": 48},
         zero=True, out_dir=OUT, tag="B6_pad48_zero")
print("ITER4 DONE")

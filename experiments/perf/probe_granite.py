import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import dataclasses, jax
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import axis_env_for, build_cell
from repro.models.registry import Model, get_config
from repro.models.sharding import axis_env

cfg0 = get_config("granite_moe_1b_a400m")
mesh = make_production_mesh()
def probe(tagged_cfg, label):
    cfg, tag = tagged_cfg, label
    model = Model.from_config(cfg)
    with mesh, axis_env(axis_env_for(mesh)):
        cell = build_cell(model, tag, "train_4k", mesh, unroll=True)
        compiled = jax.jit(cell.fn, out_shardings=cell.out_shardings,
                           donate_argnums=cell.donate_argnums).lower(*cell.args).compile()
        c = compiled.cost_analysis()
        print(f"{tag:24s} flops={c.get('flops',0):.3e} bytes={c.get('bytes accessed',0):.3e} trans={c.get('transcendentals',0):.3e}")
        return c.get('flops', 0)

base = probe(dataclasses.replace(cfg0, n_layers=1), "L1_base")
f2 = probe(dataclasses.replace(cfg0, n_layers=2), "L2_base")
print(f"per-layer slope: {f2-base:.3e}")
# isolate: expert count 32 -> 4 (same top_k? top_k 8>4 invalid; use top_k 2, E 4)
probe(dataclasses.replace(cfg0, n_layers=2, n_experts=4, top_k=2), "L2_tinymoe")
# isolate: capacity factor 1.25 -> 0.25
probe(dataclasses.replace(cfg0, n_layers=2, capacity_factor=0.25), "L2_lowcap")
# isolate: chunked attention
probe(dataclasses.replace(cfg0, n_layers=2, attn_chunk_q=512), "L2_chunk")

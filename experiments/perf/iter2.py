import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
OUT = "experiments/perf"
# C2: granite with sort-based MoE dispatch (now default)
run_cell("granite_moe_1b_a400m", "train_4k", False, out_dir=OUT, tag="C2_sortdisp")
# A2: prefill with padded heads
run_cell("qwen2_5_32b", "prefill_32k", False, overrides={"pad_heads_to": 48}, out_dir=OUT, tag="A2_pad48")
# B3: pad48 + full remat (attack the memory term)
run_cell("qwen2_5_32b", "train_4k", False, overrides={"pad_heads_to": 48}, remat="full", out_dir=OUT, tag="B3_pad48_full")
print("ITER2 DONE")

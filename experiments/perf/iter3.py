import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
OUT = "experiments/perf"
# A3: prefill pad48 + fresh-kv + chunked attention
run_cell("qwen2_5_32b", "prefill_32k", False,
         overrides={"pad_heads_to": 48, "prefill_fresh_kv": True,
                    "attn_chunk_q": 2048}, out_dir=OUT, tag="A3_freshkv_chunk")
# B4: pad48 + n_micro=8 (capacity fix without remat traffic)
run_cell("qwen2_5_32b", "train_4k", False, overrides={"pad_heads_to": 48},
         n_micro=8, out_dir=OUT, tag="B4_pad48_micro8")
# B5: pad48 + chunked attention in train (flop+logit-traffic saving)
run_cell("qwen2_5_32b", "train_4k", False,
         overrides={"pad_heads_to": 48, "attn_chunk_q": 1024},
         out_dir=OUT, tag="B5_pad48_chunk")
print("ITER3 DONE")

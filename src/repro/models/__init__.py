"""Model substrate: the ten assigned architectures behind one API."""
from repro.models.registry import ARCHS, Model, build, canon, get_config

__all__ = ["ARCHS", "Model", "build", "canon", "get_config"]

"""Mixture-of-Experts layer (granite-moe, moonshot, jamba).

Two execution plans behind one parameter layout:

* ``scatter`` (default, production): top-k routing with capacity-bounded
  scatter into per-expert buffers, batched expert GEMMs, gather+combine.
  Pure pjit-shardable XLA: expert weights and buffers shard over the
  ``model`` axis (expert parallelism); the scatter/gather lower to the
  all-to-all-style collectives visible in the dry-run roofline.
* ``dense``: every expert on every token, probability-weighted — O(E)
  FLOPs, used only by the tiny smoke configs where it doubles as the
  routing oracle for tests.

The router aux (load-balance) loss follows Switch-Transformer:
``E * mean(frac_tokens_e * mean_prob_e)``.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.layers import _dense_init
from repro.models.sharding import shard

Params = Dict[str, jax.Array]


def init_moe(key, cfg: ModelConfig) -> Params:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": _dense_init(kr, (d, e)),
        "wg": _dense_init(kg, (e, d, ff)),
        "wu": _dense_init(ku, (e, d, ff)),
        "wd": _dense_init(kd, (e, ff, d),
                          scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }


def _route(p: Params, cfg: ModelConfig, xf: jax.Array):
    """Router probabilities + aux loss. xf: (N, d)."""
    logits = kops.matmul(xf, p["router"].astype(xf.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(
        weights.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: encourages uniform expert load
    e = cfg.n_experts
    sel = jax.nn.one_hot(idx[:, 0], e)            # primary assignment
    frac_tokens = sel.mean(0)
    mean_prob = probs.mean(0)
    aux = e * jnp.sum(frac_tokens * mean_prob) * cfg.router_aux_coef
    return weights, idx, aux


def _expert_ffn(p: Params, xe: jax.Array) -> jax.Array:
    """Batched expert SwiGLU. xe: (E, C, d) -> (E, C, d)."""
    dt = xe.dtype
    g = kops.matmul(xe, p["wg"].astype(dt))
    u = kops.matmul(xe, p["wu"].astype(dt))
    h = shard(jax.nn.silu(g) * u, "model", None, None)
    return kops.matmul(h, p["wd"].astype(dt))


def moe_fwd(p: Params, cfg: ModelConfig, x: jax.Array,
            impl: str = "scatter") -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d) -> (out, aux_loss)."""
    if impl == "a2a":
        from repro.models.sharding import get_env
        env = get_env()
        if env is not None and env.mesh is not None \
                and cfg.n_experts % dict(env.sizes).get(env.model, 1) == 0:
            return moe_fwd_a2a(p, cfg, x, env.mesh, env.batch, env.model)
        impl = "scatter"                    # no mesh bound: fall back
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)
    weights, idx, aux = _route(p, cfg, xf)

    if impl == "dense":
        # (E, N, d): every expert everywhere; weight-combine
        h = _expert_ffn(p, jnp.broadcast_to(xf, (cfg.n_experts, n, d)))
        onehot = jax.nn.one_hot(idx, cfg.n_experts,
                                dtype=jnp.float32)          # (N,k,E)
        comb = (onehot * weights[..., None]).sum(1)         # (N,E)
        out = jnp.einsum("end,ne->nd", h.astype(jnp.float32), comb)
        return out.reshape(b, t, d).astype(x.dtype), aux

    # ---------------- scatter plan ---------------- #
    e, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(k * n * cfg.capacity_factor / e))
    cap = max(8, min(cap, n))

    flat_e = idx.reshape(-1)                                # (N*k,)
    # position-in-expert via stable sort (§Perf iteration C2): a token-
    # axis cumsum of the (N*k, E) one-hot costs O((N*k)^2)-class work in
    # XLA's reduce-window lowering; sort + tiny E-length cumsum is
    # O(N*k log) and matches megablocks' TPU-side dispatch. Stable order
    # preserves the FIFO capacity-drop semantics exactly.
    nk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                    # (E,) tiny
    ranks_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[sorted_e]
    pos_in_e = jnp.zeros((nk,), jnp.int32).at[order].set(ranks_sorted)
    keep = pos_in_e < cap
    dest = jnp.where(keep, flat_e * cap + pos_in_e, e * cap)

    xrep = jnp.repeat(xf, k, axis=0)                        # (N*k, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[dest].add(xrep)
    xe = shard(buf[:e * cap].reshape(e, cap, d), "model", None, None)

    h = _expert_ffn(p, xe)                                  # (E, C, d)

    hflat = jnp.concatenate(
        [h.reshape(e * cap, d), jnp.zeros((1, d), h.dtype)], axis=0)
    gathered = hflat[dest]                                  # (N*k, d)
    gathered = gathered.reshape(n, k, d).astype(jnp.float32)
    out = (gathered * weights[..., None]).sum(1)
    return out.reshape(b, t, d).astype(x.dtype), aux


# ----------------------------------------------------------------------- #
# explicit expert-parallel plan: shard_map + all_to_all                    #
# ----------------------------------------------------------------------- #
def _dispatch_local(cfg: ModelConfig, xf, weights, idx, cap: int):
    """Sort-based capacity dispatch on one shard. Returns (buf, dest,
    keep) with buf (E, cap, d) ordered globally by expert id."""
    e, k = cfg.n_experts, cfg.top_k
    n, d = xf.shape
    flat_e = idx.reshape(-1)
    nk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    ranks_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[sorted_e]
    pos_in_e = jnp.zeros((nk,), jnp.int32).at[order].set(ranks_sorted)
    keep = pos_in_e < cap
    dest = jnp.where(keep, flat_e * cap + pos_in_e, e * cap)
    xrep = jnp.repeat(xf, k, axis=0)
    buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[dest].add(xrep)
    return buf[:e * cap].reshape(e, cap, d), dest


def moe_fwd_a2a(p: Params, cfg: ModelConfig, x: jax.Array, mesh,
                batch_axes: tuple, model_axis: str = "model"
                ) -> Tuple[jax.Array, jax.Array]:
    """Expert parallelism with explicit all-to-all (1000+-node plan).

    Tokens stay sharded over the batch axes; each device dispatches its
    local tokens into per-expert buffers, all_to_all's them to the
    expert owners along ``model_axis``, runs its expert shard's FFN, and
    all_to_all's results back — two a2a's of (k·N_loc·cf·d) bytes per
    device instead of resharding gathers. Per-device capacity semantics
    (standard for EP).
    """
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map as _sm
        shard_map = _sm.shard_map if hasattr(_sm, "shard_map") else _sm
    except ImportError:  # pragma: no cover - older jax
        from jax.experimental.shard_map import shard_map

    e, k = cfg.n_experts, cfg.top_k
    m_sz = mesh.shape[model_axis]
    assert e % m_sz == 0, (e, m_sz)
    e_loc = e // m_sz
    bt = batch_axes[0] if len(batch_axes) == 1 else tuple(batch_axes)
    all_axes = tuple(mesh.axis_names)

    def body(xl, router, wg, wu, wd):
        bsz, t, d = xl.shape
        n_loc = bsz * t
        xf = xl.reshape(n_loc, d)
        logits = jnp.dot(xf, router.astype(xf.dtype))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        weights, idx = jax.lax.top_k(probs, k)
        weights = weights / jnp.maximum(
            weights.sum(-1, keepdims=True), 1e-9)
        sel = jax.nn.one_hot(idx[:, 0], e)
        aux = e * jnp.sum(sel.mean(0) * probs.mean(0)) \
            * cfg.router_aux_coef
        aux = jax.lax.pmean(aux, all_axes)

        cap = max(8, int(math.ceil(k * n_loc * cfg.capacity_factor / e)))
        buf, dest = _dispatch_local(cfg, xf, weights, idx, cap)
        # ship token blocks to their expert owners
        recv = jax.lax.all_to_all(
            buf.reshape(m_sz, e_loc, cap, d), model_axis,
            split_axis=0, concat_axis=0)               # (M, E_loc, C, d)
        xe = recv.reshape(e_loc, m_sz * cap, d)
        g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
        h = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                       wd.astype(xe.dtype))
        # ship results home
        back = jax.lax.all_to_all(
            h.reshape(e_loc, m_sz, cap, d).swapaxes(0, 1), model_axis,
            split_axis=0, concat_axis=0)               # (M, E_loc, C, d)
        hflat = jnp.concatenate(
            [back.reshape(e * cap, d), jnp.zeros((1, d), h.dtype)], 0)
        gathered = hflat[dest].reshape(n_loc, k, d).astype(jnp.float32)
        out = (gathered * weights[..., None]).sum(1)
        return out.reshape(bsz, t, d).astype(xl.dtype), aux

    import inspect
    params = inspect.signature(shard_map).parameters
    # the no-replication-check kwarg was renamed check_rep -> check_vma
    relax = ({"check_vma": False} if "check_vma" in params
             else {"check_rep": False})
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(bt, None, None), P(), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=(P(bt, None, None), P()),
        **relax)
    return fn(x, p["router"], p["wg"], p["wu"], p["wd"])

"""Logical-axis sharding environment for the model stack.

Model code annotates activations with *logical* dims ("batch", "model",
"seq"); the launcher binds them to physical mesh axes (single-pod:
``data``/``model``; multi-pod: batch spans ``("pod", "data")``). Outside
any environment (CPU smoke tests) annotations are no-ops, so the same
model code runs unsharded on one device and SPMD on 512.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisEnv:
    batch: Tuple[str, ...] = ("data",)
    model: str = "model"
    seq: Optional[str] = None       # sequence-parallel axis, if any
    sizes: Tuple[Tuple[str, int], ...] = ()   # mesh axis sizes
    # concrete mesh for shard_map sub-blocks (e.g. a2a expert parallel);
    # compare=False keeps the dataclass hashable/comparable by config
    mesh: Optional[object] = dataclasses.field(default=None, compare=False)

    def axis_size(self, name) -> int:
        d = dict(self.sizes)
        if isinstance(name, tuple):
            n = 1
            for a in name:
                n *= d.get(a, 1)
            return n
        return d.get(name, 1)


_ENV: Optional[AxisEnv] = None


def set_env(env: Optional[AxisEnv]) -> None:
    global _ENV
    _ENV = env


def get_env() -> Optional[AxisEnv]:
    return _ENV


@contextlib.contextmanager
def axis_env(env: AxisEnv):
    prev = _ENV
    set_env(env)
    try:
        yield env
    finally:
        set_env(prev)


def logical(*dims: Optional[str]) -> P:
    """Translate logical dims to a PartitionSpec under the active env."""
    env = _ENV or AxisEnv()
    out = []
    for d in dims:
        if d is None:
            out.append(None)
        elif d == "batch":
            out.append(env.batch if len(env.batch) > 1 else env.batch[0])
        elif d == "model":
            out.append(env.model)
        elif d == "seq":
            out.append(env.seq)
        else:  # already-physical axis name
            out.append(d)
    return P(*out)


def shard(x: jax.Array, *dims: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the env; identity when unbound.
    Dims not divisible by their mesh axis are left unconstrained."""
    if _ENV is None:
        return x
    spec = list(logical(*dims))
    spec += [None] * (x.ndim - len(spec))
    for i, ax in enumerate(spec):
        if ax is not None and x.shape[i] % _ENV.axis_size(ax) != 0:
            spec[i] = None
    return jax.lax.with_sharding_constraint(x, P(*spec))

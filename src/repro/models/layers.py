"""Shared transformer building blocks (all ten architectures).

Functional style: ``init_*`` builds parameter pytrees, ``*_fwd`` applies
them. The matmul hot spots route through :mod:`repro.kernels.ops` so the
TPU path hits the Pallas kernels, and activations carry logical sharding
annotations (:mod:`repro.models.sharding`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.sharding import shard

Params = Dict[str, jax.Array]


def _dense_init(key, shape, scale=None):
    scale = scale if scale is not None else 0.02
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)


# ----------------------------------------------------------------------- #
# norms                                                                    #
# ----------------------------------------------------------------------- #
def rms_norm(x: jax.Array, w: jax.Array, eps: float,
             offset: float = 0.0) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (w.astype(jnp.float32) + offset)).astype(x.dtype)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w + b).astype(x.dtype)


# ----------------------------------------------------------------------- #
# rotary position embeddings                                               #
# ----------------------------------------------------------------------- #
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, T, D); positions: (B, T) or (T,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
        ang = ang[None, None]                      # (1,1,T,half)
    else:
        ang = positions.astype(jnp.float32)[:, None, :, None] * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------------- #
# attention                                                                #
# ----------------------------------------------------------------------- #
def init_attention(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    hp = cfg.padded_heads    # TP-divisible head padding (zero-masked)
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    p = {
        "wq": _dense_init(kq, (d, hp * hd)),
        "wk": _dense_init(kk, (d, cfg.n_kv_heads * hd)),
        "wv": _dense_init(kv, (d, cfg.n_kv_heads * hd)),
        "wo": _dense_init(ko, (hp * hd, d),
                          scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hp * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    return p


def attention_fwd(p: Params, cfg: ModelConfig, x: jax.Array,
                  positions: jax.Array, *, window: int = 0,
                  causal: bool = True,
                  cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  cache_pos: Optional[jax.Array] = None,
                  kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                  ) -> Tuple[jax.Array, Optional[Tuple]]:
    """Self- (or cross-) attention with optional decode cache.

    cache: (k_cache, v_cache) each (B, Hkv, S, D), written at cache_pos.
    kv_override: precomputed (k, v) for cross-attention.
    """
    b, t, d = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.padded_heads, cfg.n_kv_heads
    dt = x.dtype

    q = kops.matmul(x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    q = shard(q.reshape(b, t, hq, hd).transpose(0, 2, 1, 3),
              "batch", "model", None, None)

    if kv_override is None:
        k = kops.matmul(x, p["wk"].astype(dt))
        v = kops.matmul(x, p["wv"].astype(dt))
        if "bk" in p:
            k = k + p["bk"].astype(dt)
            v = v + p["bv"].astype(dt)
        k = k.reshape(b, t, hkv, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, t, hkv, hd).transpose(0, 2, 1, 3)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override

    new_cache = None
    kv_len = None
    if cache is not None:
        kc, vc = cache
        pos = cache_pos if cache_pos is not None else jnp.zeros((), jnp.int32)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, 0, pos, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, 0, pos, 0))
        new_cache = (kc, vc)
        if cfg.prefill_fresh_kv and t > 1 and kv_override is None:
            # from-scratch prefill: the live keys ARE the fresh k/v; skip
            # streaming the padded cache back (§Perf iteration A3)
            kv_len = None
        else:
            k, v = kc, vc
            kv_len = pos + t

    fresh_prefill = (cache is not None and cfg.prefill_fresh_kv
                     and t > 1 and kv_override is None)
    chunk_q = cfg.attn_chunk_q if ((cache is None or fresh_prefill)
                                   and causal and window == 0) else 0
    out = kops.attention(q, k.astype(dt), v.astype(dt), causal=causal,
                         window=window, softcap=cfg.attn_softcap,
                         kv_len=kv_len, chunk_q=chunk_q)
    if hq > cfg.n_heads:
        # zero the padded heads (exact n_heads math; their wq/wo slices
        # get zero grads). Padding lives WITHIN each KV group: head
        # h = g*(hq/hkv) + j is real iff j < n_heads/hkv, so the GQA
        # q->kv mapping (h // group) of real heads is unchanged.
        group = hq // hkv
        real_per_group = cfg.n_heads // hkv
        mask = ((jnp.arange(hq) % group) < real_per_group).astype(
            out.dtype)
        out = out * mask[None, :, None, None]
    out = out.transpose(0, 2, 1, 3).reshape(b, t, hq * hd)
    out = kops.matmul(out, p["wo"].astype(dt))
    return shard(out, "batch", "seq", None), new_cache


def cross_kv(p: Params, cfg: ModelConfig, enc: jax.Array):
    """Precompute cross-attention K/V from encoder output."""
    b, s, _ = enc.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = kops.matmul(enc, p["wk"].astype(enc.dtype))
    v = kops.matmul(enc, p["wv"].astype(enc.dtype))
    k = k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    return k, v


# ----------------------------------------------------------------------- #
# feed-forward                                                             #
# ----------------------------------------------------------------------- #
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    if cfg.use_layernorm_gelu:       # whisper-style 2-matrix GELU MLP
        return {"w1": _dense_init(kg, (d, ff)),
                "b1": jnp.zeros((ff,), jnp.float32),
                "w2": _dense_init(kd, (ff, d),
                                  scale=0.02 / (2 * cfg.n_layers) ** 0.5),
                "b2": jnp.zeros((d,), jnp.float32)}
    return {"wg": _dense_init(kg, (d, ff)),
            "wu": _dense_init(ku, (d, ff)),
            "wd": _dense_init(kd, (ff, d),
                              scale=0.02 / (2 * cfg.n_layers) ** 0.5)}


def mlp_fwd(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if "w1" in p:
        h = kops.matmul(x, p["w1"].astype(dt)) + p["b1"].astype(dt)
        h = jax.nn.gelu(h)
        return kops.matmul(h, p["w2"].astype(dt)) + p["b2"].astype(dt)
    g = kops.matmul(x, p["wg"].astype(dt))
    u = kops.matmul(x, p["wu"].astype(dt))
    h = shard(jax.nn.silu(g) * u, "batch", "seq", "model")
    return kops.matmul(h, p["wd"].astype(dt))


# ----------------------------------------------------------------------- #
# embeddings / unembedding                                                 #
# ----------------------------------------------------------------------- #
def init_embed(key, cfg: ModelConfig) -> Params:
    ke, ku = jax.random.split(key)
    p = {"table": _dense_init(ke, (cfg.vocab, cfg.d_model), scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(ku, (cfg.d_model, cfg.vocab))
    return p


def embed_fwd(p: Params, cfg: ModelConfig, tokens: jax.Array,
              dtype) -> jax.Array:
    x = jnp.take(p["table"], tokens, axis=0).astype(dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return shard(x, "batch", "seq", None)


def unembed_fwd(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = x.dtype
    if cfg.tie_embeddings:
        logits = kops.matmul(x, p["table"].T.astype(dt))
    else:
        logits = kops.matmul(x, p["unembed"].astype(dt))
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap > 0:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return shard(logits, "batch", "seq", "model")

"""Architecture registry: config lookup + family-dispatched model API.

``get_config(arch)`` loads ``repro.configs.<arch>.CONFIG``;
``Model.from_config`` wraps the family's init/forward/cache functions
behind one interface used by the training loop, the serving loop and the
dry-run launcher.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer

ARCHS = (
    "qwen1_5_4b",
    "gemma2_9b",
    "qwen2_5_32b",
    "deepseek_7b",
    "whisper_tiny",
    "granite_moe_1b_a400m",
    "moonshot_v1_16b_a3b",
    "mamba2_1_3b",
    "jamba_1_5_large_398b",
    "pixtral_12b",
)

# public ids use dashes/dots; module names use underscores
def canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.CONFIG


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    @classmethod
    def from_config(cls, cfg: ModelConfig) -> "Model":
        return cls(cfg)

    # ------------------------------------------------------------------ #
    def init(self, key) -> Dict[str, Any]:
        if self.cfg.family == "encdec":
            return encdec.init_model(key, self.cfg)
        return transformer.init_model(key, self.cfg)

    def forward(self, params, tokens, **kw):
        """Returns (logits, aux_loss, new_cache)."""
        if self.cfg.family == "encdec":
            kw.pop("moe_impl", None)        # no MoE in the enc-dec family
            return encdec.forward(params, self.cfg, tokens, **kw)
        return transformer.forward(params, self.cfg, tokens, **kw)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        if self.cfg.family == "encdec":
            return encdec.init_cache(self.cfg, batch, max_len, dtype)
        return transformer.init_cache(self.cfg, batch, max_len, dtype)

    # ------------------------------------------------------------------ #
    def extra_inputs(self, batch: int, seq: int) -> Dict[str, Any]:
        """Stub-frontend inputs (shapes only) this family requires."""
        cfg = self.cfg
        if cfg.family == "encdec":
            return {"frames": (batch, cfg.encoder_seq, cfg.d_model)}
        if cfg.family == "vlm" and cfg.patch_prefix:
            return {"patch_embeds": (batch, cfg.patch_prefix, cfg.d_model)}
        return {}

    def text_len(self, seq: int) -> int:
        """Token positions given a total sequence budget (VLM reserves a
        patch prefix inside the budget)."""
        if self.cfg.family == "vlm" and self.cfg.patch_prefix:
            return seq - self.cfg.patch_prefix
        return seq


def build(arch: str) -> Tuple[Model, ModelConfig]:
    cfg = get_config(arch)
    return Model.from_config(cfg), cfg

"""Whisper-tiny backbone: encoder-decoder transformer.

The audio conv frontend is a STUB per the assignment brief —
``input_specs()`` supplies precomputed mel-frame embeddings (B, S_enc, d),
standing in for the two-conv downsampler. Everything downstream is real:
sinusoidal-position encoder with bidirectional attention, decoder with
causal self-attention + cross-attention, LayerNorm/GELU (pre-LN) as in
the Whisper paper.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]


def _sinusoid(t: int, d: int) -> jax.Array:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / (d // 2 - 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_layer(key, cfg: ModelConfig) -> Params:
    ka, kf = jax.random.split(key)
    return {"ln1": _ln(cfg), "attn": L.init_attention(ka, cfg),
            "ln2": _ln(cfg), "mlp": L.init_mlp(kf, cfg)}


def _init_dec_layer(key, cfg: ModelConfig) -> Params:
    ka, kx, kf = jax.random.split(key, 3)
    return {"ln1": _ln(cfg), "attn": L.init_attention(ka, cfg),
            "lnx": _ln(cfg), "xattn": L.init_attention(kx, cfg),
            "ln2": _ln(cfg), "mlp": L.init_mlp(kf, cfg)}


def _ln(cfg):
    return {"w": jnp.ones((cfg.d_model,), jnp.float32),
            "b": jnp.zeros((cfg.d_model,), jnp.float32)}


def init_model(key, cfg: ModelConfig) -> Params:
    ke, kenc, kdec, kp = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.init_embed(ke, cfg),
        "pos_dec": L._dense_init(kp, (4096, cfg.d_model), scale=0.01),
        "enc": jax.vmap(functools.partial(_init_enc_layer, cfg=cfg))(
            enc_keys),
        "dec": jax.vmap(functools.partial(_init_dec_layer, cfg=cfg))(
            dec_keys),
        "ln_enc": _ln(cfg),
        "ln_dec": _ln(cfg),
    }


def _enc_layer_fwd(p, cfg, x):
    h = L.layer_norm(x, p["ln1"]["w"], p["ln1"]["b"])
    # bidirectional: no rope (whisper uses absolute sinusoids)
    out, _ = L.attention_fwd(p["attn"], cfg, h,
                             jnp.zeros((x.shape[1],), jnp.int32),
                             causal=False)
    x = x + out
    h = L.layer_norm(x, p["ln2"]["w"], p["ln2"]["b"])
    return x + L.mlp_fwd(p["mlp"], h)


def encode(params: Params, cfg: ModelConfig, frames: jax.Array,
           unroll: bool = False) -> jax.Array:
    """frames: precomputed (B, S_enc, d) stub embeddings."""
    dtype = jnp.dtype(cfg.dtype)
    x = frames.astype(dtype) + _sinusoid(
        frames.shape[1], cfg.d_model).astype(dtype)

    def body(xcur, lp):
        return _enc_layer_fwd(lp, cfg, xcur), None

    if unroll:
        n = jax.tree.leaves(params["enc"])[0].shape[0]
        for g in range(n):
            lp = jax.tree.map(lambda a: a[g], params["enc"])
            x, _ = body(x, lp)
    else:
        x, _ = jax.lax.scan(body, x, params["enc"])
    return L.layer_norm(x, params["ln_enc"]["w"], params["ln_enc"]["b"])


def _dec_layer_fwd(p, cfg, x, positions, enc, cache, cache_pos):
    new_cache = None
    h = L.layer_norm(x, p["ln1"]["w"], p["ln1"]["b"])
    self_cache = cache[0] if cache is not None else None
    out, sc = L.attention_fwd(p["attn"], cfg, h, positions,
                              cache=self_cache, cache_pos=cache_pos)
    x = x + out
    h = L.layer_norm(x, p["lnx"]["w"], p["lnx"]["b"])
    if enc is not None:        # train/prefill: compute (and store) cross KV
        kv = L.cross_kv(p["xattn"], cfg, enc)
    else:                      # decode: reuse cross K/V from prefill
        kv = cache[1]
    xout, _ = L.attention_fwd(p["xattn"], cfg, h, positions,
                              causal=False, kv_override=kv)
    x = x + xout
    h = L.layer_norm(x, p["ln2"]["w"], p["ln2"]["b"])
    x = x + L.mlp_fwd(p["mlp"], h)
    if cache is not None:
        new_cache = (sc, kv)
    return x, new_cache


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            frames: Optional[jax.Array] = None,
            enc_out: Optional[jax.Array] = None,
            cache: Optional[Any] = None,
            cache_pos: Optional[jax.Array] = None,
            unroll: bool = False,
            last_only: bool = False,
            ) -> Tuple[jax.Array, jax.Array, Optional[Any]]:
    """Decoder forward. Provide ``frames`` (train/prefill) or a ``cache``
    holding cross-KV (decode). Returns (logits, aux=0, new_cache)."""
    dtype = jnp.dtype(cfg.dtype)
    if enc_out is None and frames is not None:
        enc_out = encode(params, cfg, frames, unroll=unroll)
    b, t = tokens.shape
    start = cache_pos if cache_pos is not None else 0
    positions = start + jnp.arange(t, dtype=jnp.int32)
    x = L.embed_fwd(params["embed"], cfg, tokens, dtype)
    x = x + jnp.take(params["pos_dec"], positions, axis=0).astype(dtype)

    def body(carry, xs):
        lp, lcache = xs
        xn, nc = _dec_layer_fwd(lp, cfg, carry, positions, enc_out,
                                lcache, cache_pos)
        return xn, nc

    if unroll:
        n_layers = jax.tree.leaves(params["dec"])[0].shape[0]
        caches_out = []
        for g in range(n_layers):
            lp = jax.tree.map(lambda a: a[g], params["dec"])
            lc = (jax.tree.map(lambda a: a[g], cache)
                  if cache is not None else None)
            x, nc = body(x, (lp, lc))
            caches_out.append(nc)
        new_cache = (jax.tree.map(lambda *a: jnp.stack(a), *caches_out)
                     if cache is not None else None)
    elif cache is None:
        x, _ = jax.lax.scan(
            lambda c, lp: (body(c, (lp, None))[0], None),
            x, params["dec"])
        new_cache = None
    else:
        x, new_cache = jax.lax.scan(body, x, (params["dec"], cache))

    x = L.layer_norm(x, params["ln_dec"]["w"], params["ln_dec"]["b"])
    if last_only:
        x = x[:, -1:]
    logits = L.unembed_fwd(params["embed"], cfg, x)
    return logits, jnp.zeros((), jnp.float32), new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """(self KV, cross KV) per decoder layer, stacked over layers."""
    nl, hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    self_kv = (jnp.zeros((nl, batch, hkv, max_len, hd), dtype),) * 2
    cross_kv = (jnp.zeros((nl, batch, hkv, cfg.encoder_seq, hd), dtype),) * 2
    return (self_kv, cross_kv)

"""Decoder-only LM assembly (dense, MoE, SSM, hybrid, VLM families).

Layers are grouped by the architecture's repeat period (1 for uniform
stacks, 2 for gemma2's local/global alternation and every-other-layer MoE,
8 for jamba's 7:1 mamba:attention interleave). Parameters are stacked per
period position and the stack is driven by ``jax.lax.scan``, keeping the
compiled HLO one-period-sized regardless of depth — essential for the
512-device dry-runs of 40-64 layer models.

The decode cache is a dict ``{period_pos: stacked_state}`` where state is
(K, V) for attention positions and (ssm_h, conv_state) for SSD positions —
the cache pytree is exactly what the serving layer hands to the offload
runtime for tier placement.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.sharding import shard

Params = Dict[str, Any]


def period_of(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.attn_period
    p = 1
    if cfg.alt_local_global:
        p = 2
    if cfg.n_experts and cfg.moe_every > 1:
        p = max(p, cfg.moe_every)
    return p


def _init_block(key, cfg: ModelConfig, pos: int) -> Params:
    """One block at period position ``pos``: mixer + ffn + norms."""
    km, kf, _ = jax.random.split(key, 3)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
                 "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.post_norms:
        p["ln1_post"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ln2_post"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.is_attn_layer(pos):
        p["attn"] = L.init_attention(km, cfg)
    else:
        p["ssm"] = S.init_ssm(km, cfg)
    if cfg.is_moe_layer(pos):
        p["moe"] = M.init_moe(kf, cfg)
    else:
        p["mlp"] = L.init_mlp(kf, cfg)
    return p


def _block_fwd(p: Params, cfg: ModelConfig, x, positions, pos: int, *,
               cache=None, cache_pos=None, moe_impl="scatter"):
    """Returns (x, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, p["ln1"], cfg.rms_eps, cfg.norm_offset)
    new_cache = None
    if cfg.is_attn_layer(pos):
        attn_cache = cache if cache is not None else None
        out, new_cache = L.attention_fwd(
            p["attn"], cfg, h, positions, window=cfg.layer_window(pos),
            cache=attn_cache, cache_pos=cache_pos)
    else:
        if cache is not None and h.shape[1] == 1:
            out, new_cache = S.ssd_step(p["ssm"], cfg, h, cache)
        elif cache is not None:
            # prefill into a decode cache: chunked SSD + final state
            out, new_cache = S.ssd_fwd(p["ssm"], cfg, h, return_state=True)
            new_cache = (new_cache[0],
                         new_cache[1].astype(cache[1].dtype))
        else:
            out = S.ssd_fwd(p["ssm"], cfg, h)
    if cfg.post_norms:
        out = L.rms_norm(out, p["ln1_post"], cfg.rms_eps, cfg.norm_offset)
    x = x + out

    h = L.rms_norm(x, p["ln2"], cfg.rms_eps, cfg.norm_offset)
    if cfg.is_moe_layer(pos):
        out, aux = M.moe_fwd(p["moe"], cfg, h, impl=moe_impl)
    else:
        out = L.mlp_fwd(p["mlp"], h)
    if cfg.post_norms:
        out = L.rms_norm(out, p["ln2_post"], cfg.rms_eps, cfg.norm_offset)
    return x + out, aux, new_cache


# ----------------------------------------------------------------------- #
# model init                                                               #
# ----------------------------------------------------------------------- #
def init_model(key, cfg: ModelConfig) -> Params:
    period = period_of(cfg)
    n_groups = cfg.n_layers // period
    ke, kl, kn = jax.random.split(key, 3)
    blocks = []
    for pos in range(period):
        kpos = jax.random.fold_in(kl, pos)
        gkeys = jax.random.split(kpos, n_groups)
        blocks.append(jax.vmap(
            functools.partial(_init_block, cfg=cfg, pos=pos))(gkeys))
    return {
        "embed": L.init_embed(ke, cfg),
        "blocks": tuple(blocks),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }


# ----------------------------------------------------------------------- #
# forward                                                                  #
# ----------------------------------------------------------------------- #
def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            positions: Optional[jax.Array] = None,
            patch_embeds: Optional[jax.Array] = None,
            cache: Optional[Dict[int, Any]] = None,
            cache_pos: Optional[jax.Array] = None,
            moe_impl: str = "scatter",
            unroll: bool = False,
            last_only: bool = False
            ) -> Tuple[jax.Array, jax.Array, Optional[Dict[int, Any]]]:
    """tokens: (B, T) -> (logits (B,T,V), aux_loss, new_cache).

    VLM configs prepend ``patch_embeds`` (B, P, d) from the stub frontend;
    logits then cover the text positions only.
    """
    dtype = jnp.dtype(cfg.dtype)
    period = period_of(cfg)
    x = L.embed_fwd(params["embed"], cfg, tokens, dtype)
    n_patch = 0
    if patch_embeds is not None:
        n_patch = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(dtype), x], axis=1)
    t = x.shape[1]
    if positions is None:
        start = cache_pos if cache_pos is not None else 0
        positions = start + jnp.arange(t, dtype=jnp.int32)

    def body(carry, xs):
        xcur, aux = carry
        gparams, gcache = xs
        new_gcache = []
        for pos in range(period):
            c = gcache[pos] if gcache is not None else None
            xcur, a, nc = _block_fwd(gparams[pos], cfg, xcur, positions,
                                     pos, cache=c, cache_pos=cache_pos,
                                     moe_impl=moe_impl)
            aux = aux + a
            new_gcache.append(nc)
        ys = tuple(new_gcache) if gcache is not None else None
        return (xcur, aux), ys

    aux0 = jnp.zeros((), jnp.float32)
    xs = (params["blocks"],
          cache if cache is not None else None)
    if unroll:
        # python-loop over groups: used by the dry-run cost probes, where
        # XLA's once-per-while-body cost accounting must be avoided
        n_groups = jax.tree.leaves(params["blocks"])[0].shape[0]
        carry = (x, aux0)
        caches_out = []
        for g in range(n_groups):
            gp = jax.tree.map(lambda a: a[g], params["blocks"])
            gc = (jax.tree.map(lambda a: a[g], cache)
                  if cache is not None else None)
            carry, ys = body(carry, (gp, gc))
            caches_out.append(ys)
        x, aux = carry
        new_cache = (jax.tree.map(lambda *a: jnp.stack(a), *caches_out)
                     if cache is not None else None)
    elif cache is None:
        # scan without per-layer outputs
        (x, aux), _ = jax.lax.scan(
            lambda c, gp: (body(c, (gp, None))[0], None),
            (x, aux0), params["blocks"])
        new_cache = None
    else:
        (x, aux), new_cache = jax.lax.scan(body, (x, aux0), xs)

    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps, cfg.norm_offset)
    if n_patch:
        x = x[:, n_patch:]
    if last_only:
        # prefill serving only needs the next-token logits: skip the
        # (B, T, V) unembed entirely (§Perf iteration A1)
        x = x[:, -1:]
    logits = L.unembed_fwd(params["embed"], cfg, x)
    return logits, aux, new_cache


# ----------------------------------------------------------------------- #
# decode cache                                                             #
# ----------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict[int, Any]:
    """Stacked-over-groups decode state for each period position."""
    period = period_of(cfg)
    n_groups = cfg.n_layers // period
    cache = []
    for pos in range(period):
        if cfg.is_attn_layer(pos):
            shape = (n_groups, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
            cache.append((jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)))
        else:
            hstate, cstate = S.init_ssm_state(cfg, batch, dtype)
            cache.append((
                jnp.broadcast_to(hstate, (n_groups,) + hstate.shape),
                jnp.broadcast_to(cstate, (n_groups,) + cstate.shape)))
    return tuple(cache)

"""Mamba2 SSD (state-space duality) mixer — mamba2-1.3b and jamba layers.

Hardware adaptation (DESIGN.md): Mamba1's selective scan is a sequential
GPU kernel with no MXU analogue; Mamba2's SSD formulation *is* the TPU
port — the recurrence becomes chunked batched matmuls (intra-chunk
attention-like quadratic term + inter-chunk state carry), which is exactly
the arithmetic the MXU wants. We implement:

* ``ssd_fwd``  — chunked SSD for train/prefill: O(T·Q) intra-chunk
  matmuls + a ``lax.scan`` over chunk states (Q = chunk length).
* ``ssd_step`` — O(1)/token decode: ``h = decay·h + dt·B⊗x; y = C·h``,
  the state (B, H, S, P) is the SSM analogue of the KV cache (and the
  object the paper's Device-First-Use runtime places for long-context
  serving).

Single B/C group (n_groups=1); depthwise causal conv over the (x, B, C)
projections as in the reference implementation.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.layers import _dense_init, rms_norm
from repro.models.sharding import shard

Params = Dict[str, jax.Array]

# Chunk length trades intra-chunk quadratic memory (nc·B·q²·H fp32) for
# scan length; 64 keeps the masked-decay tensor ~256 MB/device at 4k seq.
CHUNK = 64


def init_ssm(key, cfg: ModelConfig) -> Params:
    d, din, s, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * s
    ki, kc, ko, ka, kd2 = jax.random.split(key, 5)
    return {
        # zxbcdt projection: [z(din) | x(din) | B(s) | C(s) | dt(h)]
        "in_proj": _dense_init(ki, (d, 2 * din + 2 * s + h)),
        "conv_w": _dense_init(kc, (cfg.ssm_conv, conv_dim), scale=0.1),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.ones((din,), jnp.float32),
        "out_proj": _dense_init(ko, (din, d),
                                scale=0.02 / (2 * cfg.n_layers) ** 0.5),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    din, s, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:2 * din + 2 * s]
    dt = zxbcdt[..., 2 * din + 2 * s:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along T. xbc: (B, T, C).

    With ``state`` (B, K-1, C): decode mode — returns (out, new_state).
    """
    ksize = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, xbc], axis=1)    # (B, K-1+T, C)
        new_state = window[:, -(ksize - 1):]
        out = jnp.zeros_like(xbc)
        t = xbc.shape[1]
        for i in range(ksize):
            out = out + window[:, i:i + t] * w[i].astype(xbc.dtype)
        return jax.nn.silu(out + b.astype(xbc.dtype)), new_state
    pad = jnp.zeros((xbc.shape[0], ksize - 1, xbc.shape[2]), xbc.dtype)
    window = jnp.concatenate([pad, xbc], axis=1)
    t = xbc.shape[1]
    out = jnp.zeros_like(xbc)
    for i in range(ksize):
        out = out + window[:, i:i + t] * w[i].astype(xbc.dtype)
    return jax.nn.silu(out + b.astype(xbc.dtype)), None


def ssd_fwd(p: Params, cfg: ModelConfig, xin: jax.Array,
            chunk: int = CHUNK, return_state: bool = False):
    """Chunked SSD. xin: (B, T, d) -> (B, T, d).

    ``return_state=True`` additionally returns (ssm_h, conv_state) after
    the last position — the prefill path for decode serving.
    """
    bsz, t, _ = xin.shape
    din, s, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_head_dim
    dt_ = xin.dtype

    zxbcdt = kops.matmul(xin, p["in_proj"].astype(dt_))
    z, xbc, dtp = _split_proj(cfg, zxbcdt)
    raw_xbc = xbc
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    x = xbc[..., :din]
    bmat = xbc[..., din:din + s].astype(jnp.float32)          # (B,T,S)
    cmat = xbc[..., din + s:].astype(jnp.float32)             # (B,T,S)

    dt = jax.nn.softplus(dtp.astype(jnp.float32)
                         + p["dt_bias"])                      # (B,T,H)
    a = -jnp.exp(p["a_log"])                                  # (H,)
    xh = x.reshape(bsz, t, h, hp).astype(jnp.float32)         # (B,T,H,P)
    xh = shard(xh, "batch", None, "model", None)

    q = min(chunk, t)
    assert t % q == 0, (t, q)
    nc = t // q

    def reshape_c(v):  # (B,T,...) -> (nc, B, q, ...)
        return v.reshape(bsz, nc, q, *v.shape[2:]).swapaxes(0, 1)

    xc, bc, cc, dtc = map(reshape_c, (xh, bmat, cmat, dt))
    da = dtc * a                                              # (nc,B,q,H)
    cum = jnp.cumsum(da, axis=2)                              # (nc,B,q,H)
    seg_total = cum[:, :, -1]                                 # (nc,B,H)

    # intra-chunk (quadratic within chunk, like masked attention)
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (nc,B,q,q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("nbis,nbjs->nbij", cc, bc)                # (nc,B,q,q)
    xdt = xc * dtc[..., None]                                 # (nc,B,q,H,P)
    y_intra = jnp.einsum("nbij,nbijh,nbjhp->nbihp", cb, lmat, xdt)

    # chunk summary states: S_n = sum_j exp(cum_last - cum_j) B_j (x_j dt_j)
    decay_to_end = jnp.exp(seg_total[:, :, None] - cum)       # (nc,B,q,H)
    states = jnp.einsum("nbjs,nbjh,nbjhp->nbhsp",
                        bc, decay_to_end, xdt)                # (nc,B,H,S,P)

    # inter-chunk scan over running state
    def scan_body(hprev, inp):
        st, seg = inp                                         # (B,H,S,P),(B,H)
        hnew = hprev * jnp.exp(seg)[..., None, None] + st
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, s, hp), jnp.float32)
    h_final, hprevs = jax.lax.scan(scan_body, h0, (states, seg_total))
    # contribution of the carried state to each position in the chunk
    decay_in = jnp.exp(cum)                                   # (nc,B,q,H)
    y_inter = jnp.einsum("nbis,nbih,nbhsp->nbihp",
                         cc, decay_in, hprevs)

    y = y_intra + y_inter + xc * p["d_skip"][None, None, None, :, None]
    y = y.swapaxes(0, 1).reshape(bsz, t, din).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm_w"], cfg.rms_eps)
    out = kops.matmul(y, p["out_proj"].astype(dt_))
    if return_state:
        ksz = cfg.ssm_conv
        conv_state = raw_xbc[:, -(ksz - 1):, :]
        return out, (h_final, conv_state)
    return out


def ssd_step(p: Params, cfg: ModelConfig, xin: jax.Array,
             state: Tuple[jax.Array, jax.Array]
             ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Single-token decode. xin: (B, 1, d); state = (ssm_h, conv_state)."""
    bsz = xin.shape[0]
    din, s, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_head_dim
    dt_ = xin.dtype
    ssm_h, conv_state = state

    zxbcdt = kops.matmul(xin, p["in_proj"].astype(dt_))
    z, xbc, dtp = _split_proj(cfg, zxbcdt)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state=conv_state)
    x = xbc[..., :din]
    bvec = xbc[:, 0, din:din + s].astype(jnp.float32)          # (B,S)
    cvec = xbc[:, 0, din + s:].astype(jnp.float32)             # (B,S)

    dt = jax.nn.softplus(dtp[:, 0].astype(jnp.float32)
                         + p["dt_bias"])                       # (B,H)
    a = -jnp.exp(p["a_log"])
    xh = x[:, 0].reshape(bsz, h, hp).astype(jnp.float32)       # (B,H,P)

    decay = jnp.exp(dt * a)                                    # (B,H)
    upd = jnp.einsum("bs,bh,bhp->bhsp", bvec, dt, xh)
    ssm_h = ssm_h * decay[..., None, None] + upd               # (B,H,S,P)
    y = jnp.einsum("bs,bhsp->bhp", cvec, ssm_h)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, din).astype(dt_)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm_w"], cfg.rms_eps)
    return kops.matmul(y, p["out_proj"].astype(dt_)), (ssm_h, conv_state)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, s, hp = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_dim = cfg.d_inner + 2 * s
    return (jnp.zeros((batch, h, s, hp), jnp.float32),
            jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype))

"""Deterministic, shardable, exactly-resumable token pipeline.

The batch for (step, shard) is a pure function of the seed — no iterator
state exists, so checkpoint/restart only needs the step counter, restarts
are bit-exact, elastic re-sharding is free (a new mesh just changes the
shard->host mapping of the same pure function), and stragglers can't skew
the data order. This is the fault-tolerance-first design used by the
large training systems this framework targets; a file-backed corpus
plugs in through the same (step, shard) -> tokens interface.

Synthetic text is Zipf-distributed token ids with document boundaries
(EOS every ~doc_len tokens), enough structure for a ~100M-param example
run to show a real loss curve.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    doc_len: int = 512
    zipf_a: float = 1.2
    eos_id: int = 0


class TokenPipeline:
    """(step, shard) -> {"tokens", "labels"} with shard = data-slice id."""

    def __init__(self, cfg: DataConfig, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.num_shards = num_shards
        self.shard_batch = cfg.global_batch // num_shards
        # Zipf CDF once (numpy; host-side)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        w = ranks ** (-cfg.zipf_a)
        self._cdf = jnp.asarray(np.cumsum(w) / w.sum(), jnp.float32)

    # ------------------------------------------------------------------ #
    def batch(self, step: int, shard: int = 0) -> Dict[str, jax.Array]:
        cfg = self.cfg
        key = jax.random.fold_in(
            jax.random.PRNGKey(cfg.seed),
            np.uint32(step) * np.uint32(self.num_shards) + np.uint32(shard))
        shape = (self.shard_batch, cfg.seq_len + 1)
        u = jax.random.uniform(key, shape)
        toks = jnp.searchsorted(self._cdf, u).astype(jnp.int32)
        toks = jnp.clip(toks, 0, cfg.vocab - 1)
        # document boundaries: eos roughly every doc_len positions
        kb = jax.random.fold_in(key, 7)
        eos_mask = jax.random.uniform(kb, shape) < (1.0 / cfg.doc_len)
        toks = jnp.where(eos_mask, cfg.eos_id, toks)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def global_batch(self, step: int) -> Dict[str, jax.Array]:
        """All shards concatenated (single-host testing convenience)."""
        parts = [self.batch(step, s) for s in range(self.num_shards)]
        return {k: jnp.concatenate([p[k] for p in parts], axis=0)
                for k in parts[0]}

    # ------------------------------------------------------------------ #
    def iter_from(self, step: int, shard: int = 0
                  ) -> Iterator[Dict[str, jax.Array]]:
        """Resume-from-step iterator (what restart uses)."""
        s = step
        while True:
            yield self.batch(s, shard)
            s += 1

"""Assigned architecture configs (one module per arch) + shape suite."""

"""whisper-tiny [audio] — arXiv:2212.04356. Enc-dec backbone; the conv
mel frontend is a stub (input_specs supplies frame embeddings).

4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865, LayerNorm+GELU.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    encoder_layers=4,
    encoder_seq=1500,
    use_layernorm_gelu=True,
    tie_embeddings=True,
)

"""qwen1.5-4b [dense] — hf:Qwen/Qwen1.5 family scaled config.

40L d_model=2560 20H (GQA kv=20 => MHA) d_ff=6912 vocab=151936, QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1.0e4,
    tie_embeddings=False,
)

"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B.

48L d_model=2048 16H (MHA kv=16) vocab=163840, MoE 64 experts top-6,
expert d_ff=1408, every layer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    d_ff_expert=1408,
    vocab=163840,
    n_experts=64,
    top_k=6,
    moe_every=1,
    tie_embeddings=False,
)

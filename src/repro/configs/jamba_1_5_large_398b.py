"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536; mamba:attn 7:1
interleave (attention at index 3 of each 8-layer period), MoE 16e top-2
on every other layer, ssm_state=16 (Jamba uses Mamba-1 state size; the
mixer here is the SSD formulation — DESIGN.md hardware adaptation).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    d_ff_expert=24576,
    vocab=65536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    ssm_state=16,
    ssm_conv=4,
    ssm_head_dim=128,
    d_inner_mult=2,
    attn_period=8,
    attn_offset=3,
    tie_embeddings=False,
)

"""gemma2-9b [dense] — arXiv:2408.00118.

42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000; alternating
local(4096)/global attention, attn softcap 50, final softcap 30,
head_dim 256, post-norms, (1+w) RMSNorm, scaled embeddings, tied.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    attn_softcap=50.0,
    final_softcap=30.0,
    local_window=4096,
    alt_local_global=True,
    embed_scale=True,
    post_norms=True,
    norm_offset=1.0,
    tie_embeddings=True,
)

"""mamba2-1.3b [ssm] — arXiv:2405.21060 (SSD / state-space duality).

48L d_model=2048, attention-free, vocab=50280, ssm_state=128,
d_inner=2*d_model, head_dim 64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_head_dim=64,
    d_inner_mult=2,
    tie_embeddings=True,
)

"""Architecture configuration schema for the LM framework.

One frozen dataclass describes every assigned architecture family (dense /
MoE / SSM / hybrid / enc-dec / VLM). ``reduced()`` derives the CPU-sized
smoke-test variant of any config (same family and wiring, tiny widths).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1.0e4
    rms_eps: float = 1.0e-6
    tie_embeddings: bool = True

    # gemma2-isms
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    local_window: int = 0           # >0: window for local layers
    alt_local_global: bool = False  # alternate local/global attention
    embed_scale: bool = False       # multiply embeddings by sqrt(d_model)
    post_norms: bool = False        # extra post-attn/post-ffn RMSNorms
    norm_offset: float = 0.0        # gemma uses (1 + w) RMSNorm weights

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1              # 1 = every layer, 2 = alternating
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    d_inner_mult: int = 2
    attn_period: int = 0            # jamba: one attn layer per this many
    attn_offset: int = 0            # ...at this index within the period

    # enc-dec (whisper backbone; conv frontend stubbed)
    encoder_layers: int = 0
    encoder_seq: int = 0            # precomputed frame embeddings length
    use_layernorm_gelu: bool = False

    # VLM (pixtral backbone; patch frontend stubbed)
    patch_prefix: int = 0           # precomputed patch embeddings length

    dtype: str = "bfloat16"

    # --- beyond-paper performance knobs (EXPERIMENTS.md §Perf) --------
    # Pad query heads to a TP-divisible count (zero-masked: exact math).
    pad_heads_to: int = 0
    # Causal attention in query chunks, keys sliced to the causal prefix
    # (XLA-expressible flash-style flop/memory reduction). 0 = full T^2.
    attn_chunk_q: int = 0
    # Prefill attends over the fresh K/V (pre-cache-write) instead of the
    # padded cache — exact for from-scratch prefill (cache_pos=0), and
    # unlocks the chunked formulation for the prefill path.
    prefill_fresh_kv: bool = False

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(1, self.n_heads))

    @property
    def padded_heads(self) -> int:
        hp = max(self.n_heads, self.pad_heads_to or 0)
        # padding happens within KV groups so the GQA q->kv mapping of
        # the real heads is preserved; a target that breaks group
        # structure is ignored
        if self.n_kv_heads and hp % self.n_kv_heads != 0:
            return self.n_heads
        return hp

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (DESIGN.md §5)"""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.d_inner_mult * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        return (i % self.moe_every) == (self.moe_every - 1)

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return (i % self.attn_period) == self.attn_offset
        return True

    def layer_window(self, i: int) -> int:
        """Sliding window for layer i (gemma2: even layers local)."""
        if self.alt_local_global:
            return self.local_window if i % 2 == 0 else 0
        return self.local_window

    # ------------------------------------------------------------------ #
    def param_count(self) -> Tuple[int, int]:
        """(total, active) parameter estimates — drives MODEL_FLOPS."""
        d, hd = self.d_model, self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = 3 * d * self.d_ff_expert
        total = active = 0
        n_mixer_layers = self.n_layers
        for i in range(self.n_layers):
            if self.family == "ssm" or (self.family == "hybrid"
                                        and not self.is_attn_layer(i)):
                din = self.d_inner
                mixer = d * (2 * din + 2 * self.ssm_state
                             + self.ssm_heads) \
                    + din * self.ssm_conv + din * d + 2 * self.ssm_heads
            else:
                mixer = attn
            if self.is_moe_layer(i):
                ffn_t = self.n_experts * moe_ffn + d * self.n_experts
                ffn_a = self.top_k * moe_ffn + d * self.n_experts
            elif self.family == "encdec" or self.use_layernorm_gelu:
                ffn_t = ffn_a = 2 * d * self.d_ff
            else:
                ffn_t = ffn_a = dense_ffn
            total += mixer + ffn_t
            active += mixer + ffn_a
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total += emb
        active += emb
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + 2 * d * self.d_ff)
            total += enc
            active += enc
        return total, active

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small_heads = min(self.n_heads, 4)
        kv = max(1, small_heads * self.n_kv_heads
                 // self.n_heads) if self.n_heads else 0
        period = self.attn_period or 1
        layers = max(2, min(4, self.n_layers))
        if self.family == "hybrid":
            layers = period  # one full interleave group
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=layers,
            d_model=64,
            n_heads=small_heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            d_ff_expert=32 if self.n_experts else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32),
            patch_prefix=min(self.patch_prefix, 8),
            local_window=min(self.local_window, 16),
            dtype="float32",
        )

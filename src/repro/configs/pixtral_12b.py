"""pixtral-12b [vlm] — hf:mistralai/Pixtral-12B-2409. Mistral-Nemo-style
text backbone; the Pixtral ViT frontend is a stub (input_specs supplies
patch embeddings prepended to the token sequence).

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim 128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1.0e6,
    patch_prefix=256,
    tie_embeddings=False,
)

"""Roofline analysis from compiled dry-run artifacts."""
from repro.roofline.analysis import (collective_bytes, roofline_terms,
                                     RooflineReport, V5E)

__all__ = ["collective_bytes", "roofline_terms", "RooflineReport", "V5E"]

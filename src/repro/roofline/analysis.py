"""Three-term roofline model from compiled HLO (no hardware needed).

    compute    = HLO_FLOPs / (chips x peak FLOP/s)
    memory     = HLO_bytes / (chips x HBM bw)
    collective = collective_bytes / (chips x link bw)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
parsed from the post-SPMD optimized HLO: every ``all-reduce`` /
``all-gather`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` op's operand sizes are summed (per-device program,
so the sum is already per-chip traffic).

Hardware constants (TPU v5e, mandated): 197 TFLOP/s bf16/chip, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

V5E = {
    "peak_flops": 197.0e12,     # bf16 per chip
    "hbm_bw": 819.0e9,          # bytes/s per chip
    "link_bw": 50.0e9,          # bytes/s per ICI link
    "hbm_bytes": 16 << 30,
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

# one shaped value, e.g. "bf16[16,4096,320]{2,1,0}"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op, by op kind.

    HLO line form: ``%name = <shape> <op>(...)`` — the result shape of a
    collective equals the payload living on the wire for AG/AR/CP; for
    reduce-scatter the *operand* is bigger, but the ring transfers the
    result-sized shards, so result bytes are the honest wire estimate.
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", s)
        if not m:
            continue
        rhs = m.group(1)
        for coll in _COLLECTIVES:
            # match the op name at the call position
            mm = re.match(r"^((?:\([^)]*\))|(?:[\w\[\]{},: ]+?))\s*"
                          + re.escape(coll) + r"(?:-start)?\(", rhs)
            if mm:
                nbytes = _shape_bytes(mm.group(1))
                out[coll] = out.get(coll, 0) + nbytes
                break
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    model_flops: float               # 6·N·D (or 6·N_active·D)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    def __post_init__(self):
        self.t_compute = self.flops_per_chip / V5E["peak_flops"]
        self.t_memory = self.bytes_per_chip / V5E["hbm_bw"]
        self.t_collective = self.coll_bytes_per_chip / V5E["link_bw"]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap bound: the max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        total_hlo = self.flops_per_chip * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term bound that is useful model math:
        (model_flops/chips/peak) / step_time."""
        ideal = self.model_flops / self.chips / V5E["peak_flops"]
        return ideal / self.step_time if self.step_time else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "dominant": self.dominant,
            "useful_flops_ratio": round(self.useful_ratio, 4),
            "roofline_fraction": round(self.roofline_fraction, 4),
        }


def roofline_terms(*, arch: str, shape: str, mesh_name: str, chips: int,
                   cost: Optional[dict], hlo_text: str,
                   model_flops: float,
                   coll_bytes: Optional[float] = None) -> RooflineReport:
    cost = cost or {}
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    if coll_bytes is None:
        coll_bytes = float(sum(collective_bytes(hlo_text).values()))
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=nbytes,
        coll_bytes_per_chip=coll_bytes,
        model_flops=model_flops)

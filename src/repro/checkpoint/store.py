"""Sharded, atomic, async checkpointing with elastic restore.

Design points for the 1000+-node posture:

* **Atomic commit**: writes land in ``step_K.tmp/`` and are renamed to
  ``step_K/`` only after every leaf + manifest is flushed — a crashed
  writer can never produce a half-checkpoint that restore would pick up.
* **Async save**: ``save(..., blocking=False)`` hands the host copy to a
  writer thread; training continues (compute/IO overlap). ``wait()``
  joins before the next save or shutdown.
* **Elastic restore**: the manifest stores logical shapes/dtypes + the
  pytree structure, never mesh geometry. ``restore(..., shardings=)``
  re-shards every leaf onto the *current* mesh via ``jax.device_put`` —
  restoring a 512-chip checkpoint onto 256 chips (or 1 CPU) just works.
* **Retention**: ``keep`` most-recent checkpoints are preserved, older
  ones pruned after a successful commit.
* Per-host leaf files are plain ``.npy`` — no bespoke container to
  corrupt, trivially inspectable.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> List:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointStore:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: Optional[Dict] = None) -> None:
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        # host copy happens on the caller thread (device buffers are not
        # thread-safe to donate); IO happens on the writer thread.
        host_leaves = [np.asarray(l) for l in leaves]
        treedef_str = str(treedef)

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {
                "step": step,
                "n_leaves": len(host_leaves),
                "treedef": treedef_str,
                "leaves": [],
                "extra": extra or {},
            }
            for i, arr in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
                manifest["leaves"].append(
                    {"shape": list(arr.shape), "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._prune()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------ #
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.exists(
                        os.path.join(self.dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, tree_like: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``tree_like``; re-shard onto the
        current mesh when ``shardings`` (matching pytree) is given."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = jax.tree.flatten(tree_like)
        assert len(leaves_like) == manifest["n_leaves"], \
            "checkpoint/tree structure mismatch"
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves_like))
        out = []
        for i, (like, shd) in enumerate(zip(leaves_like, shard_leaves)):
            arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
            if shd is not None:
                out.append(jax.device_put(arr, shd))      # elastic reshard
            else:
                out.append(jax.device_put(arr))
        return treedef.unflatten(out), manifest

    # ------------------------------------------------------------------ #
    def _prune(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

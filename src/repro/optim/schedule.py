"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    """Linear warmup then cosine decay to ``floor_frac * peak_lr``."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(1.0, warmup)
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup),
                    0.0, 1.0)
    floor = floor_frac * peak_lr
    cos = floor + (peak_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)

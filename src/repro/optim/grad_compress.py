"""Int8 gradient compression with error feedback.

Distributed-optimization trick for the multi-pod mesh: gradients crossing
the slow inter-pod links are quantized to int8 (per-tensor scale) before
the reduction and dequantized after, with the quantization residual fed
back into the next step (error feedback keeps convergence unbiased in
practice). Two integration points:

* microbatch accumulation in the train loop (pure pytree transform), and
* :func:`compressed_psum` for explicit shard_map reductions over a named
  axis (the ``pod`` axis of the production mesh).

Wire format is int8 + one f32 scale per tensor: 4x fewer bytes on the
link than f32 gradients, 2x fewer than bf16.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any          # error-feedback accumulator, mirrors grads


def init_compression(grads_like) -> CompressionState:
    return CompressionState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, state: CompressionState
                        ) -> Tuple[Any, CompressionState]:
    """Round-trip grads through the int8 wire format with error feedback.

    Models exactly what the compressed reduction transmits; the returned
    grads are what the optimizer sees, the residual carries the loss.
    """

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quantize(x)
        deq = _dequantize(q, s)
        return deq.astype(g.dtype), x - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(state.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            CompressionState(tdef.unflatten([o[1] for o in outs])))


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire psum for shard_map code paths (pod-axis grads).

    Quantizes locally, sums the int-valued payload (widened to int32 so
    the reduction cannot overflow), and rescales by the max participating
    scale. Bytes on the link: 1/4 of f32.
    """
    q, s = _quantize(x)
    s_max = jax.lax.pmax(s, axis_name)
    # renormalize local payload to the common scale before the sum
    q_common = jnp.round(q.astype(jnp.float32) * (s / s_max))
    total = jax.lax.psum(q_common.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * s_max

"""AdamW with decoupled weight decay — functional, pytree-native.

Moments live in f32 regardless of parameter dtype. The optimizer state
pytree mirrors the parameter pytree, so the same sharding rules apply to
both (and ZeRO-style sharding over the data axis is a pure resharding of
this state — see launch/shardings.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1.0e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(zeros, params))

    def update(self, grads, state: OptState, params, lr) -> tuple:
        """Returns (new_params, new_state)."""
        step = state.step + 1
        # global-norm clip
        if self.grad_clip > 0:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return newp.astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.mu)
        flat_v = jax.tree.leaves(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, mu=new_m, nu=new_v)

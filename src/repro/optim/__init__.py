"""Optimizer substrate: AdamW, schedules, gradient compression."""
from repro.optim.adamw import AdamW, OptState
from repro.optim.schedule import cosine_schedule
from repro.optim.grad_compress import (
    compress_decompress, CompressionState, init_compression,
)

__all__ = ["AdamW", "OptState", "cosine_schedule",
           "compress_decompress", "CompressionState", "init_compression"]

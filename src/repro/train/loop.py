"""Training loop: microbatched, remat-aware, fault-tolerant.

Production posture:

* **Microbatch accumulation** via ``lax.scan`` — the global batch streams
  through in ``n_micro`` slices, holding one microbatch of activations
  live (the standard memory/throughput knob, also a §Perf lever).
* **Remat** policies (none / dots / full) wrap the per-microbatch loss.
* **Gradient compression** (int8 + error feedback) models the inter-pod
  wire format (see optim/grad_compress.py).
* **Fault tolerance**: atomic async checkpoints every ``ckpt_every``
  steps; ``Trainer.fit`` resumes exactly from the latest checkpoint (the
  data pipeline is a pure function of step, so restarts are bit-exact).
* **Straggler watchdog**: per-step wall time vs. a running median; slow
  steps are counted and surfaced (on a real cluster this feeds the
  controller that re-shards around sick hosts; here it is measured and
  logged).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointStore
from repro.data import TokenPipeline
from repro.models.registry import Model
from repro.optim import (AdamW, CompressionState, compress_decompress,
                         cosine_schedule, init_compression)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    peak_lr: float = 3.0e-4
    warmup: int = 20
    n_micro: int = 1
    remat: str = "none"             # none | dots | full
    grad_compress: bool = False
    z_loss: float = 1.0e-4
    log_every: int = 10
    ckpt_every: int = 50
    straggler_factor: float = 3.0
    moe_impl: str = "scatter"
    unroll_layers: bool = False     # dry-run cost probes only


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_coef: float = 0.0) -> jax.Array:
    """Mean CE over all positions, with optional z-loss regularizer.

    Vocab-parallel formulation (§Perf iteration B2): the gold logit is an
    iota-compare masked reduction instead of ``take_along_axis``, so with
    vocab-sharded logits XLA reduces locally and psums a (B, T) scalar
    field — no all-gather of the (B, T, V) tensor.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold_mask = vocab_iota == labels[..., None]
    gold = jnp.sum(jnp.where(gold_mask, logits, 0.0), axis=-1)
    ce = jnp.mean(lse - gold)
    if z_coef > 0:
        ce = ce + z_coef * jnp.mean(jnp.square(lse))
    return ce


def _remat_wrap(fn: Callable, mode: str) -> Callable:
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if mode == "full":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(f"unknown remat mode {mode!r}")


def make_train_step(model: Model, tcfg: TrainConfig, opt: AdamW,
                    total_steps: Optional[int] = None):
    """Builds the jit-able (params, opt_state, comp, batch, step) update."""
    total = total_steps or tcfg.steps

    def micro_loss(params, tokens, labels, extra):
        logits, aux, _ = model.forward(params, tokens,
                                       moe_impl=tcfg.moe_impl,
                                       unroll=tcfg.unroll_layers, **extra)
        return cross_entropy(logits, labels, tcfg.z_loss) + aux

    loss_fn = _remat_wrap(micro_loss, tcfg.remat)

    def train_step(params, opt_state, comp_state, batch, step):
        tokens, labels = batch["tokens"], batch["labels"]
        extra = {k: v for k, v in batch.items()
                 if k not in ("tokens", "labels")}
        b = tokens.shape[0]
        nm = tcfg.n_micro
        assert b % nm == 0, (b, nm)

        def split(x):
            return x.reshape((nm, b // nm) + x.shape[1:])

        mtok, mlab = split(tokens), split(labels)
        mextra = {k: split(v) for k, v in extra.items()}
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc_body(carry, xs):
            g_acc, l_acc = carry
            tk, lb, ex = xs
            loss, grads = jax.value_and_grad(loss_fn)(params, tk, lb, ex)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / nm, g_acc, grads)
            return (g_acc, l_acc + loss / nm), None

        (grads, loss), _ = jax.lax.scan(
            acc_body, (zero_grads, jnp.zeros((), jnp.float32)),
            (mtok, mlab, mextra))

        if tcfg.grad_compress:
            grads, comp_state = compress_decompress(grads, comp_state)

        lr = cosine_schedule(step, peak_lr=tcfg.peak_lr,
                             warmup=tcfg.warmup, total=total)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm}
        return params, opt_state, comp_state, metrics

    return train_step


class Trainer:
    """Host-side orchestration: data, checkpoints, watchdog, restart."""

    def __init__(self, model: Model, pipeline: TokenPipeline,
                 tcfg: TrainConfig, *, opt: Optional[AdamW] = None,
                 ckpt_dir: Optional[str] = None, seed: int = 0,
                 extra_batch_fn: Optional[Callable[[int], Dict]] = None):
        self.model = model
        self.pipe = pipeline
        self.tcfg = tcfg
        self.opt = opt or AdamW()
        self.store = CheckpointStore(ckpt_dir) if ckpt_dir else None
        self.extra_batch_fn = extra_batch_fn
        self._step_fn = jax.jit(make_train_step(model, tcfg, self.opt))
        key = jax.random.PRNGKey(seed)
        self.params = model.init(key)
        self.opt_state = self.opt.init(self.params)
        self.comp_state = init_compression(self.params)
        self.step = 0
        self.step_times = []
        self.straggler_events = 0
        self.history: list = []

    # ------------------------------------------------------------------ #
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "comp": self.comp_state}

    def maybe_restore(self) -> bool:
        if self.store is None or self.store.latest_step() is None:
            return False
        (tree, manifest) = self.store.restore(self._state_tree())
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.comp_state = tree["comp"]
        self.step = manifest["step"]
        return True

    def _watchdog(self, dt: float) -> None:
        self.step_times.append(dt)
        if len(self.step_times) >= 8:
            med = sorted(self.step_times[-50:])[
                len(self.step_times[-50:]) // 2]
            if dt > self.tcfg.straggler_factor * med:
                self.straggler_events += 1
                print(f"[watchdog] step {self.step} took {dt:.3f}s "
                      f"(median {med:.3f}s) — straggler flagged")

    # ------------------------------------------------------------------ #
    def fit(self, steps: Optional[int] = None, verbose: bool = True):
        steps = steps or self.tcfg.steps
        self.maybe_restore()
        while self.step < steps:
            batch = self.pipe.global_batch(self.step)
            if self.extra_batch_fn is not None:
                batch.update(self.extra_batch_fn(self.step))
            t0 = time.perf_counter()
            (self.params, self.opt_state, self.comp_state,
             metrics) = self._step_fn(self.params, self.opt_state,
                                      self.comp_state, batch,
                                      jnp.asarray(self.step, jnp.int32))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            self._watchdog(dt)
            self.step += 1
            self.history.append(metrics)
            if verbose and self.step % self.tcfg.log_every == 0:
                print(f"step {self.step:5d}  loss {metrics['loss']:.4f}  "
                      f"lr {metrics['lr']:.2e}  "
                      f"gnorm {metrics['grad_norm']:.2f}  {dt:.2f}s")
            if (self.store is not None
                    and self.step % self.tcfg.ckpt_every == 0):
                self.store.save(self.step, self._state_tree(),
                                blocking=False)
        if self.store is not None:
            self.store.save(self.step, self._state_tree(), blocking=True)
        return self.history

"""Serving loop with Device-First-Use state placement.

This is where the paper's technique becomes a first-class LM-framework
feature (DESIGN.md §4): the decode cache (KV for attention layers, SSM
state for SSD layers) is a large, massively-reused buffer — exactly the
object SCILIB-Accel's Device First-Use policy was designed for. The
server allocates the cache on the *host tier* (``memspace.HOST``), and the
active placement policy decides how it reaches the device:

* ``dfu``     — migrated to device memory on the first decode step, then
                reused in place for every later token (one transfer).
* ``memcopy`` — round-trips host<->device around every decode step (the
                conventional offload tools' behaviour; the baseline).
* ``pinned``  — born device-resident (``numactl -m 1`` analogue).

Per-policy transfer bytes and reuse counts are tracked so the serving
benchmark reproduces the paper's Tables 3/5 accounting on LM state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import memspace
from repro.core.memspace import DEVICE, HOST
from repro.models.registry import Model


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 1024
    temperature: float = 0.0        # 0 = greedy
    offload_policy: str = "dfu"     # dfu | memcopy | pinned
    cache_dtype: Any = jnp.bfloat16
    seed: int = 0


@dataclasses.dataclass
class ServeStats:
    bytes_host_to_dev: int = 0
    bytes_dev_to_host: int = 0
    cache_reuses: int = 0
    migrations: int = 0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens: int = 0


def _tree_put(tree, tier: str) -> Tuple[Any, int]:
    moved = 0
    leaves, tdef = jax.tree.flatten(tree)
    out = []
    for x in leaves:
        if memspace.tier_of(x) != tier:
            moved += x.nbytes
            # application-level weight/cache placement, not an offload
            # decision: opt out of fault injection (no fallback exists)
            x = memspace.put(x, tier, check=False)
        out.append(x)
    return tdef.unflatten(out), moved


class Server:
    """Batched greedy/temperature decoding over one model replica."""

    def __init__(self, model: Model, params, scfg: ServeConfig):
        self.model = model
        self.cfg = model.cfg
        self.scfg = scfg
        self.params = params
        self.stats = ServeStats()
        self._decode_fn = jax.jit(self._decode_step)
        self._key = jax.random.PRNGKey(scfg.seed)

    # ------------------------------------------------------------------ #
    def _decode_step(self, params, tok, cache, pos, key):
        logits, _, cache = self.model.forward(
            params, tok, cache=cache, cache_pos=pos)
        logits = logits[:, -1, :]
        if self.scfg.temperature > 0:
            tok = jax.random.categorical(
                key, logits / self.scfg.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None]
        return tok.astype(jnp.int32), cache

    # ------------------------------------------------------------------ #
    def prefill(self, tokens: jax.Array,
                extra: Optional[Dict] = None) -> Tuple[jax.Array, Any]:
        """Run the prompt, build the cache on the HOST tier (first-touch
        by the CPU side, exactly like malloc'd matrices in the paper)."""
        b, t = tokens.shape
        t0 = time.perf_counter()
        cache = self.model.init_cache(b, self.scfg.max_len,
                                      self.scfg.cache_dtype)
        if self.scfg.offload_policy == "pinned":
            cache, _ = _tree_put(cache, DEVICE)        # born device-side
        else:
            # CPU-side first touch: the cache starts host-resident, like
            # the paper's malloc'd matrices...
            cache, _ = _tree_put(cache, HOST)
            # ...and the prefill forward is its first device use: under
            # DFU this is THE one migration; under memcopy it is merely
            # the first of many round trips.
            cache, moved = _tree_put(cache, DEVICE)
            self.stats.bytes_host_to_dev += moved
            self.stats.migrations += int(
                self.scfg.offload_policy == "dfu")
        logits, _, cache = self.model.forward(
            params=self.params, tokens=tokens, cache=cache,
            cache_pos=jnp.zeros((), jnp.int32), **(extra or {}))
        if self.scfg.offload_policy == "memcopy":
            cache, moved = _tree_put(cache, HOST)
            self.stats.bytes_dev_to_host += moved
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        self.stats.prefill_s += time.perf_counter() - t0
        return next_tok.astype(jnp.int32), cache

    def decode(self, tok: jax.Array, cache, start_pos: int,
               n_tokens: int) -> Tuple[jax.Array, Any]:
        """Generate ``n_tokens``; cache placement per the active policy."""
        policy = self.scfg.offload_policy
        outs = []
        t0 = time.perf_counter()
        for i in range(n_tokens):
            pos = jnp.asarray(start_pos + i, jnp.int32)
            if policy == "dfu":
                # first device use migrates; later steps are cache hits
                tiers = {memspace.tier_of(x)
                         for x in jax.tree.leaves(cache)}
                if HOST in tiers:
                    cache, moved = _tree_put(cache, DEVICE)
                    self.stats.bytes_host_to_dev += moved
                    self.stats.migrations += 1
                else:
                    self.stats.cache_reuses += 1
            elif policy == "memcopy":
                cache, moved = _tree_put(cache, DEVICE)
                self.stats.bytes_host_to_dev += moved
            self._key, sub = jax.random.split(self._key)
            tok, cache = self._decode_fn(self.params, tok, cache, pos, sub)
            if policy == "memcopy":
                cache, moved = _tree_put(cache, HOST)
                self.stats.bytes_dev_to_host += moved
            else:
                self.stats.cache_reuses += int(policy == "pinned")
            outs.append(tok)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.tokens += n_tokens * tok.shape[0]
        return jnp.concatenate(outs, axis=1), cache

    # ------------------------------------------------------------------ #
    def generate(self, prompt: jax.Array, n_tokens: int,
                 extra: Optional[Dict] = None) -> jax.Array:
        tok, cache = self.prefill(prompt, extra)
        gen, _ = self.decode(tok, cache, prompt.shape[1], n_tokens - 1)
        return jnp.concatenate([tok, gen], axis=1)

"""Training & serving loops."""
from repro.train.loop import Trainer, TrainConfig, make_train_step
from repro.train.serve import Server, ServeConfig

__all__ = ["Trainer", "TrainConfig", "make_train_step", "Server",
           "ServeConfig"]

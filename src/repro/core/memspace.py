"""Portable logical memory tiers over JAX memory kinds.

The paper's runtime moves buffers between two physical homes: host DRAM
and device HBM, coherently addressable from both sides (GH200 NVLink-C2C).
JAX exposes that split as *memory kinds* on a sharding — but the set of
kinds is backend-dependent: a TPU/GPU backend offers ``device`` +
``pinned_host`` (+ ``unpinned_host``), while the CPU backend of a dev
container offers exactly one kind.  Hard-coding kind strings therefore
breaks every policy on CPU before a single byte moves.

This module maps two *logical* tiers onto whatever the backend has:

* :data:`HOST`   — where CPU-first-touched data lives (the malloc side),
* :data:`DEVICE` — where offloaded BLAS wants its operands (the HBM side).

``probe()`` inspects ``addressable_memories()`` once.  When the backend
has distinct kinds, ``put``/``tier_of`` are thin wrappers over real
``device_put`` transfers.  When it has only one kind (CPU container),
the mem-space runs in **simulated-tier** mode: the tier tag is carried in
a side table keyed on buffer identity, a cross-tier ``put`` materializes
a physical copy (so first-touch movement has a real cost and a distinct
destination buffer), and every policy runs identically to the multi-kind
backends — movement is still counted in the runtime statistics.

The DEVICE tier additionally carries a **device index**: a node with N
local accelerators has N device tiers, one per HBM.  ``probe()``
enumerates them from ``len(jax.devices())``, and ``SCILIB_DEVICES=n``
forces a simulated N-tier layout (mirroring the single-kind fallback) so
the multi-device tile scheduler can be exercised on any backend,
including this CPU container.  ``put_block`` re-homes a buffer to one
specific device tier; ``device_of`` reads the index back.
"""
from __future__ import annotations

import dataclasses
import threading
import weakref
from contextvars import ContextVar
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.core import faults as flt

#: logical tier names (stable across backends)
HOST = "host"
DEVICE = "device"

# --------------------------------------------------------------------- #
# failure semantics (PR 6): a real cross-tier movement toward a DEVICE   #
# tier is the transfer guard site.  The active runtime installs its      #
# fault injector here (set_fault_hook) so injected transfer faults fire  #
# at the genuine call site, *before* any copy or tag mutates state; and  #
# real backend failures during the move are wrapped into the typed       #
# hierarchy of repro.core.faults instead of escaping as bare             #
# XlaRuntimeError — the runtime's retry/fallback guard catches them.     #
#                                                                        #
# The hook, the debug level, and the active tier mapping are all         #
# *context-local* (PR 7): concurrent sessions in different threads       #
# each see their own runtime's hook and mapping, never a neighbour's.    #
# --------------------------------------------------------------------- #
#: (device_index_or_None, nbytes) -> None; raises to inject a fault
_FAULT_HOOK: ContextVar[Optional[Callable[[Optional[int], int], None]]] = (
    ContextVar("scilib_fault_hook", default=None))

#: SCILIB_DEBUG level, plumbed in by the owning runtime (config boundary)
_DEBUG: ContextVar[int] = ContextVar("scilib_debug", default=0)

#: exception types a data movement may legitimately raise (XlaRuntimeError
#: subclasses RuntimeError); anything else is a bug and propagates as-is
_MOVE_ERRORS = (RuntimeError, MemoryError, OSError)


def set_fault_hook(hook: Optional[Callable[[Optional[int], int], None]],
                   ) -> None:
    """Install (or clear, with None) the transfer-fault injection hook.

    The runtime layer owns this: it points the hook at the active
    runtime's :class:`repro.core.faults.FaultInjector` on activation and
    reconfiguration.  The hook runs immediately before every *real*
    movement toward a DEVICE tier (never on no-op puts or cache hits),
    except movements explicitly opted out with ``check=False`` — the
    host execution path and user-level ``pin()`` must not inherit
    offload-path faults they cannot fall back from.  Context-local:
    one thread's injector never fires in another thread's transfers."""
    _FAULT_HOOK.set(hook)


def set_debug(level: int) -> None:
    """Plumb the config's ``debug`` level in (``SCILIB_DEBUG`` stays
    behind the config boundary; this module never reads the env)."""
    _DEBUG.set(int(level))


def _debug_log(msg: str, level: int = 1) -> None:
    if _DEBUG.get() >= level:
        print(f"[scilib] {msg}")


def _wrap_move_error(exc: BaseException, *, device: Optional[int],
                     nbytes: int) -> flt.OffloadError:
    """Classify a raw movement failure into the typed hierarchy."""
    err = flt.classify("transfer", exc, device=device, nbytes=nbytes)
    assert err is not None    # _MOVE_ERRORS are always classifiable
    return err


@dataclasses.dataclass(frozen=True)
class MemSpace:
    """Resolved mapping of logical tiers onto one backend's memory kinds."""

    host_kind: str      # physical kind backing the HOST tier
    device_kind: str    # physical kind backing the DEVICE tier
    simulated: bool     # True when the backend exposes a single kind
    backend: str        # jax.default_backend() at probe time
    n_devices: int = 1  # number of logical DEVICE tiers (accelerators)

    def kind_of(self, tier: str) -> str:
        return self.host_kind if tier == HOST else self.device_kind


def device_bytes_from_env() -> Optional[int]:
    """Back-compat wrapper: the per-device-tier byte cap, read through
    the config boundary (:meth:`repro.core.config.OffloadConfig.
    from_env`).  The runtime itself is plumbed from its config; this
    exists for callers that inspect the env-derived cap directly."""
    from repro.core.config import OffloadConfig
    return OffloadConfig.from_env().device_bytes


def probe(device: Optional[jax.Device] = None,
          n_devices: Optional[int] = None) -> MemSpace:
    """Inspect the backend once and resolve the tier mapping.

    ``n_devices`` is the logical device-tier count; the runtime passes
    its config's resolved value.  When omitted (a bare re-probe outside
    any runtime), it comes from the env-derived config — the single
    ``SCILIB_*`` ingestion boundary — falling back to
    ``len(jax.devices())``.
    """
    d = device if device is not None else jax.devices()[0]
    backend = jax.default_backend()
    if n_devices is None:
        from repro.core.config import OffloadConfig
        n_devices = OffloadConfig.from_env().devices
    if n_devices is None:
        try:
            n_devices = len(jax.devices())
        except Exception:  # pragma: no cover - no devices
            n_devices = 1
    try:
        kinds = [m.kind for m in d.addressable_memories()]
    except Exception:  # pragma: no cover - very old jaxlib
        kinds = []
    try:
        device_kind = d.default_memory().kind
    except Exception:  # pragma: no cover
        device_kind = kinds[0] if kinds else "device"
    if device_kind not in kinds and kinds:
        device_kind = kinds[0]
    # prefer pinned host memory for the HOST tier (DMA-able, what the
    # paper's cudaMallocHost-style staging uses), else any non-device kind
    host_kind = next((k for k in ("pinned_host", "unpinned_host")
                      if k in kinds and k != device_kind), None)
    if host_kind is None:
        host_kind = next((k for k in kinds if k != device_kind), None)
    if host_kind is None:
        return MemSpace(host_kind=device_kind, device_kind=device_kind,
                        simulated=True, backend=backend,
                        n_devices=n_devices)
    return MemSpace(host_kind=host_kind, device_kind=device_kind,
                    simulated=False, backend=backend, n_devices=n_devices)


# --------------------------------------------------------------------- #
# module state: active mapping + simulated-tier tag table                 #
# --------------------------------------------------------------------- #
# The *installed* mapping is context-local (a session's devices layout
# must not leak into a neighbouring thread); the lazily-probed fallback
# for sessionless threads is process-wide and built once under a lock.
_ACTIVE: ContextVar[Optional[MemSpace]] = (
    ContextVar("scilib_memspace", default=None))
_PROBED: Optional[MemSpace] = None
_PROBE_LOCK = threading.Lock()

# id(arr) -> (weakref(arr), logical tier, device index); only consulted
# in simulated mode, but tags are maintained unconditionally so a mapping
# re-probe (e.g. tests switching modes) never orphans tier state.  The
# table is process-wide (a tier is a property of the buffer, not of the
# observing session) and its dict operations are GIL-atomic.
_TIERS: Dict[int, Tuple[weakref.ref, str, int]] = {}


def active() -> MemSpace:
    """The resolved tier mapping: the context's installed mapping when a
    session owns this thread, else the lazily-probed process default."""
    space = _ACTIVE.get()
    if space is not None:
        return space
    global _PROBED
    with _PROBE_LOCK:
        if _PROBED is None:
            _PROBED = probe()
        return _PROBED


def install(space: Optional[MemSpace] = None,
            n_devices: Optional[int] = None) -> MemSpace:
    """Re-probe (or inject, for tests) the mapping; runtime.install hook.
    ``n_devices`` plumbs the owning config's device-tier count through.
    The installed mapping is context-local."""
    space = probe(n_devices=n_devices) if space is None else space
    _ACTIVE.set(space)
    return space


def reset() -> None:
    global _PROBED
    _ACTIVE.set(None)
    with _PROBE_LOCK:
        _PROBED = None
    _TIERS.clear()


def n_devices() -> int:
    """Number of logical device tiers (accelerators) of the active space."""
    return active().n_devices


def _tag(x: jax.Array, tier: str, device: int = 0) -> None:
    key = id(x)

    def _drop(_ref, key=key):
        _TIERS.pop(key, None)

    _TIERS[key] = (weakref.ref(x, _drop), tier, device)


def tier_of(x) -> str:
    """Logical tier of a buffer (HOST or DEVICE).

    Untagged buffers default to DEVICE: on accelerator backends freshly
    created arrays are born in device memory, and the simulated mode
    mirrors that so policies behave identically everywhere.  Data that is
    semantically CPU-first-touched must come through :func:`host_array` /
    ``put(x, HOST)``, exactly like the paper's malloc'd inputs.
    """
    ent = _TIERS.get(id(x))
    if ent is not None and ent[0]() is not None:
        return ent[1]
    ms = active()
    if ms.simulated:
        return DEVICE
    try:
        kind = x.sharding.memory_kind or ms.device_kind
    except (AttributeError, TypeError) as exc:  # non-array leaves
        _debug_log(f"tier_of: no sharding on {type(x).__name__} "
                   f"({exc!r}); assuming DEVICE", level=2)
        return DEVICE
    return HOST if kind == ms.host_kind else DEVICE


def device_of(x) -> Optional[int]:
    """Device-tier index of a buffer, or None when it has no explicit
    device placement (host-resident or never routed by the scheduler)."""
    ent = _TIERS.get(id(x))
    if ent is not None and ent[0]() is not None:
        return ent[2] if ent[1] == DEVICE else None
    ms = active()
    if ms.simulated:
        return None
    try:
        devs = list(x.devices())
    except (AttributeError, TypeError) as exc:  # non-array / old jaxlib
        _debug_log(f"device_of: no devices() on {type(x).__name__} "
                   f"({exc!r})", level=2)
        return None
    if len(devs) != 1:
        return None
    try:
        return jax.devices().index(devs[0])
    except ValueError:  # pragma: no cover - device of another backend
        return None


def put(x: jax.Array, tier: str, *, check: bool = True) -> jax.Array:
    """Re-home a buffer to a logical tier (the ``move_pages()`` analogue).

    Real-tier mode issues a physical ``device_put`` to the mapped memory
    kind.  Simulated mode materializes a copy tagged with the target tier
    — the source keeps its own tag, so Mem-Copy-style round trips remain
    observable and DFU's placement registry gets a distinct device-side
    buffer to cache.

    A real movement toward DEVICE is a transfer guard site: the fault
    hook runs first (injection point — before any state changes), and a
    failure of the movement itself raises a typed
    :class:`repro.core.faults.TransferError` / ``DeviceOOMError`` the
    runtime's retry/fallback guard can absorb.  ``check=False`` opts a
    call site out of injection (host-path streaming, explicit pins).
    """
    ms = active()
    if not ms.simulated:
        kind = ms.kind_of(tier)
        cur = x.sharding.memory_kind or ms.device_kind
        if cur == kind:
            return x
        hook = _FAULT_HOOK.get()
        if check and tier == DEVICE and hook is not None:
            hook(None, x.nbytes)
        try:
            return jax.device_put(x, x.sharding.with_memory_kind(kind))
        except _MOVE_ERRORS as exc:
            raise _wrap_move_error(exc, device=None,
                                   nbytes=x.nbytes) from exc
    if tier_of(x) == tier:
        return x
    hook = _FAULT_HOOK.get()
    if check and tier == DEVICE and hook is not None:
        hook(None, x.nbytes)
    import jax.numpy as jnp
    try:
        moved = jnp.array(x, copy=True)
    except _MOVE_ERRORS as exc:
        raise _wrap_move_error(exc, device=None, nbytes=x.nbytes) from exc
    _tag(moved, tier)
    return moved


def put_block(x: jax.Array, device: int) -> jax.Array:
    """Re-home a buffer onto one specific DEVICE tier (tile scheduling).

    With multiple *real* devices the block is ``device_put`` onto that
    accelerator's memory.  Otherwise the device tier is logical: a copy
    tagged ``(DEVICE, device)`` — same first-touch cost model as
    :func:`put`, so per-device movement statistics stay honest on the
    CPU container's ``SCILIB_DEVICES=n`` layout.

    Like :func:`put`, a real movement is a transfer guard site — the
    fault hook fires first (with the device index, so ``device=``
    rules in ``SCILIB_FAULTS`` target one tier), and movement failures
    raise the typed hierarchy.
    """
    if tier_of(x) == DEVICE and device_of(x) == device:
        return x
    hook = _FAULT_HOOK.get()
    if hook is not None:
        hook(device, x.nbytes)
    try:
        real = jax.devices()
    except RuntimeError as exc:  # pragma: no cover - no devices
        _debug_log(f"put_block: jax.devices() unavailable ({exc!r})")
        real = []
    if len(real) > 1:
        try:
            moved = jax.device_put(x, real[device % len(real)])
        except _MOVE_ERRORS as exc:
            raise _wrap_move_error(exc, device=device,
                                   nbytes=x.nbytes) from exc
        _tag(moved, DEVICE, device)
        return moved
    import jax.numpy as jnp
    try:
        moved = jnp.array(x, copy=True)
    except _MOVE_ERRORS as exc:
        raise _wrap_move_error(exc, device=device,
                               nbytes=x.nbytes) from exc
    _tag(moved, DEVICE, device)
    return moved


def tag_device(x: jax.Array) -> jax.Array:
    """Mark an array device-resident without moving it (outputs of
    offloaded compute are born on the device tier)."""
    ms = active()
    if ms.simulated and tier_of(x) != DEVICE:
        _tag(x, DEVICE)
    return x


def tag_host(x: jax.Array) -> jax.Array:
    """Mark an array host-resident without moving it (eviction bookkeeping
    in simulated mode: the buffer's next device use must re-migrate)."""
    ms = active()
    if ms.simulated and tier_of(x) != HOST:
        _tag(x, HOST)
    return x

"""Symbol interception — the DBI / LD_PRELOAD analogue (paper §3.1).

The paper patches BLAS symbols in an *unmodified CPU binary* with a
trampoline that runs the offload wrapper. The JAX ecosystem's equivalent
entry points are the public matmul symbols: ``jnp.dot``, ``jnp.matmul``,
``jnp.einsum``, ``jnp.tensordot`` (NumPy-style application code calls
these, not ``repro.core.blas``). :func:`install` rebinds them to trampolines that
route level-3-shaped calls through the offload runtime and fall through to
the original for everything else — no caller changes, no re-"linking".

Two usage modes mirror the paper's two library builds:

* **DBI mode** (``repro.session(...)`` or the legacy ``install()``
  shim): patch the public symbols; works for any caller importing
  ``jax.numpy`` — the analogue of ``scilib-dbi.so``.
* **dlsym mode**: call ``repro.core.blas`` directly — the analogue of
  ``scilib-dl.so``'s same-name wrappers (profiler-friendly, explicit).

The patch itself is refcounted (``patch_symbols``/``unpatch_symbols``)
so nested sessions share one set of trampolines; ``install()`` /
``uninstall()`` / ``offload()`` below are thin shims over an implicit
default :class:`repro.core.session.Session`.  Matrix-vector ``dot`` /
``matmul`` calls are intercepted as gemv-shaped level-2 calls (counted,
traced, threshold-dispatched — they stay host at realistic sizes)
instead of silently bypassing the runtime.

Inside jit traces the trampolines pass straight through to the original
functions: placement is a runtime concept; traced code gets its offload
decision statically from the ops layer.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import blas
from repro.core import runtime as rt

_ORIG: Dict[str, callable] = {}


def _is_eager_array(x) -> bool:
    return isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer)


def _blasable(*arrays) -> bool:
    if rt.active() is None:
        return False
    for x in arrays:
        if not _is_eager_array(x):
            return False
        if not (jnp.issubdtype(x.dtype, jnp.floating)
                or jnp.issubdtype(x.dtype, jnp.complexfloating)):
            return False
    return True


# --------------------------------------------------------------------- #
# trampolines                                                            #
# --------------------------------------------------------------------- #
def _benign_kwargs(a, b, kw) -> bool:
    """NumPy-style callers routinely pass ``precision=None`` and/or a
    ``preferred_element_type`` that merely restates the operand dtype —
    both are no-ops for same-dtype operands.  Bailing to the original on
    *any* kwarg sent those calls around the offload path entirely; the
    benign ones are accepted (and dropped — they request exactly what
    the offload kernels already do).  A real precision override or an
    accumulation-type change still falls through to the original."""
    for key, val in kw.items():
        if key == "precision" and val is None:
            continue
        if key == "preferred_element_type" and (
                val is None
                or (a.dtype == b.dtype and jnp.dtype(val) == a.dtype)):
            continue
        return False
    return True


def _gemv_shaped(a, b) -> Optional[tuple]:
    """Matrix-vector operands of ``dot``/``matmul``, canonicalized to
    ``(matrix, vector, trans)`` — ``A @ x`` is a plain gemv, ``x @ A``
    is the transposed one (same result as ``A.T @ x``)."""
    if a.ndim == 2 and b.ndim == 1 and a.shape[1] == b.shape[0]:
        return a, b, "N"
    if a.ndim == 1 and b.ndim == 2 and b.shape[0] == a.shape[0]:
        return b, a, "T"
    return None


def _matmul(a, b, **kw):
    if _blasable(a, b) and _benign_kwargs(a, b, kw):
        if a.ndim >= 2 and b.ndim >= 2:
            return blas.gemm(a, b)
        mv = _gemv_shaped(a, b)
        if mv is not None:
            return blas.gemv(mv[0], mv[1], trans=mv[2])
    if rt.active() is not None:
        rt.active().note_uninstrumented()
    return _ORIG["matmul"](a, b, **kw)


def _dot(a, b, **kw):
    if _blasable(a, b) and _benign_kwargs(a, b, kw):
        if a.ndim == 2 and b.ndim == 2:
            return blas.gemm(a, b)
        mv = _gemv_shaped(a, b)
        if mv is not None:
            return blas.gemv(mv[0], mv[1], trans=mv[2])
    if rt.active() is not None:
        rt.active().note_uninstrumented()
    return _ORIG["dot"](a, b, **kw)


_GEMM_PATTERNS = None


def _build_patterns():
    """Einsum specs that are exactly a (possibly transposed) gemm."""
    global _GEMM_PATTERNS
    if _GEMM_PATTERNS is not None:
        return _GEMM_PATTERNS
    pats = {}
    for ta in ("N", "T"):
        for tb in ("N", "T"):
            lhs_a = "ij" if ta == "N" else "ji"
            lhs_b = "jk" if tb == "N" else "kj"
            pats[f"{lhs_a},{lhs_b}->ik"] = (ta, tb)
    _GEMM_PATTERNS = pats
    return pats


def _canon_spec(spec: str):
    """Canonicalize a two-operand einsum that is exactly a (possibly
    transposed, possibly leading-batched) gemm.

    Returns ``(canonical_2d_spec, batched)`` or None.  Batched specs are
    the cublas*Batched shapes — ``bij,bjk->bik`` and transposed variants:
    one leading index shared by both operands and the output, with a
    plain gemm on the trailing two."""
    spec = spec.replace(" ", "")
    if "->" not in spec or spec.count(",") != 1:
        return None
    lhs, out = spec.split("->")
    a, b = lhs.split(",")
    batched = False
    if len(a) == 3 and len(b) == 3 and len(out) == 3:
        bt = a[0]
        if not (b[0] == bt and out[0] == bt):
            return None
        if bt in a[1:] or bt in b[1:] or bt in out[1:]:
            return None
        a, b, out = a[1:], b[1:], out[1:]
        batched = True
    if len(a) != 2 or len(b) != 2 or len(out) != 2:
        return None
    # map: contraction index = the one shared between a and b
    shared = set(a) & set(b)
    if len(shared) != 1:
        return None
    j = shared.pop()
    rest_a = [c for c in a if c != j]
    rest_b = [c for c in b if c != j]
    if len(rest_a) != 1 or len(rest_b) != 1:
        return None
    i, k = rest_a[0], rest_b[0]
    if set(out) != {i, k} or out[0] != i:
        return None
    ren = {i: "i", j: "j", k: "k"}
    canon = "".join(ren[c] for c in a) + "," + \
        "".join(ren[c] for c in b) + "->ik"
    return canon, batched


def _tensordot(a, b, axes=2, **kw):
    """2-D tensordot contractions with one contracted axis per operand
    are exactly a (possibly transposed) gemm — tensordot-heavy code no
    longer bypasses offload."""
    if (_blasable(a, b) and not kw
            and getattr(a, "ndim", 0) == 2 and getattr(b, "ndim", 0) == 2):
        flags = blas.tensordot_flags(axes)
        if flags is not None:
            return blas.gemm(a, b, trans_a=flags[0], trans_b=flags[1])
    if rt.active() is not None:
        rt.active().note_uninstrumented()
    return _ORIG["tensordot"](a, b, axes, **kw)


def _einsum(spec, *operands, **kw):
    if (isinstance(spec, str) and len(operands) == 2
            and _blasable(*operands) and not kw):
        canon = _canon_spec(spec)
        pats = _build_patterns()
        if canon is not None and canon[0] in pats:
            spec2d, batched = canon
            a, b = operands
            want_ndim = 3 if batched else 2
            if (a.ndim == want_ndim and b.ndim == want_ndim
                    and (not batched or a.shape[0] == b.shape[0])):
                ta, tb = pats[spec2d]
                return blas.gemm(a, b, trans_a=ta, trans_b=tb)
    if rt.active() is not None:
        rt.active().note_uninstrumented()
    return _ORIG["einsum"](spec, *operands, **kw)


# --------------------------------------------------------------------- #
# symbol patching (refcounted: one patch serves any number of sessions;  #
# the refcount and the symbol swap are lock-guarded — concurrent         #
# sessions opening/closing must not double-patch or restore early)       #
# --------------------------------------------------------------------- #
_PATCHED = 0
_PATCH_LOCK = threading.Lock()


def patch_symbols() -> None:
    """Install the trampolines over the public ``jnp`` symbols.
    Refcounted: nested intercepting sessions share one patch, and the
    originals come back only when the last one unpatches."""
    global _PATCHED
    with _PATCH_LOCK:
        _PATCHED += 1
        if not _ORIG:
            _ORIG["matmul"] = jnp.matmul
            _ORIG["dot"] = jnp.dot
            _ORIG["einsum"] = jnp.einsum
            _ORIG["tensordot"] = jnp.tensordot
            jnp.matmul = _matmul
            jnp.dot = _dot
            jnp.einsum = _einsum
            jnp.tensordot = _tensordot


def unpatch_symbols() -> None:
    """Release one patch reference; restore the originals at zero."""
    global _PATCHED
    with _PATCH_LOCK:
        _PATCHED = max(0, _PATCHED - 1)
        if _PATCHED == 0 and _ORIG:
            jnp.matmul = _ORIG.pop("matmul")
            jnp.dot = _ORIG.pop("dot")
            jnp.einsum = _ORIG.pop("einsum")
            jnp.tensordot = _ORIG.pop("tensordot")


# --------------------------------------------------------------------- #
# install / uninstall — legacy shims over an implicit default Session    #
# --------------------------------------------------------------------- #
def install(policy: Optional[str] = None,
            threshold: Optional[float] = None,
            record_trace: bool = True,
            config=None) -> rt.OffloadRuntime:
    """Activate the runtime and patch the public symbols (.init_array).

    Now a thin shim over an implicit :class:`repro.core.session.Session`
    — behavior-identical (``SCILIB_*`` env knobs honored through
    :meth:`~repro.core.config.OffloadConfig.legacy`), but everything it
    does is the session object's doing.  An explicit ``config``
    bypasses the legacy resolution (and the environment) entirely.
    Prefer ``repro.session(...)`` for new code: it takes a typed config
    and isolates state per workload."""
    from repro.core import session as ses
    from repro.core.config import OffloadConfig
    if config is None:
        config = OffloadConfig.legacy(policy=policy, threshold=threshold)
    return ses.open_legacy(config, record_trace=record_trace,
                           intercept=True).runtime


def uninstall():
    """Restore symbols and return final stats (.fini_array); shares one
    legacy-session stack with ``runtime.uninstall`` so mixed-level
    install/uninstall pairs cannot desynchronize."""
    from repro.core import session as ses
    return ses.close_legacy()


@contextlib.contextmanager
def offload(policy: Optional[str] = None,
            threshold: Optional[float] = None,
            record_trace: bool = True):
    """``with offload("dfu"): ...`` — scoped automatic BLAS offload."""
    runtime = install(policy=policy, threshold=threshold,
                      record_trace=record_trace)
    try:
        yield runtime
    finally:
        uninstall()

"""Symbol interception — the DBI / LD_PRELOAD analogue (paper §3.1).

The paper patches BLAS symbols in an *unmodified CPU binary* with a
trampoline that runs the offload wrapper. The JAX ecosystem's equivalent
entry points are the public matmul symbols: ``jnp.dot``, ``jnp.matmul``,
``jnp.einsum``, ``jnp.tensordot`` (NumPy-style application code calls
these, not ``repro.core.blas``). :func:`install` rebinds them to trampolines that
route level-3-shaped calls through the offload runtime and fall through to
the original for everything else — no caller changes, no re-"linking".

Two usage modes mirror the paper's two library builds:

* **DBI mode** (``install()``): patch the public symbols; works for any
  caller importing ``jax.numpy`` — the analogue of ``scilib-dbi.so``.
* **dlsym mode**: call ``repro.core.blas`` directly — the analogue of
  ``scilib-dl.so``'s same-name wrappers (profiler-friendly, explicit).

Inside jit traces the trampolines pass straight through to the original
functions: placement is a runtime concept; traced code gets its offload
decision statically from the ops layer.
"""
from __future__ import annotations

import contextlib
import re
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import blas
from repro.core import runtime as rt

_ORIG: Dict[str, callable] = {}


def _is_eager_array(x) -> bool:
    return isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer)


def _blasable(*arrays) -> bool:
    if rt.active() is None:
        return False
    for x in arrays:
        if not _is_eager_array(x):
            return False
        if not (jnp.issubdtype(x.dtype, jnp.floating)
                or jnp.issubdtype(x.dtype, jnp.complexfloating)):
            return False
    return True


# --------------------------------------------------------------------- #
# trampolines                                                            #
# --------------------------------------------------------------------- #
def _benign_kwargs(a, b, kw) -> bool:
    """NumPy-style callers routinely pass ``precision=None`` and/or a
    ``preferred_element_type`` that merely restates the operand dtype —
    both are no-ops for same-dtype operands.  Bailing to the original on
    *any* kwarg sent those calls around the offload path entirely; the
    benign ones are accepted (and dropped — they request exactly what
    the offload kernels already do).  A real precision override or an
    accumulation-type change still falls through to the original."""
    for key, val in kw.items():
        if key == "precision" and val is None:
            continue
        if key == "preferred_element_type" and (
                val is None
                or (a.dtype == b.dtype and jnp.dtype(val) == a.dtype)):
            continue
        return False
    return True


def _matmul(a, b, **kw):
    if (_blasable(a, b) and a.ndim >= 2 and b.ndim >= 2
            and _benign_kwargs(a, b, kw)):
        return blas.gemm(a, b)
    if rt.active() is not None:
        rt.active().stats.uninstrumented_calls += 1
    return _ORIG["matmul"](a, b, **kw)


def _dot(a, b, **kw):
    if (_blasable(a, b) and a.ndim == 2 and b.ndim == 2
            and _benign_kwargs(a, b, kw)):
        return blas.gemm(a, b)
    if rt.active() is not None:
        rt.active().stats.uninstrumented_calls += 1
    return _ORIG["dot"](a, b, **kw)


_GEMM_PATTERNS = None


def _build_patterns():
    """Einsum specs that are exactly a (possibly transposed) gemm."""
    global _GEMM_PATTERNS
    if _GEMM_PATTERNS is not None:
        return _GEMM_PATTERNS
    pats = {}
    for ta in ("N", "T"):
        for tb in ("N", "T"):
            lhs_a = "ij" if ta == "N" else "ji"
            lhs_b = "jk" if tb == "N" else "kj"
            pats[f"{lhs_a},{lhs_b}->ik"] = (ta, tb)
    _GEMM_PATTERNS = pats
    return pats


def _canon_spec(spec: str):
    """Canonicalize a two-operand einsum that is exactly a (possibly
    transposed, possibly leading-batched) gemm.

    Returns ``(canonical_2d_spec, batched)`` or None.  Batched specs are
    the cublas*Batched shapes — ``bij,bjk->bik`` and transposed variants:
    one leading index shared by both operands and the output, with a
    plain gemm on the trailing two."""
    spec = spec.replace(" ", "")
    if "->" not in spec or spec.count(",") != 1:
        return None
    lhs, out = spec.split("->")
    a, b = lhs.split(",")
    batched = False
    if len(a) == 3 and len(b) == 3 and len(out) == 3:
        bt = a[0]
        if not (b[0] == bt and out[0] == bt):
            return None
        if bt in a[1:] or bt in b[1:] or bt in out[1:]:
            return None
        a, b, out = a[1:], b[1:], out[1:]
        batched = True
    if len(a) != 2 or len(b) != 2 or len(out) != 2:
        return None
    # map: contraction index = the one shared between a and b
    shared = set(a) & set(b)
    if len(shared) != 1:
        return None
    j = shared.pop()
    rest_a = [c for c in a if c != j]
    rest_b = [c for c in b if c != j]
    if len(rest_a) != 1 or len(rest_b) != 1:
        return None
    i, k = rest_a[0], rest_b[0]
    if set(out) != {i, k} or out[0] != i:
        return None
    ren = {i: "i", j: "j", k: "k"}
    canon = "".join(ren[c] for c in a) + "," + \
        "".join(ren[c] for c in b) + "->ik"
    return canon, batched


def _tensordot(a, b, axes=2, **kw):
    """2-D tensordot contractions with one contracted axis per operand
    are exactly a (possibly transposed) gemm — tensordot-heavy code no
    longer bypasses offload."""
    if (_blasable(a, b) and not kw
            and getattr(a, "ndim", 0) == 2 and getattr(b, "ndim", 0) == 2):
        flags = blas.tensordot_flags(axes)
        if flags is not None:
            return blas.gemm(a, b, trans_a=flags[0], trans_b=flags[1])
    if rt.active() is not None:
        rt.active().stats.uninstrumented_calls += 1
    return _ORIG["tensordot"](a, b, axes, **kw)


def _einsum(spec, *operands, **kw):
    if (isinstance(spec, str) and len(operands) == 2
            and _blasable(*operands) and not kw):
        canon = _canon_spec(spec)
        pats = _build_patterns()
        if canon is not None and canon[0] in pats:
            spec2d, batched = canon
            a, b = operands
            want_ndim = 3 if batched else 2
            if (a.ndim == want_ndim and b.ndim == want_ndim
                    and (not batched or a.shape[0] == b.shape[0])):
                ta, tb = pats[spec2d]
                return blas.gemm(a, b, trans_a=ta, trans_b=tb)
    if rt.active() is not None:
        rt.active().stats.uninstrumented_calls += 1
    return _ORIG["einsum"](spec, *operands, **kw)


# --------------------------------------------------------------------- #
# install / uninstall                                                    #
# --------------------------------------------------------------------- #
def install(policy: str = "dfu", threshold: Optional[float] = None,
            record_trace: bool = True) -> rt.OffloadRuntime:
    """Activate the runtime and patch the public symbols (.init_array)."""
    runtime = rt.install(policy=policy, threshold=threshold,
                         record_trace=record_trace)
    if not _ORIG:
        _ORIG["matmul"] = jnp.matmul
        _ORIG["dot"] = jnp.dot
        _ORIG["einsum"] = jnp.einsum
        _ORIG["tensordot"] = jnp.tensordot
        jnp.matmul = _matmul
        jnp.dot = _dot
        jnp.einsum = _einsum
        jnp.tensordot = _tensordot
    return runtime


def uninstall():
    """Restore symbols and return final stats (.fini_array)."""
    if _ORIG:
        jnp.matmul = _ORIG.pop("matmul")
        jnp.dot = _ORIG.pop("dot")
        jnp.einsum = _ORIG.pop("einsum")
        jnp.tensordot = _ORIG.pop("tensordot")
    return rt.uninstall()


@contextlib.contextmanager
def offload(policy: str = "dfu", threshold: Optional[float] = None,
            record_trace: bool = True):
    """``with offload("dfu"): ...`` — scoped automatic BLAS offload."""
    runtime = install(policy=policy, threshold=threshold,
                      record_trace=record_trace)
    try:
        yield runtime
    finally:
        uninstall()

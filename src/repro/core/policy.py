"""Data-movement policies (paper §3.2) over portable logical memory tiers.

The JAX adaptation of the paper's three strategies plus two controls.
Tiers are the *logical* HOST/DEVICE pair of :mod:`repro.core.memspace`:
on a TPU/GPU backend they map to real distinct memory kinds (host DRAM
vs HBM) and every ``put`` is a physical transfer; on a single-kind CPU
backend the mem-space simulates the tier split (tag + copy) so the same
policies run — and produce the same statistics — on every backend.

Buffer identity follows the source array object (the JAX analogue of the
paper's virtual-address identity): placement is cached per buffer, so a
matrix moved by Device First-Use stays device-resident for all later calls
that pass the same array — that cache *is* the page table remap of Fig. 2.

Placement state lives in the runtime's residency stores
(:mod:`repro.core.residency`): ``runtime.placements`` for whole-buffer
placements and ``runtime.block_stores[d]`` per device tier for tile
blocks.  Policies read and write those stores directly — the stores own
byte caps, eviction, pinning and event accounting, so every policy gets
them for free and none keeps private residency state.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core import memspace

#: logical tier names, re-exported for the runtime and tests.  These were
#: once hard-coded physical memory kinds ("pinned_host"/"device"); the
#: mem-space now resolves the physical kind per backend.
HOST_KIND = memspace.HOST
DEVICE_KIND = memspace.DEVICE


def _put(x: jax.Array, tier: str) -> jax.Array:
    """Re-home a buffer to a logical tier (the move_pages() analogue)."""
    return memspace.put(x, tier)


def memory_kind_of(x: jax.Array) -> str:
    """Logical tier of a buffer (kept under its historical name)."""
    return memspace.tier_of(x)


def host_array(x) -> jax.Array:
    """The malloc() analogue: materialize an array on the HOST tier.

    Application inputs in the paper are CPU-first-touched; use this for
    inputs so the offload policies have real movement to manage."""
    import jax.numpy as jnp
    x = jnp.asarray(x)
    return _put(x, HOST_KIND)


@dataclasses.dataclass
class Placement:
    """Outcome of placing one operand for one call."""

    array: jax.Array
    moved_bytes: int = 0
    cache_hit: bool = False
    #: which device tier the buffer landed on (multi-device scheduling);
    #: 0 is the only tier on single-accelerator systems.
    device: int = 0


class PolicyBase:
    """Interface: how operands reach the device tier and results return."""

    name = "base"
    #: whether outputs of offloaded calls are copied back to the host tier
    copy_back = False
    #: whether placements persist across calls (the reuse mechanism)
    persistent = True
    #: whether this policy executes on the device tier at all — the
    #: dispatch pipeline's decide stage vetoes offload when False
    #: (host-only baselines), instead of string-matching policy names
    offloads = True
    #: whether the multi-device tile scheduler may shard calls under this
    #: policy.  Only policies that migrate every operand on (first) use
    #: keep their semantics when the runtime moves blocks itself; the
    #: access-counter model decides per-operand and must stay
    #: single-device or it would silently degenerate to DFU.
    shardable = True

    def place_operand(self, runtime, x: jax.Array) -> Placement:
        raise NotImplementedError

    def place_output(self, runtime, y: jax.Array) -> Placement:
        """Offloaded compute produces device-tier outputs; policies decide
        whether they stay (DFU) or bounce back to host (Mem-Copy)."""
        if self.copy_back:
            nbytes = y.nbytes
            return Placement(_put(y, HOST_KIND), moved_bytes=nbytes)
        return Placement(memspace.tag_device(y))

    def select_device(self, runtime, blocks) -> int:
        """Which device tier runs one tile of a sharded call (BLASX-style
        round-robin with affinity).

        ``blocks``: (key, nbytes, shared) per tile operand.  Persistent
        policies prefer the device already holding the most operand-block
        bytes — first use moved the block there, every later tile on the
        same device is free, the exact multi-device generalization of
        first-touch.  Blocks shared by every tile (trsm's triangle) are
        replicated and never steer the choice.  With no residency
        anywhere, tiles deal round-robin so work spreads evenly.
        Score ties — a block replicated onto several devices by an
        earlier grid layout — break toward the device with the fewest
        tiles scheduled this call, so replication cannot funnel a whole
        grid onto one device and idle the rest.  A quarantined device
        (circuit breaker open) is never selected, even by affinity —
        its residents were invalidated at trip time anyway."""
        if self.persistent:
            scores: dict = {}
            for key, nbytes, shared in blocks:
                if shared:
                    continue
                for home, store in enumerate(runtime.block_stores):
                    if key in store and runtime.device_usable(home):
                        scores[home] = scores.get(home, 0) + nbytes
            if scores:
                return min(scores, key=lambda d: (-scores[d],
                                                  runtime.scheduled_load(d),
                                                  d))
        return runtime.next_device()


class MemCopyPolicy(PolicyBase):
    """Strategy 1 (§3.2.1): stage in and out around *every* call."""

    name = "memcopy"
    copy_back = True
    persistent = False

    def place_operand(self, runtime, x):
        if memspace.tier_of(x) == DEVICE_KIND:
            # even Mem-Copy tools skip the copy when data is already there
            return Placement(x, cache_hit=True)
        return Placement(_put(x, DEVICE_KIND), moved_bytes=x.nbytes)


class DeviceFirstUsePolicy(PolicyBase):
    """Strategy 3 (§3.2.3): the paper's contribution.

    First device use migrates the buffer to the device tier and registers
    the placement; every later use of the same buffer is a cache hit with
    zero movement. Outputs are born device-resident and registered, so
    chained calls (``C = A·B`` then ``E = D·C``) never touch the link.
    """

    name = "dfu"
    copy_back = False
    persistent = True

    def place_operand(self, runtime, x):
        store = runtime.placements
        cached = store.get(id(x))
        if cached is not None:
            return Placement(cached, cache_hit=True)
        if memspace.tier_of(x) == DEVICE_KIND:
            store.put(id(x), x, x.nbytes, anchor=x)
            return Placement(x, cache_hit=False)
        moved = _put(x, DEVICE_KIND)
        store.put(id(x), moved, moved.nbytes, anchor=x)
        return Placement(moved, moved_bytes=x.nbytes)

    def place_output(self, runtime, y):
        memspace.tag_device(y)
        runtime.placements.put(id(y), y, y.nbytes, anchor=y)
        return Placement(y)


class CounterPolicy(PolicyBase):
    """Strategy 2 (§3.2.2): model of the hardware access-counter migration.

    Reproduces the size- and reuse-biased behaviour measured in Table 6
    (rules R1-R4 of ``repro.memtier.simulator``): some operands never
    migrate and are streamed from the host tier on every call — which is
    why this policy loses to DFU in the paper's application tests.
    """

    name = "counter"
    copy_back = False
    persistent = True
    shardable = False     # R1-R4 are per-operand host-vs-device rules

    reuse_min = 100.0
    byte_budget = 3.4e9
    c_small = 16e6

    def place_operand(self, runtime, x, *, reads_per_elem: float = 1.0,
                      written: bool = False, ai: float = 0.0,
                      budget_used: int = 0) -> Placement:
        store = runtime.placements
        cached = store.get(id(x))
        if cached is not None:
            return Placement(cached, cache_hit=True)
        if memspace.tier_of(x) == DEVICE_KIND:
            store.put(id(x), x, x.nbytes, anchor=x)
            return Placement(x)
        if written:
            ok = x.nbytes <= self.c_small and ai >= 30.0
        else:
            ok = (reads_per_elem >= self.reuse_min
                  and budget_used + x.nbytes <= self.byte_budget)
        if not ok:
            return Placement(x)         # stays host: remote-streamed reads
        moved = _put(x, DEVICE_KIND)
        store.put(id(x), moved, moved.nbytes, anchor=x)
        return Placement(moved, moved_bytes=x.nbytes)


class PinnedDevicePolicy(PolicyBase):
    """``numactl -m 1`` control: everything lives on the device tier."""

    name = "pinned"
    copy_back = False

    def place_operand(self, runtime, x):
        store = runtime.placements
        cached = store.get(id(x))
        if cached is not None:
            return Placement(cached, cache_hit=True)
        if memspace.tier_of(x) == DEVICE_KIND:
            store.put(id(x), x, x.nbytes, anchor=x)
            return Placement(x)
        moved = _put(x, DEVICE_KIND)
        store.put(id(x), moved, moved.nbytes, anchor=x)
        return Placement(moved, moved_bytes=x.nbytes)


class CpuOnlyPolicy(PolicyBase):
    """Baseline: never offload (the paper's NVPL CPU runs)."""

    name = "cpu"
    copy_back = False
    persistent = False
    offloads = False

    def place_operand(self, runtime, x):
        return Placement(x)


POLICY_CLASSES = {
    p.name: p for p in (MemCopyPolicy, CounterPolicy, DeviceFirstUsePolicy,
                        PinnedDevicePolicy, CpuOnlyPolicy)
}


def make_policy(name: str) -> PolicyBase:
    try:
        return POLICY_CLASSES[name]()
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; choose from {sorted(POLICY_CLASSES)}")

"""First-class offload sessions: a runtime you hold, not a process global.

The paper's tool is necessarily process-global — an ``LD_PRELOAD``
interposer has exactly one ``.init_array``/``.fini_array`` lifecycle.
The reproduction inherited that shape (``install()``/``uninstall()``
flipping one module-level runtime configured by ambient env vars), and
it is the main obstacle to the ROADMAP's serve-many-workloads goal:
two workloads in one process cannot hold different thresholds, caps, or
policies, and nothing isolates their statistics.

A :class:`Session` owns the full offload stack for one workload:

* its :class:`~repro.core.runtime.OffloadRuntime` (placement registry,
  dispatch pipeline, statistics, trace),
* the installed interceptors (``jnp.dot``/``matmul``/``einsum``/
  ``tensordot`` trampolines — patched while at least one intercepting
  session is open, refcounted),
* its :class:`~repro.core.config.OffloadConfig` — typed, validated,
  serializable; no env vars read after construction.

Sessions **nest via a stack**: the innermost open session's runtime is
the active dispatch target (its config wins), and closing it restores
the outer session — so a library can open a scoped session with its own
tuned config inside an application's long-lived one:

    import repro

    with repro.session(OffloadConfig.load("tuned.json")) as s:
        ...                      # dispatched under the tuned config
        print(s.report())

Long-lived use is the same object without ``with``: ``s =
repro.session(cfg)`` ... ``s.close()``.  Mid-run changes go through
:meth:`Session.reconfigure`, which flushes the dispatch cache and the
adaptive locks the change invalidates instead of leaving stale
decisions behind.

The legacy surface (``repro.core.install``/``uninstall``/``offload``)
is now a thin shim over an implicit default session — behavior-identical
(the parity tests assert decisions, counters and report output match),
but everything it did is expressible, and testable, as objects.

An ``atexit`` hook dumps the recorded trace of any session still open
at interpreter shutdown to its ``config.trace_path`` — traces are no
longer lost when a process exits without ``uninstall()``/``close()``.
"""
from __future__ import annotations

import atexit
import contextlib
import threading
from contextvars import ContextVar
from typing import List, Optional, Tuple

from repro.core.config import OffloadConfig

__all__ = ["Session", "session", "active_session"]

#: innermost-last stack of open sessions (the nesting discipline).
#: Context-local (PR 7): each thread nests its own sessions; one
#: thread's open/close can never corrupt another thread's restore
#: order.  The stack is an immutable tuple — push/pop replace it
#: wholesale, so a reader never observes a half-mutated stack.
_STACK: ContextVar[Tuple["Session", ...]] = (
    ContextVar("scilib_session_stack", default=()))

#: all open sessions process-wide, for the atexit trace-dump fallback
#: (context-local stacks are invisible across threads; shutdown isn't).
_OPEN: List["Session"] = []
_OPEN_LOCK = threading.Lock()

_ATEXIT_REGISTERED = False


def _ensure_atexit() -> None:
    global _ATEXIT_REGISTERED
    if not _ATEXIT_REGISTERED:
        atexit.register(_atexit_dump)
        _ATEXIT_REGISTERED = True


def _atexit_dump() -> None:
    """Fallback trace dump: a process exiting with sessions still open
    (crash path, forgotten ``uninstall()``) keeps its recorded traces —
    each open session with a ``trace_path`` dumps before teardown."""
    with _OPEN_LOCK:
        pending = list(_OPEN)
    for s in pending:
        try:
            s._dump_trace(reason="atexit")
        except Exception:   # never let shutdown raise   # noqa: BLE001
            pass


class Session:
    """One workload's offload stack: config + runtime + interceptors.

    ``intercept=False`` activates the runtime without patching the
    public ``jnp`` symbols (the dlsym-mode analogue: callers invoke
    ``repro.core.blas`` directly).

    ``name`` is the session's tenant id for multi-tenant runs: trace
    events are stamped with it and per-tenant pool statistics report
    under it.  Unnamed sessions stamp nothing — their traces serialize
    byte-identically to the single-tenant format.  ``pool`` joins the
    session to a :class:`~repro.core.residency.SharedDevicePool`
    (quota from ``config.pool_quota``); with no explicit pool, setting
    ``config.pool_bytes``/``pool_quota`` joins the process-default
    pool.
    """

    def __init__(self, config: Optional[OffloadConfig] = None, *,
                 record_trace: bool = True, intercept: bool = True,
                 name: str = "", pool=None):
        self.config = (OffloadConfig.from_env() if config is None
                       else config)
        self.record_trace = record_trace
        self.intercept = intercept
        self.name = name
        self.pool = pool
        self.runtime = None      # type: Optional[object]
        self._traced_dumped = False
        # whether this session holds a LAPACK-tier patch reference
        # (config.lapack + intercept): jnp.linalg / jax.scipy.linalg
        # factorizations routed onto the repro.solvers drivers
        self._lapack_patched = False

    # ------------------------------------------------------------------ #
    # lifecycle                                                           #
    # ------------------------------------------------------------------ #
    def open(self) -> "Session":
        """Create this session's runtime and make it the active dispatch
        target (pushing any currently-active session one level out)."""
        if self.runtime is not None:
            raise RuntimeError("session is already open")
        self._traced_dumped = False     # a reopened session dumps again
        from repro.core import intercept as icp
        from repro.core import residency as res
        from repro.core import runtime as rt
        pool = self.pool
        if pool is None and (self.config.pool_bytes is not None
                             or self.config.pool_quota is not None):
            pool = res.default_pool(self.config.pool_bytes)
        self.runtime = rt.OffloadRuntime(config=self.config,
                                         record_trace=self.record_trace,
                                         session_id=self.name,
                                         pool=pool)
        self.name = self.runtime.session_id   # pool may auto-assign one
        _STACK.set(_STACK.get() + (self,))
        with _OPEN_LOCK:
            _OPEN.append(self)
        rt.activate(self.runtime)
        if self.intercept:
            icp.patch_symbols()
            if self.config.lapack:
                from repro.solvers import intercept as slv
                slv.patch_symbols()
                self._lapack_patched = True
        _ensure_atexit()
        return self

    def close(self):
        """Drain in-flight work, dump the trace (``config.trace_path``),
        deactivate, and return final :class:`RuntimeStats`.  The outer
        session (if any) becomes active again.  Idempotent."""
        if self.runtime is None:
            return None
        from repro.core import intercept as icp
        from repro.core import runtime as rt
        runtime, self.runtime = self.runtime, None
        runtime.sync()
        self._dump_trace(runtime=runtime)
        runtime.detach_pool()
        stack = _STACK.get()
        if self in stack:
            _STACK.set(tuple(s for s in stack if s is not self))
        with _OPEN_LOCK:
            if self in _OPEN:
                _OPEN.remove(self)
        if self.intercept:
            if self._lapack_patched:
                from repro.solvers import intercept as slv
                slv.unpatch_symbols()
                self._lapack_patched = False
            icp.unpatch_symbols()
        # the innermost remaining session's runtime is the dispatch
        # target again; with none left, dispatch deactivates entirely.
        # Module-level state this runtime set (the blas-layer cache
        # flag, the resolved memspace mapping) is restored to the outer
        # session's values too — "outer restored on exit" must hold for
        # everything the inner config touched, not just the runtime.
        from repro.core import blas, memspace
        stack = _STACK.get()
        prev = stack[-1] if stack else None
        rt.activate(prev.runtime if prev is not None else None)
        if prev is not None and prev.runtime is not None:
            blas.refresh_cache_flag(prev.config.dispatch_cache)
            memspace.install(space=prev.runtime.memspace)
        else:
            blas.refresh_cache_flag()    # env-derived default again
        return runtime.stats

    def __enter__(self) -> "Session":
        if self.runtime is None:
            self.open()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def closed(self) -> bool:
        return self.runtime is None

    @contextlib.contextmanager
    def scope(self):
        """Adopt this open session in the *current* thread/context.

        Sessions are context-local: a worker thread does not inherit
        the thread that opened them.  ``with s.scope():`` makes ``s``
        the active dispatch target here without reopening it — several
        workers may scope one session concurrently (its runtime
        serializes their calls).  The previous context state is
        restored on exit."""
        self._require_open()
        from repro.core import blas, memspace
        from repro.core import runtime as rt
        token = _STACK.set(_STACK.get() + (self,))
        rt.activate(self.runtime)
        blas.refresh_cache_flag(self.config.dispatch_cache)
        memspace.install(space=self.runtime.memspace)
        try:
            yield self
        finally:
            _STACK.reset(token)
            stack = _STACK.get()
            prev = stack[-1] if stack else None
            rt.activate(prev.runtime if prev is not None else None)
            if prev is not None and prev.runtime is not None:
                blas.refresh_cache_flag(prev.config.dispatch_cache)
                memspace.install(space=prev.runtime.memspace)
            else:
                blas.refresh_cache_flag()

    # ------------------------------------------------------------------ #
    # what a workload reads off its session                               #
    # ------------------------------------------------------------------ #
    @property
    def stats(self):
        self._require_open()
        return self.runtime.stats

    @property
    def trace(self):
        """The recorded BLAS trace (None with ``record_trace=False``)."""
        self._require_open()
        return self.runtime.trace

    def report(self) -> str:
        """The runtime's statistics report, scoped to this session."""
        self._require_open()
        return self.runtime.stats.report()

    def sync(self) -> "Session":
        self._require_open()
        self.runtime.sync()
        return self

    def pin(self, x):
        """Pin a buffer on this session's device tier (survives cap
        pressure until :meth:`unpin` or buffer death)."""
        self._require_open()
        return self.runtime.pin(x)

    def unpin(self, x) -> None:
        self._require_open()
        self.runtime.unpin(x)

    # ------------------------------------------------------------------ #
    # safe mid-run reconfiguration                                        #
    # ------------------------------------------------------------------ #
    def reconfigure(self, **kw) -> OffloadConfig:
        """Apply config changes to the live runtime.

        Builds the new config with :meth:`OffloadConfig.replace` (so it
        is validated as a whole), then applies it: the memoized dispatch
        cache and any adaptive per-site locks invalidated by the change
        are flushed, residency caps and eviction policies are updated in
        place.  ``devices`` cannot change mid-run (the block-store
        topology is fixed at open); use a new session.  Returns the new
        config.
        """
        self._require_open()
        was_lapack = self.config.lapack
        new = self.config.replace(**kw)
        self.runtime.apply_config(new)
        self.config = new
        # the LAPACK-tier patch follows the flag: flipping it mid-run
        # (re)patches or releases this session's reference
        if self.intercept and new.lapack != was_lapack:
            from repro.solvers import intercept as slv
            if new.lapack and not self._lapack_patched:
                slv.patch_symbols()
                self._lapack_patched = True
            elif not new.lapack and self._lapack_patched:
                slv.unpatch_symbols()
                self._lapack_patched = False
        return new

    # ------------------------------------------------------------------ #
    def _dump_trace(self, runtime=None, reason: str = "close") -> None:
        runtime = self.runtime if runtime is None else runtime
        if runtime is None or self._traced_dumped:
            return
        path = self.config.trace_path
        if not path or runtime.trace is None:
            return
        self._traced_dumped = True
        try:
            runtime.trace.dump(path)
            if self.config.debug >= 1:
                print(f"[scilib] trace ({len(runtime.trace)} calls) "
                      f"-> {path} ({reason})")
        except Exception as exc:   # noqa: BLE001 — teardown must finish:
            # a failed dump (bad path, full disk, serialization bug) is
            # reported, never allowed to mask the process exit status or
            # leave a half-closed session.  trace.dump writes through a
            # temp file + rename, so `path` is never left truncated.
            print(f"[scilib] trace dump to {path!r} failed: "
                  f"{type(exc).__name__}: {exc}")

    def _require_open(self) -> None:
        if self.runtime is None:
            raise RuntimeError("session is closed")

    def __repr__(self) -> str:
        state = "open" if self.runtime is not None else "closed"
        return f"Session({self.config!r}, {state})"


# --------------------------------------------------------------------- #
# module-level helpers                                                   #
# --------------------------------------------------------------------- #
def session(config: Optional[OffloadConfig] = None, *,
            record_trace: bool = True,
            intercept: bool = True,
            name: str = "", pool=None, **kw) -> Session:
    """Open a session (the primary public entry point).

    ``repro.session(cfg)`` returns an **open** session: use it as a
    context manager for scoped offload, or keep it long-lived and call
    ``close()`` yourself.  Extra keyword arguments are config fields
    applied on top (``repro.session(threshold=800)``), so quick
    one-off overrides need no explicit config object.  ``name`` and
    ``pool`` are the multi-tenant knobs (see :class:`Session`).
    """
    if config is None:
        config = OffloadConfig.from_env()
    if kw:
        config = config.replace(**kw)
    return Session(config, record_trace=record_trace,
                   intercept=intercept, name=name, pool=pool).open()


def active_session() -> Optional[Session]:
    """The innermost open session of the current context, or None."""
    stack = _STACK.get()
    return stack[-1] if stack else None


# --------------------------------------------------------------------- #
# the implicit default-session stack behind the legacy shims             #
# --------------------------------------------------------------------- #
#: sessions opened by install() (both the runtime- and intercept-level
#: shims), closed LIFO by uninstall().  One shared stack — exactly like
#: the one module global the shims used to flip — so a runtime-level
#: uninstall() after an intercept-level install() (or vice versa)
#: cannot leave a stale closed session behind.  Context-local like the
#: session stack: each thread's install()/uninstall() pairs are its own.
_LEGACY: ContextVar[Tuple[Session, ...]] = (
    ContextVar("scilib_legacy_stack", default=()))


def open_legacy(config: OffloadConfig, *, record_trace: bool = True,
                intercept: bool = False) -> Session:
    """Open the implicit session behind a legacy ``install()`` call.

    One deliberate divergence from the pre-session globals: repeated
    ``install()`` calls **nest** (each ``uninstall()`` closes the most
    recent and restores the previous one).  The old code silently
    orphaned the previous runtime on a second ``install()`` and one
    ``uninstall()`` tore everything down — nesting is strictly more
    useful and is what the session stack already guarantees."""
    s = Session(config, record_trace=record_trace,
                intercept=intercept).open()
    _LEGACY.set(_LEGACY.get() + (s,))
    return s


def close_legacy():
    """Close the most recent legacy session (the ``uninstall()`` shim);
    falls back to the innermost open session, then to a no-op."""
    legacy = _LEGACY.get()
    if legacy:
        _LEGACY.set(legacy[:-1])
        return legacy[-1].close()
    s = active_session()
    return s.close() if s is not None else None

"""Call-site identity and per-site profiles (paper §3.1, per-site patching).

The paper's tool does not make one global offload decision: dynamic binary
instrumentation patches each BLAS *call site* individually, profiles it,
and locks in a site-specific decision.  This module is the JAX analogue:

* :func:`fingerprint` — a cheap call-site id built from the interception
  entry point (the BLAS routine) plus the first caller frame outside the
  dispatch machinery.  A loop calling ``blas.gemm`` from one line is one
  site; the same gemm shape issued from two places is two sites.
* :class:`CallSiteProfile` — what the runtime learns about one site:
  call count, size (N_avg) distribution, residency hit rate, observed
  per-path wall time, and — in adaptive mode — the locked decision.
* :class:`CallSiteRegistry` — the per-runtime site table; the analogue of
  the paper's patched-trampoline table.

Frames inside the dispatch machinery itself (``blas.py``, ``runtime.py``,
``intercept.py``, this file) are skipped, so a ``lapack.getrf`` driver's
internal gemm calls fingerprint to their line *inside the driver* —
exactly what the paper's patching of BLAS symbols inside libraries does.
"""
from __future__ import annotations

import dataclasses
import os
import sys
import threading
from typing import Dict, Iterator, List, Optional

_HERE = os.path.dirname(os.path.abspath(__file__))
#: dispatch-machinery files whose frames never count as the call site.
#: Mutable on purpose: other dispatch layers (repro.solvers' trampolines)
#: register themselves via :func:`register_machinery`.
_MACHINERY = set(
    os.path.join(_HERE, name)
    for name in ("callsite.py", "runtime.py", "blas.py", "intercept.py"))
_MAX_WALK = 16


def register_machinery(path: str) -> None:
    """Mark a module file as dispatch machinery — its frames are skipped
    when fingerprinting call sites.  Trampoline layers outside this
    package (e.g. ``repro.solvers.intercept``) register themselves so a
    patched ``jnp.linalg.solve`` call fingerprints to the *application*
    line, not to the trampoline."""
    _MACHINERY.add(os.path.abspath(path))

UNKNOWN = "<unknown>"

#: execution venues in probe-schedule order: the host path, the generic
#: XLA offload, and the hand-written kernel offload (``kernel_path``).
VENUES = ("host", "xla", "pallas")


def fingerprint(entry: str) -> str:
    """Cheap call-site id: ``entry@file:function:lineno``.

    ``entry`` is the interception entry point (the BLAS routine name).
    The caller frame is the first one outside the dispatch machinery.
    Cost is a short frame walk (~1 us) — negligible against even a
    sub-threshold host gemm, and the fast dispatch path stays fast.
    """
    try:
        frame = sys._getframe(1)
    except ValueError:                      # pragma: no cover - no caller
        return f"{entry}@{UNKNOWN}"
    for _ in range(_MAX_WALK):
        if frame is None:
            break
        code = frame.f_code
        if code.co_filename not in _MACHINERY:
            return (f"{entry}@{os.path.basename(code.co_filename)}"
                    f":{code.co_name}:{frame.f_lineno}")
        frame = frame.f_back
    return f"{entry}@{UNKNOWN}"


@dataclasses.dataclass
class CallSiteProfile:
    """Everything the runtime has learned about one BLAS call site."""

    site: str
    calls: int = 0
    flops: float = 0.0
    seconds: float = 0.0
    offloaded: int = 0
    on_host: int = 0
    # size distribution (N_avg per call; locked adaptive calls skip the
    # derivation entirely, so the count can trail ``calls``)
    n_avg_min: float = float("inf")
    n_avg_max: float = 0.0
    n_avg_sum: float = 0.0
    n_avg_count: int = 0
    # residency: operand placements attempted / found already resident
    lookups: int = 0
    hits: int = 0
    # adaptive warmup: per-path wall-time samples (paper: profile the
    # first N calls on both paths, then patch in the faster decision)
    host_timed: int = 0
    host_seconds: float = 0.0
    host_best: float = float("inf")
    device_timed: int = 0
    device_seconds: float = 0.0
    device_best: float = float("inf")
    # third venue (kernel_path): probes of the hand-written kernel offload
    kernel_timed: int = 0
    kernel_seconds: float = 0.0
    kernel_best: float = float("inf")
    # completed calls that executed on the pallas venue (subdivides
    # ``offloaded``) and their wall time
    pallas_calls: int = 0
    pallas_seconds: float = 0.0
    # split-precision pseudo-venue (SCILIB_PRECISION): timed probes of
    # the split-representation formulation, the scheme/venue they ran
    # under, and whether any probe missed its error bound — a site that
    # escalated during warmup never locks split.
    split_timed: int = 0
    split_seconds: float = 0.0
    split_best: float = float("inf")
    split_scheme: str = ""                 # scheme the probes ran
    split_venue: str = ""                  # venue the probes ran on
    split_bad: bool = False                # a probe escalated
    # completed calls that executed split (subdivides ``offloaded``)
    split_calls: int = 0
    locked: Optional[bool] = None          # the locked offload decision
    locked_venue: str = ""                 # "" until locked (see VENUES)
    locked_precision: str = ""             # locked split scheme (or "")
    locked_why: str = ""
    last_offload: Optional[bool] = None    # decision of the latest call
    # several threads adopting one session can observe a shared site
    # concurrently; the profile lock keeps each observation atomic
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    # ------------------------------------------------------------------ #
    def observe(self, n_avg: float, flops: float, seconds: float,
                offload: bool, venue: str = "",
                precision: str = "") -> None:
        """Record one completed call at this site.  ``n_avg <= 0``
        means "not derived" (the locked adaptive fast path skips the
        derivation): the call still counts, the size distribution —
        already captured during warmup — is left untouched."""
        with self._lock:
            self.calls += 1
            self.flops += flops
            self.seconds += seconds
            if offload:
                self.offloaded += 1
                if venue == "pallas":
                    self.pallas_calls += 1
                    self.pallas_seconds += seconds
                if precision:
                    self.split_calls += 1
            else:
                self.on_host += 1
            self.last_offload = offload
            if n_avg > 0:
                if n_avg < self.n_avg_min:
                    self.n_avg_min = n_avg
                if n_avg > self.n_avg_max:
                    self.n_avg_max = n_avg
                self.n_avg_sum += n_avg
                self.n_avg_count += 1

    def observe_residency(self, hit: bool) -> None:
        """Residency hit-rate source: one operand placement attempt at
        this site found (or missed) a resident entry in the runtime's
        residency store.  The per-site ``hit%`` column and the adaptive
        mode's view of locality both read these counters — sites whose
        operands are always resident are exactly the sites DFU wins on.
        """
        with self._lock:
            self.lookups += 1
            self.hits += int(hit)

    def observe_probe(self, offload: bool, seconds: float,
                      venue: str = "", precision: str = "") -> None:
        """Record one timed adaptive-warmup probe on one venue.  With no
        ``venue`` given, ``offload`` picks between the two classic
        paths; ``venue="pallas"`` routes to the kernel-venue counters;
        a non-empty ``precision`` routes to the split pseudo-venue
        counters regardless of the venue the split passes ran on."""
        with self._lock:
            if precision:
                self.split_timed += 1
                self.split_seconds += seconds
                self.split_scheme = precision
                self.split_venue = venue or "xla"
                if seconds < self.split_best:
                    self.split_best = seconds
            elif venue == "pallas":
                self.kernel_timed += 1
                self.kernel_seconds += seconds
                if seconds < self.kernel_best:
                    self.kernel_best = seconds
            elif offload:
                self.device_timed += 1
                self.device_seconds += seconds
                if seconds < self.device_best:
                    self.device_best = seconds
            else:
                self.host_timed += 1
                self.host_seconds += seconds
                if seconds < self.host_best:
                    self.host_best = seconds

    # ------------------------------------------------------------------ #
    @property
    def probes_done(self) -> int:
        return (self.host_timed + self.device_timed + self.kernel_timed
                + self.split_timed)

    def probe_path(self) -> bool:
        """Deterministic warmup schedule: even probes run the host path,
        odd probes offload — both paths get equal samples regardless of
        what the threshold rule would have said."""
        return self.probes_done % 2 == 1

    def probe_venue(self, venues: int = 2, split: bool = False) -> str:
        """Round-robin warmup schedule over the first ``venues`` entries
        of :data:`VENUES`.  ``venues=2`` reproduces the classic
        host/offload alternation exactly; ``venues=3`` adds the kernel
        venue to the rotation — every venue gets equal samples.
        ``split=True`` appends the split-precision pseudo-venue (the
        "split" slot) so precision variants race like venues do."""
        order = VENUES[:venues] + (("split",) if split else ())
        return order[self.probes_done % len(order)]

    def lock(self, fallback: Optional[bool] = None) -> bool:
        """Lock the fastest venue (paper's warmup-then-patch step).

        Compares the *best* sample per venue, not the mean: the first
        probe of each venue pays jit compilation, and the minimum is
        robust to that one-off cost.  A venue with no samples (e.g. the
        ``cpu`` policy forces every probe host-side) loses by default;
        with no samples at all the threshold ``fallback`` decides.  The
        kernel venue competes only when it was probed at all.
        """
        with self._lock:
            if self.locked is not None:
                return self.locked
            if self.probes_done == 0:
                self.locked = bool(fallback)
                self.locked_venue = "xla" if self.locked else "host"
                self.locked_why = "no probes; threshold fallback"
                return self.locked
            if (self.split_timed and not self.split_bad
                    and self.split_best < self.device_best
                    and self.split_best < self.host_best
                    and self.split_best < self.kernel_best):
                self.locked = True
                self.locked_venue = self.split_venue or "xla"
                self.locked_precision = self.split_scheme
                self.locked_why = (
                    f"{self.split_scheme} {self.split_best * 1e6:.0f}us vs "
                    f"device {self.device_best * 1e6:.0f}us vs "
                    f"host {self.host_best * 1e6:.0f}us "
                    f"over {self.probes_done} probes")
                return self.locked
            if (self.kernel_timed
                    and self.kernel_best < self.device_best
                    and self.kernel_best < self.host_best):
                self.locked = True
                self.locked_venue = "pallas"
                self.locked_why = (
                    f"pallas {self.kernel_best * 1e6:.0f}us vs "
                    f"device {self.device_best * 1e6:.0f}us vs "
                    f"host {self.host_best * 1e6:.0f}us "
                    f"over {self.probes_done} probes")
                return self.locked
            self.locked = self.device_best < self.host_best
            self.locked_venue = "xla" if self.locked else "host"
            self.locked_why = (f"device {self.device_best * 1e6:.0f}us "
                               f"vs host {self.host_best * 1e6:.0f}us "
                               f"over {self.probes_done} probes")
            return self.locked

    # ------------------------------------------------------------------ #
    @property
    def n_avg_mean(self) -> float:
        return (self.n_avg_sum / self.n_avg_count
                if self.n_avg_count else 0.0)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def decision_label(self) -> str:
        """Human label for the report table."""
        if self.locked is not None:
            tag = f"~{self.locked_precision}" if self.locked_precision else ""
            if self.locked_venue == "pallas":
                return "pallas*" + tag
            return ("offload*" if self.locked else "host*") + tag
        if self.last_offload is None:
            return "-"
        return "offload" if self.last_offload else "host"


class CallSiteRegistry:
    """Site id -> profile; the runtime's patched-call-site table.
    Creation is lock-guarded so two threads hitting a new site for the
    first time agree on one profile (a lost profile loses its counts)."""

    def __init__(self) -> None:
        self._sites: Dict[str, CallSiteProfile] = {}
        self._lock = threading.Lock()

    def profile(self, site: str) -> CallSiteProfile:
        prof = self._sites.get(site)
        if prof is None:
            with self._lock:
                prof = self._sites.get(site)
                if prof is None:
                    prof = self._sites[site] = CallSiteProfile(site)
        return prof

    def get(self, site: str) -> Optional[CallSiteProfile]:
        return self._sites.get(site)

    def top_by_flops(self, n: int = 8) -> List[CallSiteProfile]:
        return sorted(self._sites.values(), key=lambda p: -p.flops)[:n]

    def __len__(self) -> int:
        return len(self._sites)

    def __iter__(self) -> Iterator[CallSiteProfile]:
        return iter(self._sites.values())

    def __contains__(self, site: str) -> bool:
        return site in self._sites

"""Fault model for the offload runtime: errors, injection, retry, breaker.

The source tool (arxiv 2501.00279) is an ``LD_PRELOAD`` interposer on an
unmodified binary — its one hard obligation is *transparency*: whatever
goes wrong on the accelerator side, the application must still get the
answer the unmodified binary would have computed.  A transfer that
faults, a kernel that aborts, a device that wedges — none of those may
surface as a crash in application code that never asked to be offloaded.
The correct degraded behaviour is always "run it on the host".

This module is the vocabulary the runtime uses to deliver that:

* a **typed exception hierarchy** — :class:`OffloadError` with
  transient-vs-permanent classification (:class:`TransferError` and
  :class:`KernelError` are transient and retried;
  :class:`DeviceOOMError` is permanent and falls straight back to the
  host path).  ``classify()`` wraps raw backend exceptions
  (``XlaRuntimeError``, ``MemoryError``...) into the hierarchy at the
  guard boundaries; unrecognized exception types pass through unwrapped
  so genuine bugs keep their tracebacks.
* a **deterministic seeded fault injector** — :class:`FaultInjector`,
  configured from the ``SCILIB_FAULTS`` spec grammar::

      transfer:p=0.05,device=1,seed=7;kernel:nth=13

  Rules are ``kind:param=value,...`` joined by ``;``.  Kinds:
  ``transfer`` / ``kernel`` (transient faults at the matching guard),
  ``oom`` (a permanent :class:`DeviceOOMError` at transfer guards) and
  ``latency`` (a sleep of ``ms`` milliseconds — a spike, not an error).
  Params: ``p`` (per-check fire probability), ``nth`` (fire every nth
  applicable check), ``device`` (restrict to one device index),
  ``seed`` (per-rule ``random.Random``), ``ms`` (latency duration).
  Faults fire at the *entry* of the real call sites — before any state
  mutates — so a fault absorbed by a retry is a perfect no-op: every
  residency counter, placement and trace event of the run is
  bit-identical to the unfaulted run.  That property is what lets the
  whole test suite run green under chaos injection.
* a **retry policy** — :class:`RetryPolicy`, configurable attempts with
  exponential backoff, applied by the runtime to transient classes only.
* a **per-device circuit breaker** — :class:`HealthTracker`.  Each
  device tier carries a consecutive-failure count (one count per
  *exhausted* unit, i.e. after retries, not per attempt); reaching the
  threshold trips the device to quarantined (``open``).  After a
  cooldown the device turns ``half-open``: it is schedulable again and
  the first unit that touches it is the probe — success closes the
  breaker (a *recover*), failure re-opens it for another cooldown.
  The tracker is clock-injectable for deterministic tests.

The module is dependency-free (stdlib only) so every layer — memspace,
config validation, the runtime — can import it without cycles.
"""
from __future__ import annotations

import dataclasses
import random
import re
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["OffloadError", "TransferError", "DeviceOOMError",
           "KernelError", "classify", "FaultRule", "parse_spec",
           "FaultInjector", "RetryPolicy", "DeviceHealth",
           "HealthTracker", "CLOSED", "OPEN", "HALF_OPEN",
           "FAULT_EVENT_KINDS"]

#: trace-event kinds the failure paths emit (the residency-event channel
#: carries them; the memtier simulator replays them)
FAULT_EVENT_KINDS = ("fault", "retry", "fallback", "quarantine", "recover")


# --------------------------------------------------------------------- #
# the typed exception hierarchy                                          #
# --------------------------------------------------------------------- #
class OffloadError(RuntimeError):
    """Base of every offload-path failure the runtime can absorb.

    ``transient`` decides retry eligibility; ``kind`` labels the trace
    events and the decision IR's ``why``; ``device`` is the device-tier
    index the failure is attributed to (None when the site has no
    per-device identity, e.g. the whole-call logical device put);
    ``injected`` marks synthetic faults from the injector.
    """

    transient = False
    kind = "offload"

    def __init__(self, msg: str, *, device: Optional[int] = None,
                 nbytes: int = 0, injected: bool = False):
        super().__init__(msg)
        self.device = device
        self.nbytes = int(nbytes)
        self.injected = injected


class TransferError(OffloadError):
    """A host<->device movement failed (transient: link hiccup, a
    transient allocation failure, an interrupted DMA)."""

    transient = True
    kind = "transfer"


class DeviceOOMError(TransferError):
    """The device memory is exhausted.  Permanent: retrying the same
    allocation immediately cannot succeed — fall back to the host."""

    transient = False
    kind = "oom"


class KernelError(OffloadError):
    """Device compute failed after its operands were placed."""

    transient = True
    kind = "kernel"


_OOM_RE = re.compile(r"RESOURCE_EXHAUSTED|out of memory|OOM",
                     re.IGNORECASE)

#: raw exception types the guards are allowed to absorb; anything else
#: (TypeError, ValueError...) is a bug in our stack, not a device fault,
#: and must keep its traceback.
_ABSORBABLE = (RuntimeError, MemoryError, OSError)


def classify(site: str, exc: BaseException, *,
             device: Optional[int] = None,
             nbytes: int = 0) -> Optional[OffloadError]:
    """Map a raw exception at a guard site to the typed hierarchy.

    ``site`` is ``"transfer"`` or ``"kernel"``.  Returns the exception
    unchanged when it is already typed, a wrapped :class:`OffloadError`
    for absorbable backend errors (``XlaRuntimeError`` is a
    ``RuntimeError`` subclass), and None for everything else — the
    caller re-raises those unwrapped.
    """
    if isinstance(exc, OffloadError):
        return exc
    if not isinstance(exc, _ABSORBABLE):
        return None
    msg = f"{type(exc).__name__}: {exc}"
    if isinstance(exc, MemoryError) or _OOM_RE.search(str(exc)):
        return DeviceOOMError(msg, device=device, nbytes=nbytes)
    cls = KernelError if site == "kernel" else TransferError
    return cls(msg, device=device, nbytes=nbytes)


# --------------------------------------------------------------------- #
# the fault-injection spec                                               #
# --------------------------------------------------------------------- #
_KINDS = ("transfer", "kernel", "oom", "latency")

#: guard site -> rule kinds consulted there.  ``oom`` and ``latency``
#: piggyback on transfer checks (allocation happens at transfer time);
#: latency spikes additionally apply to kernel launches.
_SITE_KINDS = {"transfer": ("transfer", "oom", "latency"),
               "kernel": ("kernel", "latency")}


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One parsed rule of a ``SCILIB_FAULTS`` spec."""

    kind: str                      # transfer | kernel | oom | latency
    p: float = 0.0                 # per-check fire probability
    nth: int = 0                   # fire every nth applicable check
    device: Optional[int] = None   # restrict to one device index
    seed: int = 0                  # per-rule RNG seed (determinism)
    ms: float = 1.0                # latency spike duration


def parse_spec(spec: str) -> Tuple[FaultRule, ...]:
    """Parse ``"transfer:p=0.05,device=1,seed=7;kernel:nth=13"``.

    Raises ``ValueError`` with a pointed message on any malformed
    fragment; an empty/whitespace spec parses to no rules.
    """
    rules: List[FaultRule] = []
    for frag in spec.split(";"):
        frag = frag.strip()
        if not frag:
            continue
        kind, _, params = frag.partition(":")
        kind = kind.strip().lower()
        if kind not in _KINDS:
            raise ValueError(f"unknown fault kind {kind!r} in "
                             f"{frag!r}; choose from {sorted(_KINDS)}")
        kw: Dict[str, object] = {}
        for item in filter(None, (s.strip() for s in params.split(","))):
            name, sep, raw = item.partition("=")
            name = name.strip().lower()
            if not sep:
                raise ValueError(f"fault param {item!r} is not "
                                 f"name=value (in {frag!r})")
            try:
                if name == "p":
                    val = float(raw)
                    if not 0.0 <= val <= 1.0:
                        raise ValueError
                elif name == "nth":
                    val = int(raw)
                    if val < 1:
                        raise ValueError
                elif name == "device":
                    val = int(raw)
                    if val < 0:
                        raise ValueError
                elif name == "seed":
                    val = int(raw)
                elif name == "ms":
                    val = float(raw)
                    if val < 0:
                        raise ValueError
                else:
                    raise ValueError(
                        f"unknown fault param {name!r} in {frag!r}; "
                        f"choose from p, nth, device, seed, ms")
            except ValueError as exc:
                if exc.args and "fault param" in str(exc):
                    raise
                raise ValueError(f"bad value {raw!r} for fault param "
                                 f"{name!r} in {frag!r}") from None
            kw[name] = val
        if "p" not in kw and "nth" not in kw and kind != "latency":
            raise ValueError(f"fault rule {frag!r} needs p= or nth= "
                             f"to ever fire")
        rules.append(FaultRule(kind=kind, **kw))   # type: ignore[arg-type]
    return tuple(rules)


_INJECTED_ERRORS = {"transfer": TransferError, "oom": DeviceOOMError,
                    "kernel": KernelError}


class FaultInjector:
    """Deterministic seeded fault injection at the real guard sites.

    One independent ``random.Random(seed)`` per rule, plus a per-rule
    applicable-check counter for ``nth`` — the fire pattern is a pure
    function of the rule and the sequence of checks it sees, so two
    identically-configured runs (or a run and its CI re-run) inject the
    exact same faults.
    """

    def __init__(self, rules: Tuple[FaultRule, ...]):
        self.rules = tuple(rules)
        self._rngs = [random.Random(r.seed) for r in self.rules]
        self._counts = [0] * len(self.rules)
        #: injected faults by kind (latency spikes count too)
        self.injected: Dict[str, int] = {k: 0 for k in _KINDS}
        # the counter/RNG walk is the determinism contract; keep it
        # atomic per check so shared injectors stay sequence-exact
        self._lock = threading.Lock()

    @classmethod
    def from_spec(cls, spec: str) -> Optional["FaultInjector"]:
        """An injector for a spec string, or None when it is empty."""
        rules = parse_spec(spec or "")
        return cls(rules) if rules else None

    def total_injected(self) -> int:
        return sum(self.injected.values())

    def check(self, site: str, *, device: Optional[int] = None,
              nbytes: int = 0) -> None:
        """Consult every applicable rule at one guard site; raises the
        mapped :class:`OffloadError` (or sleeps, for latency) when a
        rule fires.  Called *before* the guarded operation touches any
        state, so an absorbed fault perturbs nothing.

        The rule walk (counters, RNG draws, injected tallies) runs under
        the injector lock so concurrent checks interleave as whole
        checks; the latency sleep and the raise happen outside it."""
        sleep_s = 0.0
        fired: Optional[FaultRule] = None
        with self._lock:
            for i, rule in enumerate(self.rules):
                if rule.kind not in _SITE_KINDS[site]:
                    continue
                if rule.device is not None and rule.device != device:
                    continue
                fire = False
                if rule.nth:
                    self._counts[i] += 1
                    fire = self._counts[i] % rule.nth == 0
                if not fire and rule.p:
                    fire = self._rngs[i].random() < rule.p
                if not fire:
                    continue
                self.injected[rule.kind] += 1
                if rule.kind == "latency":
                    sleep_s += rule.ms / 1000.0
                    continue
                fired = rule
                break
        if sleep_s > 0:
            time.sleep(sleep_s)
        if fired is not None:
            err = _INJECTED_ERRORS[fired.kind]
            raise err(f"injected {fired.kind} fault at {site} "
                      f"(device={device}, nbytes={nbytes})",
                      device=device, nbytes=nbytes, injected=True)


# --------------------------------------------------------------------- #
# retry with exponential backoff                                         #
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """``attempts`` extra tries after the first failure, sleeping
    ``backoff_ms * 2**n`` before retry ``n`` (n = 0, 1, ...).  Applied
    by the runtime to transient fault classes only."""

    attempts: int = 2
    backoff_ms: float = 1.0

    def delay_s(self, attempt: int) -> float:
        """Backoff before the given 0-based retry attempt."""
        return (self.backoff_ms / 1000.0) * (2.0 ** attempt)

    def sleep(self, attempt: int) -> None:
        d = self.delay_s(attempt)
        if d > 0:
            time.sleep(d)


# --------------------------------------------------------------------- #
# per-device health / circuit breaker                                    #
# --------------------------------------------------------------------- #
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"


@dataclasses.dataclass
class DeviceHealth:
    """Breaker state of one device tier."""

    state: str = CLOSED
    consecutive: int = 0       # consecutive exhausted-unit failures
    failures: int = 0          # total exhausted-unit failures
    quarantines: int = 0       # times tripped open (incl. re-opens)
    opened_at: float = 0.0     # clock() at the last trip


class HealthTracker:
    """Per-device consecutive-failure circuit breaker.

    State machine (per device)::

        closed --threshold consecutive failures--> open (quarantined)
        open   --cooldown elapses--------------->  half-open (probe)
        half-open --unit succeeds--------------->  closed   (recover)
        half-open --unit fails------------------>  open     (re-trip)

    ``threshold=0`` disables the breaker entirely: every device is
    always usable and failures only accumulate totals.  ``on_quarantine``
    / ``on_recover`` fire on the closed->open and ->closed transitions
    (the runtime invalidates block stores and emits trace events there).
    """

    def __init__(self, n_devices: int, *, threshold: int = 3,
                 cooldown_ms: float = 1000.0,
                 clock: Callable[[], float] = time.monotonic,
                 on_quarantine: Optional[Callable[[int], None]] = None,
                 on_recover: Optional[Callable[[int], None]] = None):
        self.n_devices = max(1, int(n_devices))
        self.threshold = int(threshold)
        self.cooldown_ms = float(cooldown_ms)
        self.clock = clock
        self.on_quarantine = on_quarantine
        self.on_recover = on_recover
        self._devs = [DeviceHealth() for _ in range(self.n_devices)]
        self._n_not_closed = 0
        # breaker transitions are multi-field updates; the RLock keeps
        # them atomic (reentrant: usable_count -> usable).  Lock order:
        # acquired after the runtime lock, before any store lock.
        self._lock = threading.RLock()

    # ------------------------------------------------------------------ #
    @property
    def enabled(self) -> bool:
        return self.threshold > 0

    def device(self, d: int) -> DeviceHealth:
        return self._devs[d]

    def devices(self) -> List[DeviceHealth]:
        return list(self._devs)

    def reconfigure(self, *, threshold: int,
                    cooldown_ms: float) -> None:
        """Update the knobs in place, keeping per-device state (live
        ``Session.reconfigure``).  Disabling re-admits every device."""
        with self._lock:
            self.threshold = int(threshold)
            self.cooldown_ms = float(cooldown_ms)
            if not self.enabled:
                for h in self._devs:
                    h.state = CLOSED
                    h.consecutive = 0
                self._n_not_closed = 0

    # ------------------------------------------------------------------ #
    def usable(self, d: int) -> bool:
        """May the scheduler send work to this device now?  An open
        device whose cooldown elapsed turns half-open here (lazily) and
        becomes schedulable — the next unit on it is the probe."""
        with self._lock:
            h = self._devs[d]
            if not self.enabled or h.state == CLOSED:
                return True
            if (h.state == OPEN
                    and (self.clock() - h.opened_at) * 1000.0
                    >= self.cooldown_ms):
                h.state = HALF_OPEN
            return h.state != OPEN

    def usable_count(self) -> int:
        with self._lock:
            if not self.enabled or self._n_not_closed == 0:
                return self.n_devices
            return sum(1 for d in range(self.n_devices) if self.usable(d))

    def usable_devices(self) -> List[int]:
        return [d for d in range(self.n_devices) if self.usable(d)]

    def any_usable(self) -> bool:
        return self.usable_count() > 0

    # ------------------------------------------------------------------ #
    def ok(self, d: int) -> None:
        """One unit succeeded on ``d``: reset the consecutive count; a
        half-open (or open) device closes — the recover transition."""
        with self._lock:
            h = self._devs[d]
            if h.state == CLOSED:
                if h.consecutive:
                    h.consecutive = 0
                return
            h.consecutive = 0
            h.state = CLOSED
            self._n_not_closed -= 1
            recovered = self.on_recover is not None
        if recovered:
            self.on_recover(d)

    def failure(self, d: int) -> bool:
        """One unit *exhausted* its retries (or failed permanently) on
        ``d``.  Returns True when this failure trips (or re-trips) the
        breaker."""
        with self._lock:
            h = self._devs[d]
            h.failures += 1
            h.consecutive += 1
            if not self.enabled:
                return False
            trip = (h.state == HALF_OPEN
                    or (h.state == CLOSED
                        and h.consecutive >= self.threshold))
            if not trip:
                return False
            if h.state == CLOSED:
                self._n_not_closed += 1
            h.state = OPEN
            h.opened_at = self.clock()
            h.quarantines += 1
            quarantined = self.on_quarantine is not None
        if quarantined:
            self.on_quarantine(d)
        return True

"""Offload-threshold logic (paper §3.3).

Small matrix math stays on the host: the paper's default is
``N_avg > 500`` where ``N_avg`` is a routine-dependent geometric-mean
dimension — for ``C = A x B``, ``N_avg = (M·N·K)^(1/3)``. The constant is
device-dependent; 500 is the paper's conservative Grace-Hopper value, and
it can be overridden per-process with ``SCILIB_THRESHOLD`` exactly like the
original tool's environment knob.
"""
from __future__ import annotations

from typing import Tuple

DEFAULT_THRESHOLD = 500.0

#: Per-device safe lower bounds (the paper: "the optimal threshold is
#: GPU-dependent"). v5e MXU pipelines saturate earlier for bf16 than H100
#: FP64 tensor cores, but dispatch overheads are comparable.  Keys are
#: the canonical device keys :func:`detect_device_key` produces.
DEVICE_DEFAULTS = {
    "gh200": 500.0,
    "tpu-v5e": 384.0,
    "tpu": 384.0,     # other TPU generations: same MXU-saturation regime
    "gpu": 500.0,     # unknown CUDA/ROCm parts: the paper's safe value
    "cpu": 500.0,     # no accelerator: value only matters for simulation
}


def detect_device_key(backend: str = None, device_kind: str = None) -> str:
    """Canonical device key for DEVICE_DEFAULTS from the live backend.

    ``backend``/``device_kind`` exist for tests; by default they come from
    ``jax.default_backend()`` / ``jax.devices()[0].device_kind``.
    """
    if backend is None or device_kind is None:
        import jax
        if backend is None:
            backend = jax.default_backend()
        if device_kind is None:
            try:
                device_kind = jax.devices()[0].device_kind
            except Exception:  # pragma: no cover - no devices
                device_kind = ""
    kind = (device_kind or "").lower()
    if backend == "tpu":
        return "tpu-v5e" if "v5" in kind else "tpu"
    if backend == "gpu":
        return "gh200" if ("gh200" in kind or "grace" in kind) else "gpu"
    return backend


def default_threshold() -> float:
    """Backend-detected threshold default (still SCILIB_THRESHOLD-
    overridable via :func:`threshold_from_env`)."""
    return DEVICE_DEFAULTS.get(detect_device_key(), DEFAULT_THRESHOLD)


def threshold_from_env(default: float = DEFAULT_THRESHOLD) -> float:
    """Back-compat wrapper: the ``SCILIB_THRESHOLD`` override, read
    through the config boundary (:meth:`repro.core.config.OffloadConfig.
    from_env`).  The runtime itself is plumbed from its config."""
    from repro.core.config import OffloadConfig
    t = OffloadConfig.from_env().threshold
    return default if t is None else t


def base_routine(routine: str) -> str:
    """Routine family without the precision prefix (``"dsyrk"`` ->
    ``"syrk"``).  Not ``lstrip("sdcz")``: that also eats the base's own
    leading ``s`` (``"dsyrk"`` -> ``"yrk"``) and broke the syrk/symm
    branches below for every precision."""
    return routine[1:] if routine[:1] in ("s", "d", "c", "z") else routine


def n_avg(routine: str, m: int, n: int, k: int = 0) -> float:
    """Routine-dependent mean dimension (paper §3.3)."""
    base = base_routine(routine)
    m, n, k = max(1, m), max(1, n), max(1, k)
    if base == "gemm":
        return (m * n * k) ** (1.0 / 3.0)
    if base in ("trsm", "trmm", "symm", "hemm"):
        # A is m x m, applied to an m x n panel.
        return (m * m * n) ** (1.0 / 3.0)
    if base in ("syrk", "herk", "syr2k", "her2k"):
        return (n * n * k) ** (1.0 / 3.0)
    return (m * n * max(k, 1)) ** (1.0 / 3.0)


def should_offload(routine: str, m: int, n: int, k: int = 0, *,
                   threshold: float = DEFAULT_THRESHOLD,
                   batch: int = 1) -> Tuple[bool, float]:
    """Offload decision. Batched calls amortize launch cost, so the batch
    size enters through the cube root (equivalent total-work heuristic)."""
    nav = n_avg(routine, m, n, k) * (max(1, batch) ** (1.0 / 3.0))
    return nav > threshold, nav


def threshold_grid(n_avgs, limit: int = 8) -> Tuple[float, ...]:
    """Candidate thresholds for a workload's observed N_avg values.

    The only thresholds worth trying are the ones that flip at least one
    call's decision: midpoints between adjacent distinct N_avg values,
    plus one below the smallest and one above the largest, plus the
    paper's default.  Deduplicated, sorted, and capped at ``limit``
    (evenly subsampled) so autotune grids stay small on ragged traces.
    """
    uniq = sorted({round(float(v), 3) for v in n_avgs if v > 0})
    cands = {DEFAULT_THRESHOLD}
    if uniq:
        cands.add(max(1.0, uniq[0] * 0.5))
        cands.add(uniq[-1] * 2.0)
        for lo, hi in zip(uniq, uniq[1:]):
            cands.add((lo + hi) / 2.0)
    grid = sorted(cands)
    if len(grid) > limit:
        step = (len(grid) - 1) / (limit - 1)
        grid = [grid[round(i * step)] for i in range(limit)]
    return tuple(grid)

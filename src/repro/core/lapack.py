"""Blocked LAPACK routines built on the intercepted BLAS (paper §4.2).

MuST's hot path is LU factorization/solve (``zgetrf``/``zgetrs``) whose
inner loops are the very ``zgemm``/``ztrsm`` calls SCILIB-Accel offloads.
This module reproduces that call structure: right-looking blocked LU with
partial pivoting, triangular solves, and blocked Cholesky — every panel
update flows through :mod:`repro.core.blas`, so an installed offload
runtime sees exactly the BLAS stream a LAPACK-linked binary would emit.

These are eager, host-orchestrated drivers (like LAPACK itself: Python
plays the role of the Fortran driver; the FLOPs are in the BLAS calls).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import blas

DEFAULT_NB = 128


def _pivot_panel(panel: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Unblocked LU with partial pivoting on a (m x nb) panel.

    jit-compiled; returns the factored panel and local pivot rows.
    """

    @jax.jit
    def factor(p):
        m, nb = p.shape

        def body(j, carry):
            p, piv = carry
            col = p[:, j]
            mag = jnp.abs(col)
            mask = jnp.arange(m) < j
            mag = jnp.where(mask, -jnp.inf, mag)
            r = jnp.argmax(mag)
            piv = piv.at[j].set(r.astype(piv.dtype))
            # swap rows j <-> r
            rowj, rowr = p[j], p[r]
            p = p.at[j].set(rowr).at[r].set(rowj)
            pivval = p[j, j]
            scale = jnp.where(pivval != 0, 1.0 / pivval, 0.0)
            below = jnp.arange(m) > j
            l = jnp.where(below, p[:, j] * scale, p[:, j])
            p = p.at[:, j].set(l)
            # rank-1 update of the trailing panel columns
            trail = jnp.arange(nb) > j
            lcol = jnp.where(below, l, 0.0)[:, None]
            urow = jnp.where(trail, p[j], 0.0)[None, :]
            p = p - lcol * urow
            return p, piv

        piv0 = jnp.zeros(nb, dtype=jnp.int32)
        return jax.lax.fori_loop(0, nb, body, (p, piv0))

    return factor(panel)


def getrf(a: jax.Array, nb: int = DEFAULT_NB
          ) -> Tuple[jax.Array, jax.Array]:
    """Blocked right-looking LU with partial pivoting.

    Returns (LU, piv) in LAPACK convention: ``piv[j]`` is the row swapped
    with row ``j`` (0-based, absolute). The trailing-matrix updates are
    the trsm+gemm pairs that dominate MuST's runtime.
    """
    n = a.shape[0]
    lu = a
    piv = jnp.arange(n, dtype=jnp.int32)
    for j0 in range(0, n, nb):
        jb = min(nb, n - j0)
        panel = lu[j0:, j0:j0 + jb]
        fpanel, lpiv = _pivot_panel(panel)
        # apply local pivots to the whole rows (left + right of panel)
        rows = jnp.arange(n - j0)
        perm = rows
        for jj in range(jb):           # compose swaps (host loop, nb small)
            r = lpiv[jj]
            perm = perm.at[jj].set(perm[r]).at[r].set(perm[jj])
        abs_perm = jnp.concatenate([jnp.arange(j0), perm + j0])
        lu = lu[abs_perm]
        piv = piv[abs_perm]
        lu = lu.at[j0:, j0:j0 + jb].set(fpanel)
        if j0 + jb < n:
            # U12 = L11^{-1} A12           (trsm, unit lower)
            a12 = lu[j0:j0 + jb, j0 + jb:]
            l11 = lu[j0:j0 + jb, j0:j0 + jb]
            u12 = blas.trsm(l11, a12, side="L", uplo="L", trans="N",
                            diag="U")
            lu = lu.at[j0:j0 + jb, j0 + jb:].set(u12)
            # A22 -= L21 U12               (gemm: the hot spot)
            l21 = lu[j0 + jb:, j0:j0 + jb]
            a22 = lu[j0 + jb:, j0 + jb:]
            upd = blas.gemm(l21, u12, a22, alpha=-1.0, beta=1.0)
            lu = lu.at[j0 + jb:, j0 + jb:].set(upd)
    return lu, piv


def getrs(lu: jax.Array, piv: jax.Array, b: jax.Array) -> jax.Array:
    """Solve A X = B from getrf output (laswp + two trsm calls)."""
    if b.ndim == 1:
        b = b[:, None]
        squeeze = True
    else:
        squeeze = False
    # getrf returned LU = (P A) with piv the absolute row permutation:
    # A x = b  <=>  LU x = (P b)
    x = b[piv]
    y = blas.trsm(lu, x, side="L", uplo="L", trans="N", diag="U")
    z = blas.trsm(lu, y, side="L", uplo="U", trans="N", diag="N")
    return z[:, 0] if squeeze else z


def gesv(a: jax.Array, b: jax.Array, nb: int = DEFAULT_NB) -> jax.Array:
    """Driver: solve A X = B (the zgetrf+zgetrs pair MuST calls)."""
    lu, piv = getrf(a, nb=nb)
    return getrs(lu, piv, b)


def potrf(a: jax.Array, nb: int = DEFAULT_NB, *,
          uplo: str = "L") -> jax.Array:
    """Blocked Cholesky (syrk + trsm + small unblocked factor)."""
    assert uplo == "L", "upper Cholesky via potrf(a.T) conventions"
    n = a.shape[0]
    l = jnp.zeros_like(a)

    @jax.jit
    def chol_block(blk):
        # jnp.linalg.cholesky symmetrizes its input, so feed full blocks
        return jnp.linalg.cholesky(blk)

    for j0 in range(0, n, nb):
        jb = min(nb, n - j0)
        # diagonal block: A11 - L10 L10^T
        l10 = l[j0:j0 + jb, :j0]
        a11 = a[j0:j0 + jb, j0:j0 + jb]
        if j0 > 0:
            a11 = blas.gemm(l10, l10, a11, alpha=-1.0, beta=1.0,
                            trans_b="T")
        l11 = chol_block(a11)
        l = l.at[j0:j0 + jb, j0:j0 + jb].set(l11)
        if j0 + jb < n:
            l20 = l[j0 + jb:, :j0]
            a21 = a[j0 + jb:, j0:j0 + jb]
            if j0 > 0:
                a21 = blas.gemm(l20, l10, a21, alpha=-1.0, beta=1.0,
                                trans_b="T")
            # L21 = A21 L11^{-T}    (right-side trsm)
            l21 = blas.trsm(l11, a21, side="R", uplo="L", trans="T",
                            diag="N")
            l = l.at[j0 + jb:, j0:j0 + jb].set(l21)
    return l

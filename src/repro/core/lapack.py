"""Blocked LAPACK routines built on the intercepted BLAS (paper §4.2).

MuST's hot path is LU factorization/solve (``zgetrf``/``zgetrs``) whose
inner loops are the very ``zgemm``/``ztrsm`` calls SCILIB-Accel offloads.
This module reproduces that call structure: right-looking blocked LU with
partial pivoting, triangular solves, and blocked Cholesky — every panel
update flows through :mod:`repro.core.blas`, so an installed offload
runtime sees exactly the BLAS stream a LAPACK-linked binary would emit.

These are eager, host-orchestrated drivers (like LAPACK itself: Python
plays the role of the Fortran driver; the FLOPs are in the BLAS calls).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import blas
from repro.core import runtime as rtm

DEFAULT_NB = 128

_PREC = {"float32": "s", "float64": "d",
         "complex64": "c", "complex128": "z"}


def _prec(dtype) -> str:
    return _PREC.get(jnp.dtype(dtype).name, "d")


def _note_panel(prec: str, m: int, nb: int, panel: jax.Array) -> None:
    """Report an unblocked panel factorization to the active runtime.

    Panels are host-side getf2 work — they never offload, but inside a
    solver span (repro.solvers) they count toward the span's panel
    fraction and appear as ``getf2`` trace calls.  Outside a span this
    is a no-op, keeping direct driver calls byte-identical to before."""
    rt = rtm.active()
    if rt is not None:
        rt.note_panel(prec, m, nb, panel)


def _pivot_panel(panel: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Unblocked LU with partial pivoting on a (m x nb) panel.

    jit-compiled; returns the factored panel and local pivot rows.
    """

    @jax.jit
    def factor(p):
        m, nb = p.shape

        def body(j, carry):
            p, piv = carry
            col = p[:, j]
            mag = jnp.abs(col)
            mask = jnp.arange(m) < j
            mag = jnp.where(mask, -jnp.inf, mag)
            r = jnp.argmax(mag)
            piv = piv.at[j].set(r.astype(piv.dtype))
            # swap rows j <-> r
            rowj, rowr = p[j], p[r]
            p = p.at[j].set(rowr).at[r].set(rowj)
            pivval = p[j, j]
            scale = jnp.where(pivval != 0, 1.0 / pivval, 0.0)
            below = jnp.arange(m) > j
            l = jnp.where(below, p[:, j] * scale, p[:, j])
            p = p.at[:, j].set(l)
            # rank-1 update of the trailing panel columns
            trail = jnp.arange(nb) > j
            lcol = jnp.where(below, l, 0.0)[:, None]
            urow = jnp.where(trail, p[j], 0.0)[None, :]
            p = p - lcol * urow
            return p, piv

        piv0 = jnp.zeros(nb, dtype=jnp.int32)
        return jax.lax.fori_loop(0, nb, body, (p, piv0))

    return factor(panel)


def getrf(a: jax.Array, nb: int = DEFAULT_NB
          ) -> Tuple[jax.Array, jax.Array]:
    """Blocked right-looking LU with partial pivoting (general m x n).

    Returns (LU, piv): ``piv`` is the absolute row permutation (length
    ``m``) such that ``A[piv] == L @ U`` — the composed form of LAPACK's
    sequential ipiv swaps. The trailing-matrix updates are the trsm+gemm
    pairs that dominate MuST's runtime.
    """
    m, n = a.shape
    prec = _prec(a.dtype)
    k_max = min(m, n)
    lu = a
    piv = jnp.arange(m, dtype=jnp.int32)
    for j0 in range(0, k_max, nb):
        jb = min(nb, k_max - j0)
        panel = lu[j0:, j0:j0 + jb]
        fpanel, lpiv = _pivot_panel(panel)
        _note_panel(prec, m - j0, jb, fpanel)
        # apply local pivots to the whole rows (left + right of panel)
        perm = jnp.arange(m - j0)
        for jj in range(jb):           # compose swaps (host loop, nb small)
            r = lpiv[jj]
            perm = perm.at[jj].set(perm[r]).at[r].set(perm[jj])
        abs_perm = jnp.concatenate([jnp.arange(j0), perm + j0])
        lu = lu[abs_perm]
        piv = piv[abs_perm]
        lu = lu.at[j0:, j0:j0 + jb].set(fpanel)
        if j0 + jb < n:
            # U12 = L11^{-1} A12           (trsm, unit lower)
            a12 = lu[j0:j0 + jb, j0 + jb:]
            l11 = lu[j0:j0 + jb, j0:j0 + jb]
            u12 = blas.trsm(l11, a12, side="L", uplo="L", trans="N",
                            diag="U")
            lu = lu.at[j0:j0 + jb, j0 + jb:].set(u12)
            if j0 + jb < m:
                # A22 -= L21 U12           (gemm: the hot spot)
                l21 = lu[j0 + jb:, j0:j0 + jb]
                a22 = lu[j0 + jb:, j0 + jb:]
                upd = blas.gemm(l21, u12, a22, alpha=-1.0, beta=1.0)
                lu = lu.at[j0 + jb:, j0 + jb:].set(upd)
    return lu, piv


def getrs(lu: jax.Array, piv: jax.Array, b: jax.Array) -> jax.Array:
    """Solve A X = B from getrf output (laswp + two trsm calls)."""
    if b.ndim == 1:
        b = b[:, None]
        squeeze = True
    else:
        squeeze = False
    # getrf returned LU = (P A) with piv the absolute row permutation:
    # A x = b  <=>  LU x = (P b)
    x = b[piv]
    y = blas.trsm(lu, x, side="L", uplo="L", trans="N", diag="U")
    z = blas.trsm(lu, y, side="L", uplo="U", trans="N", diag="N")
    return z[:, 0] if squeeze else z


def gesv(a: jax.Array, b: jax.Array, nb: int = DEFAULT_NB) -> jax.Array:
    """Driver: solve A X = B (the zgetrf+zgetrs pair MuST calls)."""
    lu, piv = getrf(a, nb=nb)
    return getrs(lu, piv, b)


def potrf(a: jax.Array, nb: int = DEFAULT_NB, *,
          uplo: str = "L") -> jax.Array:
    """Blocked Cholesky (syrk-shaped gemm + trsm + small unblocked factor).

    Handles real-symmetric and complex-Hermitian inputs (the updates use
    conjugate transposes, which reduce to plain transposes for real
    dtypes).  ``uplo="U"`` factors the conjugate-transposed matrix and
    returns ``U`` with ``A = U^H U``.
    """
    if uplo == "U":
        l = potrf(jnp.conj(a.T), nb, uplo="L")
        return jnp.conj(l.T)
    n = a.shape[0]
    l = jnp.zeros_like(a)

    @jax.jit
    def chol_block(blk):
        # jnp.linalg.cholesky symmetrizes its input, so feed full blocks
        return jnp.linalg.cholesky(blk)

    for j0 in range(0, n, nb):
        jb = min(nb, n - j0)
        # diagonal block: A11 - L10 L10^H
        l10 = l[j0:j0 + jb, :j0]
        a11 = a[j0:j0 + jb, j0:j0 + jb]
        if j0 > 0:
            a11 = blas.gemm(l10, l10, a11, alpha=-1.0, beta=1.0,
                            trans_b="C")
        l11 = chol_block(a11)
        l = l.at[j0:j0 + jb, j0:j0 + jb].set(l11)
        if j0 + jb < n:
            l20 = l[j0 + jb:, :j0]
            a21 = a[j0 + jb:, j0:j0 + jb]
            if j0 > 0:
                a21 = blas.gemm(l20, l10, a21, alpha=-1.0, beta=1.0,
                                trans_b="C")
            # L21 = A21 L11^{-H}    (right-side trsm)
            l21 = blas.trsm(l11, a21, side="R", uplo="L", trans="C",
                            diag="N")
            l = l.at[j0 + jb:, j0:j0 + jb].set(l21)
    return l


def potrs(f: jax.Array, b: jax.Array, *, uplo: str = "L") -> jax.Array:
    """Solve A X = B from potrf output (two triangular solves)."""
    if b.ndim == 1:
        b = b[:, None]
        squeeze = True
    else:
        squeeze = False
    if uplo == "L":
        # A = L L^H: solve L y = b, then L^H x = y
        y = blas.trsm(f, b, side="L", uplo="L", trans="N", diag="N")
        x = blas.trsm(f, y, side="L", uplo="L", trans="C", diag="N")
    else:
        # A = U^H U: solve U^H y = b, then U x = y
        y = blas.trsm(f, b, side="L", uplo="U", trans="C", diag="N")
        x = blas.trsm(f, y, side="L", uplo="U", trans="N", diag="N")
    return x[:, 0] if squeeze else x

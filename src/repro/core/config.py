"""Typed offload configuration: one validated object instead of 14 env vars.

The source tool is configured through ``SCILIB_*`` environment knobs —
right for an ``LD_PRELOAD`` interposer on an unmodified CPU binary, but
wrong for a library serving many concurrent workloads: ambient process
state cannot express "this session uses threshold 810, that one a 2 MB
cap", and a recommendation produced by the autotuner could only be
*deployed* by exporting strings.

:class:`OffloadConfig` is the typed replacement.  It is

* **frozen** — a config never mutates; derive with :meth:`replace`,
* **validated** — unknown policies, negative thresholds, bad eviction
  names fail at construction, not deep inside a BLAS call,
* **complete** — every knob that used to live in an env var is a field
  (see :data:`ENV_FIELDS` for the one-to-one mapping),
* **serializable** — :meth:`save`/:meth:`load` round-trip through JSON,
  so ``python -m repro.tools.autotune trace.json --emit-config out.json``
  produces a file a session can run directly,
* **presettable** — :meth:`preset` names the common shapes (``"paper"``,
  ``"throughput"``, ``"low-memory"``).

:meth:`OffloadConfig.from_env` is the **single** environment-ingestion
boundary of the whole package: it layers the ``SCILIB_*`` vars over a
base config with exactly the legacy parsing semantics (lenient — a
malformed value falls back to the base, like the original tool), and it
warns once per process about any ``SCILIB_*`` var it does not recognize,
with the nearest valid name — a typo like ``SCILIB_THRESOLD`` is no
longer silently ignored.  No other module reads ``os.environ``; the
runtime, the memory tiers, the residency engine and the simulator are
all plumbed from a config object.
"""
from __future__ import annotations

import dataclasses
import difflib
import json
import os
import warnings
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

__all__ = ["OffloadConfig", "ENV_FIELDS", "KNOWN_ENV_VARS", "PRESETS",
           "get_default", "set_default"]

#: config field -> the legacy ``SCILIB_*`` env var it replaces.  This is
#: the documented one-to-one mapping the parity tests assert over.
ENV_FIELDS: Dict[str, str] = {
    "policy": "SCILIB_POLICY",
    "threshold": "SCILIB_THRESHOLD",
    "sync": "SCILIB_SYNC",
    "adaptive": "SCILIB_ADAPTIVE",
    "adaptive_warmup": "SCILIB_ADAPTIVE_WARMUP",
    "callsite": "SCILIB_CALLSITE",
    "dispatch_cache": "SCILIB_DISPATCH_CACHE",
    "devices": "SCILIB_DEVICES",
    "device_bytes": "SCILIB_DEVICE_BYTES",
    "tile_min": "SCILIB_TILE_MIN",
    "evict": "SCILIB_EVICT",
    "pin": "SCILIB_PIN",
    "trace_path": "SCILIB_TRACE",
    "debug": "SCILIB_DEBUG",
    "faults": "SCILIB_FAULTS",
    "retries": "SCILIB_RETRIES",
    "backoff_ms": "SCILIB_BACKOFF_MS",
    "breaker": "SCILIB_BREAKER",
    "breaker_cooldown_ms": "SCILIB_BREAKER_COOLDOWN_MS",
    "pool_bytes": "SCILIB_POOL_BYTES",
    "pool_quota": "SCILIB_POOL_QUOTA",
    "kernel_path": "SCILIB_KERNELS",
    "kernel_block": "SCILIB_KERNEL_BLOCK",
    "precision": "SCILIB_PRECISION",
    "precision_rtol": "SCILIB_PRECISION_RTOL",
    "lapack": "SCILIB_LAPACK",
    "lapack_nb": "SCILIB_LAPACK_NB",
}

#: ``SCILIB_*`` vars that are legitimate but not config fields: kernel
#: backend selection and benchmark knobs read by their own tools.
_NON_CONFIG_VARS = frozenset({"SCILIB_PALLAS", "SCILIB_BENCH_QUICK"})

KNOWN_ENV_VARS = frozenset(ENV_FIELDS.values()) | _NON_CONFIG_VARS

#: valid placement policies (mirrors ``repro.core.policy.POLICY_CLASSES``;
#: asserted in tests so the two cannot drift)
POLICY_NAMES = ("cpu", "counter", "dfu", "memcopy", "pinned")
#: valid eviction policies (mirrors ``repro.core.residency``)
EVICT_NAMES = ("lru", "lfu", "refetch")

#: values of ``SCILIB_PIN`` that mean "pin every placement"
_PIN_ALL = ("never-evict", "all", "1")


# --------------------------------------------------------------------- #
# env parsing (legacy-lenient: malformed values fall back to the base)   #
# --------------------------------------------------------------------- #
_INVALID = object()


def _parse_policy(raw: str):
    return raw if raw in POLICY_NAMES else _INVALID


def _parse_threshold(raw: str):
    try:
        return float(raw)
    except ValueError:
        return _INVALID


def _parse_sync(raw: str):
    return raw == "1"


def _parse_adaptive(raw: str):
    return raw == "1"


def _parse_warmup(raw: str):
    try:
        return max(2, int(raw))
    except ValueError:
        return _INVALID


def _parse_on_unless_zero(raw: str):
    return raw != "0"


def _parse_devices(raw: str):
    try:
        return max(1, int(raw))
    except ValueError:
        return _INVALID


def _parse_device_bytes(raw: str):
    try:
        return int(float(raw))       # "0" = explicit uncapped (-> None)
    except ValueError:
        return _INVALID


def _parse_tile_min(raw: str):
    try:
        return max(1, int(raw))
    except ValueError:
        return _INVALID


def _parse_evict(raw: str):
    low = raw.strip().lower()
    return low if low in EVICT_NAMES else _INVALID


def _parse_pin(raw: str):
    return raw.strip().lower() in _PIN_ALL


def _parse_trace(raw: str):
    return raw


def _parse_debug(raw: str):
    try:
        return int(raw or 0)
    except ValueError:
        return _INVALID


def _parse_faults(raw: str):
    from repro.core import faults as _flt
    try:
        _flt.parse_spec(raw)
    except ValueError:
        return _INVALID
    return raw


def _parse_retries(raw: str):
    try:
        val = int(raw)
    except ValueError:
        return _INVALID
    return val if val >= 0 else _INVALID


def _parse_nonneg_ms(raw: str):
    try:
        val = float(raw)
    except ValueError:
        return _INVALID
    return val if val >= 0 else _INVALID


def _parse_breaker(raw: str):
    try:
        val = int(raw)
    except ValueError:
        return _INVALID
    return val if val >= 0 else _INVALID


def _parse_kernel_block(raw: str):
    try:
        val = int(raw)
    except ValueError:
        return _INVALID
    return val if val >= 0 else _INVALID


#: valid SCILIB_PRECISION spellings; "native" normalizes to "" (off) so
#: an explicitly-native config stays byte-identical to the default.
PRECISION_NAMES = ("native", "split2", "split3", "auto")


def _parse_precision(raw: str):
    low = raw.strip().lower()
    if low not in PRECISION_NAMES:
        return _INVALID
    return "" if low == "native" else low


def _parse_precision_rtol(raw: str):
    try:
        val = float(raw)
    except ValueError:
        return _INVALID
    return val if 0 < val < 1 else _INVALID


_PARSERS: Dict[str, Callable[[str], Any]] = {
    "policy": _parse_policy,
    "threshold": _parse_threshold,
    "sync": _parse_sync,
    "adaptive": _parse_adaptive,
    "adaptive_warmup": _parse_warmup,
    "callsite": _parse_on_unless_zero,
    "dispatch_cache": _parse_on_unless_zero,
    "devices": _parse_devices,
    "device_bytes": _parse_device_bytes,
    "tile_min": _parse_tile_min,
    "evict": _parse_evict,
    "pin": _parse_pin,
    "trace_path": _parse_trace,
    "debug": _parse_debug,
    "faults": _parse_faults,
    "retries": _parse_retries,
    "backoff_ms": _parse_nonneg_ms,
    "breaker": _parse_breaker,
    "breaker_cooldown_ms": _parse_nonneg_ms,
    "pool_bytes": _parse_device_bytes,
    "pool_quota": _parse_device_bytes,
    "kernel_path": _parse_adaptive,      # "1" enables, like adaptive
    "kernel_block": _parse_kernel_block,
    "precision": _parse_precision,
    "precision_rtol": _parse_precision_rtol,
    "lapack": _parse_adaptive,           # "1" enables, like adaptive
    "lapack_nb": _parse_kernel_block,    # int >= 0 (0 = driver default)
}

#: unknown-var names already warned about (once per process per name)
_WARNED: set = set()


def _warn_unknown(environ: Mapping[str, str]) -> None:
    """Warn (once, with the nearest valid name) on every ``SCILIB_*``
    var :meth:`OffloadConfig.from_env` does not recognize."""
    for var in sorted(environ):
        if not var.startswith("SCILIB_") or var in KNOWN_ENV_VARS:
            continue
        if var in _WARNED:
            continue
        _WARNED.add(var)
        near = difflib.get_close_matches(var, sorted(KNOWN_ENV_VARS), n=1)
        hint = f"; did you mean {near[0]!r}?" if near else ""
        warnings.warn(f"unrecognized environment variable {var!r} is "
                      f"ignored{hint}", stacklevel=3)


# --------------------------------------------------------------------- #
# the config                                                             #
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class OffloadConfig:
    """Every offload-runtime knob, typed and validated.

    ``None`` means "resolve automatically": ``threshold`` falls back to
    the backend-detected default
    (:func:`repro.core.threshold.default_threshold`), ``devices`` to
    ``len(jax.devices())``, ``device_bytes`` to uncapped.
    """

    policy: str = "dfu"                  # placement policy
    threshold: Optional[float] = None    # N_avg offload threshold
    sync: bool = False                   # block after every call
    adaptive: bool = False               # per-site probe-then-lock mode
    adaptive_warmup: int = 6             # timed probes per site (min 2)
    callsite: bool = True                # call-site fingerprinting
    dispatch_cache: bool = True          # memoized decisions/kernels
    devices: Optional[int] = None        # logical device tiers
    device_bytes: Optional[int] = None   # per-tier residency byte cap
    tile_min: int = 64                   # minimum tile edge for sharding
    evict: str = "lru"                   # cap eviction policy
    pin: bool = False                    # pin every placement
    trace_path: str = ""                 # dump trace here on close/exit
    debug: int = 0                       # 1 = events, 2 = per-call
    # fault tolerance (repro.core.faults): deterministic injection spec,
    # transient-fault retry, and the per-device circuit breaker
    faults: str = ""                     # e.g. "transfer:p=0.05,seed=7"
    retries: int = 2                     # retries for transient faults
    backoff_ms: float = 1.0              # base exponential backoff
    breaker: int = 3                     # consecutive failures to trip
    #                                    # a device (0 = breaker off)
    breaker_cooldown_ms: float = 1000.0  # quarantine -> half-open probe
    # multi-tenant shared pool: sessions with pool_bytes set draw on the
    # process-wide SharedDevicePool of that capacity; pool_quota is this
    # session's byte quota inside it (None = fair equal share)
    pool_bytes: Optional[int] = None     # shared-pool capacity (0 = off)
    pool_quota: Optional[int] = None     # this tenant's quota (0 = none)
    # the `pallas` execution venue (repro.kernels): race hand-written
    # kernels against the generic XLA offload per call site
    kernel_path: bool = False            # enable the third dispatch venue
    kernel_block: int = 0                # kernel block edge (0 = default)
    # tunable-precision emulation (repro.core.precision): rewrite fp64
    # BLAS onto fp32/bf16 split passes with error-bounded escalation.
    # "" = native (off); "split2"/"split3" force a scheme; "auto" picks
    # per call from the a-priori bound vs precision_rtol.
    precision: str = ""                  # split scheme ("" = native)
    precision_rtol: float = 1e-4         # max accepted relative error
    # the LAPACK solver tier (repro.solvers): patch jnp.linalg /
    # jax.scipy.linalg factorizations onto the blocked drivers, wrap
    # each in a solver span (pinned factor, tagged inner BLAS calls)
    lapack: bool = False                 # intercept the solver tier
    lapack_nb: int = 0                   # LU/Cholesky block size
    #                                    # (0 = driver default)

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.policy not in POLICY_NAMES:
            raise ValueError(f"unknown policy {self.policy!r}; choose "
                             f"from {sorted(POLICY_NAMES)}")
        if self.evict not in EVICT_NAMES:
            raise ValueError(f"unknown eviction policy {self.evict!r}; "
                             f"choose from {sorted(EVICT_NAMES)}")
        if self.threshold is not None:
            object.__setattr__(self, "threshold", float(self.threshold))
            if self.threshold <= 0:
                raise ValueError("threshold must be positive "
                                 f"(got {self.threshold})")
        if self.adaptive_warmup < 2:
            raise ValueError("adaptive_warmup must be >= 2 "
                             f"(got {self.adaptive_warmup})")
        if self.devices is not None and self.devices < 1:
            raise ValueError(f"devices must be >= 1 (got {self.devices})")
        if self.device_bytes is not None:
            if self.device_bytes < 0:
                raise ValueError("device_bytes must be >= 0 "
                                 f"(got {self.device_bytes})")
            if self.device_bytes == 0:    # explicit "uncapped" sentinel
                object.__setattr__(self, "device_bytes", None)
        if self.tile_min < 1:
            raise ValueError(f"tile_min must be >= 1 (got {self.tile_min})")
        if self.debug < 0:
            raise ValueError(f"debug must be >= 0 (got {self.debug})")
        if self.faults:
            from repro.core import faults as _flt
            _flt.parse_spec(self.faults)   # ValueError on a bad spec
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0 (got {self.retries})")
        if self.backoff_ms < 0:
            raise ValueError("backoff_ms must be >= 0 "
                             f"(got {self.backoff_ms})")
        object.__setattr__(self, "backoff_ms", float(self.backoff_ms))
        if self.breaker < 0:
            raise ValueError(f"breaker must be >= 0 (got {self.breaker})")
        if self.breaker_cooldown_ms < 0:
            raise ValueError("breaker_cooldown_ms must be >= 0 "
                             f"(got {self.breaker_cooldown_ms})")
        object.__setattr__(self, "breaker_cooldown_ms",
                           float(self.breaker_cooldown_ms))
        for name in ("pool_bytes", "pool_quota"):
            val = getattr(self, name)
            if val is not None:
                if val < 0:
                    raise ValueError(f"{name} must be >= 0 (got {val})")
                if val == 0:              # explicit "unset" sentinel
                    object.__setattr__(self, name, None)
        if self.kernel_block < 0:
            raise ValueError("kernel_block must be >= 0 "
                             f"(got {self.kernel_block})")
        if self.lapack_nb < 0:
            raise ValueError("lapack_nb must be >= 0 "
                             f"(got {self.lapack_nb})")
        if self.precision == "native":   # explicit spelling of the default
            object.__setattr__(self, "precision", "")
        if self.precision not in ("", "split2", "split3", "auto"):
            raise ValueError(f"unknown precision {self.precision!r}; "
                             f"choose from {sorted(PRECISION_NAMES)}")
        if not 0 < self.precision_rtol < 1:
            raise ValueError("precision_rtol must be in (0, 1) "
                             f"(got {self.precision_rtol})")
        object.__setattr__(self, "precision_rtol",
                           float(self.precision_rtol))

    # ------------------------------------------------------------------ #
    def replace(self, **kw) -> "OffloadConfig":
        """Derive a new config with some fields changed (re-validated)."""
        return dataclasses.replace(self, **kw)

    def resolved_threshold(self) -> float:
        """The threshold this config actually runs at: the explicit
        value, or the backend-detected default."""
        if self.threshold is not None:
            return self.threshold
        from repro.core import threshold as thr
        return thr.default_threshold()

    def resolved_devices(self) -> int:
        """The device-tier count this config actually runs at."""
        if self.devices is not None:
            return self.devices
        try:
            import jax
            return max(1, len(jax.devices()))
        except Exception:  # pragma: no cover - no backend at all
            return 1

    # ------------------------------------------------------------------ #
    # the single environment-ingestion boundary                           #
    # ------------------------------------------------------------------ #
    @classmethod
    def from_env(cls, base: Optional["OffloadConfig"] = None,
                 environ: Optional[Mapping[str, str]] = None,
                 ) -> "OffloadConfig":
        """Layer the ``SCILIB_*`` env vars over ``base`` (default: the
        process-default config, see :func:`set_default`).

        Parsing is lenient, matching the legacy knobs exactly: an unset
        or empty var leaves the base value; a malformed value falls back
        to the base value rather than raising.  Unknown ``SCILIB_*``
        vars trigger a one-time warning with the nearest valid name.
        """
        env = os.environ if environ is None else environ
        _warn_unknown(env)
        cfg = get_default() if base is None else base
        for field_name, var in ENV_FIELDS.items():
            raw = env.get(var)
            if raw is None or raw == "":
                continue
            parsed = _PARSERS[field_name](raw)
            if parsed is not _INVALID:
                # one field at a time so a parseable-but-invalid value
                # (negative threshold, devices=0 ...) falls back too
                # instead of escaping the boundary as a ValueError
                try:
                    cfg = cfg.replace(**{field_name: parsed})
                    continue
                except ValueError:
                    pass
            if var not in _WARNED:
                _WARNED.add(var)
                warnings.warn(f"malformed {var}={raw!r} ignored; "
                              f"using {getattr(cfg, field_name)!r}",
                              stacklevel=3)
        return cfg

    @classmethod
    def legacy(cls, policy: Optional[str] = None,
               threshold: Optional[float] = None,
               sync: Optional[bool] = None,
               device_bytes: Optional[int] = None) -> "OffloadConfig":
        """Resolve the legacy ``install()`` argument surface with its
        historical precedence: ``SCILIB_POLICY``/``SCILIB_THRESHOLD``
        override the arguments, while explicit ``sync``/``device_bytes``
        arguments override their env vars.  ``None`` means "not given":
        the process-default base (:func:`set_default`) supplies the
        value, so a file-configured process is honored by the shims."""
        seed: Dict[str, Any] = {}
        if policy is not None:
            seed["policy"] = policy
        if threshold is not None:
            seed["threshold"] = threshold
        cfg = cls.from_env(get_default().replace(**seed) if seed
                           else get_default())
        over: Dict[str, Any] = {}
        if sync is not None:
            over["sync"] = bool(sync)
        if device_bytes is not None:
            over["device_bytes"] = device_bytes
        return cfg.replace(**over) if over else cfg

    # ------------------------------------------------------------------ #
    # serialization                                                       #
    # ------------------------------------------------------------------ #
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, data: Mapping[str, Any]) -> "OffloadConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - fields)
        if unknown:
            hints = []
            for key in unknown:
                near = difflib.get_close_matches(key, sorted(fields), n=1)
                hints.append(f"{key!r}" + (f" (did you mean {near[0]!r}?)"
                                           if near else ""))
            raise ValueError("unknown config field(s): " + ", ".join(hints))
        return cls(**data)

    def save(self, path: str) -> None:
        """Write the config as JSON (the tune->deploy artifact)."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "OffloadConfig":
        """Load and validate a JSON config file (unknown fields error,
        with the nearest valid name)."""
        with open(path) as f:
            data = json.load(f)
        if not isinstance(data, dict):
            raise ValueError(f"{path}: expected a JSON object of config "
                             f"fields, got {type(data).__name__}")
        return cls.from_dict(data)

    def env(self) -> Dict[str, str]:
        """The ``SCILIB_*`` assignments equivalent to this config — the
        inverse of :meth:`from_env` for every non-default field.  Kept
        for interop (shell scripts, the autotuner's printed settings)."""
        default = OffloadConfig()
        out: Dict[str, str] = {}
        for field_name, var in ENV_FIELDS.items():
            val = getattr(self, field_name)
            if val == getattr(default, field_name):
                continue
            if isinstance(val, bool):
                if field_name == "pin":
                    out[var] = "never-evict"
                else:
                    out[var] = "1" if val else "0"
            elif isinstance(val, float) and float(val).is_integer():
                out[var] = str(int(val))
            else:
                out[var] = str(val)
        return out

    # ------------------------------------------------------------------ #
    # presets                                                             #
    # ------------------------------------------------------------------ #
    @classmethod
    def preset(cls, name: str) -> "OffloadConfig":
        """A named preset: ``"paper"``, ``"throughput"``, ``"low-memory"``
        (see :data:`PRESETS`)."""
        try:
            return cls(**PRESETS[name])
        except KeyError:
            raise ValueError(f"unknown preset {name!r}; choose from "
                             f"{sorted(PRESETS)}")


#: named presets: field overrides applied on top of the defaults.
#:
#: * ``paper`` — the source paper's conservative GH200 configuration:
#:   DFU at threshold 500, synchronous per-call timing (how Tables 3/5
#:   were measured), uncapped residency.
#: * ``throughput`` — serve-many-calls shape: async dispatch, adaptive
#:   per-site lock-in so steady-state sites skip threshold math, memoized
#:   dispatch cache on.
#: * ``low-memory`` — shared-accelerator shape: a 256 MB per-tier
#:   residency cap with cost-aware ``refetch`` eviction, so one workload
#:   cannot monopolize HBM.
PRESETS: Dict[str, Dict[str, Any]] = {
    "paper": {"policy": "dfu", "threshold": 500.0, "sync": True},
    "throughput": {"policy": "dfu", "adaptive": True,
                   "adaptive_warmup": 6, "sync": False},
    "low-memory": {"policy": "dfu", "device_bytes": 256 << 20,
                   "evict": "refetch"},
}


# --------------------------------------------------------------------- #
# process-default config (what from_env layers env vars over)            #
# --------------------------------------------------------------------- #
_DEFAULT = OffloadConfig()


def get_default() -> OffloadConfig:
    """The process-default base config (all-defaults unless
    :func:`set_default` installed another — e.g. the CI config-file job
    supplying settings from a checked-in JSON file instead of env)."""
    return _DEFAULT


def set_default(config: OffloadConfig) -> OffloadConfig:
    """Install a process-default base config; returns the previous one.
    ``from_env()`` (and therefore every legacy ``install()``) starts
    from this instead of the all-defaults config — the env-free way to
    configure a whole process from a file:

        config.set_default(OffloadConfig.load("tuned.json"))
    """
    global _DEFAULT
    prev, _DEFAULT = _DEFAULT, config
    return prev

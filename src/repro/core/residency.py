"""The residency engine: one block store behind runtime, scheduler, sim.

The paper's core claim is that residency — not dispatch — dominates
offload cost (Fig. 2, Tables 3/5): Device First-Use wins because a
buffer moves once and every later use is free, and BLASX shows the same
lesson at tile granularity with a software cache plus an eviction
discipline.  Before this module the repo had four drifting copies of
that bookkeeping: the runtime's whole-buffer placement registry, the
per-device tile-block registries, the trace-id weakref table, and the
memtier simulator's own device-residency model.  They could not agree —
so the autotuner's replay predictions could not see the live runtime's
cap-induced evictions and refetches.

:class:`ResidencyStore` is the single implementation all four now
share.  It is a keyed table of resident entries with

* **byte accounting** — every entry carries ``nbytes``; the store keeps
  ``resident_bytes`` exact at all times,
* **weakref lifecycle** — an entry may be anchored to a live object
  (the application's array); when the anchor dies the entry drops
  itself, exactly like the old registries' weakref callbacks,
* **pin flags** — pinned entries survive arbitrary cap pressure
  (``runtime.pin(x)``, or ``SCILIB_PIN=never-evict`` to pin every
  placement),
* **byte caps** with **pluggable eviction policies** — ``lru`` (the
  default, byte-for-byte the pre-refactor behaviour), ``lfu`` (evict
  the least-used entry), and ``refetch`` (cost-aware: evict the entry
  with the cheapest bytes-to-refetch-per-use, so a big rarely-reused
  block goes before a small hot one), selected with ``SCILIB_EVICT``,
* **residency events** — ``place`` / ``hit`` / ``evict`` / ``refetch``
  emitted through a callback the runtime points at the trace, so a
  recorded run carries its residency history and the simulator's replay
  can be checked against it count-for-count.

Two admission semantics coexist because the live runtime and the
hardware model genuinely differ:

* :meth:`ResidencyStore.put` is the *runtime registry* semantic —
  admit, then evict other entries until back under the cap (the entry
  just placed is in use by the current call and is protected, so one
  oversized buffer is admitted rather than thrashed);
* :meth:`ResidencyStore.reserve` is the *HBM capacity* semantic the
  simulator's page table needs — check (and optionally make) room
  first, refuse the migration entirely when it cannot fit.
"""
from __future__ import annotations

import collections
import dataclasses
import weakref
from typing import Callable, Dict, Hashable, Iterator, Optional

__all__ = ["Entry", "ResidencyEvent", "ResidencyStore",
           "EVICTION_POLICIES", "make_eviction_policy",
           "evict_policy_from_env", "pin_all_from_env"]


# --------------------------------------------------------------------- #
# entries and events                                                     #
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class Entry:
    """One resident block: payload + the accounting the policies read."""

    key: Hashable
    payload: object            # placed array / Buffer / trace-buffer id
    nbytes: int
    pinned: bool = False
    uses: int = 0              # lookup hits (LFU / refetch-cost input)
    ref: Optional[weakref.ref] = None   # lifecycle anchor (may be None)


@dataclasses.dataclass(frozen=True)
class ResidencyEvent:
    """One residency transition, recorded into the trace.

    ``store`` names the owning store (``"placements"``, ``"dev0"``...),
    ``call_index`` is the position in ``Trace.calls`` at emission time
    (-1 when no trace context exists), so events interleave with the
    call stream on replay.
    """

    kind: str                  # "place" | "hit" | "evict" | "refetch"
    store: str
    nbytes: int
    call_index: int = -1

    def to_json(self):
        return dataclasses.asdict(self)


# --------------------------------------------------------------------- #
# eviction policies                                                      #
# --------------------------------------------------------------------- #
class EvictionPolicy:
    """Chooses the next victim among evictable entries.

    ``entries`` is the store's ordered table — least-recently-used
    first, because lookups and placements move entries to the end.
    ``protect`` is the entry the current call just placed (never a
    victim).  Return ``None`` when nothing is evictable.
    """

    name = "base"

    def victim(self, entries: "collections.OrderedDict[Hashable, Entry]",
               protect: Optional[Hashable]) -> Optional[Hashable]:
        raise NotImplementedError

    @staticmethod
    def _candidates(entries, protect) -> Iterator[Entry]:
        for key, ent in entries.items():
            if key == protect or ent.pinned:
                continue
            yield ent


class LruPolicy(EvictionPolicy):
    """Evict the least-recently-used entry (pre-refactor behaviour)."""

    name = "lru"

    def victim(self, entries, protect):
        for ent in self._candidates(entries, protect):
            return ent.key
        return None


class LfuPolicy(EvictionPolicy):
    """Evict the least-frequently-used entry; ties fall back to LRU."""

    name = "lfu"

    def victim(self, entries, protect):
        best = None
        for ent in self._candidates(entries, protect):
            if best is None or ent.uses < best.uses:
                best = ent
        return None if best is None else best.key


class RefetchCostPolicy(EvictionPolicy):
    """Cost-aware: evict the cheapest bytes-to-refetch-per-use.

    Refetching an evicted entry costs its ``nbytes`` over the link; an
    entry's uses say how often that cost would recur.  Evicting the
    entry with the smallest ``nbytes / uses`` sacrifices the least
    expected future traffic — a large block used once goes before a
    small block in every call.  Ties fall back to LRU order.
    """

    name = "refetch"

    def victim(self, entries, protect):
        best, best_cost = None, None
        for ent in self._candidates(entries, protect):
            cost = ent.nbytes / max(1, ent.uses)
            if best is None or cost < best_cost:
                best, best_cost = ent, cost
        return None if best is None else best.key


EVICTION_POLICIES = {p.name: p for p in (LruPolicy, LfuPolicy,
                                         RefetchCostPolicy)}


def make_eviction_policy(name: str) -> EvictionPolicy:
    try:
        return EVICTION_POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown eviction policy {name!r}; choose from "
                         f"{sorted(EVICTION_POLICIES)}")


def evict_policy_from_env(default: str = "lru") -> str:
    """Back-compat wrapper: the ``SCILIB_EVICT`` knob read through the
    config boundary (unknown values fall back to the default so a typo
    cannot silently disable eviction).  The runtime itself is plumbed
    from its config's ``evict`` field."""
    from repro.core.config import OffloadConfig
    cfg = OffloadConfig.from_env(OffloadConfig(evict=default))
    return cfg.evict


def pin_all_from_env() -> bool:
    """Back-compat wrapper: ``SCILIB_PIN=never-evict`` pins every
    placement at registration — residency only grows (the paper's
    uncapped DFU), caps never evict.  Read through the config boundary;
    the runtime itself is plumbed from its config's ``pin`` field."""
    from repro.core.config import OffloadConfig
    return OffloadConfig.from_env().pin


# --------------------------------------------------------------------- #
# the store                                                              #
# --------------------------------------------------------------------- #
class ResidencyStore:
    """Byte-capped keyed residency table with pluggable eviction.

    The ordered table doubles as the recency list: :meth:`get` hits and
    :meth:`put` placements move entries to the end, so iteration order
    is always least-recently-used first — the ``lru`` policy just takes
    the front, and ``lfu``/``refetch`` break their ties on it.

    ``on_evict(key, payload, nbytes)`` runs for every *pressure*
    eviction (not lifecycle drops): the owner re-tags tiers, bills
    statistics, or moves simulated pages there.  ``emit(kind, store,
    nbytes)`` mirrors place/hit/evict/refetch into the owner's trace.
    """

    def __init__(self, name: str = "store", *,
                 cap: Optional[int] = None,
                 policy: str = "lru",
                 on_evict: Optional[Callable] = None,
                 emit: Optional[Callable] = None,
                 pin_new: bool = False):
        self.name = name
        self.cap = cap
        self.policy = make_eviction_policy(policy)
        self.on_evict = on_evict
        self.emit = emit
        self.pin_new = pin_new
        self._entries: "collections.OrderedDict[Hashable, Entry]" = (
            collections.OrderedDict())
        self.resident_bytes = 0
        # counters (mirrored into RuntimeStats / PolicyReport by owners)
        self.places = 0
        self.hits = 0
        self.evictions = 0
        self.evicted_bytes = 0
        self.refetches = 0
        self.refetched_bytes = 0
        # keys evicted under pressure whose next placement is a refetch;
        # anchored keys clean themselves up when the anchor dies so id()
        # reuse cannot masquerade as a refetch.
        self._evicted: Dict[Hashable, Optional[weakref.ref]] = {}

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def keys(self):
        return self._entries.keys()

    def entry(self, key: Hashable) -> Optional[Entry]:
        return self._entries.get(key)

    # ------------------------------------------------------------------ #
    def get(self, key: Hashable):
        """Payload for ``key`` or None; a hit refreshes recency and the
        use count.  Entries whose anchor died (stale ``id()`` after GC)
        drop themselves and miss, exactly like the old registries."""
        ent = self._entries.get(key)
        if ent is None:
            return None
        if ent.ref is not None and ent.ref() is None:
            self.drop(key)
            return None
        ent.uses += 1
        self._entries.move_to_end(key)
        self.hits += 1
        # hit events only matter for residency analysis under a cap —
        # uncapped runs (the default) would accumulate one event per
        # operand lookup forever for nothing, so they skip the record;
        # place/evict/refetch are rare and always emitted.
        if self.emit is not None and self.cap is not None:
            self.emit("hit", self.name, ent.nbytes)
        return ent.payload

    def put(self, key: Hashable, payload, nbytes: int, *,
            anchor=None, pinned: bool = False) -> Entry:
        """Register a resident entry, then evict others over the cap.

        The runtime-registry admission semantic: the new entry is
        protected during the eviction sweep (its operand is in use by
        the current call), so a single oversized buffer is admitted and
        the *next* registration pushes it out.
        """
        if key in self._entries:
            self.drop(key)
        ref = None
        if anchor is not None:
            def _lifecycle(_ref, key=key, self=self):
                self.drop(key)
            ref = weakref.ref(anchor, _lifecycle)
        ent = Entry(key=key, payload=payload, nbytes=int(nbytes),
                    pinned=pinned or self.pin_new, ref=ref)
        self._entries[key] = ent
        self.resident_bytes += ent.nbytes
        self.places += 1
        kind = "place"
        if key in self._evicted:
            del self._evicted[key]
            self.refetches += 1
            self.refetched_bytes += ent.nbytes
            kind = "refetch"
        if self.emit is not None:
            self.emit(kind, self.name, ent.nbytes)
        self.evict_over_cap(protect=key)
        return ent

    def drop(self, key: Hashable) -> None:
        """Remove an entry without eviction accounting (lifecycle death,
        explicit invalidation, or re-registration)."""
        ent = self._entries.pop(key, None)
        if ent is not None:
            self.resident_bytes -= ent.nbytes

    # ------------------------------------------------------------------ #
    # pinning                                                             #
    # ------------------------------------------------------------------ #
    def pin(self, key: Hashable) -> bool:
        ent = self._entries.get(key)
        if ent is None:
            return False
        ent.pinned = True
        return True

    def unpin(self, key: Hashable) -> bool:
        ent = self._entries.get(key)
        if ent is None:
            return False
        ent.pinned = False
        return True

    def pinned_bytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values() if e.pinned)

    # ------------------------------------------------------------------ #
    # eviction                                                            #
    # ------------------------------------------------------------------ #
    def _evict(self, key: Hashable) -> Entry:
        ent = self._entries.pop(key)
        self.resident_bytes -= ent.nbytes
        self.evictions += 1
        self.evicted_bytes += ent.nbytes
        # remember the key so its next placement counts as a refetch;
        # an anchored key forgets itself when the application's own
        # handle dies (a dead buffer can never be refetched).
        if ent.ref is not None and ent.ref() is not None:
            anchor = ent.ref()

            def _forget(_ref, key=key, self=self):
                self._evicted.pop(key, None)
            self._evicted[key] = weakref.ref(anchor, _forget)
        else:
            self._evicted[key] = None
        if self.emit is not None:
            self.emit("evict", self.name, ent.nbytes)
        if self.on_evict is not None:
            self.on_evict(key, ent.payload, ent.nbytes)
        return ent

    def evict_over_cap(self, protect: Optional[Hashable] = None) -> int:
        """Evict policy-chosen victims until resident bytes fit the cap
        (or nothing evictable remains).  Returns evictions performed."""
        if self.cap is None:
            return 0
        n = 0
        while self.resident_bytes > self.cap:
            victim = self.policy.victim(self._entries, protect)
            if victim is None:
                break
            self._evict(victim)
            n += 1
        return n

    def evict_all(self) -> int:
        """Force-evict every entry through the normal eviction path —
        ``evict`` events, ``on_evict`` hooks and refetch tracking all
        run.  This is *invalidation*, not cap pressure: a quarantined
        device's residents are gone regardless of pin state (a pin can
        survive pressure, not a dead device).  Returns entries evicted.
        """
        n = 0
        for key in list(self._entries.keys()):
            if key in self._entries:      # a hook may drop siblings
                self._evict(key)
                n += 1
        return n

    def reserve(self, nbytes: int, *, limit: Optional[int] = None,
                evict: bool = True) -> bool:
        """HBM-capacity admission (the simulator's page-table semantic):
        make room for ``nbytes`` under ``limit`` (default: the cap) by
        evicting policy-chosen victims, or refuse — the caller leaves
        the buffer remote rather than thrashing residents for a block
        that cannot fit anyway."""
        limit = self.cap if limit is None else limit
        if limit is None:
            return True
        if self.resident_bytes + nbytes <= limit:
            return True
        if not evict:
            return False
        while self.resident_bytes + nbytes > limit:
            victim = self.policy.victim(self._entries, None)
            if victim is None:
                return False
            self._evict(victim)
        return True

    # ------------------------------------------------------------------ #
    def clear(self) -> None:
        self._entries.clear()
        self._evicted.clear()
        self.resident_bytes = 0

"""The residency engine: one block store behind runtime, scheduler, sim.

The paper's core claim is that residency — not dispatch — dominates
offload cost (Fig. 2, Tables 3/5): Device First-Use wins because a
buffer moves once and every later use is free, and BLASX shows the same
lesson at tile granularity with a software cache plus an eviction
discipline.  Before this module the repo had four drifting copies of
that bookkeeping: the runtime's whole-buffer placement registry, the
per-device tile-block registries, the trace-id weakref table, and the
memtier simulator's own device-residency model.  They could not agree —
so the autotuner's replay predictions could not see the live runtime's
cap-induced evictions and refetches.

:class:`ResidencyStore` is the single implementation all four now
share.  It is a keyed table of resident entries with

* **byte accounting** — every entry carries ``nbytes``; the store keeps
  ``resident_bytes`` exact at all times,
* **weakref lifecycle** — an entry may be anchored to a live object
  (the application's array); when the anchor dies the entry drops
  itself, exactly like the old registries' weakref callbacks,
* **pin flags** — pinned entries survive arbitrary cap pressure
  (``runtime.pin(x)``, or ``SCILIB_PIN=never-evict`` to pin every
  placement),
* **byte caps** with **pluggable eviction policies** — ``lru`` (the
  default, byte-for-byte the pre-refactor behaviour), ``lfu`` (evict
  the least-used entry), and ``refetch`` (cost-aware: evict the entry
  with the cheapest bytes-to-refetch-per-use, so a big rarely-reused
  block goes before a small hot one), selected with ``SCILIB_EVICT``,
* **residency events** — ``place`` / ``hit`` / ``evict`` / ``refetch``
  emitted through a callback the runtime points at the trace, so a
  recorded run carries its residency history and the simulator's replay
  can be checked against it count-for-count.

Two admission semantics coexist because the live runtime and the
hardware model genuinely differ:

* :meth:`ResidencyStore.put` is the *runtime registry* semantic —
  admit, then evict other entries until back under the cap (the entry
  just placed is in use by the current call and is protected, so one
  oversized buffer is admitted rather than thrashed);
* :meth:`ResidencyStore.reserve` is the *HBM capacity* semantic the
  simulator's page table needs — check (and optionally make) room
  first, refuse the migration entirely when it cannot fit.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import weakref
from typing import Callable, Dict, Hashable, Iterator, List, Optional, Tuple

__all__ = ["Entry", "ResidencyEvent", "ResidencyStore", "SharedDevicePool",
           "EVICTION_POLICIES", "make_eviction_policy",
           "evict_policy_from_env", "pin_all_from_env",
           "default_pool", "reset_default_pool"]


# --------------------------------------------------------------------- #
# entries and events                                                     #
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class Entry:
    """One resident block: payload + the accounting the policies read."""

    key: Hashable
    payload: object            # placed array / Buffer / trace-buffer id
    nbytes: int
    pinned: bool = False
    uses: int = 0              # lookup hits (LFU / refetch-cost input)
    ref: Optional[weakref.ref] = None   # lifecycle anchor (may be None)


@dataclasses.dataclass(frozen=True)
class ResidencyEvent:
    """One residency transition, recorded into the trace.

    ``store`` names the owning store (``"placements"``, ``"dev0"``...),
    ``call_index`` is the position in ``Trace.calls`` at emission time
    (-1 when no trace context exists), so events interleave with the
    call stream on replay.  ``session`` is the owning session's id for
    multi-tenant runs; unnamed single-tenant sessions leave it empty
    and their serialized form is unchanged (dumps stay byte-identical
    to pre-tenant traces).
    """

    kind: str                  # "place" | "hit" | "evict" | "refetch"
    store: str
    nbytes: int
    call_index: int = -1
    session: str = ""

    def to_json(self):
        d = dataclasses.asdict(self)
        if not d["session"]:
            del d["session"]
        return d


# --------------------------------------------------------------------- #
# eviction policies                                                      #
# --------------------------------------------------------------------- #
class EvictionPolicy:
    """Chooses the next victim among evictable entries.

    ``entries`` is the store's ordered table — least-recently-used
    first, because lookups and placements move entries to the end.
    ``protect`` is the entry the current call just placed (never a
    victim).  Return ``None`` when nothing is evictable.
    """

    name = "base"

    def victim(self, entries: "collections.OrderedDict[Hashable, Entry]",
               protect: Optional[Hashable]) -> Optional[Hashable]:
        raise NotImplementedError

    @staticmethod
    def _candidates(entries, protect) -> Iterator[Entry]:
        for key, ent in entries.items():
            if key == protect or ent.pinned:
                continue
            yield ent


class LruPolicy(EvictionPolicy):
    """Evict the least-recently-used entry (pre-refactor behaviour)."""

    name = "lru"

    def victim(self, entries, protect):
        for ent in self._candidates(entries, protect):
            return ent.key
        return None


class LfuPolicy(EvictionPolicy):
    """Evict the least-frequently-used entry; ties fall back to LRU."""

    name = "lfu"

    def victim(self, entries, protect):
        best = None
        for ent in self._candidates(entries, protect):
            if best is None or ent.uses < best.uses:
                best = ent
        return None if best is None else best.key


class RefetchCostPolicy(EvictionPolicy):
    """Cost-aware: evict the cheapest bytes-to-refetch-per-use.

    Refetching an evicted entry costs its ``nbytes`` over the link; an
    entry's uses say how often that cost would recur.  Evicting the
    entry with the smallest ``nbytes / uses`` sacrifices the least
    expected future traffic — a large block used once goes before a
    small block in every call.  Ties fall back to LRU order.
    """

    name = "refetch"

    def victim(self, entries, protect):
        best, best_cost = None, None
        for ent in self._candidates(entries, protect):
            cost = ent.nbytes / max(1, ent.uses)
            if best is None or cost < best_cost:
                best, best_cost = ent, cost
        return None if best is None else best.key


EVICTION_POLICIES = {p.name: p for p in (LruPolicy, LfuPolicy,
                                         RefetchCostPolicy)}


def make_eviction_policy(name: str) -> EvictionPolicy:
    try:
        return EVICTION_POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown eviction policy {name!r}; choose from "
                         f"{sorted(EVICTION_POLICIES)}")


def evict_policy_from_env(default: str = "lru") -> str:
    """Back-compat wrapper: the ``SCILIB_EVICT`` knob read through the
    config boundary (unknown values fall back to the default so a typo
    cannot silently disable eviction).  The runtime itself is plumbed
    from its config's ``evict`` field."""
    from repro.core.config import OffloadConfig
    cfg = OffloadConfig.from_env(OffloadConfig(evict=default))
    return cfg.evict


def pin_all_from_env() -> bool:
    """Back-compat wrapper: ``SCILIB_PIN=never-evict`` pins every
    placement at registration — residency only grows (the paper's
    uncapped DFU), caps never evict.  Read through the config boundary;
    the runtime itself is plumbed from its config's ``pin`` field."""
    from repro.core.config import OffloadConfig
    return OffloadConfig.from_env().pin


# --------------------------------------------------------------------- #
# the store                                                              #
# --------------------------------------------------------------------- #
class ResidencyStore:
    """Byte-capped keyed residency table with pluggable eviction.

    The ordered table doubles as the recency list: :meth:`get` hits and
    :meth:`put` placements move entries to the end, so iteration order
    is always least-recently-used first — the ``lru`` policy just takes
    the front, and ``lfu``/``refetch`` break their ties on it.

    ``on_evict(key, payload, nbytes)`` runs for every *pressure*
    eviction (not lifecycle drops): the owner re-tags tiers, bills
    statistics, or moves simulated pages there.  ``emit(kind, store,
    nbytes)`` mirrors place/hit/evict/refetch into the owner's trace.

    Every mutating method holds the store's reentrant lock — reentrant
    because weakref lifecycle callbacks and ``on_evict``/``emit`` hooks
    can re-enter the store from inside an eviction sweep.  Lock order
    is store → pool: the store notifies its :class:`SharedDevicePool`
    (if bound) while holding its own lock, and the pool never calls
    back into a store while holding the pool lock.
    """

    def __init__(self, name: str = "store", *,
                 cap: Optional[int] = None,
                 policy: str = "lru",
                 on_evict: Optional[Callable] = None,
                 emit: Optional[Callable] = None,
                 pin_new: bool = False):
        self.name = name
        self.cap = cap
        self.policy = make_eviction_policy(policy)
        self.on_evict = on_evict
        self.emit = emit
        self.pin_new = pin_new
        self._lock = threading.RLock()
        self._entries: "collections.OrderedDict[Hashable, Entry]" = (
            collections.OrderedDict())
        self.resident_bytes = 0
        # counters (mirrored into RuntimeStats / PolicyReport by owners)
        self.places = 0
        self.hits = 0
        self.evictions = 0
        self.evicted_bytes = 0
        self.refetches = 0
        self.refetched_bytes = 0
        # multi-tenant binding: set by SharedDevicePool.attach()
        self.pool: Optional["SharedDevicePool"] = None
        self.owner: str = ""
        # keys evicted under pressure whose next placement is a refetch;
        # anchored keys clean themselves up when the anchor dies so id()
        # reuse cannot masquerade as a refetch.
        self._evicted: Dict[Hashable, Optional[weakref.ref]] = {}

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def keys(self):
        return self._entries.keys()

    def entry(self, key: Hashable) -> Optional[Entry]:
        return self._entries.get(key)

    # ------------------------------------------------------------------ #
    def get(self, key: Hashable):
        """Payload for ``key`` or None; a hit refreshes recency and the
        use count.  Entries whose anchor died (stale ``id()`` after GC)
        drop themselves and miss, exactly like the old registries."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return None
            if ent.ref is not None and ent.ref() is None:
                self.drop(key)
                return None
            ent.uses += 1
            self._entries.move_to_end(key)
            self.hits += 1
            # hit events only matter for residency analysis under a cap —
            # uncapped runs (the default) would accumulate one event per
            # operand lookup forever for nothing, so they skip the record;
            # place/evict/refetch are rare and always emitted.
            if self.emit is not None and self.cap is not None:
                self.emit("hit", self.name, ent.nbytes)
            return ent.payload

    def put(self, key: Hashable, payload, nbytes: int, *,
            anchor=None, pinned: bool = False) -> Entry:
        """Register a resident entry, then evict others over the cap.

        The runtime-registry admission semantic: the new entry is
        protected during the eviction sweep (its operand is in use by
        the current call), so a single oversized buffer is admitted and
        the *next* registration pushes it out.
        """
        with self._lock:
            if key in self._entries:
                self.drop(key)
            ref = None
            if anchor is not None:
                def _lifecycle(_ref, key=key, self=self):
                    self.drop(key)
                ref = weakref.ref(anchor, _lifecycle)
            ent = Entry(key=key, payload=payload, nbytes=int(nbytes),
                        pinned=pinned or self.pin_new, ref=ref)
            self._entries[key] = ent
            self.resident_bytes += ent.nbytes
            self.places += 1
            kind = "place"
            if key in self._evicted:
                del self._evicted[key]
                self.refetches += 1
                self.refetched_bytes += ent.nbytes
                kind = "refetch"
            if self.emit is not None:
                self.emit(kind, self.name, ent.nbytes)
            self.evict_over_cap(protect=key)
        # Charge the shared pool *after* releasing the store lock: the
        # pool may rebalance into other tenants' stores, and holding a
        # store lock while taking another store's lock would deadlock.
        if self.pool is not None:
            self.pool.charge(self.owner, ent.nbytes,
                             refetch=(kind == "refetch"))
        return ent

    def drop(self, key: Hashable) -> None:
        """Remove an entry without eviction accounting (lifecycle death,
        explicit invalidation, or re-registration)."""
        with self._lock:
            ent = self._entries.pop(key, None)
            if ent is not None:
                self.resident_bytes -= ent.nbytes
                if self.pool is not None:
                    self.pool.credit(self.owner, ent.nbytes)

    # ------------------------------------------------------------------ #
    # pinning                                                             #
    # ------------------------------------------------------------------ #
    def pin(self, key: Hashable) -> bool:
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return False
            ent.pinned = True
            return True

    def unpin(self, key: Hashable) -> bool:
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                return False
            ent.pinned = False
            return True

    def pinned_bytes(self) -> int:
        with self._lock:
            return sum(e.nbytes for e in self._entries.values()
                       if e.pinned)

    # ------------------------------------------------------------------ #
    # eviction                                                            #
    # ------------------------------------------------------------------ #
    def _evict(self, key: Hashable) -> Entry:
        with self._lock:
            ent = self._entries.pop(key)
            self.resident_bytes -= ent.nbytes
            self.evictions += 1
            self.evicted_bytes += ent.nbytes
            # remember the key so its next placement counts as a refetch;
            # an anchored key forgets itself when the application's own
            # handle dies (a dead buffer can never be refetched).
            if ent.ref is not None and ent.ref() is not None:
                anchor = ent.ref()

                def _forget(_ref, key=key, self=self):
                    self._evicted.pop(key, None)
                self._evicted[key] = weakref.ref(anchor, _forget)
            else:
                self._evicted[key] = None
            if self.emit is not None:
                self.emit("evict", self.name, ent.nbytes)
            if self.on_evict is not None:
                self.on_evict(key, ent.payload, ent.nbytes)
            if self.pool is not None:
                self.pool.evicted(self.owner, ent.nbytes)
            return ent

    def evict_one(self) -> int:
        """Evict a single policy-chosen victim regardless of the local
        cap (shared-pool pressure from another tenant's placement).
        Returns the bytes freed, 0 when nothing is evictable — pinned
        entries survive pool pressure exactly as they survive cap
        pressure."""
        with self._lock:
            victim = self.policy.victim(self._entries, None)
            if victim is None:
                return 0
            return self._evict(victim).nbytes

    def evict_over_cap(self, protect: Optional[Hashable] = None) -> int:
        """Evict policy-chosen victims until resident bytes fit the cap
        (or nothing evictable remains).  Returns evictions performed."""
        if self.cap is None:
            return 0
        with self._lock:
            n = 0
            while self.resident_bytes > self.cap:
                victim = self.policy.victim(self._entries, protect)
                if victim is None:
                    break
                self._evict(victim)
                n += 1
            return n

    def evict_all(self) -> int:
        """Force-evict every entry through the normal eviction path —
        ``evict`` events, ``on_evict`` hooks and refetch tracking all
        run.  This is *invalidation*, not cap pressure: a quarantined
        device's residents are gone regardless of pin state (a pin can
        survive pressure, not a dead device).  Returns entries evicted.
        """
        with self._lock:
            n = 0
            for key in list(self._entries.keys()):
                if key in self._entries:      # a hook may drop siblings
                    self._evict(key)
                    n += 1
            return n

    def reserve(self, nbytes: int, *, limit: Optional[int] = None,
                evict: bool = True) -> bool:
        """HBM-capacity admission (the simulator's page-table semantic):
        make room for ``nbytes`` under ``limit`` (default: the cap) by
        evicting policy-chosen victims, or refuse — the caller leaves
        the buffer remote rather than thrashing residents for a block
        that cannot fit anyway."""
        with self._lock:
            limit = self.cap if limit is None else limit
            if limit is None:
                return True
            if self.resident_bytes + nbytes <= limit:
                return True
            if not evict:
                return False
            while self.resident_bytes + nbytes > limit:
                victim = self.policy.victim(self._entries, None)
                if victim is None:
                    return False
                self._evict(victim)
            return True

    # ------------------------------------------------------------------ #
    def clear(self) -> None:
        with self._lock:
            freed = self.resident_bytes
            self._entries.clear()
            self._evicted.clear()
            self.resident_bytes = 0
            if self.pool is not None and freed:
                self.pool.credit(self.owner, freed)


# --------------------------------------------------------------------- #
# the shared multi-tenant pool                                           #
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class _Tenant:
    """One pool member: its quota, live usage, and lifetime counters."""

    quota: Optional[int] = None
    usage: int = 0
    places: int = 0
    placed_bytes: int = 0
    evictions: int = 0
    evicted_bytes: int = 0
    refetches: int = 0
    stores: List[ResidencyStore] = dataclasses.field(default_factory=list)

    def row(self) -> dict:
        return {"quota": self.quota, "usage": self.usage,
                "places": self.places, "placed_bytes": self.placed_bytes,
                "evictions": self.evictions,
                "evicted_bytes": self.evicted_bytes,
                "refetches": self.refetches}


class SharedDevicePool:
    """One device-byte budget shared by many concurrent sessions.

    Each tenant (a :class:`~repro.core.session.Session`'s runtime)
    registers with an optional per-tenant byte quota and attaches its
    residency stores.  Stores notify the pool on every placement,
    eviction and drop, so the pool's usage ledger mirrors the sum of
    tenant ``resident_bytes`` exactly — the concurrency test suite
    asserts that equality under a 32-thread storm.

    Pressure is resolved by :meth:`rebalance`, which runs after every
    charge:

    1. any tenant over its *own* quota is evicted down first, then
    2. while the *pool total* exceeds ``total_bytes``, the tenant with
       the highest ``usage / quota`` ratio loses one entry — fair,
       quota-proportional eviction (a tenant with 3x the quota settles
       at 3x the residency under uniform load).

    The victim plan is computed under the pool lock but the eviction
    itself runs outside it via the victim store's :meth:`evict_one`,
    preserving the store → pool lock order (never pool → store).
    Pinned entries are skipped by the policies, so a tenant whose
    residency is fully pinned is simply exempted from that sweep.
    """

    def __init__(self, total_bytes: Optional[int] = None, *,
                 name: str = "pool",
                 default_quota: Optional[int] = None):
        self.name = name
        self.total_bytes = total_bytes
        self.default_quota = default_quota
        self._lock = threading.RLock()
        self._members: Dict[str, _Tenant] = {}
        self._next_id = 0
        # pool-wide totals, maintained independently of the per-tenant
        # rows (the stress tests assert sum(tenants) == totals).
        self.places = 0
        self.placed_bytes = 0
        self.evictions = 0
        self.evicted_bytes = 0
        self.refetches = 0

    # ------------------------------------------------------------------ #
    # membership                                                          #
    # ------------------------------------------------------------------ #
    def register(self, session_id: str = "", *,
                 quota: Optional[int] = None) -> str:
        """Add a tenant; returns its (possibly auto-assigned) id."""
        with self._lock:
            sid = session_id
            if not sid:
                while True:
                    sid = f"tenant-{self._next_id}"
                    self._next_id += 1
                    if sid not in self._members:
                        break
            elif sid in self._members:
                raise ValueError(
                    f"session id {sid!r} already registered with "
                    f"pool {self.name!r}")
            self._members[sid] = _Tenant(
                quota=self.default_quota if quota is None else quota)
            return sid

    def attach(self, session_id: str, *stores: ResidencyStore) -> None:
        """Bind stores to a tenant: their placements charge the pool."""
        with self._lock:
            m = self._members[session_id]
            for s in stores:
                s.pool = self
                s.owner = session_id
                m.stores.append(s)
                m.usage += s.resident_bytes

    def unregister(self, session_id: str) -> None:
        """Detach a tenant's stores and forget its usage (session
        close); lifetime counters stay in the pool totals."""
        with self._lock:
            m = self._members.pop(session_id, None)
            if m is None:
                return
            for s in m.stores:
                s.pool = None
                s.owner = ""

    def members(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(self._members)

    def quota_of(self, session_id: str) -> Optional[int]:
        with self._lock:
            m = self._members.get(session_id)
            return None if m is None else m.quota

    def usage(self, session_id: Optional[str] = None) -> int:
        with self._lock:
            if session_id is not None:
                m = self._members.get(session_id)
                return 0 if m is None else m.usage
            return sum(m.usage for m in self._members.values())

    # ------------------------------------------------------------------ #
    # store notifications (store lock may be held; pool lock is inner)    #
    # ------------------------------------------------------------------ #
    def charge(self, owner: str, nbytes: int, *,
               refetch: bool = False) -> None:
        with self._lock:
            m = self._members.get(owner)
            if m is None:
                return
            m.usage += nbytes
            m.places += 1
            m.placed_bytes += nbytes
            self.places += 1
            self.placed_bytes += nbytes
            if refetch:
                m.refetches += 1
                self.refetches += 1
        self.rebalance()

    def credit(self, owner: str, nbytes: int) -> None:
        with self._lock:
            m = self._members.get(owner)
            if m is not None:
                m.usage -= nbytes

    def evicted(self, owner: str, nbytes: int) -> None:
        with self._lock:
            m = self._members.get(owner)
            if m is None:
                return
            m.usage -= nbytes
            m.evictions += 1
            m.evicted_bytes += nbytes
            self.evictions += 1
            self.evicted_bytes += nbytes

    # ------------------------------------------------------------------ #
    # pressure                                                            #
    # ------------------------------------------------------------------ #
    def _pick_victim(self, exclude) -> Optional[str]:
        # caller holds the pool lock
        for sid, m in self._members.items():
            if sid in exclude or not m.stores:
                continue
            if m.quota is not None and m.usage > m.quota:
                return sid
        if self.total_bytes is None:
            return None
        total = sum(m.usage for m in self._members.values())
        if total <= self.total_bytes:
            return None
        best, best_ratio = None, -1.0
        share = self.total_bytes / max(1, len(self._members))
        for sid, m in self._members.items():
            if sid in exclude or not m.stores or m.usage <= 0:
                continue
            denom = m.quota if m.quota else share
            ratio = m.usage / max(1.0, denom)
            if ratio > best_ratio:
                best, best_ratio = sid, ratio
        return best

    def rebalance(self) -> int:
        """Evict until every tenant fits its quota and the pool fits
        ``total_bytes`` (or nothing evictable remains).  Returns the
        number of entries evicted."""
        n = 0
        exhausted = set()
        while True:
            with self._lock:
                sid = self._pick_victim(exhausted)
                if sid is None:
                    return n
                stores = tuple(self._members[sid].stores)
            freed = 0
            for s in stores:       # outside the pool lock (store order)
                freed = s.evict_one()
                if freed:
                    n += 1
                    break
            if not freed:          # fully pinned / empty: exempt it
                exhausted.add(sid)

    # ------------------------------------------------------------------ #
    # reporting                                                           #
    # ------------------------------------------------------------------ #
    def tenant_stats(self) -> Dict[str, dict]:
        with self._lock:
            return {sid: m.row() for sid, m in self._members.items()}

    def report(self) -> str:
        with self._lock:
            cap = ("uncapped" if self.total_bytes is None
                   else f"{self.total_bytes}B")
            lines = [f"shared pool {self.name!r}: {len(self._members)} "
                     f"tenant(s), {self.usage_locked()}B / {cap}",
                     f"  totals: places={self.places} "
                     f"evictions={self.evictions} "
                     f"evicted_bytes={self.evicted_bytes} "
                     f"refetches={self.refetches}"]
            for sid, m in sorted(self._members.items()):
                quota = "none" if m.quota is None else f"{m.quota}B"
                lines.append(
                    f"  {sid:<16} usage={m.usage}B quota={quota} "
                    f"places={m.places} evictions={m.evictions} "
                    f"refetches={m.refetches}")
            return "\n".join(lines)

    def usage_locked(self) -> int:
        # caller holds the pool lock (RLock: safe either way)
        return sum(m.usage for m in self._members.values())


# --------------------------------------------------------------------- #
# the process-default pool (config-driven: SCILIB_POOL_BYTES/_QUOTA)     #
# --------------------------------------------------------------------- #
_DEFAULT_POOL: Optional[SharedDevicePool] = None
_DEFAULT_POOL_LOCK = threading.Lock()


def default_pool(total_bytes: Optional[int] = None) -> SharedDevicePool:
    """The lazily-created process-wide pool that config-driven sessions
    (``pool_bytes``/``pool_quota`` set, no explicit ``pool=``) join.
    The first caller's ``total_bytes`` wins; later values are ignored
    so concurrent openers agree on one budget."""
    global _DEFAULT_POOL
    with _DEFAULT_POOL_LOCK:
        if _DEFAULT_POOL is None:
            _DEFAULT_POOL = SharedDevicePool(total_bytes, name="default")
        return _DEFAULT_POOL


def reset_default_pool() -> None:
    """Drop the process-default pool (test isolation)."""
    global _DEFAULT_POOL
    with _DEFAULT_POOL_LOCK:
        _DEFAULT_POOL = None

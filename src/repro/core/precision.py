"""Split-representation fp64 emulation (pilot study, arXiv 2503.22875).

The interception point that moves data (the paper) is also the right
place to rewrite precision: an fp64 operand is decomposed into 2-3
lower-precision slices, the slice cross products run on the fast
low-precision units, and the partial products are re-accumulated in
fp64.  Accuracy degradation is opt-in and bounded, never silent: every
scheme carries a computed a-priori error bound (:func:`error_bound`)
and a cheap sampled-residual check (:func:`gemm_residual` /
:func:`trsm_residual`) that the runtime compares against
``precision_rtol`` — a result that misses the bound escalates back to
native fp64.

Schemes
-------

``split2``
    ``x = hi + lo`` with two fp32 slices (Dekker-style hi/lo).  Three
    cross passes (``hi*hi``, ``hi*lo``, ``lo*hi``; the ``lo*lo`` term is
    below the accumulation floor and dropped), each a plain fp32 GEMM
    with fp32 accumulation, summed in fp64.  The bound is dominated by
    the fp32 accumulation over the contraction: ``~(k+12)*eps32``
    relative to the ``|A|@|B|`` scale.  Fastest scheme — on hosts where
    sgemm beats dgemm by more than 3x it wins outright.

``split3``
    Adds a third bf16 slice of the remaining residual (fp32+fp32+bf16,
    56 mantissa bits of coverage) and three more cross passes, and
    chunks the contraction at ``SPLIT3_CHUNK`` columns with fp64
    inter-chunk accumulation, which caps the accumulation term at
    ``~(256+24)*eps32`` independent of ``k``.  Tighter and
    shape-stable, but six passes — it pays off where low-precision
    matrix units are >6x faster than fp64 (MXU/tensor cores), not on
    SIMD hosts.

All pass primitives are injectable (``mm=``) so the same decomposition
runs on the xla venue (``jnp.matmul``) and the pallas venue
(:mod:`repro.kernels.split_gemm`), and on sharded tiles unchanged.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

#: Schemes orderable by pass count; "auto" resolves to the cheapest
#: member whose a-priori bound meets the configured rtol.
SCHEMES = ("split2", "split3")

EPS32 = 2.0 ** -24
EPS64 = 2.0 ** -53

#: split3 contraction chunk: per-pass fp32 accumulation runs over at
#: most this many columns before the partial product is widened to
#: fp64, capping the accumulation error independently of k.
SPLIT3_CHUNK = 256

#: BLAS bases the split schemes implement.
SPLIT_BASES = ("gemm", "syrk", "trsm")

MatMul = Callable[[jax.Array, jax.Array], jax.Array]


def supported(base: str, dtype) -> bool:
    """True when ``base`` has a split formulation for ``dtype``.

    Only real fp64 splits: fp32 inputs gain nothing, and complex
    operands would need a 4M decomposition on top (future work).
    """
    return base in SPLIT_BASES and jnp.dtype(dtype) == jnp.float64


def slices(x: jax.Array, scheme: str) -> Tuple[jax.Array, ...]:
    """Decompose an fp64 array into the scheme's low-precision slices.

    Every slice is returned as fp32 (the bf16 third slice of split3 is
    rounded through bf16, then widened) so any fp32 GEMM primitive can
    consume it directly.
    """
    hi = x.astype(jnp.float32)
    rem = x - hi.astype(jnp.float64)
    lo = rem.astype(jnp.float32)
    if scheme == "split2":
        return hi, lo
    if scheme == "split3":
        rem2 = rem - lo.astype(jnp.float64)
        tail = rem2.astype(jnp.bfloat16).astype(jnp.float32)
        return hi, lo, tail
    raise ValueError(f"unknown split scheme: {scheme!r}")


#: Cross passes per scheme as (slice_i, slice_j) index pairs.  split2
#: drops lo*lo (below its accumulation floor); split3 keeps every term
#: that can reach the fp64 accumulation level.
_PASSES = {
    "split2": ((0, 0), (0, 1), (1, 0)),
    "split3": ((0, 0), (0, 1), (1, 0), (1, 1), (0, 2), (2, 0)),
}


def _plain_mm(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def _pass_mm(a: jax.Array, b: jax.Array, mm: MatMul, chunk: int) -> jax.Array:
    """One slice cross product in fp64, fp32-accumulated per chunk."""
    if not chunk or a.shape[-1] <= chunk:
        return mm(a, b).astype(jnp.float64)
    k = a.shape[-1]
    out = None
    for c0 in range(0, k, chunk):
        p = mm(a[..., c0:c0 + chunk], b[c0:c0 + chunk, :])
        out = p.astype(jnp.float64) if out is None else out + p
    return out


def matmul(a: jax.Array, b: jax.Array, scheme: str,
           mm: Optional[MatMul] = None) -> jax.Array:
    """``a @ b`` for fp64 2-D operands via split low-precision passes.

    ``mm`` is the fp32 pass primitive — defaults to the XLA matmul;
    the pallas venue injects its kernel-backed equivalent.
    """
    mm = mm or _plain_mm
    chunk = SPLIT3_CHUNK if scheme == "split3" else 0
    sa = slices(a, scheme)
    sb = slices(b, scheme)
    out = None
    for i, j in _PASSES[scheme]:
        p = _pass_mm(sa[i], sb[j], mm, chunk)
        out = p if out is None else out + p
    return out


def syrk(a: jax.Array, scheme: str, trans: bool = False,
         mm: Optional[MatMul] = None) -> jax.Array:
    """``a @ a.T`` (or ``a.T @ a``) via the split matmul."""
    at = a.T
    return matmul(at, a, scheme, mm) if trans else matmul(a, at, scheme, mm)


def trsm(a: jax.Array, b: jax.Array, scheme: str, *, left_side: bool = True,
         lower: bool = True, trans_a: bool = False, unit_diag: bool = False,
         mm: Optional[MatMul] = None) -> jax.Array:
    """Triangular solve via fp32 solve + one split-residual refinement.

    ``X0 = solve32(A, B)`` seeds the solution, the residual
    ``R = B - A X0`` is formed with the split matmul (so no fp64 GEMM
    sneaks in), and one fp32 correction solve is added back.  For
    well-conditioned triangles the refined error is
    ``O(cond(A) * eps32^2)``; ill-conditioned systems are exactly what
    the sampled-residual check and escalation exist for.
    """
    solve = functools.partial(
        jax.lax.linalg.triangular_solve, left_side=left_side, lower=lower,
        transpose_a=trans_a, unit_diagonal=unit_diag)
    a32 = a.astype(jnp.float32)

    def apply_a(x):
        # op(A) @ X (left) or X @ op(A) (right) with the split matmul.
        am = a.T if trans_a else a
        if left_side:
            return matmul(am, x, scheme, mm)
        return matmul(x, am, scheme, mm)

    x = solve(a32, b.astype(jnp.float32)).astype(jnp.float64)
    r = b - apply_a(x)
    if unit_diag:
        # Unit-diagonal residual solve stays exact for the diagonal.
        pass
    x = x + solve(a32, r.astype(jnp.float32)).astype(jnp.float64)
    return x


def error_bound(scheme: str, k: int, base: str = "gemm") -> float:
    """A-priori relative error bound of an accepted split result.

    Relative to the ``(|A| @ |B|)`` inner-product scale — the standard
    backward-error scale, which the bound provably satisfies for any
    input (hypothesis-tested in ``tests/test_precision.py``).  The
    forward relative error matches it when no catastrophic cancellation
    occurs; cancellation is caught at runtime by the sampled-residual
    check instead.
    """
    k = max(1, int(k))
    if scheme == "split2":
        bound = (k + 12) * EPS32
    elif scheme == "split3":
        bound = (min(k, SPLIT3_CHUNK) + 24) * EPS32
    else:
        raise ValueError(f"unknown split scheme: {scheme!r}")
    if base == "trsm":
        # Refinement multiplies the GEMM-level bound by a modest
        # conditioning allowance; anything worse must escalate via the
        # residual check.
        bound *= 4.0
    return bound


def choose(scheme: str, base: str, k: int, rtol: float) -> str:
    """Resolve a configured scheme for one call.

    ``auto`` picks the cheapest scheme whose a-priori bound fits
    ``rtol`` (or native, empty string, when none does); explicit
    schemes are refused up front when their own bound cannot fit.
    """
    if scheme == "auto":
        for cand in SCHEMES:
            if error_bound(cand, k, base) <= rtol:
                return cand
        return ""
    if scheme in SCHEMES:
        return scheme if error_bound(scheme, k, base) <= rtol else ""
    return ""


def probe_vector(n: int) -> jax.Array:
    """Deterministic +-1 probe for the sampled-residual check."""
    signs = jnp.where(jnp.arange(n) % 2 == 0, 1.0, -1.0)
    return signs.astype(jnp.float64)


def _rel(err_vec: jax.Array, ref_vec: jax.Array) -> jax.Array:
    denom = jnp.max(jnp.abs(ref_vec)) + 1e-300
    return jnp.max(jnp.abs(err_vec)) / denom


def gemm_residual(out: jax.Array, a: jax.Array, b: jax.Array,
                  c: Optional[jax.Array], alpha, beta) -> jax.Array:
    """Sampled forward-error estimate of a split GEMM result.

    One fp64 matvec chain (O(n^2), vs the O(n^3) call) compares
    ``out @ x`` against ``(alpha op(A) op(B) + beta C) @ x``; the
    returned scalar is relative to the reference's magnitude, so
    catastrophic cancellation — where the scale-relative bound is
    honest but the forward error is not — shows up as a large value and
    triggers escalation.
    """
    x = probe_vector(out.shape[-1])
    ref = alpha * (a @ (b @ x))
    if c is not None:
        ref = ref + beta * (c @ x)
    return _rel(out @ x - ref, ref)


def trsm_residual(x_out: jax.Array, a: jax.Array, b: jax.Array,
                  *, left_side: bool = True, lower: bool = True,
                  trans_a: bool = False, alpha=1.0) -> jax.Array:
    """Sampled forward-error estimate of a split triangular solve
    ``op(A) X = alpha B`` (left) or ``X op(A) = alpha B`` (right).

    The probe residual is back-solved through ``op(A)`` (an O(n^2)
    vector triangular solve), converting the backward residual into a
    forward-error estimate on ``X`` itself — normalizing the raw
    residual by ``|B|`` would scale with cond(A) and flag solves whose
    forward error is actually fine.
    """
    am = a.T if trans_a else a
    solve = functools.partial(jax.lax.linalg.triangular_solve, a,
                              lower=lower, transpose_a=trans_a)
    if left_side:
        v = probe_vector(x_out.shape[-1])
        r = am @ (x_out @ v) - alpha * (b @ v)
        err = solve(r[:, None], left_side=True)[:, 0]
        return _rel(err, x_out @ v)
    v = probe_vector(x_out.shape[0])
    r = (v @ x_out) @ am - alpha * (v @ b)
    err = solve(r[None, :], left_side=False)[0]
    return _rel(err, v @ x_out)

"""BLAS call traces (paper §3: what the interceptor sees).

A trace is the sequence of level-3 BLAS invocations an application makes,
with operand identities (so reuse is visible) but no array payloads. The
interception layer records traces; the memtier simulator replays them under
different data-movement policies with calibrated hardware constants — the
methodology behind Tables 3 and 5 of the paper.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.residency import ResidencyEvent

# FLOP multipliers: complex arithmetic costs 4 real mul + 4 real add per
# complex multiply-add -> 4x the real FLOP count at equal dimensions.
_COMPLEX = {"c": 4.0, "z": 4.0, "s": 1.0, "d": 1.0}
_ELEM = {"s": 4, "d": 8, "c": 8, "z": 16}


@dataclasses.dataclass(frozen=True)
class BlasCall:
    """One level-3 BLAS invocation.

    ``operands`` maps role -> (buffer_id, bytes, reads_per_elem, written):
    the per-element device read multiplicity drives the access-counter
    model, ``written`` marks output operands (matrix C, or B for trsm/trmm).

    ``devices`` records the multi-device tile schedule: one device-tier
    index per tile when the runtime sharded the call, empty for
    single-device execution (older traces load with the empty default).

    ``callsite_id`` is the call-site fingerprint of
    :mod:`repro.core.callsite` (``routine@file:function:lineno``) — the
    per-site identity the paper's DBI patching keys on; ``seconds`` is
    the runtime's measured per-call wall time (dispatch/submission time
    in async mode, device wall time under ``SCILIB_SYNC=1``).  Both
    default empty/zero so older traces load unchanged.

    ``out_buf``/``out_nbytes`` identify the call's *output* buffer when
    it is a fresh allocation (no written operand to alias).  Offloaded
    outputs are born device-resident and occupy residency-store bytes
    in the live runtime, so the simulator must account them too or its
    cap-eviction replay drifts from the live run.  Default -1/0 keeps
    older traces loading unchanged (and replaying exactly as before).
    """

    routine: str                     # e.g. "zgemm", "dtrsm"
    m: int
    n: int
    k: int                           # 0 where not applicable
    operands: Tuple[Tuple[str, int, int, float, bool], ...]
    # each: (role, buffer_id, nbytes, reads_per_elem, written)
    batch: int = 1
    devices: Tuple[int, ...] = ()    # device tier per scheduled tile
    callsite_id: str = ""            # per-site fingerprint (may be "")
    seconds: float = 0.0             # measured per-call wall time
    out_buf: int = -1                # fresh-output buffer id (or -1)
    out_nbytes: int = 0              # its size (0 when out_buf is -1)
    # execution venue ("host"/"xla"/"pallas"); recorded only by
    # kernel-path runs (OffloadConfig.kernel_path) so default-off trace
    # dumps stay byte-identical to pre-venue traces
    venue: str = ""
    # split-precision scheme the call dispatched under ("split2"/
    # "split3"); recorded only by SCILIB_PRECISION runs, same
    # byte-stability rule as ``venue``.  Escalated calls keep the
    # attempted scheme here — the ``escalate`` trace event carries the
    # rest of the story.
    precision: str = ""
    # the solver span this call ran inside ("<solver>#<seq>", e.g.
    # "getrf#0"); stamped only by runs driving the LAPACK solver tier
    # (repro.solvers), same byte-stability rule as ``venue``
    solver_id: str = ""

    # ------------------------------------------------------------------ #
    @property
    def prec_prefix(self) -> str:
        """The BLAS precision prefix of the routine (s/d/c/z) — distinct
        from ``precision``, the split-emulation scheme."""
        return self.routine[0]

    @property
    def flops(self) -> float:
        """Real-FLOP count (paper's convention for speedup accounting)."""
        mult = _COMPLEX[self.prec_prefix] * self.batch
        base = self.routine[1:]
        m, n, k = self.m, self.n, self.k
        if base == "gemm":
            return mult * 2.0 * m * n * k
        if base == "gemv":       # level-2 matrix-vector (intercepted)
            return mult * 2.0 * m * n
        if base in ("trsm", "trmm"):
            return mult * 1.0 * m * m * n  # side='L'; side='R' callers swap
        if base in ("syrk", "herk"):
            return mult * 1.0 * n * n * k
        if base in ("syr2k", "her2k"):
            return mult * 2.0 * n * n * k
        if base in ("symm", "hemm"):
            return mult * 2.0 * m * m * n
        if base == "getf2":       # unblocked panel LU (rank-1 updates)
            return mult * 1.0 * m * n * n
        raise ValueError(f"unknown routine {self.routine}")

    @property
    def bytes_touched(self) -> int:
        return self.batch * sum(nb for _, _, nb, _, _ in self.operands)

    @property
    def n_avg(self) -> float:
        """Routine-dependent mean dimension (paper §3.3)."""
        m, n, k = max(1, self.m), max(1, self.n), max(1, self.k)
        base = self.routine[1:]
        if base == "gemm":
            return float((m * n * k) ** (1.0 / 3.0))
        if base in ("trsm", "trmm", "symm", "hemm"):
            return float((m * m * n) ** (1.0 / 3.0))
        if base in ("syrk", "herk", "syr2k", "her2k"):
            return float((n * n * k) ** (1.0 / 3.0))
        return float((m * n * max(k, 1)) ** (1.0 / 3.0))

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        if not self.venue:           # keep default-off dumps byte-stable
            del d["venue"]
        if not self.precision:
            del d["precision"]
        if not self.solver_id:
            del d["solver_id"]
        return d

    @property
    def solver(self) -> str:
        """The solver name of the span this call ran inside ("" when
        the call was not part of a solver span)."""
        return self.solver_id.split("#", 1)[0] if self.solver_id else ""


class Trace:
    """Append-only BLAS trace with named buffer registry.

    ``events`` carries the residency history of the recording run —
    ``place``/``hit``/``evict``/``refetch`` transitions of the runtime's
    residency stores (:mod:`repro.core.residency`), each stamped with
    the call index it interleaves at.  A replay of the same trace under
    the same cap and eviction policy can therefore be checked
    count-for-count against what the live run actually did.  Fault
    tolerance reuses the same channel: ``fault``/``retry``/``fallback``/
    ``quarantine``/``recover`` events (:mod:`repro.core.faults`) record
    what actually went wrong and where the run degraded, so a faulted
    trace replays to the exact live fallback/retry counters.
    """

    def __init__(self) -> None:
        self.calls: List[BlasCall] = []
        self.buffer_sizes: Dict[int, int] = {}
        self.buffer_names: Dict[int, str] = {}
        self.events: List[ResidencyEvent] = []
        self._next_buf = 1
        # guards append paths only: a trace may be shared by several
        # threads adopting one session (Session.scope); readers iterate
        # snapshots after the run drains.
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ #
    def record_event(self, kind: str, store: str, nbytes: int,
                     session: str = "") -> None:
        """Append one residency transition, stamped at the current call
        position (the runtime's residency stores point here) and the
        owning session id (empty for single-tenant runs)."""
        with self._lock:
            self.events.append(ResidencyEvent(kind=kind, store=store,
                                              nbytes=int(nbytes),
                                              call_index=len(self.calls),
                                              session=session))

    def event_count(self, kind: str, session: Optional[str] = None) -> int:
        return sum(1 for e in self.events if e.kind == kind
                   and (session is None or e.session == session))

    def event_bytes(self, kind: str, session: Optional[str] = None) -> int:
        return sum(e.nbytes for e in self.events if e.kind == kind
                   and (session is None or e.session == session))

    # ------------------------------------------------------------------ #
    def new_buffer(self, nbytes: int, name: str = "") -> int:
        with self._lock:
            bid = self._next_buf
            self._next_buf += 1
            self.buffer_sizes[bid] = int(nbytes)
            self.buffer_names[bid] = name or f"buf{bid}"
            return bid

    def gemm(self, prec: str, m: int, n: int, k: int,
             a: int, b: int, c: int, batch: int = 1,
             site: str = "", solver: str = "") -> None:
        el = _ELEM[prec]
        self.calls.append(BlasCall(
            routine=f"{prec}gemm", m=m, n=n, k=k, batch=batch,
            operands=(
                ("A", a, m * k * el, float(n), False),
                ("B", b, k * n * el, float(m), False),
                ("C", c, m * n * el, 1.0, True),
            ), callsite_id=site, solver_id=solver))

    def trsm(self, prec: str, m: int, n: int,
             a: int, b: int, batch: int = 1, site: str = "",
             solver: str = "") -> None:
        el = _ELEM[prec]
        self.calls.append(BlasCall(
            routine=f"{prec}trsm", m=m, n=n, k=0, batch=batch,
            operands=(
                ("A", a, m * m * el, float(n), False),
                ("B", b, m * n * el, float(m), True),
            ), callsite_id=site, solver_id=solver))

    def syrk(self, prec: str, n: int, k: int,
             a: int, c: int, batch: int = 1, site: str = "") -> None:
        el = _ELEM[prec]
        self.calls.append(BlasCall(
            routine=f"{prec}syrk", m=n, n=n, k=k, batch=batch,
            operands=(
                ("A", a, n * k * el, float(n), False),
                ("C", c, n * n * el, 1.0, True),
            ), callsite_id=site))

    def panel(self, prec: str, m: int, nb: int, a: int,
              solver: str = "") -> None:
        """Unblocked LU panel factorization (getf2) — host-only work."""
        el = _ELEM[prec]
        self.calls.append(BlasCall(
            routine=f"{prec}getf2", m=m, n=nb, k=0,
            operands=(("P", a, m * nb * el, float(nb), True),),
            solver_id=solver))

    def symm(self, prec: str, m: int, n: int,
             a: int, b: int, c: int, batch: int = 1) -> None:
        el = _ELEM[prec]
        self.calls.append(BlasCall(
            routine=f"{prec}symm", m=m, n=n, k=0, batch=batch,
            operands=(
                ("A", a, m * m * el, float(n), False),
                ("B", b, m * n * el, float(m), False),
                ("C", c, m * n * el, 1.0, True),
            )))

    # ------------------------------------------------------------------ #
    def __iter__(self) -> Iterator[BlasCall]:
        return iter(self.calls)

    def __len__(self) -> int:
        return len(self.calls)

    @property
    def total_flops(self) -> float:
        return sum(c.flops for c in self.calls)

    def dump(self, path: str) -> None:
        """Write the trace atomically: serialize to a sibling temp file,
        fsync, then rename over ``path`` — a crash mid-dump can never
        leave a truncated trace where a valid one (or nothing) should
        be, and a reader racing the dump sees old-or-new, not garbage."""
        payload = {
            "buffers": {str(k): [v, self.buffer_names[k]]
                        for k, v in self.buffer_sizes.items()},
            "calls": [c.to_json() for c in self.calls],
        }
        if self.events:
            payload["events"] = [e.to_json() for e in self.events]
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(payload, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            raw = json.load(f)
        t = cls()
        for k, (size, name) in raw["buffers"].items():
            t.buffer_sizes[int(k)] = size
            t.buffer_names[int(k)] = name
            t._next_buf = max(t._next_buf, int(k) + 1)
        for c in raw["calls"]:
            c["operands"] = tuple(tuple(o) for o in c["operands"])
            if "devices" in c:
                c["devices"] = tuple(c["devices"])
            t.calls.append(BlasCall(**c))
        for e in raw.get("events", ()):
            t.events.append(ResidencyEvent(**e))
        return t

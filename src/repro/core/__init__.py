"""SCILIB-Accel core: the paper's contribution as a composable JAX module.

Public surface:

* :mod:`repro.core.blas` — level-3 BLAS routines (dlsym-mode API).
* :mod:`repro.core.intercept` — ``install``/``uninstall``/``offload``:
  automatic interception of ``jnp.dot/matmul/einsum`` (DBI-mode).
* :mod:`repro.core.lapack` — blocked LU/Cholesky drivers on that BLAS.
* :mod:`repro.core.runtime` — the placement runtime + statistics.
* :mod:`repro.core.policy` — Mem-Copy / counter / Device-First-Use /
  pinned / cpu data-movement policies.
"""
from repro.core import blas, lapack
from repro.core.intercept import install, offload, uninstall
from repro.core.policy import host_array
from repro.core.runtime import OffloadRuntime, active
from repro.core.trace import BlasCall, Trace

__all__ = ["blas", "lapack", "install", "offload", "uninstall",
           "OffloadRuntime", "active", "BlasCall", "Trace", "host_array"]

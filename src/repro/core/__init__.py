"""SCILIB-Accel core: the paper's contribution as a composable JAX module.

Public surface:

* :mod:`repro.core.blas` — level-3 BLAS routines (dlsym-mode API).
* :mod:`repro.core.intercept` — ``install``/``uninstall``/``offload``:
  automatic interception of ``jnp.dot/matmul/einsum`` (DBI-mode).
* :mod:`repro.core.lapack` — blocked LU/Cholesky drivers on that BLAS.
* :mod:`repro.core.runtime` — the placement runtime + statistics
  (async by default; ``SCILIB_SYNC=1`` or ``runtime.sync()`` to fence).
* :mod:`repro.core.policy` — Mem-Copy / counter / Device-First-Use /
  pinned / cpu data-movement policies.
* :mod:`repro.core.memspace` — portable logical HOST/DEVICE memory
  tiers mapped onto the backend's real memory kinds (simulated-tier
  fallback on single-kind backends).
* :mod:`repro.core.callsite` — per-call-site fingerprints and profiles
  (the paper's patched call sites; drives ``SCILIB_ADAPTIVE=1``).
* :mod:`repro.core.residency` — the residency engine: the one byte-
  capped, policy-evicting, pinnable block store behind the runtime's
  registries and the memtier simulator (``SCILIB_EVICT``,
  ``SCILIB_PIN``; :func:`pin`/:func:`unpin` pin live buffers).
* :mod:`repro.core.config` — :class:`OffloadConfig`: every knob as a
  typed, validated, serializable field; ``from_env()`` is the single
  ``SCILIB_*`` ingestion boundary.
* :mod:`repro.core.session` — :class:`Session`: a first-class offload
  stack (runtime + interceptors + trace) per workload; sessions nest,
  and ``install``/``uninstall``/``offload`` above are shims over an
  implicit default session.
* :mod:`repro.core.faults` — fault tolerance: the typed offload error
  hierarchy, the deterministic fault injector (``SCILIB_FAULTS``), the
  transient-fault retry policy (``SCILIB_RETRIES``/
  ``SCILIB_BACKOFF_MS``) and the per-device circuit breaker
  (``SCILIB_BREAKER``); exhausted faults fall back to the host path
  bit-identically.
"""
from repro.core import blas, callsite, faults, lapack, memspace, residency
from repro.core.config import OffloadConfig
from repro.core.intercept import install, offload, uninstall
from repro.core.policy import host_array
from repro.core.residency import ResidencyStore
from repro.core.runtime import OffloadRuntime, active, pin, unpin
# NOTE: the session() helper is NOT re-exported here — that name is the
# repro.core.session submodule; the helper lives at the top level as
# repro.session().
from repro.core.session import Session, active_session
from repro.core.trace import BlasCall, Trace

__all__ = ["blas", "callsite", "faults", "lapack", "memspace",
           "residency", "install", "offload", "uninstall",
           "OffloadRuntime", "active", "BlasCall", "Trace", "host_array",
           "ResidencyStore", "pin", "unpin", "OffloadConfig", "Session",
           "active_session"]

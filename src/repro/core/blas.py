"""Level-3 BLAS surface in JAX (paper §3: "all level-3 BLAS routines").

This is the dlsym-mode API: applications (or the interceptor) call these
functions directly; each routes through the active ``OffloadRuntime`` for
the offload decision, data placement and statistics, then executes
jit-compiled arithmetic. Real BLAS semantics are honoured: ``uplo``
triangles are the only parts of symmetric/triangular operands referenced,
``beta`` scaling, unit diagonals, side selection, and conjugate
transposes.

Precision prefix follows dtype: s/d/c/z for f32/f64/c64/c128 (bf16 maps
to the s-path on TPU). Leading batch dimensions select the batched
variants (cublas*Batched analogues) with the same placement logic.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import runtime as rt

__all__ = ["gemm", "symm", "hemm", "syrk", "herk", "syr2k", "her2k",
           "trmm", "trsm", "routine_name"]


def routine_name(base: str, dtype) -> str:
    dt = jnp.dtype(dtype)
    prefix = {"float32": "s", "float64": "d", "complex64": "c",
              "complex128": "z", "bfloat16": "s", "float16": "s"}.get(
                  dt.name, "s")
    return prefix + base


def _op(x: jax.Array, trans: str) -> jax.Array:
    if trans == "N":
        return x
    xt = jnp.swapaxes(x, -1, -2)
    return jnp.conj(xt) if trans == "C" else xt


def _tri_mask(n: int, uplo: str, dtype=bool) -> jax.Array:
    r = jnp.arange(n)
    mask = r[:, None] >= r[None, :] if uplo == "L" else r[:, None] <= r[None, :]
    return mask


def _tri_ref(a: jax.Array, uplo: str, diag: str = "N") -> jax.Array:
    """The triangle of A that BLAS actually references."""
    n = a.shape[-1]
    t = jnp.tril(a) if uplo == "L" else jnp.triu(a)
    if diag == "U":
        eye = jnp.eye(n, dtype=a.dtype)
        t = t - t * eye + eye  # force unit diagonal
    return t


def _sym_full(a: jax.Array, uplo: str, conj: bool = False) -> jax.Array:
    """Materialize the full symmetric/hermitian matrix from one triangle."""
    n = a.shape[-1]
    tri = jnp.tril(a, -1) if uplo == "L" else jnp.triu(a, 1)
    other = jnp.swapaxes(tri, -1, -2)
    diag = jnp.diagonal(a, axis1=-2, axis2=-1)
    if conj:
        other = jnp.conj(other)
        diag = jnp.real(diag).astype(a.dtype)  # hermitian diag is real
    dmat = jnp.eye(n, dtype=a.dtype) * diag[..., :, None]
    return tri + other + dmat


def _batch_of(*arrays) -> int:
    b = 1
    for a in arrays:
        if a is not None and a.ndim > 2:
            b = int(functools.reduce(lambda x, y: x * y, a.shape[:-2], 1))
    return b


# ----------------------------------------------------------------------- #
# jitted arithmetic (shape-cached by jax)                                  #
# ----------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("trans_a", "trans_b", "has_c"))
def _gemm_kernel(a, b, c, alpha, beta, *, trans_a, trans_b, has_c):
    from repro.kernels import ops as kops
    acc = kops.matmul(_op(a, trans_a), _op(b, trans_b))
    out = alpha.astype(acc.dtype) * acc
    if has_c:
        out = out + beta.astype(acc.dtype) * c
    return out.astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("side", "uplo", "conj", "has_c"))
def _symm_kernel(a, b, c, alpha, beta, *, side, uplo, conj, has_c):
    from repro.kernels import ops as kops
    full = _sym_full(a, uplo, conj=conj)
    acc = kops.matmul(full, b) if side == "L" else kops.matmul(b, full)
    out = alpha.astype(acc.dtype) * acc
    if has_c:
        out = out + beta.astype(acc.dtype) * c
    return out.astype(b.dtype)


@functools.partial(jax.jit,
                   static_argnames=("uplo", "trans", "conj", "has_c"))
def _syrk_kernel(a, c, alpha, beta, *, uplo, trans, conj, has_c):
    from repro.kernels import ops as kops
    opa = _op(a, trans)
    at = jnp.swapaxes(opa, -1, -2)
    if conj:
        at = jnp.conj(at)
    acc = kops.matmul(opa, at)
    upd = alpha.astype(acc.dtype) * acc
    n = upd.shape[-1]
    mask = _tri_mask(n, uplo)
    if has_c:
        tri = jnp.where(mask, upd + beta.astype(acc.dtype) * c, c)
    else:
        tri = jnp.where(mask, upd, jnp.zeros_like(upd))
    return tri.astype(a.dtype)


@functools.partial(jax.jit,
                   static_argnames=("uplo", "trans", "conj", "has_c"))
def _syr2k_kernel(a, b, c, alpha, beta, *, uplo, trans, conj, has_c):
    from repro.kernels import ops as kops
    opa, opb = _op(a, trans), _op(b, trans)
    bt, at = jnp.swapaxes(opb, -1, -2), jnp.swapaxes(opa, -1, -2)
    if conj:
        # her2k: C := alpha A B^H + conj(alpha) B A^H + beta C
        bt, at = jnp.conj(bt), jnp.conj(at)
        al = alpha.astype(opa.dtype)
        upd = al * kops.matmul(opa, bt) + jnp.conj(al) * kops.matmul(opb, at)
    else:
        acc = kops.matmul(opa, bt) + kops.matmul(opb, at)
        upd = alpha.astype(acc.dtype) * acc
    n = upd.shape[-1]
    mask = _tri_mask(n, uplo)
    if has_c:
        tri = jnp.where(mask, upd + beta.astype(acc.dtype) * c, c)
    else:
        tri = jnp.where(mask, upd, jnp.zeros_like(upd))
    return tri.astype(a.dtype)


@functools.partial(jax.jit,
                   static_argnames=("side", "uplo", "trans", "diag"))
def _trmm_kernel(a, b, alpha, *, side, uplo, trans, diag):
    from repro.kernels import ops as kops
    tri = _tri_ref(a, uplo, diag)
    tri = _op(tri, trans)
    out = kops.matmul(tri, b) if side == "L" else kops.matmul(b, tri)
    return (alpha.astype(out.dtype) * out).astype(b.dtype)


@functools.partial(jax.jit,
                   static_argnames=("side", "uplo", "trans", "diag"))
def _trsm_kernel(a, b, alpha, *, side, uplo, trans, diag):
    from repro.kernels import ops as kops
    rhs = alpha.astype(b.dtype) * b
    return kops.trsm(a, rhs, side=side, uplo=uplo, trans=trans,
                     diag=diag).astype(b.dtype)


# ----------------------------------------------------------------------- #
# public routines                                                          #
# ----------------------------------------------------------------------- #
def _dispatch(routine, m, n, k, operands, compute, batch=1):
    runtime = rt.active()
    if runtime is None:
        return compute(*[x for _, x, _, _ in operands])
    return runtime.blas_call(routine, m, n, k, operands, compute,
                             batch=batch)


def gemm(a: jax.Array, b: jax.Array, c: Optional[jax.Array] = None, *,
         alpha=1.0, beta=0.0, trans_a: str = "N",
         trans_b: str = "N") -> jax.Array:
    """C := alpha op(A) op(B) + beta C (the paper's headline routine)."""
    opm = a.shape[-2] if trans_a == "N" else a.shape[-1]
    opk = a.shape[-1] if trans_a == "N" else a.shape[-2]
    opn = b.shape[-1] if trans_b == "N" else b.shape[-2]
    batch = _batch_of(a, b, c)
    alpha_ = jnp.asarray(alpha, dtype=a.dtype)
    beta_ = jnp.asarray(beta, dtype=a.dtype)
    has_c = c is not None
    c_in = c if has_c else jnp.zeros((), dtype=a.dtype)

    def compute(a_, b_, c_=c_in):
        return _gemm_kernel(a_, b_, c_, alpha_, beta_, trans_a=trans_a,
                            trans_b=trans_b, has_c=has_c)

    ops = [("A", a, float(opn), False), ("B", b, float(opm), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))

        def compute(a_, b_, c_):
            return _gemm_kernel(a_, b_, c_, alpha_, beta_, trans_a=trans_a,
                                trans_b=trans_b, has_c=True)

    return _dispatch(routine_name("gemm", a.dtype), opm, opn, opk,
                     ops, compute, batch)


def symm(a, b, c=None, *, side="L", uplo="L", alpha=1.0, beta=0.0):
    """C := alpha A B + beta C with A symmetric (one triangle referenced)."""
    return _symm_like(a, b, c, side=side, uplo=uplo, alpha=alpha,
                      beta=beta, conj=False, base="symm")


def hemm(a, b, c=None, *, side="L", uplo="L", alpha=1.0, beta=0.0):
    return _symm_like(a, b, c, side=side, uplo=uplo, alpha=alpha,
                      beta=beta, conj=True, base="hemm")


def _symm_like(a, b, c, *, side, uplo, alpha, beta, conj, base):
    m, n = b.shape[-2], b.shape[-1]
    batch = _batch_of(a, b, c)
    alpha_ = jnp.asarray(alpha, dtype=b.dtype)
    beta_ = jnp.asarray(beta, dtype=b.dtype)
    has_c = c is not None
    ops = [("A", a, float(n if side == "L" else m), False),
           ("B", b, float(a.shape[-1]), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))

        def compute(a_, b_, c_):
            return _symm_kernel(a_, b_, c_, alpha_, beta_, side=side,
                                uplo=uplo, conj=conj, has_c=True)
    else:
        def compute(a_, b_):
            return _symm_kernel(a_, b_, jnp.zeros((), b.dtype), alpha_,
                                beta_, side=side, uplo=uplo, conj=conj,
                                has_c=False)

    return _dispatch(routine_name(base, b.dtype), a.shape[-1], n, 0,
                     ops, compute, batch)


def syrk(a, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    """C := alpha op(A) op(A)^T + beta C, triangle ``uplo`` only."""
    return _syrk_like(a, c, uplo=uplo, trans=trans, alpha=alpha, beta=beta,
                      conj=False, base="syrk")


def herk(a, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    return _syrk_like(a, c, uplo=uplo, trans=trans, alpha=alpha, beta=beta,
                      conj=True, base="herk")


def _syrk_like(a, c, *, uplo, trans, alpha, beta, conj, base):
    n = a.shape[-2] if trans == "N" else a.shape[-1]
    k = a.shape[-1] if trans == "N" else a.shape[-2]
    batch = _batch_of(a, c)
    alpha_ = jnp.asarray(alpha, dtype=a.dtype)
    beta_ = jnp.asarray(beta, dtype=a.dtype)
    has_c = c is not None
    ops = [("A", a, float(n), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))

        def compute(a_, c_):
            return _syrk_kernel(a_, c_, alpha_, beta_, uplo=uplo,
                                trans=trans, conj=conj, has_c=True)
    else:
        def compute(a_):
            return _syrk_kernel(a_, jnp.zeros((), a.dtype), alpha_, beta_,
                                uplo=uplo, trans=trans, conj=conj,
                                has_c=False)

    return _dispatch(routine_name(base, a.dtype), n, n, k, ops, compute,
                     batch)


def syr2k(a, b, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    return _syr2k_like(a, b, c, uplo=uplo, trans=trans, alpha=alpha,
                       beta=beta, conj=False, base="syr2k")


def her2k(a, b, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    return _syr2k_like(a, b, c, uplo=uplo, trans=trans, alpha=alpha,
                       beta=beta, conj=True, base="her2k")


def _syr2k_like(a, b, c, *, uplo, trans, alpha, beta, conj, base):
    n = a.shape[-2] if trans == "N" else a.shape[-1]
    k = a.shape[-1] if trans == "N" else a.shape[-2]
    batch = _batch_of(a, b, c)
    alpha_ = jnp.asarray(alpha, dtype=a.dtype)
    beta_ = jnp.asarray(beta, dtype=a.dtype)
    has_c = c is not None
    ops = [("A", a, float(n), False), ("B", b, float(n), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))

        def compute(a_, b_, c_):
            return _syr2k_kernel(a_, b_, c_, alpha_, beta_, uplo=uplo,
                                 trans=trans, conj=conj, has_c=True)
    else:
        def compute(a_, b_):
            return _syr2k_kernel(a_, b_, jnp.zeros((), a.dtype), alpha_,
                                 beta_, uplo=uplo, trans=trans, conj=conj,
                                 has_c=False)

    return _dispatch(routine_name(base, a.dtype), n, n, k, ops, compute,
                     batch)


def trmm(a, b, *, side="L", uplo="L", trans="N", diag="N", alpha=1.0):
    """B := alpha op(A) B (or B op(A)), A triangular."""
    m, n = b.shape[-2], b.shape[-1]
    batch = _batch_of(a, b)
    alpha_ = jnp.asarray(alpha, dtype=b.dtype)

    def compute(a_, b_):
        return _trmm_kernel(a_, b_, alpha_, side=side, uplo=uplo,
                            trans=trans, diag=diag)

    tri_n = a.shape[-1]
    ops = [("A", a, float(n if side == "L" else m), False),
           ("B", b, float(tri_n), True)]
    return _dispatch(routine_name("trmm", b.dtype), tri_n, n if side == "L"
                     else m, 0, ops, compute, batch)


def trsm(a, b, *, side="L", uplo="L", trans="N", diag="N", alpha=1.0):
    """Solve op(A) X = alpha B (or X op(A) = alpha B), A triangular."""
    m, n = b.shape[-2], b.shape[-1]
    batch = _batch_of(a, b)
    alpha_ = jnp.asarray(alpha, dtype=b.dtype)

    def compute(a_, b_):
        return _trsm_kernel(a_, b_, alpha_, side=side, uplo=uplo,
                            trans=trans, diag=diag)

    tri_n = a.shape[-1]
    ops = [("A", a, float(n if side == "L" else m), False),
           ("B", b, float(tri_n), True)]
    return _dispatch(routine_name("trsm", b.dtype), tri_n,
                     n if side == "L" else m, 0, ops, compute, batch)

"""Level-3 BLAS surface in JAX (paper §3: "all level-3 BLAS routines").

This is the dlsym-mode API: applications (or the interceptor) call these
functions directly; each routes through the active ``OffloadRuntime`` for
the offload decision, data placement and statistics, then executes
jit-compiled arithmetic. Real BLAS semantics are honoured: ``uplo``
triangles are the only parts of symmetric/triangular operands referenced,
``beta`` scaling, unit diagonals, side selection, and conjugate
transposes.

Precision prefix follows dtype: s/d/c/z for f32/f64/c64/c128 (bf16 maps
to the s-path on TPU). Leading batch dimensions select the batched
variants (cublas*Batched analogues) with the same placement logic.
"""
from __future__ import annotations

import functools
import os
from typing import Callable, Dict, Hashable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import runtime as rt

__all__ = ["gemm", "symm", "hemm", "syrk", "herk", "syr2k", "her2k",
           "trmm", "trsm", "routine_name"]

_RNAMES: Dict[Tuple[str, str], str] = {}


def routine_name(base: str, dtype) -> str:
    dt = jnp.dtype(dtype)
    name = _RNAMES.get((base, dt.name))
    if name is None:
        prefix = {"float32": "s", "float64": "d", "complex64": "c",
                  "complex128": "z", "bfloat16": "s", "float16": "s"}.get(
                      dt.name, "s")
        name = _RNAMES[(base, dt.name)] = prefix + base
    return name


# ----------------------------------------------------------------------- #
# dispatch fast path: memoized device scalars and bound kernels            #
#                                                                          #
# The seed runtime re-created ``jnp.asarray(alpha)`` device scalars and a  #
# fresh compute closure on *every* call (~50us per scalar on this          #
# container — dwarfing the 64^3 gemm it wraps).  Steady-state BLAS calls   #
# hit these tables instead and re-derive nothing; ``SCILIB_DISPATCH_CACHE  #
# =0`` restores the per-call re-derivation for A/B benchmarking.           #
# ----------------------------------------------------------------------- #
_CACHE_ON = os.environ.get("SCILIB_DISPATCH_CACHE", "1") != "0"
_SCALARS: Dict[Tuple, jax.Array] = {}
_BOUND: Dict[Hashable, Callable] = {}
_CACHE_LIMIT = 4096


def refresh_cache_flag() -> None:
    """Re-read SCILIB_DISPATCH_CACHE (called from runtime.install)."""
    global _CACHE_ON
    _CACHE_ON = os.environ.get("SCILIB_DISPATCH_CACHE", "1") != "0"


def clear_caches() -> None:
    _SCALARS.clear()
    _BOUND.clear()


def _hashable(v):
    """Scalar cache key for alpha/beta, or None if uncacheable (arrays)."""
    if isinstance(v, (bool, int, float, complex)):
        return v
    return None


def _scalar(v, dtype) -> jax.Array:
    """Device scalar for alpha/beta, memoized by (value, dtype)."""
    key = _hashable(v)
    if not _CACHE_ON or key is None:
        return jnp.asarray(v, dtype=dtype)
    full = (key, jnp.dtype(dtype).name)
    arr = _SCALARS.get(full)
    if arr is None:
        if len(_SCALARS) > _CACHE_LIMIT:
            _SCALARS.clear()
        arr = _SCALARS[full] = jnp.asarray(v, dtype=dtype)
    return arr


def _bound(key: Optional[Hashable], factory: Callable[[], Callable]):
    """Memoize the bound compute closure for one call-site signature."""
    if not _CACHE_ON or key is None:
        return factory()
    fn = _BOUND.get(key)
    if fn is None:
        if len(_BOUND) > _CACHE_LIMIT:
            _BOUND.clear()
        fn = _BOUND[key] = factory()
    return fn


def _call_key(bkey: Optional[Hashable], m: int, n: int, k: int,
              batch: int) -> Optional[Hashable]:
    """Dispatch-cache key: (routine, flags, dtype, alpha, beta) + shape."""
    if bkey is None:
        return None
    return (bkey, m, n, k, batch)


def _op(x: jax.Array, trans: str) -> jax.Array:
    if trans == "N":
        return x
    xt = jnp.swapaxes(x, -1, -2)
    return jnp.conj(xt) if trans == "C" else xt


def _tri_mask(n: int, uplo: str, dtype=bool) -> jax.Array:
    r = jnp.arange(n)
    mask = r[:, None] >= r[None, :] if uplo == "L" else r[:, None] <= r[None, :]
    return mask


def _tri_ref(a: jax.Array, uplo: str, diag: str = "N") -> jax.Array:
    """The triangle of A that BLAS actually references."""
    n = a.shape[-1]
    t = jnp.tril(a) if uplo == "L" else jnp.triu(a)
    if diag == "U":
        eye = jnp.eye(n, dtype=a.dtype)
        t = t - t * eye + eye  # force unit diagonal
    return t


def _sym_full(a: jax.Array, uplo: str, conj: bool = False) -> jax.Array:
    """Materialize the full symmetric/hermitian matrix from one triangle."""
    n = a.shape[-1]
    tri = jnp.tril(a, -1) if uplo == "L" else jnp.triu(a, 1)
    other = jnp.swapaxes(tri, -1, -2)
    diag = jnp.diagonal(a, axis1=-2, axis2=-1)
    if conj:
        other = jnp.conj(other)
        diag = jnp.real(diag).astype(a.dtype)  # hermitian diag is real
    dmat = jnp.eye(n, dtype=a.dtype) * diag[..., :, None]
    return tri + other + dmat


def _batch_of(*arrays) -> int:
    b = 1
    for a in arrays:
        if a is not None and a.ndim > 2:
            b = int(functools.reduce(lambda x, y: x * y, a.shape[:-2], 1))
    return b


# ----------------------------------------------------------------------- #
# jitted arithmetic (shape-cached by jax)                                  #
# ----------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("trans_a", "trans_b", "has_c"))
def _gemm_kernel(a, b, c, alpha, beta, *, trans_a, trans_b, has_c):
    from repro.kernels import ops as kops
    acc = kops.matmul(_op(a, trans_a), _op(b, trans_b))
    out = alpha.astype(acc.dtype) * acc
    if has_c:
        out = out + beta.astype(acc.dtype) * c
    return out.astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("side", "uplo", "conj", "has_c"))
def _symm_kernel(a, b, c, alpha, beta, *, side, uplo, conj, has_c):
    from repro.kernels import ops as kops
    full = _sym_full(a, uplo, conj=conj)
    acc = kops.matmul(full, b) if side == "L" else kops.matmul(b, full)
    out = alpha.astype(acc.dtype) * acc
    if has_c:
        out = out + beta.astype(acc.dtype) * c
    return out.astype(b.dtype)


@functools.partial(jax.jit,
                   static_argnames=("uplo", "trans", "conj", "has_c"))
def _syrk_kernel(a, c, alpha, beta, *, uplo, trans, conj, has_c):
    from repro.kernels import ops as kops
    opa = _op(a, trans)
    at = jnp.swapaxes(opa, -1, -2)
    if conj:
        at = jnp.conj(at)
    acc = kops.matmul(opa, at)
    upd = alpha.astype(acc.dtype) * acc
    n = upd.shape[-1]
    mask = _tri_mask(n, uplo)
    if has_c:
        tri = jnp.where(mask, upd + beta.astype(acc.dtype) * c, c)
    else:
        tri = jnp.where(mask, upd, jnp.zeros_like(upd))
    return tri.astype(a.dtype)


@functools.partial(jax.jit,
                   static_argnames=("uplo", "trans", "conj", "has_c"))
def _syr2k_kernel(a, b, c, alpha, beta, *, uplo, trans, conj, has_c):
    from repro.kernels import ops as kops
    opa, opb = _op(a, trans), _op(b, trans)
    bt, at = jnp.swapaxes(opb, -1, -2), jnp.swapaxes(opa, -1, -2)
    if conj:
        # her2k: C := alpha A B^H + conj(alpha) B A^H + beta C
        bt, at = jnp.conj(bt), jnp.conj(at)
        al = alpha.astype(opa.dtype)
        upd = al * kops.matmul(opa, bt) + jnp.conj(al) * kops.matmul(opb, at)
    else:
        acc = kops.matmul(opa, bt) + kops.matmul(opb, at)
        upd = alpha.astype(acc.dtype) * acc
    n = upd.shape[-1]
    mask = _tri_mask(n, uplo)
    if has_c:
        tri = jnp.where(mask, upd + beta.astype(acc.dtype) * c, c)
    else:
        tri = jnp.where(mask, upd, jnp.zeros_like(upd))
    return tri.astype(a.dtype)


@functools.partial(jax.jit,
                   static_argnames=("side", "uplo", "trans", "diag"))
def _trmm_kernel(a, b, alpha, *, side, uplo, trans, diag):
    from repro.kernels import ops as kops
    tri = _tri_ref(a, uplo, diag)
    tri = _op(tri, trans)
    out = kops.matmul(tri, b) if side == "L" else kops.matmul(b, tri)
    return (alpha.astype(out.dtype) * out).astype(b.dtype)


@functools.partial(jax.jit,
                   static_argnames=("side", "uplo", "trans", "diag"))
def _trsm_kernel(a, b, alpha, *, side, uplo, trans, diag):
    from repro.kernels import ops as kops
    rhs = alpha.astype(b.dtype) * b
    return kops.trsm(a, rhs, side=side, uplo=uplo, trans=trans,
                     diag=diag).astype(b.dtype)


# ----------------------------------------------------------------------- #
# public routines                                                          #
# ----------------------------------------------------------------------- #
def _dispatch(routine, m, n, k, operands, compute, batch=1, key=None):
    runtime = rt.active()
    if runtime is None:
        return compute(*[x for _, x, _, _ in operands])
    return runtime.blas_call(routine, m, n, k, operands, compute,
                             batch=batch, key=key)


def gemm(a: jax.Array, b: jax.Array, c: Optional[jax.Array] = None, *,
         alpha=1.0, beta=0.0, trans_a: str = "N",
         trans_b: str = "N") -> jax.Array:
    """C := alpha op(A) op(B) + beta C (the paper's headline routine)."""
    opm = a.shape[-2] if trans_a == "N" else a.shape[-1]
    opk = a.shape[-1] if trans_a == "N" else a.shape[-2]
    opn = b.shape[-1] if trans_b == "N" else b.shape[-2]
    batch = _batch_of(a, b, c)
    dt = a.dtype
    has_c = c is not None
    av, bv = _hashable(alpha), _hashable(beta)
    bkey = (("gemm", dt.name, trans_a, trans_b, has_c, av, bv)
            if av is not None and bv is not None else None)

    def factory():
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def compute(a_, b_, c_):
                return _gemm_kernel(a_, b_, c_, alpha_, beta_,
                                    trans_a=trans_a, trans_b=trans_b,
                                    has_c=True)
        else:
            c0 = _scalar(0.0, dt)

            def compute(a_, b_):
                return _gemm_kernel(a_, b_, c0, alpha_, beta_,
                                    trans_a=trans_a, trans_b=trans_b,
                                    has_c=False)
        return compute

    compute = _bound(bkey, factory)
    ops = [("A", a, float(opn), False), ("B", b, float(opm), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))
    return _dispatch(routine_name("gemm", dt), opm, opn, opk,
                     ops, compute, batch,
                     key=_call_key(bkey, opm, opn, opk, batch))


def symm(a, b, c=None, *, side="L", uplo="L", alpha=1.0, beta=0.0):
    """C := alpha A B + beta C with A symmetric (one triangle referenced)."""
    return _symm_like(a, b, c, side=side, uplo=uplo, alpha=alpha,
                      beta=beta, conj=False, base="symm")


def hemm(a, b, c=None, *, side="L", uplo="L", alpha=1.0, beta=0.0):
    return _symm_like(a, b, c, side=side, uplo=uplo, alpha=alpha,
                      beta=beta, conj=True, base="hemm")


def _symm_like(a, b, c, *, side, uplo, alpha, beta, conj, base):
    m, n = b.shape[-2], b.shape[-1]
    batch = _batch_of(a, b, c)
    dt = b.dtype
    has_c = c is not None
    av, bv = _hashable(alpha), _hashable(beta)
    bkey = ((base, dt.name, side, uplo, has_c, av, bv)
            if av is not None and bv is not None else None)

    def factory():
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def compute(a_, b_, c_):
                return _symm_kernel(a_, b_, c_, alpha_, beta_, side=side,
                                    uplo=uplo, conj=conj, has_c=True)
        else:
            c0 = _scalar(0.0, dt)

            def compute(a_, b_):
                return _symm_kernel(a_, b_, c0, alpha_, beta_, side=side,
                                    uplo=uplo, conj=conj, has_c=False)
        return compute

    compute = _bound(bkey, factory)
    ops = [("A", a, float(n if side == "L" else m), False),
           ("B", b, float(a.shape[-1]), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))
    return _dispatch(routine_name(base, dt), a.shape[-1], n, 0,
                     ops, compute, batch,
                     key=_call_key(bkey, a.shape[-1], n, 0, batch))


def syrk(a, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    """C := alpha op(A) op(A)^T + beta C, triangle ``uplo`` only."""
    return _syrk_like(a, c, uplo=uplo, trans=trans, alpha=alpha, beta=beta,
                      conj=False, base="syrk")


def herk(a, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    return _syrk_like(a, c, uplo=uplo, trans=trans, alpha=alpha, beta=beta,
                      conj=True, base="herk")


def _syrk_like(a, c, *, uplo, trans, alpha, beta, conj, base):
    n = a.shape[-2] if trans == "N" else a.shape[-1]
    k = a.shape[-1] if trans == "N" else a.shape[-2]
    batch = _batch_of(a, c)
    dt = a.dtype
    has_c = c is not None
    av, bv = _hashable(alpha), _hashable(beta)
    bkey = ((base, dt.name, uplo, trans, has_c, av, bv)
            if av is not None and bv is not None else None)

    def factory():
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def compute(a_, c_):
                return _syrk_kernel(a_, c_, alpha_, beta_, uplo=uplo,
                                    trans=trans, conj=conj, has_c=True)
        else:
            c0 = _scalar(0.0, dt)

            def compute(a_):
                return _syrk_kernel(a_, c0, alpha_, beta_, uplo=uplo,
                                    trans=trans, conj=conj, has_c=False)
        return compute

    compute = _bound(bkey, factory)
    ops = [("A", a, float(n), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))
    return _dispatch(routine_name(base, dt), n, n, k, ops, compute,
                     batch, key=_call_key(bkey, n, n, k, batch))


def syr2k(a, b, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    return _syr2k_like(a, b, c, uplo=uplo, trans=trans, alpha=alpha,
                       beta=beta, conj=False, base="syr2k")


def her2k(a, b, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    return _syr2k_like(a, b, c, uplo=uplo, trans=trans, alpha=alpha,
                       beta=beta, conj=True, base="her2k")


def _syr2k_like(a, b, c, *, uplo, trans, alpha, beta, conj, base):
    n = a.shape[-2] if trans == "N" else a.shape[-1]
    k = a.shape[-1] if trans == "N" else a.shape[-2]
    batch = _batch_of(a, b, c)
    dt = a.dtype
    has_c = c is not None
    av, bv = _hashable(alpha), _hashable(beta)
    bkey = ((base, dt.name, uplo, trans, has_c, av, bv)
            if av is not None and bv is not None else None)

    def factory():
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def compute(a_, b_, c_):
                return _syr2k_kernel(a_, b_, c_, alpha_, beta_, uplo=uplo,
                                     trans=trans, conj=conj, has_c=True)
        else:
            c0 = _scalar(0.0, dt)

            def compute(a_, b_):
                return _syr2k_kernel(a_, b_, c0, alpha_, beta_, uplo=uplo,
                                     trans=trans, conj=conj, has_c=False)
        return compute

    compute = _bound(bkey, factory)
    ops = [("A", a, float(n), False), ("B", b, float(n), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))
    return _dispatch(routine_name(base, dt), n, n, k, ops, compute,
                     batch, key=_call_key(bkey, n, n, k, batch))


def trmm(a, b, *, side="L", uplo="L", trans="N", diag="N", alpha=1.0):
    """B := alpha op(A) B (or B op(A)), A triangular."""
    return _tri_like(a, b, side=side, uplo=uplo, trans=trans, diag=diag,
                     alpha=alpha, base="trmm", kernel=_trmm_kernel)


def trsm(a, b, *, side="L", uplo="L", trans="N", diag="N", alpha=1.0):
    """Solve op(A) X = alpha B (or X op(A) = alpha B), A triangular."""
    return _tri_like(a, b, side=side, uplo=uplo, trans=trans, diag=diag,
                     alpha=alpha, base="trsm", kernel=_trsm_kernel)


def _tri_like(a, b, *, side, uplo, trans, diag, alpha, base, kernel):
    m, n = b.shape[-2], b.shape[-1]
    batch = _batch_of(a, b)
    dt = b.dtype
    av = _hashable(alpha)
    bkey = ((base, dt.name, side, uplo, trans, diag, av)
            if av is not None else None)

    def factory():
        alpha_ = _scalar(alpha, dt)

        def compute(a_, b_):
            return kernel(a_, b_, alpha_, side=side, uplo=uplo,
                          trans=trans, diag=diag)
        return compute

    compute = _bound(bkey, factory)
    tri_n = a.shape[-1]
    opn = n if side == "L" else m
    ops = [("A", a, float(opn), False),
           ("B", b, float(tri_n), True)]
    return _dispatch(routine_name(base, dt), tri_n, opn, 0, ops, compute,
                     batch, key=_call_key(bkey, tri_n, opn, 0, batch))

"""Level-3 BLAS surface in JAX (paper §3: "all level-3 BLAS routines").

This is the dlsym-mode API: applications (or the interceptor) call these
functions directly; each routes through the active ``OffloadRuntime`` for
the offload decision, data placement and statistics, then executes
jit-compiled arithmetic. Real BLAS semantics are honoured: ``uplo``
triangles are the only parts of symmetric/triangular operands referenced,
``beta`` scaling, unit diagonals, side selection, and conjugate
transposes.

Precision prefix follows dtype: s/d/c/z for f32/f64/c64/c128 (bf16 maps
to the s-path on TPU). Leading batch dimensions select the batched
variants (cublas*Batched analogues) with the same placement logic.

Failure semantics: every call returns a correct result or raises.  A
transfer or kernel failure on the offload path is retried
(``SCILIB_RETRIES``) and, on exhaustion, the call re-executes on the
host path with the same operand values — bit-identical output, surfaced
as a ``fallback:<kind>`` decision and a trace event rather than a user
exception (:mod:`repro.core.faults`).  Only genuine bugs (type errors,
shape errors) propagate to the caller.
"""
from __future__ import annotations

import functools
import math
import operator
from typing import Callable, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import runtime as rt
from repro.core.runtime import Tile, TileOp, TilePlan

__all__ = ["gemm", "symm", "hemm", "syrk", "herk", "syr2k", "her2k",
           "trmm", "trsm", "routine_name", "tensordot_flags"]

_RNAMES: Dict[Tuple[str, str], str] = {}


def routine_name(base: str, dtype) -> str:
    dt = jnp.dtype(dtype)
    name = _RNAMES.get((base, dt.name))
    if name is None:
        prefix = {"float32": "s", "float64": "d", "complex64": "c",
                  "complex128": "z", "bfloat16": "s", "float16": "s"}.get(
                      dt.name, "s")
        name = _RNAMES[(base, dt.name)] = prefix + base
    return name


# ----------------------------------------------------------------------- #
# dispatch fast path: memoized device scalars and bound kernels            #
#                                                                          #
# The seed runtime re-created ``jnp.asarray(alpha)`` device scalars and a  #
# fresh compute closure on *every* call (~50us per scalar on this          #
# container — dwarfing the 64^3 gemm it wraps).  Steady-state BLAS calls   #
# hit these tables instead and re-derive nothing; ``SCILIB_DISPATCH_CACHE  #
# =0`` restores the per-call re-derivation for A/B benchmarking.           #
# ----------------------------------------------------------------------- #
_CACHE_ON = True        # re-resolved at import (module bottom) and on
_SCALARS: Dict[Tuple, jax.Array] = {}   # every runtime construction
_BOUND: Dict[Hashable, Callable] = {}
_CACHE_LIMIT = 4096

# Context-local override of the cache flag (PR 7): concurrent sessions
# with different ``dispatch_cache`` settings each see their own flag.
# ``_CACHE_ON`` stays as the process-wide default/mirror — it is what
# sessionless threads fall back to, and what single-threaded callers
# introspect (the config tests assert on it directly).  The memo tables
# themselves stay shared: entries are pure functions of their keys, the
# dict writes are GIL-atomic, and a racing over-limit clear only costs
# a re-derivation.
from contextvars import ContextVar
_CACHE_VAR: ContextVar[Optional[bool]] = (
    ContextVar("scilib_dispatch_cache", default=None))


def refresh_cache_flag(enabled: Optional[bool] = None) -> None:
    """Sync the cache flag with the owning config's ``dispatch_cache``
    field (called from runtime construction / reconfigure).  With no
    argument, re-resolves through the config env boundary — the
    dlsym-mode path with no runtime installed.  Sets both the
    context-local flag (this session's threads) and the process mirror
    (sessionless fallback)."""
    global _CACHE_ON
    if enabled is None:
        from repro.core.config import OffloadConfig
        enabled = OffloadConfig.from_env().dispatch_cache
    _CACHE_VAR.set(bool(enabled))
    _CACHE_ON = bool(enabled)


def _cache_enabled() -> bool:
    v = _CACHE_VAR.get()
    return _CACHE_ON if v is None else v


def clear_caches() -> None:
    _SCALARS.clear()
    _BOUND.clear()


def _hashable(v):
    """Scalar cache key for alpha/beta, or None if uncacheable (arrays)."""
    if isinstance(v, (bool, int, float, complex)):
        return v
    return None


def _scalar(v, dtype) -> jax.Array:
    """Device scalar for alpha/beta, memoized by (value, dtype)."""
    key = _hashable(v)
    if not _cache_enabled() or key is None:
        return jnp.asarray(v, dtype=dtype)
    full = (key, jnp.dtype(dtype).name)
    arr = _SCALARS.get(full)
    if arr is None:
        if len(_SCALARS) > _CACHE_LIMIT:
            _SCALARS.clear()
        arr = _SCALARS[full] = jnp.asarray(v, dtype=dtype)
    return arr


def _bound(key: Optional[Hashable], factory: Callable[[], Callable]):
    """Memoize the bound compute closure for one call-site signature."""
    if not _cache_enabled() or key is None:
        return factory()
    fn = _BOUND.get(key)
    if fn is None:
        if len(_BOUND) > _CACHE_LIMIT:
            _BOUND.clear()
        fn = _BOUND[key] = factory()
    return fn


def _call_key(bkey: Optional[Hashable], m: int, n: int, k: int,
              batch: int) -> Optional[Hashable]:
    """Dispatch-cache key: (routine, flags, dtype, alpha, beta) + shape."""
    if bkey is None:
        return None
    return (bkey, m, n, k, batch)


def tensordot_flags(axes) -> Optional[Tuple[str, str]]:
    """Canonicalize a 2-D ``tensordot`` axes spec into gemm transpose
    flags, or None when the contraction is not gemm-shaped.

    For two matrices, a single contracted axis per operand is exactly a
    (possibly transposed) gemm — tensordot orders the output as (free
    axes of a, free axes of b), which is gemm's ``ik`` layout:

    ========================  ==========
    axes                      (ta, tb)
    ========================  ==========
    ``1`` / ``(1, 0)``        ``N, N``
    ``(0, 0)``                ``T, N``
    ``(1, 1)``                ``N, T``
    ``(0, 1)``                ``T, T``
    ========================  ==========

    ``axes=2`` (full double contraction -> scalar) and anything
    higher-rank are not level-3 calls and return None.
    """
    if isinstance(axes, int):
        if axes != 1:
            return None
        ax_a, ax_b = 1, 0              # a's last axis against b's first
    else:
        try:
            ax_a, ax_b = axes
        except (TypeError, ValueError):
            return None
        ax_a, ax_b = _single_axis(ax_a), _single_axis(ax_b)
        if ax_a is None or ax_b is None:
            return None
    ta = "N" if ax_a == 1 else "T"
    tb = "N" if ax_b == 0 else "T"
    return ta, tb


def _single_axis(ax) -> Optional[int]:
    """One matrix axis as a plain 0/1 int, or None.  Accepts ints,
    integer-likes (numpy scalars), and single-axis sequences."""
    if not isinstance(ax, int):
        try:                           # single-element sequence?
            if len(ax) != 1:
                return None
            ax = ax[0]
        except TypeError:
            pass                       # scalar-like: fall through
    try:
        ax = operator.index(ax)        # numpy integers included
    except TypeError:
        return None
    if ax not in (-2, -1, 0, 1):
        return None                    # out of range for a matrix
    return ax % 2                      # accept negative axes


def _op(x: jax.Array, trans: str) -> jax.Array:
    if trans == "N":
        return x
    xt = jnp.swapaxes(x, -1, -2)
    return jnp.conj(xt) if trans == "C" else xt


def _tri_mask(n: int, uplo: str, dtype=bool) -> jax.Array:
    r = jnp.arange(n)
    mask = r[:, None] >= r[None, :] if uplo == "L" else r[:, None] <= r[None, :]
    return mask


def _tri_ref(a: jax.Array, uplo: str, diag: str = "N") -> jax.Array:
    """The triangle of A that BLAS actually references."""
    n = a.shape[-1]
    t = jnp.tril(a) if uplo == "L" else jnp.triu(a)
    if diag == "U":
        eye = jnp.eye(n, dtype=a.dtype)
        t = t - t * eye + eye  # force unit diagonal
    return t


def _sym_full(a: jax.Array, uplo: str, conj: bool = False) -> jax.Array:
    """Materialize the full symmetric/hermitian matrix from one triangle."""
    n = a.shape[-1]
    tri = jnp.tril(a, -1) if uplo == "L" else jnp.triu(a, 1)
    other = jnp.swapaxes(tri, -1, -2)
    diag = jnp.diagonal(a, axis1=-2, axis2=-1)
    if conj:
        other = jnp.conj(other)
        diag = jnp.real(diag).astype(a.dtype)  # hermitian diag is real
    dmat = jnp.eye(n, dtype=a.dtype) * diag[..., :, None]
    return tri + other + dmat


def _batch_of(*arrays) -> int:
    b = 1
    for a in arrays:
        if a is not None and a.ndim > 2:
            b = int(functools.reduce(lambda x, y: x * y, a.shape[:-2], 1))
    return b


# ----------------------------------------------------------------------- #
# jitted arithmetic (shape-cached by jax)                                  #
# ----------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("trans_a", "trans_b", "has_c"))
def _gemm_kernel(a, b, c, alpha, beta, *, trans_a, trans_b, has_c):
    from repro.kernels import ops as kops
    acc = kops.matmul(_op(a, trans_a), _op(b, trans_b))
    out = alpha.astype(acc.dtype) * acc
    if has_c:
        out = out + beta.astype(acc.dtype) * c
    return out.astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("side", "uplo", "conj", "has_c"))
def _symm_kernel(a, b, c, alpha, beta, *, side, uplo, conj, has_c):
    from repro.kernels import ops as kops
    full = _sym_full(a, uplo, conj=conj)
    acc = kops.matmul(full, b) if side == "L" else kops.matmul(b, full)
    out = alpha.astype(acc.dtype) * acc
    if has_c:
        out = out + beta.astype(acc.dtype) * c
    return out.astype(b.dtype)


@functools.partial(jax.jit,
                   static_argnames=("uplo", "trans", "conj", "has_c"))
def _syrk_kernel(a, c, alpha, beta, *, uplo, trans, conj, has_c):
    from repro.kernels import ops as kops
    opa = _op(a, trans)
    at = jnp.swapaxes(opa, -1, -2)
    if conj:
        at = jnp.conj(at)
    acc = kops.matmul(opa, at)
    upd = alpha.astype(acc.dtype) * acc
    n = upd.shape[-1]
    mask = _tri_mask(n, uplo)
    if has_c:
        tri = jnp.where(mask, upd + beta.astype(acc.dtype) * c, c)
    else:
        tri = jnp.where(mask, upd, jnp.zeros_like(upd))
    return tri.astype(a.dtype)


@functools.partial(jax.jit,
                   static_argnames=("uplo", "trans", "conj", "has_c"))
def _syr2k_kernel(a, b, c, alpha, beta, *, uplo, trans, conj, has_c):
    from repro.kernels import ops as kops
    opa, opb = _op(a, trans), _op(b, trans)
    bt, at = jnp.swapaxes(opb, -1, -2), jnp.swapaxes(opa, -1, -2)
    if conj:
        # her2k: C := alpha A B^H + conj(alpha) B A^H + beta C
        bt, at = jnp.conj(bt), jnp.conj(at)
        al = alpha.astype(opa.dtype)
        upd = al * kops.matmul(opa, bt) + jnp.conj(al) * kops.matmul(opb, at)
    else:
        acc = kops.matmul(opa, bt) + kops.matmul(opb, at)
        upd = alpha.astype(acc.dtype) * acc
    n = upd.shape[-1]
    mask = _tri_mask(n, uplo)
    if has_c:
        # upd.dtype, not acc.dtype: the her2k branch above never binds
        # acc, and referencing it crashed every her2k call with a C
        tri = jnp.where(mask, upd + beta.astype(upd.dtype) * c, c)
    else:
        tri = jnp.where(mask, upd, jnp.zeros_like(upd))
    return tri.astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("trans", "conj", "has_c"))
def _syrk_block_kernel(ai, aj, c, alpha, beta, *, trans, conj, has_c):
    """Off-diagonal block of a tiled syrk/herk:
    C[i,j] := alpha op(A)_i op(A)_j^{T|H} + beta C[i,j] (full block)."""
    from repro.kernels import ops as kops
    opi, opj = _op(ai, trans), _op(aj, trans)
    jt = jnp.swapaxes(opj, -1, -2)
    if conj:
        jt = jnp.conj(jt)
    acc = kops.matmul(opi, jt)
    out = alpha.astype(acc.dtype) * acc
    if has_c:
        out = out + beta.astype(acc.dtype) * c
    return out.astype(ai.dtype)


@functools.partial(jax.jit, static_argnames=("trans", "conj", "has_c"))
def _syr2k_block_kernel(ai, bi, aj, bj, c, alpha, beta, *, trans, conj,
                        has_c):
    """Off-diagonal block of a tiled syr2k/her2k — the two-term rank-2k
    analogue of :func:`_syrk_block_kernel`:

    C[i,j] := alpha op(A)_i op(B)_j^T + alpha op(B)_i op(A)_j^T + beta C
    (her2k conjugate-transposes and uses conj(alpha) on the second term).
    """
    from repro.kernels import ops as kops
    opai, opbi = _op(ai, trans), _op(bi, trans)
    bjt = jnp.swapaxes(_op(bj, trans), -1, -2)
    ajt = jnp.swapaxes(_op(aj, trans), -1, -2)
    if conj:
        bjt, ajt = jnp.conj(bjt), jnp.conj(ajt)
        al = alpha.astype(opai.dtype)
        acc = (al * kops.matmul(opai, bjt)
               + jnp.conj(al) * kops.matmul(opbi, ajt))
    else:
        acc = kops.matmul(opai, bjt) + kops.matmul(opbi, ajt)
        acc = alpha.astype(acc.dtype) * acc
    out = acc
    if has_c:
        out = out + beta.astype(acc.dtype) * c
    return out.astype(ai.dtype)


@functools.partial(jax.jit,
                   static_argnames=("side", "uplo", "trans", "diag"))
def _trmm_kernel(a, b, alpha, *, side, uplo, trans, diag):
    from repro.kernels import ops as kops
    tri = _tri_ref(a, uplo, diag)
    tri = _op(tri, trans)
    out = kops.matmul(tri, b) if side == "L" else kops.matmul(b, tri)
    return (alpha.astype(out.dtype) * out).astype(b.dtype)


@functools.partial(jax.jit,
                   static_argnames=("side", "uplo", "trans", "diag"))
def _trsm_kernel(a, b, alpha, *, side, uplo, trans, diag):
    from repro.kernels import ops as kops
    rhs = alpha.astype(b.dtype) * b
    return kops.trsm(a, rhs, side=side, uplo=uplo, trans=trans,
                     diag=diag).astype(b.dtype)


# ----------------------------------------------------------------------- #
# pallas-venue arithmetic (OffloadConfig.kernel_path / SCILIB_KERNELS)     #
#                                                                          #
# Mirrors of the jitted kernels above that route the inner product         #
# through the hand-written kernels (``kops.kernel_*``) instead of the      #
# generic XLA formulation.  These closures are built only when the kernel  #
# path is on and the routine has a kernel (``kops.kernel_available``), so  #
# default-off runs never trace — or even import — any of this.  The        #
# ``_*_klean`` variants serve the dominant alpha=1 / beta=0 / no-C call    #
# shape with no scalar epilogue at all: fewer jit arguments and no         #
# multiply, which is the venue's measurable edge on backends where         #
# ``kernel_*`` itself degrades to the same XLA matmul.                     #
# ----------------------------------------------------------------------- #
_KOPS = None


def _kops():
    """repro.kernels.ops, imported on first kernel-path use only (the
    default pipeline keeps its import graph unchanged)."""
    global _KOPS
    if _KOPS is None:
        from repro.kernels import ops
        _KOPS = ops
    return _KOPS


def _kernel_path_active() -> bool:
    runtime = rt.active()
    return runtime is not None and runtime.kernel_path


def _kernel_block() -> int:
    runtime = rt.active()
    return runtime.kernel_block if runtime is not None else 0


@functools.partial(jax.jit, static_argnames=("block",))
def _gemm_klean(a, b, *, block):
    from repro.kernels import ops as kops
    return kops.kernel_matmul(a, b, block=block)


@functools.partial(jax.jit, static_argnames=(
    "trans_a", "trans_b", "has_c", "block"))
def _gemm_kvenue(a, b, c, alpha, beta, *, trans_a, trans_b, has_c, block):
    from repro.kernels import ops as kops
    acc = kops.kernel_matmul(_op(a, trans_a), _op(b, trans_b), block=block)
    out = alpha.astype(acc.dtype) * acc
    if has_c:
        out = out + beta.astype(acc.dtype) * c
    return out.astype(a.dtype)


@functools.partial(jax.jit, static_argnames=("uplo", "trans", "block"))
def _syrk_klean(a, *, uplo, trans, block):
    from repro.kernels import ops as kops
    # real syrk only reaches the venue (kernel_available), so "C" == "T"
    t = "N" if trans == "N" else "T"
    return kops.kernel_syrk(a, uplo=uplo, trans=t, block=block)


@functools.partial(jax.jit, static_argnames=(
    "uplo", "trans", "conj", "has_c", "block"))
def _syrk_kvenue(a, c, alpha, beta, *, uplo, trans, conj, has_c, block):
    from repro.kernels import ops as kops
    opa = _op(a, trans)
    at = jnp.swapaxes(opa, -1, -2)
    if conj:
        at = jnp.conj(at)
    acc = kops.kernel_matmul(opa, at, block=block)
    upd = alpha.astype(acc.dtype) * acc
    n = upd.shape[-1]
    mask = _tri_mask(n, uplo)
    if has_c:
        tri = jnp.where(mask, upd + beta.astype(acc.dtype) * c, c)
    else:
        tri = jnp.where(mask, upd, jnp.zeros_like(upd))
    return tri.astype(a.dtype)


@functools.partial(jax.jit, static_argnames=(
    "trans", "conj", "has_c", "block"))
def _syrk_block_kvenue(ai, aj, c, alpha, beta, *, trans, conj, has_c,
                       block):
    from repro.kernels import ops as kops
    opi, opj = _op(ai, trans), _op(aj, trans)
    jt = jnp.swapaxes(opj, -1, -2)
    if conj:
        jt = jnp.conj(jt)
    acc = kops.kernel_matmul(opi, jt, block=block)
    out = alpha.astype(acc.dtype) * acc
    if has_c:
        out = out + beta.astype(acc.dtype) * c
    return out.astype(ai.dtype)


@functools.partial(jax.jit, static_argnames=(
    "side", "uplo", "trans", "diag", "block"))
def _trsm_klean(a, b, *, side, uplo, trans, diag, block):
    from repro.kernels import ops as kops
    return kops.kernel_trsm(a, b, side=side, uplo=uplo, trans=trans,
                            diag=diag, block=block).astype(b.dtype)


@functools.partial(jax.jit, static_argnames=(
    "side", "uplo", "trans", "diag", "block"))
def _trsm_kvenue(a, b, alpha, *, side, uplo, trans, diag, block):
    from repro.kernels import ops as kops
    rhs = alpha.astype(b.dtype) * b
    return kops.kernel_trsm(a, rhs, side=side, uplo=uplo, trans=trans,
                            diag=diag, block=block).astype(b.dtype)


# ----------------------------------------------------------------------- #
# split-precision arithmetic (OffloadConfig.precision / SCILIB_PRECISION)  #
#                                                                          #
# Twins of the jitted kernels above that run the fp64 inner product as     #
# split low-precision slice passes (repro.core.precision) instead of       #
# native dgemm.  Like the pallas-venue closures, these are built only      #
# when a split scheme is configured and the base/dtype supports one        #
# (real 2-D fp64), so default-off runs never trace — or import — any of   #
# it.  Each builder is memoized per (scheme, venue, block): the xla        #
# venue runs the plain fp32 XLA matmul per pass, the pallas venue the     #
# fp32 Pallas GEMM kernel (repro.kernels.split_gemm) — which is the       #
# only fp64 path that venue has.                                           #
# ----------------------------------------------------------------------- #
def _split_mm(venue: str, block: int):
    """The fp32 pass primitive for one venue (None = precision module
    default, the XLA fp32 matmul)."""
    if venue == "pallas":
        from repro.kernels import split_gemm
        return split_gemm.pass_mm(block)
    return None


def _split_gemm_kernel(scheme, venue, block):
    """Jitted gemm-shaped split kernel, memoized per (scheme, venue,
    block) — the split twin of :func:`_gemm_kernel`."""
    def build():
        from repro.core import precision as prc
        mm = _split_mm(venue, block)

        @functools.partial(jax.jit,
                           static_argnames=("trans_a", "trans_b", "has_c"))
        def kern(a, b, c, alpha, beta, *, trans_a, trans_b, has_c):
            acc = prc.matmul(_op(a, trans_a), _op(b, trans_b), scheme,
                             mm=mm)
            out = alpha.astype(acc.dtype) * acc
            if has_c:
                out = out + beta.astype(acc.dtype) * c
            return out.astype(a.dtype)
        return kern
    return _bound(("splitk", "gemm", scheme, venue, block), build)


def _split_syrk_kernel(scheme, venue, block):
    """Split twin of :func:`_syrk_kernel` (real fp64 only, so no conj)."""
    def build():
        from repro.core import precision as prc
        mm = _split_mm(venue, block)

        @functools.partial(jax.jit,
                           static_argnames=("uplo", "trans", "has_c"))
        def kern(a, c, alpha, beta, *, uplo, trans, has_c):
            opa = _op(a, trans)
            acc = prc.matmul(opa, jnp.swapaxes(opa, -1, -2), scheme,
                             mm=mm)
            upd = alpha.astype(acc.dtype) * acc
            mask = _tri_mask(upd.shape[-1], uplo)
            if has_c:
                tri = jnp.where(mask, upd + beta.astype(acc.dtype) * c, c)
            else:
                tri = jnp.where(mask, upd, jnp.zeros_like(upd))
            return tri.astype(a.dtype)
        return kern
    return _bound(("splitk", "syrk", scheme, venue, block), build)


def _split_syrk_block_kernel(scheme, venue, block):
    """Split twin of :func:`_syrk_block_kernel` (tiled syrk off-diagonal
    blocks are gemm-shaped)."""
    def build():
        from repro.core import precision as prc
        mm = _split_mm(venue, block)

        @functools.partial(jax.jit, static_argnames=("trans", "has_c"))
        def kern(ai, aj, c, alpha, beta, *, trans, has_c):
            opi, opj = _op(ai, trans), _op(aj, trans)
            acc = prc.matmul(opi, jnp.swapaxes(opj, -1, -2), scheme,
                             mm=mm)
            out = alpha.astype(acc.dtype) * acc
            if has_c:
                out = out + beta.astype(acc.dtype) * c
            return out.astype(ai.dtype)
        return kern
    return _bound(("splitk", "syrkb", scheme, venue, block), build)


def _split_trsm_kernel(scheme, venue, block):
    """Split twin of :func:`_trsm_kernel`: fp32 solve + one refinement
    step whose residual runs the split matmul.  The referenced triangle
    is materialized first — the refinement's ``op(A) X`` product reads
    the full array, unlike the solves."""
    def build():
        from repro.core import precision as prc
        mm = _split_mm(venue, block)

        @functools.partial(jax.jit,
                           static_argnames=("side", "uplo", "trans",
                                            "diag"))
        def kern(a, b, alpha, *, side, uplo, trans, diag):
            rhs = alpha.astype(b.dtype) * b
            tri = _tri_ref(a, uplo, diag)
            return prc.trsm(tri, rhs, scheme,
                            left_side=(side == "L"),
                            lower=(uplo == "L"),
                            trans_a=(trans != "N"),
                            unit_diag=(diag == "U"),
                            mm=mm).astype(b.dtype)
        return kern
    return _bound(("splitk", "trsm", scheme, venue, block), build)


def _split_bound(base, dt, bkey, sfactory, flat2d=True):
    """The split-precision twin of ``_kernel_bound``: build the
    ``(scheme, venue) -> compute`` factory the runtime's precision
    stage consults, or None when no split scheme is configured or the
    base/dtype/shape has no split formulation (real 2-D fp64 only —
    batched calls stay native).  Memo keys get a ``"split"`` prefix
    plus scheme/venue/block so split closures never collide with the
    XLA or pallas-venue ones in ``_BOUND``."""
    runtime = rt.active()
    if runtime is None or not runtime.precision or not flat2d:
        return None
    from repro.core import precision as prc
    if not prc.supported(base, dt):
        return None
    block = _kernel_block()

    def split_compute(scheme, venue):
        venue = venue or "xla"
        skey = (("split", scheme, venue, block) + bkey
                if bkey is not None else None)
        return _bound(skey,
                      functools.partial(sfactory, scheme, venue, block))
    return split_compute


# ----------------------------------------------------------------------- #
# multi-device tile decomposition (BLASX-style 2-D sharding)               #
#                                                                          #
# When the runtime sees more than one device tier, super-threshold calls   #
# are split into tiles the scheduler deals round-robin-with-affinity       #
# across devices.  The decomposition is per-routine: gemm tiles the        #
# output 2-D; symm/trmm/trsm split the rectangular panel along its free    #
# dimension (the triangle replicates); syrk/herk tile the stored triangle  #
# of C by block, diagonal blocks through the syrk kernel, off-diagonal     #
# through a gemm-shaped block kernel; syr2k/her2k ride the same triangle   #
# grid with a two-term block kernel (the last level-3 gap closed).         #
# Builders return None when the matrix is too small to split               #
# (``SCILIB_TILE_MIN``), which falls back to the single-device path.       #
# ----------------------------------------------------------------------- #
def _tile_min() -> int:
    """Minimum tile edge, from the active runtime's config (the
    ``tile_min`` field replacing ``SCILIB_TILE_MIN``)."""
    runtime = rt.active()
    return runtime.config.tile_min if runtime is not None else 64


def _shard_active(batch: int, *arrays) -> bool:
    """Tile decomposition applies to plain 2-D calls only: a leading
    batch axis — even a singleton one — uses the batched kernels, whose
    axes the 2-D tile coordinates do not address."""
    runtime = rt.active()
    if runtime is None or batch != 1 or runtime.n_devices < 2:
        return False
    return all(x is None or x.ndim == 2 for x in arrays)


def _splits(dim: int, g: int) -> List[Tuple[int, int]]:
    """g contiguous block ranges covering [0, dim)."""
    base, rem = divmod(dim, g)
    edges = [0]
    for i in range(g):
        edges.append(edges[-1] + base + (1 if i < rem else 0))
    return [(edges[i], edges[i + 1]) for i in range(g)]


def _grid2d(n_dev: int, m: int, n: int) -> Optional[Tuple[int, int]]:
    """Near-square tile grid with >= n_dev tiles, clamped so no tile edge
    drops under the minimum; None when the call is too small to shard."""
    min_tile = _tile_min()
    gm = max(1, math.isqrt(n_dev))
    gn = -(-n_dev // gm)
    if n < m:                       # split the longer dimension more finely
        gm, gn = gn, gm
    gm = min(gm, max(1, m // min_tile))
    gn = min(gn, max(1, n // min_tile))
    if gm * gn < 2:
        return None
    return gm, gn


def _grid1d(n_dev: int, dim: int) -> Optional[int]:
    g = min(n_dev, max(1, dim // _tile_min()))
    return g if g >= 2 else None


def _assemble(blocks: List[List[jax.Array]]) -> jax.Array:
    rows = [row[0] if len(row) == 1 else jnp.concatenate(row, axis=-1)
            for row in blocks]
    return rows[0] if len(rows) == 1 else jnp.concatenate(rows, axis=-2)


def _full_coords(x: jax.Array) -> Tuple[int, int, int, int]:
    return (0, x.shape[-2], 0, x.shape[-1])


def _rowblock_coords(x: jax.Array, trans: str,
                     r0: int, r1: int) -> Tuple[int, int, int, int]:
    """Coords on the parent for row block [r0:r1) of op(x)."""
    if trans == "N":
        return (r0, r1, 0, x.shape[-1])
    return (0, x.shape[-2], r0, r1)


def _colblock_coords(x: jax.Array, trans: str,
                     c0: int, c1: int) -> Tuple[int, int, int, int]:
    """Coords on the parent for column block [c0:c1) of op(x)."""
    if trans == "N":
        return (0, x.shape[-2], c0, c1)
    return (c0, c1, 0, x.shape[-1])


def _shard_gemm(a, b, c, alpha, beta, trans_a, trans_b,
                n_dev, venue="xla", precision="") -> Optional[TilePlan]:
    m = a.shape[-2] if trans_a == "N" else a.shape[-1]
    n = b.shape[-1] if trans_b == "N" else b.shape[-2]
    g = _grid2d(n_dev, m, n)
    if g is None:
        return None
    gm, gn = g
    rows, cols = _splits(m, gm), _splits(n, gn)
    dt = a.dtype
    has_c = c is not None
    alpha_, beta_ = _scalar(alpha, dt), _scalar(beta, dt)
    # pallas venue: every tile runs the kernel-backed block arithmetic;
    # a split decision swaps in the split tile kernel the same way
    if precision:
        gemm_k = _split_gemm_kernel(precision, venue, _kernel_block())
    elif venue == "pallas":
        gemm_k = functools.partial(_gemm_kvenue, block=_kernel_block())
    else:
        gemm_k = _gemm_kernel
    if has_c:
        def tile_fn(a_, b_, c_):
            return gemm_k(a_, b_, c_, alpha_, beta_, trans_a=trans_a,
                          trans_b=trans_b, has_c=True)
    else:
        czero = _scalar(0.0, dt)

        def tile_fn(a_, b_):
            return gemm_k(a_, b_, czero, alpha_, beta_,
                          trans_a=trans_a, trans_b=trans_b,
                          has_c=False)
    tiles = []
    for (r0, r1) in rows:
        for (q0, q1) in cols:
            ops = [TileOp("A", a, _rowblock_coords(a, trans_a, r0, r1),
                          shared=(gm == 1)),
                   TileOp("B", b, _colblock_coords(b, trans_b, q0, q1),
                          shared=(gn == 1))]
            if has_c:
                ops.append(TileOp("C", c, (r0, r1, q0, q1), written=True))
            tiles.append(Tile(tuple(ops), tile_fn, (r0, r1, q0, q1)))

    def gather(outs):
        it = iter(outs)
        return _assemble([[next(it) for _ in cols] for _ in rows])

    return TilePlan((gm, gn), tuple(tiles), gather)


def _shard_symm(a, b, c, alpha, beta, side, uplo, conj,
                n_dev) -> Optional[TilePlan]:
    m, n = b.shape[-2], b.shape[-1]
    dim = n if side == "L" else m
    g = _grid1d(n_dev, dim)
    if g is None:
        return None
    panels = _splits(dim, g)
    dt = b.dtype
    has_c = c is not None
    alpha_, beta_ = _scalar(alpha, dt), _scalar(beta, dt)
    if has_c:
        def tile_fn(a_, b_, c_):
            return _symm_kernel(a_, b_, c_, alpha_, beta_, side=side,
                                uplo=uplo, conj=conj, has_c=True)
    else:
        czero = _scalar(0.0, dt)

        def tile_fn(a_, b_):
            return _symm_kernel(a_, b_, czero, alpha_, beta_, side=side,
                                uplo=uplo, conj=conj, has_c=False)
    tiles = []
    for (p0, p1) in panels:
        coords = (0, m, p0, p1) if side == "L" else (p0, p1, 0, n)
        ops = [TileOp("A", a, _full_coords(a), shared=True),
               TileOp("B", b, coords)]
        if has_c:
            ops.append(TileOp("C", c, coords, written=True))
        tiles.append(Tile(tuple(ops), tile_fn, coords))

    def gather(outs):
        return jnp.concatenate(outs, axis=-1 if side == "L" else -2)

    return TilePlan((1, g) if side == "L" else (g, 1), tuple(tiles), gather)


def _shard_syrk(a, c, alpha, beta, uplo, trans, conj,
                n_dev, venue="xla", precision="") -> Optional[TilePlan]:
    n = a.shape[-2] if trans == "N" else a.shape[-1]
    g = 2
    while g * (g + 1) // 2 < n_dev:
        g += 1
    g = min(g, max(1, n // _tile_min()))
    if g < 2:
        return None
    blocks = _splits(n, g)
    dt = a.dtype
    has_c = c is not None
    alpha_, beta_ = _scalar(alpha, dt), _scalar(beta, dt)
    czero = _scalar(0.0, dt)
    if precision:
        blk = _kernel_block()
        # real fp64 only reaches the split path, so conj never applies
        sk = _split_syrk_kernel(precision, venue, blk)
        sbk = _split_syrk_block_kernel(precision, venue, blk)

        def syrk_k(a_, c_, al, be, *, uplo, trans, conj, has_c):
            return sk(a_, c_, al, be, uplo=uplo, trans=trans,
                      has_c=has_c)

        def syrk_block_k(ai, aj, c_, al, be, *, trans, conj, has_c):
            return sbk(ai, aj, c_, al, be, trans=trans, has_c=has_c)
    elif venue == "pallas":
        blk = _kernel_block()
        syrk_k = functools.partial(_syrk_kvenue, block=blk)
        syrk_block_k = functools.partial(_syrk_block_kvenue, block=blk)
    else:
        syrk_k, syrk_block_k = _syrk_kernel, _syrk_block_kernel
    if has_c:
        def diag_fn(a_, c_):
            return syrk_k(a_, c_, alpha_, beta_, uplo=uplo,
                          trans=trans, conj=conj, has_c=True)

        def off_fn(ai, aj, cij):
            return syrk_block_k(ai, aj, cij, alpha_, beta_,
                                trans=trans, conj=conj, has_c=True)
    else:
        def diag_fn(a_):
            return syrk_k(a_, czero, alpha_, beta_, uplo=uplo,
                          trans=trans, conj=conj, has_c=False)

        def off_fn(ai, aj):
            return syrk_block_k(ai, aj, czero, alpha_, beta_,
                                trans=trans, conj=conj, has_c=False)
    tiles, stored = [], {}
    for i in range(g):
        for j in range(g):
            if not (i >= j if uplo == "L" else i <= j):
                continue
            (r0, r1), (q0, q1) = blocks[i], blocks[j]
            coords = (r0, r1, q0, q1)
            if i == j:
                ops = [TileOp("A", a, _rowblock_coords(a, trans, r0, r1))]
                fn = diag_fn
            else:
                ops = [TileOp("A", a, _rowblock_coords(a, trans, r0, r1)),
                       TileOp("A", a, _rowblock_coords(a, trans, q0, q1))]
                fn = off_fn
            if has_c:
                ops.append(TileOp("C", c, coords, written=True))
            stored[(i, j)] = len(tiles)
            tiles.append(Tile(tuple(ops), fn, coords))

    def gather(outs):
        grid = []
        for i in range(g):
            row = []
            for j in range(g):
                idx = stored.get((i, j))
                if idx is not None:
                    row.append(outs[idx])
                    continue
                (r0, r1), (q0, q1) = blocks[i], blocks[j]
                if has_c:          # untouched triangle keeps C verbatim
                    row.append(c[r0:r1, q0:q1].astype(dt))
                else:
                    row.append(jnp.zeros((r1 - r0, q1 - q0), dt))
            grid.append(row)
        return _assemble(grid)

    return TilePlan((g, g), tuple(tiles), gather)


def _shard_syr2k(a, b, c, alpha, beta, uplo, trans, conj,
                 n_dev) -> Optional[TilePlan]:
    """syr2k/her2k on the syrk triangle grid: the stored triangle of C
    tiles by block — diagonal blocks run the full rank-2k kernel on the
    matching op-row blocks of A and B, off-diagonal blocks the two-term
    block kernel.  A and B row blocks steer affinity exactly like syrk's
    single operand (each block appears in one grid row and one column)."""
    n = a.shape[-2] if trans == "N" else a.shape[-1]
    g = 2
    while g * (g + 1) // 2 < n_dev:
        g += 1
    g = min(g, max(1, n // _tile_min()))
    if g < 2:
        return None
    blocks = _splits(n, g)
    dt = a.dtype
    has_c = c is not None
    alpha_, beta_ = _scalar(alpha, dt), _scalar(beta, dt)
    czero = _scalar(0.0, dt)
    if has_c:
        def diag_fn(a_, b_, c_):
            return _syr2k_kernel(a_, b_, c_, alpha_, beta_, uplo=uplo,
                                 trans=trans, conj=conj, has_c=True)

        def off_fn(ai, bi, aj, bj, cij):
            return _syr2k_block_kernel(ai, bi, aj, bj, cij, alpha_, beta_,
                                       trans=trans, conj=conj, has_c=True)
    else:
        def diag_fn(a_, b_):
            return _syr2k_kernel(a_, b_, czero, alpha_, beta_, uplo=uplo,
                                 trans=trans, conj=conj, has_c=False)

        def off_fn(ai, bi, aj, bj):
            return _syr2k_block_kernel(ai, bi, aj, bj, czero, alpha_,
                                       beta_, trans=trans, conj=conj,
                                       has_c=False)
    tiles, stored = [], {}
    for i in range(g):
        for j in range(g):
            if not (i >= j if uplo == "L" else i <= j):
                continue
            (r0, r1), (q0, q1) = blocks[i], blocks[j]
            coords = (r0, r1, q0, q1)
            if i == j:
                ops = [TileOp("A", a, _rowblock_coords(a, trans, r0, r1)),
                       TileOp("B", b, _rowblock_coords(b, trans, r0, r1))]
                fn = diag_fn
            else:
                ops = [TileOp("A", a, _rowblock_coords(a, trans, r0, r1)),
                       TileOp("B", b, _rowblock_coords(b, trans, r0, r1)),
                       TileOp("A", a, _rowblock_coords(a, trans, q0, q1)),
                       TileOp("B", b, _rowblock_coords(b, trans, q0, q1))]
                fn = off_fn
            if has_c:
                ops.append(TileOp("C", c, coords, written=True))
            stored[(i, j)] = len(tiles)
            tiles.append(Tile(tuple(ops), fn, coords))

    def gather(outs):
        grid = []
        for i in range(g):
            row = []
            for j in range(g):
                idx = stored.get((i, j))
                if idx is not None:
                    row.append(outs[idx])
                    continue
                (r0, r1), (q0, q1) = blocks[i], blocks[j]
                if has_c:          # untouched triangle keeps C verbatim
                    row.append(c[r0:r1, q0:q1].astype(dt))
                else:
                    row.append(jnp.zeros((r1 - r0, q1 - q0), dt))
            grid.append(row)
        return _assemble(grid)

    return TilePlan((g, g), tuple(tiles), gather)


def _shard_tri(a, b, side, uplo, trans, diag, alpha, kernel,
               n_dev, venue="xla", precision="") -> Optional[TilePlan]:
    """trmm/trsm: the RHS panel splits along its free dimension; each
    panel solve/multiply is independent, the triangle replicates."""
    m, n = b.shape[-2], b.shape[-1]
    dim = n if side == "L" else m
    g = _grid1d(n_dev, dim)
    if g is None:
        return None
    panels = _splits(dim, g)
    dt = b.dtype
    alpha_ = _scalar(alpha, dt)
    if precision and kernel is _trsm_kernel:
        # split trsm panels: same geometry, refined fp32 panel solves
        kernel = _split_trsm_kernel(precision, venue, _kernel_block())
    elif venue == "pallas" and kernel is _trsm_kernel:
        # only trsm has a kernel; trmm never resolves to the pallas venue
        kernel = functools.partial(_trsm_kvenue, block=_kernel_block())

    def tile_fn(a_, b_):
        return kernel(a_, b_, alpha_, side=side, uplo=uplo, trans=trans,
                      diag=diag)

    tiles = []
    for (p0, p1) in panels:
        coords = (0, m, p0, p1) if side == "L" else (p0, p1, 0, n)
        tiles.append(Tile((TileOp("A", a, _full_coords(a), shared=True),
                           TileOp("B", b, coords, written=True)),
                          tile_fn, coords))

    def gather(outs):
        return jnp.concatenate(outs, axis=-1 if side == "L" else -2)

    return TilePlan((1, g) if side == "L" else (g, 1), tuple(tiles), gather)


# ----------------------------------------------------------------------- #
# public routines                                                          #
# ----------------------------------------------------------------------- #
def _dispatch(routine, m, n, k, operands, compute, batch=1, key=None,
              shard=None, kernel_compute=None, split_compute=None,
              split_check=None):
    runtime = rt.active()
    if runtime is None:
        return compute(*[x for _, x, _, _ in operands])
    return runtime.blas_call(routine, m, n, k, operands, compute,
                             batch=batch, key=key, shard=shard,
                             kernel_compute=kernel_compute,
                             split_compute=split_compute,
                             split_check=split_check)


def _kernel_bound(base, dt, bkey, kfactory):
    """The pallas-venue twin of ``_bound``: build (or recall) the
    kernel-backed compute closure for one call-site signature, or None
    when the kernel path is off or the routine/dtype has no kernel.
    Memo keys get a ``"kern"`` prefix plus the block edge so venue
    closures never collide with the XLA ones in ``_BOUND``."""
    if not _kernel_path_active() or not _kops().kernel_available(base, dt):
        return None
    block = _kernel_block()
    kkey = ("kern", block) + bkey if bkey is not None else None
    return _bound(kkey, functools.partial(kfactory, block))


def gemm(a: jax.Array, b: jax.Array, c: Optional[jax.Array] = None, *,
         alpha=1.0, beta=0.0, trans_a: str = "N",
         trans_b: str = "N") -> jax.Array:
    """C := alpha op(A) op(B) + beta C (the paper's headline routine)."""
    opm = a.shape[-2] if trans_a == "N" else a.shape[-1]
    opk = a.shape[-1] if trans_a == "N" else a.shape[-2]
    opn = b.shape[-1] if trans_b == "N" else b.shape[-2]
    batch = _batch_of(a, b, c)
    dt = a.dtype
    has_c = c is not None
    av, bv = _hashable(alpha), _hashable(beta)
    bkey = (("gemm", dt.name, trans_a, trans_b, has_c, av, bv)
            if av is not None and bv is not None else None)

    def factory():
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def compute(a_, b_, c_):
                return _gemm_kernel(a_, b_, c_, alpha_, beta_,
                                    trans_a=trans_a, trans_b=trans_b,
                                    has_c=True)
        else:
            c0 = _scalar(0.0, dt)

            def compute(a_, b_):
                return _gemm_kernel(a_, b_, c0, alpha_, beta_,
                                    trans_a=trans_a, trans_b=trans_b,
                                    has_c=False)
        return compute

    def kfactory(block):
        if (not has_c and av == 1 and bv == 0
                and trans_a == "N" and trans_b == "N"):
            def kcompute(a_, b_):          # lean: no scalar epilogue
                return _gemm_klean(a_, b_, block=block)
            return kcompute
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def kcompute(a_, b_, c_):
                return _gemm_kvenue(a_, b_, c_, alpha_, beta_,
                                    trans_a=trans_a, trans_b=trans_b,
                                    has_c=True, block=block)
        else:
            c0 = _scalar(0.0, dt)

            def kcompute(a_, b_):
                return _gemm_kvenue(a_, b_, c0, alpha_, beta_,
                                    trans_a=trans_a, trans_b=trans_b,
                                    has_c=False, block=block)
        return kcompute

    def sfactory(scheme, venue, block):
        kern = _split_gemm_kernel(scheme, venue, block)
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def scompute(a_, b_, c_):
                return kern(a_, b_, c_, alpha_, beta_, trans_a=trans_a,
                            trans_b=trans_b, has_c=True)
        else:
            c0 = _scalar(0.0, dt)

            def scompute(a_, b_):
                return kern(a_, b_, c0, alpha_, beta_, trans_a=trans_a,
                            trans_b=trans_b, has_c=False)
        return scompute

    compute = _bound(bkey, factory)
    kernel_compute = _kernel_bound("gemm", dt, bkey, kfactory)
    flat2d = (a.ndim == 2 and b.ndim == 2
              and (c is None or c.ndim == 2))
    split_compute = _split_bound("gemm", dt, bkey, sfactory, flat2d)
    split_check = None
    if split_compute is not None:
        from repro.core import precision as prc

        def split_check(out, a_, b_, c_=None):
            return prc.gemm_residual(out, _op(a_, trans_a),
                                     _op(b_, trans_b), c_, alpha, beta)
    ops = [("A", a, float(opn), False), ("B", b, float(opm), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))
    shard = (functools.partial(_shard_gemm, a, b, c, alpha, beta,
                               trans_a, trans_b)
             if _shard_active(batch, a, b, c) else None)
    return _dispatch(routine_name("gemm", dt), opm, opn, opk,
                     ops, compute, batch,
                     key=_call_key(bkey, opm, opn, opk, batch),
                     shard=shard, kernel_compute=kernel_compute,
                     split_compute=split_compute,
                     split_check=split_check)


@jax.jit
def _gemv_kernel_n(a, x):
    return a @ x


@jax.jit
def _gemv_kernel_t(a, x):
    return jnp.swapaxes(a, -1, -2) @ x


def gemv(a: jax.Array, x: jax.Array, *, trans: str = "N") -> jax.Array:
    """y := op(A) x — the matrix-vector (level-2) interception surface.

    The paper's tool intercepts level-3 BLAS; matrix-vector products
    used to bypass interception entirely and vanish from the report.
    They are now recorded and counted as gemv-shaped calls and routed
    through the same dispatch pipeline with the ordinary threshold
    rule: N_avg = (m*n)^(1/3) sits below any level-3 threshold until
    the matrix alone is ~0.5 GB, so dispatch stays host below the
    threshold — i.e. at realistic sizes — while the call is visible
    everywhere: per-routine counts, call-site profiles and the trace
    all see it.  (Above the threshold a gemv offloads like any other
    call; DFU placement makes a *repeated* huge gemv pay its migration
    once, and the adaptive mode's measured probes will lock host when
    offload loses.)
    """
    m, n = a.shape[-2], a.shape[-1]
    opm, opn = (m, n) if trans == "N" else (n, m)
    dt = a.dtype
    bkey = ("gemv", dt.name, trans)
    compute = _gemv_kernel_t if trans == "T" else _gemv_kernel_n
    # A streams once; x is re-read for every one of the opm output rows.
    ops = [("A", a, 1.0, False), ("X", x, float(opm), False)]
    return _dispatch(routine_name("gemv", dt), opm, opn, 0, ops, compute,
                     key=_call_key(bkey, opm, opn, 0, 1))


def symm(a, b, c=None, *, side="L", uplo="L", alpha=1.0, beta=0.0):
    """C := alpha A B + beta C with A symmetric (one triangle referenced)."""
    return _symm_like(a, b, c, side=side, uplo=uplo, alpha=alpha,
                      beta=beta, conj=False, base="symm")


def hemm(a, b, c=None, *, side="L", uplo="L", alpha=1.0, beta=0.0):
    return _symm_like(a, b, c, side=side, uplo=uplo, alpha=alpha,
                      beta=beta, conj=True, base="hemm")


def _symm_like(a, b, c, *, side, uplo, alpha, beta, conj, base):
    m, n = b.shape[-2], b.shape[-1]
    batch = _batch_of(a, b, c)
    dt = b.dtype
    has_c = c is not None
    av, bv = _hashable(alpha), _hashable(beta)
    bkey = ((base, dt.name, side, uplo, has_c, av, bv)
            if av is not None and bv is not None else None)

    def factory():
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def compute(a_, b_, c_):
                return _symm_kernel(a_, b_, c_, alpha_, beta_, side=side,
                                    uplo=uplo, conj=conj, has_c=True)
        else:
            c0 = _scalar(0.0, dt)

            def compute(a_, b_):
                return _symm_kernel(a_, b_, c0, alpha_, beta_, side=side,
                                    uplo=uplo, conj=conj, has_c=False)
        return compute

    compute = _bound(bkey, factory)
    ops = [("A", a, float(n if side == "L" else m), False),
           ("B", b, float(a.shape[-1]), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))
    shard = (functools.partial(_shard_symm, a, b, c, alpha, beta,
                               side, uplo, conj)
             if _shard_active(batch, a, b, c) else None)
    return _dispatch(routine_name(base, dt), a.shape[-1], n, 0,
                     ops, compute, batch,
                     key=_call_key(bkey, a.shape[-1], n, 0, batch),
                     shard=shard)


def syrk(a, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    """C := alpha op(A) op(A)^T + beta C, triangle ``uplo`` only."""
    return _syrk_like(a, c, uplo=uplo, trans=trans, alpha=alpha, beta=beta,
                      conj=False, base="syrk")


def herk(a, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    return _syrk_like(a, c, uplo=uplo, trans=trans, alpha=alpha, beta=beta,
                      conj=True, base="herk")


def _syrk_like(a, c, *, uplo, trans, alpha, beta, conj, base):
    n = a.shape[-2] if trans == "N" else a.shape[-1]
    k = a.shape[-1] if trans == "N" else a.shape[-2]
    batch = _batch_of(a, c)
    dt = a.dtype
    has_c = c is not None
    av, bv = _hashable(alpha), _hashable(beta)
    bkey = ((base, dt.name, uplo, trans, has_c, av, bv)
            if av is not None and bv is not None else None)

    def factory():
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def compute(a_, c_):
                return _syrk_kernel(a_, c_, alpha_, beta_, uplo=uplo,
                                    trans=trans, conj=conj, has_c=True)
        else:
            c0 = _scalar(0.0, dt)

            def compute(a_):
                return _syrk_kernel(a_, c0, alpha_, beta_, uplo=uplo,
                                    trans=trans, conj=conj, has_c=False)
        return compute

    def kfactory(block):
        if not has_c and av == 1 and bv == 0:
            def kcompute(a_):              # lean: no scalar epilogue
                return _syrk_klean(a_, uplo=uplo, trans=trans, block=block)
            return kcompute
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def kcompute(a_, c_):
                return _syrk_kvenue(a_, c_, alpha_, beta_, uplo=uplo,
                                    trans=trans, conj=conj, has_c=True,
                                    block=block)
        else:
            c0 = _scalar(0.0, dt)

            def kcompute(a_):
                return _syrk_kvenue(a_, c0, alpha_, beta_, uplo=uplo,
                                    trans=trans, conj=conj, has_c=False,
                                    block=block)
        return kcompute

    def sfactory(scheme, venue, block):
        kern = _split_syrk_kernel(scheme, venue, block)
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def scompute(a_, c_):
                return kern(a_, c_, alpha_, beta_, uplo=uplo,
                            trans=trans, has_c=True)
        else:
            c0 = _scalar(0.0, dt)

            def scompute(a_):
                return kern(a_, c0, alpha_, beta_, uplo=uplo,
                            trans=trans, has_c=False)
        return scompute

    compute = _bound(bkey, factory)
    kernel_compute = _kernel_bound(base, dt, bkey, kfactory)
    flat2d = a.ndim == 2 and (c is None or c.ndim == 2)
    # no sampled-residual check for syrk: the rank-k update has no
    # cancellation channel beyond gemm's and the masked triangle defeats
    # the O(n^2) matvec probe; acceptance rests on the a-priori bound
    split_compute = _split_bound(base, dt, bkey, sfactory, flat2d)
    ops = [("A", a, float(n), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))
    shard = (functools.partial(_shard_syrk, a, c, alpha, beta, uplo,
                               trans, conj)
             if _shard_active(batch, a, c) else None)
    return _dispatch(routine_name(base, dt), n, n, k, ops, compute,
                     batch, key=_call_key(bkey, n, n, k, batch),
                     shard=shard, kernel_compute=kernel_compute,
                     split_compute=split_compute)


def syr2k(a, b, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    return _syr2k_like(a, b, c, uplo=uplo, trans=trans, alpha=alpha,
                       beta=beta, conj=False, base="syr2k")


def her2k(a, b, c=None, *, uplo="L", trans="N", alpha=1.0, beta=0.0):
    return _syr2k_like(a, b, c, uplo=uplo, trans=trans, alpha=alpha,
                       beta=beta, conj=True, base="her2k")


def _syr2k_like(a, b, c, *, uplo, trans, alpha, beta, conj, base):
    n = a.shape[-2] if trans == "N" else a.shape[-1]
    k = a.shape[-1] if trans == "N" else a.shape[-2]
    batch = _batch_of(a, b, c)
    dt = a.dtype
    has_c = c is not None
    av, bv = _hashable(alpha), _hashable(beta)
    bkey = ((base, dt.name, uplo, trans, has_c, av, bv)
            if av is not None and bv is not None else None)

    def factory():
        alpha_ = _scalar(alpha, dt)
        beta_ = _scalar(beta, dt)
        if has_c:
            def compute(a_, b_, c_):
                return _syr2k_kernel(a_, b_, c_, alpha_, beta_, uplo=uplo,
                                     trans=trans, conj=conj, has_c=True)
        else:
            c0 = _scalar(0.0, dt)

            def compute(a_, b_):
                return _syr2k_kernel(a_, b_, c0, alpha_, beta_, uplo=uplo,
                                     trans=trans, conj=conj, has_c=False)
        return compute

    compute = _bound(bkey, factory)
    ops = [("A", a, float(n), False), ("B", b, float(n), False)]
    if has_c:
        ops.append(("C", c, 1.0, True))
    shard = (functools.partial(_shard_syr2k, a, b, c, alpha, beta, uplo,
                               trans, conj)
             if _shard_active(batch, a, b, c) else None)
    return _dispatch(routine_name(base, dt), n, n, k, ops, compute,
                     batch, key=_call_key(bkey, n, n, k, batch),
                     shard=shard)


def trmm(a, b, *, side="L", uplo="L", trans="N", diag="N", alpha=1.0):
    """B := alpha op(A) B (or B op(A)), A triangular."""
    return _tri_like(a, b, side=side, uplo=uplo, trans=trans, diag=diag,
                     alpha=alpha, base="trmm", kernel=_trmm_kernel)


def trsm(a, b, *, side="L", uplo="L", trans="N", diag="N", alpha=1.0):
    """Solve op(A) X = alpha B (or X op(A) = alpha B), A triangular."""
    return _tri_like(a, b, side=side, uplo=uplo, trans=trans, diag=diag,
                     alpha=alpha, base="trsm", kernel=_trsm_kernel)


def _tri_like(a, b, *, side, uplo, trans, diag, alpha, base, kernel):
    m, n = b.shape[-2], b.shape[-1]
    batch = _batch_of(a, b)
    dt = b.dtype
    av = _hashable(alpha)
    bkey = ((base, dt.name, side, uplo, trans, diag, av)
            if av is not None else None)

    def factory():
        alpha_ = _scalar(alpha, dt)

        def compute(a_, b_):
            return kernel(a_, b_, alpha_, side=side, uplo=uplo,
                          trans=trans, diag=diag)
        return compute

    def kfactory(block):
        if av == 1:
            def kcompute(a_, b_):          # lean: no alpha scaling
                return _trsm_klean(a_, b_, side=side, uplo=uplo,
                                   trans=trans, diag=diag, block=block)
            return kcompute
        alpha_ = _scalar(alpha, dt)

        def kcompute(a_, b_):
            return _trsm_kvenue(a_, b_, alpha_, side=side, uplo=uplo,
                                trans=trans, diag=diag, block=block)
        return kcompute

    def sfactory(scheme, venue, block):
        kern = _split_trsm_kernel(scheme, venue, block)
        alpha_ = _scalar(alpha, dt)

        def scompute(a_, b_):
            return kern(a_, b_, alpha_, side=side, uplo=uplo,
                        trans=trans, diag=diag)
        return scompute

    compute = _bound(bkey, factory)
    kernel_compute = _kernel_bound(base, dt, bkey, kfactory)
    flat2d = a.ndim == 2 and b.ndim == 2
    split_compute = (_split_bound(base, dt, bkey, sfactory, flat2d)
                     if base == "trsm" else None)
    split_check = None
    if split_compute is not None:
        from repro.core import precision as prc

        def split_check(out, a_, b_):
            tri = _tri_ref(a_, uplo, diag)
            return prc.trsm_residual(out, tri, b_,
                                     left_side=(side == "L"),
                                     lower=(uplo == "L"),
                                     trans_a=(trans != "N"),
                                     alpha=alpha)
    tri_n = a.shape[-1]
    opn = n if side == "L" else m
    ops = [("A", a, float(opn), False),
           ("B", b, float(tri_n), True)]
    shard = (functools.partial(_shard_tri, a, b, side, uplo, trans, diag,
                               alpha, kernel)
             if _shard_active(batch, a, b) else None)
    return _dispatch(routine_name(base, dt), tri_n, opn, 0, ops, compute,
                     batch, key=_call_key(bkey, tri_n, opn, 0, batch),
                     shard=shard, kernel_compute=kernel_compute,
                     split_compute=split_compute,
                     split_check=split_check)


# dlsym mode with no runtime installed still honors the env-derived
# dispatch_cache knob: resolve it once at import through the config
# boundary (runtime construction re-resolves it from its own config).
refresh_cache_flag()

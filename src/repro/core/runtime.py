"""SCILIB-Accel offload runtime: the JAX re-implementation of paper §3.

Every runtime is configured by one typed
:class:`~repro.core.config.OffloadConfig` (the ``SCILIB_*`` env names
below remain supported spellings, ingested solely by
``OffloadConfig.from_env()``), and normally lives inside a
:class:`~repro.core.session.Session`; ``install()``/``uninstall()``
below are legacy shims over an implicit session.

One ``OffloadRuntime`` owns

* the **placement registry** — buffer identity -> device-tier placement.
  This is the JAX analogue of the remapped page table (Fig. 2): the caller
  keeps its handle, the physical home changes once, later uses are free.
  The registry is a byte-capped :class:`~repro.core.residency.
  ResidencyStore` (``SCILIB_DEVICE_BYTES``): when device residency
  exceeds the cap, the eviction policy (``SCILIB_EVICT`` — ``lru``
  default, ``lfu``, or cost-aware ``refetch``) pushes placements back
  to the host tier so DFU cannot grow HBM use unboundedly.  Pinned
  entries (``runtime.pin(x)``, or ``SCILIB_PIN=never-evict`` for
  everything) survive arbitrary pressure.  The same store class backs
  the per-device tile-block registries, the trace-id table, and the
  memtier simulator's replay, so live runs and simulation share one
  accounting implementation — residency events (place/hit/evict/
  refetch) are recorded into the trace and can be checked
  count-for-count against a replay.
* the **offload decision** (threshold logic of §3.3), memoized per call
  site in the **dispatch cache** — steady-state calls re-derive nothing,
* the **statistics** the paper's ``.fini_array`` hook prints (per-routine
  call/offload counts, bytes moved, wall time, reuse counts),
* a **BLAS trace** so any run can be replayed through the memtier
  simulator under calibrated GH200/TPU constants (Tables 3/5 methodology),
* the **multi-device tile scheduler**: with more than one device tier
  (``len(jax.devices()) > 1``, or ``SCILIB_DEVICES=n`` forcing a
  simulated N-tier layout), super-threshold calls are split into 2-D
  tiles scheduled round-robin-with-affinity across devices, BLASX-style
  — a tile runs on the device where its operand block is already
  resident, tracked in per-device block registries with per-device byte
  caps and eviction counters.  With one device the scheduler is inert
  and the single-device fast path is untouched.

Execution is **asynchronous by default**: the runtime manages *placement*
and hands XLA the jit-compiled arithmetic without blocking, exactly like
the paper's tool returns control to the host thread while cuBLAS runs.
``SCILIB_SYNC=1`` (or ``install(..., sync=True)``) restores the fully
synchronous seed behaviour — per-call ``block_until_ready`` with wall
time measured around the device work — and ``runtime.sync()`` drains
in-flight results explicitly (what benchmarks call before reading clocks).

**The dispatch pipeline.**  ``blas_call`` is a staged pipeline with
call-site identity threaded through every layer, mirroring the paper's
per-call-site DBI patching:

    canonicalize -> decide -> plan -> execute -> record

* *canonicalize* bundles the call into a :class:`CallContext` and
  fingerprints the call site (:mod:`repro.core.callsite`).
* *decide* runs the ordered ``decision_stages`` — adaptive per-site
  lock-in (``SCILIB_ADAPTIVE=1``), the memoized dispatch cache, then the
  threshold rule — until one yields a :class:`DispatchDecision`; the
  policy capability (``policy.offloads``) can veto offload afterwards.
  Stages are plain callables on the runtime: later policies plug in by
  inserting into ``decision_stages`` instead of editing branches.
* *plan* consults the multi-device tile planner only when the decision
  offloads and more than one device tier exists.
* *execute* runs the host path, the whole-call offload path, or the
  sharded tile schedule.
* *record* updates per-routine and per-site statistics and appends the
  :class:`~repro.core.trace.BlasCall` (with ``callsite_id`` and the
  measured per-call ``seconds``) to the trace.

**Adaptive per-site mode** (``SCILIB_ADAPTIVE=1``): the first
``SCILIB_ADAPTIVE_WARMUP`` calls at each site alternate deterministically
between the host and offload paths, timed synchronously, and the faster
path is then locked — exactly the paper's warmup-then-patch behaviour.
With ``SCILIB_ADAPTIVE=0`` (default) the pipeline is behaviour-identical
to the flat dispatch it replaced.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from contextvars import ContextVar
from typing import Callable, Dict, Hashable, Optional, Sequence, Tuple

import jax

from repro.core import callsite as cs
from repro.core import faults as flt
from repro.core import memspace
from repro.core import precision as prec
from repro.core import residency as res
from repro.core import threshold as thr
from repro.core.config import OffloadConfig
from repro.core.policy import CounterPolicy, PolicyBase, make_policy
from repro.core.trace import Trace

#: how many in-flight outputs the async mode keeps alive for ``sync()``;
#: XLA executes in submission order, so a bounded window is enough.
_PENDING_WINDOW = 32

#: dispatch-decision entries kept per runtime before a full reset
#: (long-lived servers over ragged shapes must not leak decisions).
_DECISION_CACHE_LIMIT = 65536


# --------------------------------------------------------------------- #
# tile plans (built by core.blas, executed by the scheduler below)       #
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class TileOp:
    """One operand block of one tile.

    ``coords`` is the (r0, r1, c0, c1) window on the *parent* array —
    the stable block identity the affinity registry keys on, so the same
    window of the same buffer lands on the same device call after call.
    ``shared`` marks blocks identical across every tile of the plan
    (e.g. the triangle of trsm): they replicate per device and must not
    steer affinity, or every tile would chase one device.
    """

    role: str
    parent: jax.Array
    coords: Tuple[int, int, int, int]
    shared: bool = False
    written: bool = False

    def key(self) -> Tuple:
        return (id(self.parent),) + self.coords

    @property
    def nbytes(self) -> int:
        r0, r1, c0, c1 = self.coords
        return (r1 - r0) * (c1 - c0) * self.parent.dtype.itemsize

    def materialize(self) -> jax.Array:
        r0, r1, c0, c1 = self.coords
        if (r0, c0) == (0, 0) and (r1, c1) == self.parent.shape[-2:]:
            return self.parent
        return self.parent[r0:r1, c0:c1]


@dataclasses.dataclass
class Tile:
    """One unit of scheduled work: placed operand blocks -> output block."""

    ops: Tuple[TileOp, ...]
    compute: Callable[..., jax.Array]
    out_coords: Tuple[int, int, int, int]


@dataclasses.dataclass
class TilePlan:
    """A 2-D decomposition of one level-3 call plus its gather."""

    grid: Tuple[int, int]
    tiles: Tuple[Tile, ...]
    gather: Callable[[Sequence[jax.Array]], jax.Array]


# --------------------------------------------------------------------- #
# dispatch-pipeline IR                                                   #
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class CallContext:
    """One canonicalized BLAS call flowing through the pipeline."""

    routine: str
    m: int
    n: int
    k: int
    batch: int
    operands: Sequence[Tuple[str, jax.Array, float, bool]]
    arrays: list
    compute: Callable[..., jax.Array]
    key: Optional[Hashable]
    shard: Optional[Callable[[int], Optional["TilePlan"]]]
    # the pallas-venue arithmetic for this call (same operand order as
    # ``compute``); None when the routine has no kernel — the venue
    # resolution then falls back to the generic XLA offload
    kernel_compute: Optional[Callable[..., jax.Array]] = None
    # split-precision factory ``(scheme, venue) -> compute`` (same
    # placed operand order); None when the call has no split
    # formulation (non-f64 dtype, unsupported base).  Built lazily by
    # core.blas only when SCILIB_PRECISION is configured, so the
    # default pipeline never pays for it.
    split_compute: Optional[Callable[[str, str],
                                     Callable[..., jax.Array]]] = None
    # sampled-residual estimator ``(out, *arrays) -> rel error`` for
    # the escalation check (repro.core.precision.gemm_residual et al.,
    # with the call's scalars/flags captured); None disables the check.
    split_check: Optional[Callable[..., jax.Array]] = None
    site: Optional[cs.CallSiteProfile] = None
    site_id: str = ""


@dataclasses.dataclass
class DispatchDecision:
    """The small dispatch IR a decision stage emits: offload?  why?
    Later stages attach the tile plan (device? shard plan?)."""

    offload: bool
    n_avg: float = 0.0
    why: str = "threshold"      # "cache" | "threshold" | "adaptive:probe"
    #                           # | "adaptive:locked" | "policy:host-only"
    #                           # (+ "+kernel" suffix on the pallas venue)
    plan: Optional[TilePlan] = None
    timed: bool = False         # adaptive probe: block + bill path timing
    # execution venue ("host"/"xla"/"pallas"); "" with kernel_path off,
    # so the default pipeline is byte-identical to the two-venue one
    venue: str = ""
    # split-precision scheme ("split2"/"split3"); "" with
    # SCILIB_PRECISION off, keeping the default pipeline bit-identical.
    # An escalated call keeps the attempted scheme (why gains "+esc").
    precision: str = ""


@dataclasses.dataclass
class RoutineStats:
    calls: int = 0
    offloaded: int = 0
    on_host: int = 0
    seconds: float = 0.0
    flops: float = 0.0
    bytes_in: int = 0
    bytes_out: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    # dispatch fast path: calls whose offload decision came from the
    # per-call-site dispatch cache vs. calls that had to derive it
    dispatch_hits: int = 0
    dispatch_misses: int = 0
    # bytes streamed from the host tier without persisting (the coherent
    # remote-read path of GH200; a transient copy on this container)
    transient_bytes: int = 0
    # multi-device tile scheduler: calls split across devices / tiles run
    sharded: int = 0
    tiles: int = 0
    # failure paths: transient-fault retries and host fallbacks after
    # retry exhaustion / quarantine (the call still completed, on host)
    retries: int = 0
    fallbacks: int = 0
    # kernel_path: offloaded calls executed on the pallas venue (a
    # subset of ``offloaded``) and their wall time
    kernel_calls: int = 0
    kernel_seconds: float = 0.0
    # split precision (SCILIB_PRECISION): offloaded calls executed via
    # a split scheme (subset of ``offloaded``), their wall time, and
    # calls whose residual check escalated back to native fp64
    split_calls: int = 0
    split_seconds: float = 0.0
    escalations: int = 0


@dataclasses.dataclass
class DeviceStats:
    """Per-device-tier accounting of the multi-device tile scheduler."""

    tiles: int = 0               # tile kernels scheduled on this device
    moved_bytes: int = 0         # host -> this device block movement
    affinity_hits: int = 0       # blocks already resident here (free)
    evictions: int = 0           # per-device byte-cap LRU pressure
    evicted_bytes: int = 0


@dataclasses.dataclass
class SolverStats:
    """Per-solver accounting of the LAPACK tier (:mod:`repro.solvers`):
    one row per solver name, aggregated over that solver's spans."""

    spans: int = 0               # solver_begin/solver_end pairs
    calls: int = 0               # inner BLAS + panel calls in the spans
    panel_calls: int = 0         # unblocked getf2 panels (host work)
    moved_bytes: int = 0         # movement attributed to the spans
    seconds: float = 0.0         # wall time inside the spans


@dataclasses.dataclass
class RuntimeStats:
    per_routine: Dict[str, RoutineStats] = dataclasses.field(
        default_factory=dict)
    per_device: Dict[int, DeviceStats] = dataclasses.field(
        default_factory=dict)
    # LAPACK-tier solver spans (getrf/potrf/syev...), keyed by solver
    # name — empty (and invisible in the report) unless repro.solvers ran
    solvers: Dict[str, SolverStats] = dataclasses.field(
        default_factory=dict)
    uninstrumented_calls: int = 0
    # placement-registry cap pressure (mirrors the residency store)
    evictions: int = 0
    evicted_bytes: int = 0
    # evicted entries placed again later: the cap's real cost in link
    # traffic (summed over the placement and per-device block stores)
    refetches: int = 0
    refetched_bytes: int = 0
    # failure-path counters (each increments exactly when the matching
    # trace event is emitted, so a live run and its replay agree)
    faults: int = 0            # fault errors observed (injected + real)
    retries: int = 0           # transient-fault retries performed
    fallbacks: int = 0         # calls completed on host after a failure
    quarantines: int = 0       # breaker trips (incl. half-open re-trips)
    recoveries: int = 0        # quarantined devices re-admitted
    # per-call-site profiles (shared with the owning runtime's registry)
    callsites: Optional[cs.CallSiteRegistry] = None
    # the owning runtime's per-device circuit breaker (health section)
    breaker: Optional[flt.HealthTracker] = None

    def routine(self, name: str) -> RoutineStats:
        return self.per_routine.setdefault(name, RoutineStats())

    def solver(self, name: str) -> SolverStats:
        return self.solvers.setdefault(name, SolverStats())

    def device(self, index: int) -> DeviceStats:
        return self.per_device.setdefault(index, DeviceStats())

    @property
    def total_moved_bytes(self) -> int:
        return sum(r.bytes_in + r.bytes_out
                   for r in self.per_routine.values())

    def reuse_ratio(self) -> float:
        hits = sum(r.cache_hits for r in self.per_routine.values())
        miss = sum(r.cache_misses for r in self.per_routine.values())
        return hits / max(1, miss)

    def dispatch_hit_ratio(self) -> float:
        hits = sum(r.dispatch_hits for r in self.per_routine.values())
        total = hits + sum(r.dispatch_misses
                           for r in self.per_routine.values())
        return hits / max(1, total)

    def report(self) -> str:
        lines = ["scilib-accel runtime report",
                 f"{'routine':<10}{'calls':>8}{'offload':>9}{'host':>7}"
                 f"{'sec':>10}{'GB moved':>10}{'reuse':>8}{'dhit':>7}"]
        for name, r in sorted(self.per_routine.items()):
            gb = (r.bytes_in + r.bytes_out) / 1e9
            reuse = r.cache_hits / max(1, r.cache_misses)
            dhit = r.dispatch_hits / max(1, r.dispatch_hits
                                         + r.dispatch_misses)
            lines.append(f"{name:<10}{r.calls:>8}{r.offloaded:>9}"
                         f"{r.on_host:>7}{r.seconds:>10.3f}{gb:>10.3f}"
                         f"{reuse:>8.1f}{dhit:>7.2f}")
        lines.append(f"uninstrumented calls: {self.uninstrumented_calls}")
        if self.evictions:
            lines.append(f"evictions: {self.evictions} "
                         f"({self.evicted_bytes / 1e9:.3f} GB)")
        if self.refetches:
            lines.append(f"refetches: {self.refetches} "
                         f"({self.refetched_bytes / 1e9:.3f} GB)")
        if self.per_device:
            lines.append(f"{'device':<10}{'tiles':>8}{'GB moved':>10}"
                         f"{'affinity':>10}{'evict':>7}")
            for dev, d in sorted(self.per_device.items()):
                lines.append(f"{'dev' + str(dev):<10}{d.tiles:>8}"
                             f"{d.moved_bytes / 1e9:>10.3f}"
                             f"{d.affinity_hits:>10}{d.evictions:>7}")
        kernel_calls = sum(r.kernel_calls
                           for r in self.per_routine.values())
        if kernel_calls:
            # the venue section appears only once the pallas venue ran,
            # so kernel_path=0 reports are byte-identical to before
            ksec = sum(r.kernel_seconds
                       for r in self.per_routine.values())
            lines.append(f"pallas venue: {kernel_calls} calls "
                         f"({ksec:.3f} s)")
        split_calls = sum(r.split_calls
                          for r in self.per_routine.values())
        if split_calls:
            # precision section appears only once a split scheme ran,
            # so SCILIB_PRECISION-off reports are byte-identical
            ssec = sum(r.split_seconds
                       for r in self.per_routine.values())
            esc = sum(r.escalations for r in self.per_routine.values())
            lines.append(f"split precision: {split_calls} calls "
                         f"({ssec:.3f} s, {esc} escalations)")
        if self.solvers:
            # the solver section appears only once a LAPACK-tier span
            # ran, so solver-free reports are byte-identical to before
            lines.append("solvers (LAPACK tier)")
            lines.append(f"{'solver':<10}{'spans':>7}{'calls':>8}"
                         f"{'panel%':>8}{'GB moved':>10}{'sec':>9}")
            for name, s in sorted(self.solvers.items()):
                pct = 100.0 * s.panel_calls / max(1, s.calls)
                lines.append(f"{name:<10}{s.spans:>7}{s.calls:>8}"
                             f"{pct:>8.0f}{s.moved_bytes / 1e9:>10.3f}"
                             f"{s.seconds:>9.3f}")
        fault_activity = (self.faults + self.retries + self.fallbacks
                          + self.quarantines + self.recoveries)
        if fault_activity:
            # the health section appears only once failure paths ran, so
            # fault-free reports are byte-identical to older releases
            lines.append(f"health: faults={self.faults} "
                         f"retries={self.retries} "
                         f"fallbacks={self.fallbacks} "
                         f"quarantines={self.quarantines} "
                         f"recoveries={self.recoveries}")
            if self.breaker is not None:
                for d, h in enumerate(self.breaker.devices()):
                    lines.append(f"  dev{d}: {h.state} "
                                 f"consecutive={h.consecutive} "
                                 f"failures={h.failures} "
                                 f"quarantines={h.quarantines}")
        if self.callsites is not None and len(self.callsites):
            lines.append("call sites (top by flops; * = adaptive lock)")
            lines.append(f"{'site':<44}{'calls':>7}{'GFLOP':>9}"
                         f"{'decision':>10}{'hit%':>6}{'sec':>9}")
            for p in self.callsites.top_by_flops():
                site = (p.site if len(p.site) <= 43
                        else p.site[:40] + "...")
                lines.append(f"{site:<44}{p.calls:>7}"
                             f"{p.flops / 1e9:>9.2f}"
                             f"{p.decision_label():>10}"
                             f"{100 * p.hit_rate:>6.0f}{p.seconds:>9.3f}")
        return "\n".join(lines)


#: real-FLOP factors per base routine (shared by the access-counter
#: arithmetic-intensity input and the per-site flops accounting)
_FLOP_FACTORS = {
    "gemm": lambda m, n, k: 2.0 * m * n * k,
    "gemv": lambda m, n, k: 2.0 * m * n,
    "trsm": lambda m, n, k: 1.0 * m * m * n,
    "trmm": lambda m, n, k: 1.0 * m * m * n,
    "syrk": lambda m, n, k: 1.0 * n * n * k,
    "herk": lambda m, n, k: 1.0 * n * n * k,
    "symm": lambda m, n, k: 2.0 * m * m * n,
    "hemm": lambda m, n, k: 2.0 * m * m * n,
    "syr2k": lambda m, n, k: 2.0 * n * n * k,
    "her2k": lambda m, n, k: 2.0 * n * n * k,
}


def _flops_of(routine: str, m: int, n: int, k: int, batch: int = 1) -> float:
    """Real-FLOP count, matching :meth:`repro.core.trace.BlasCall.flops`:
    complex multiply-adds cost 4x their real counterparts."""
    fn = _FLOP_FACTORS.get(thr.base_routine(routine))
    if fn is None:
        return 0.0
    mult = 4.0 if routine[:1] in ("c", "z") else 1.0
    return mult * batch * fn(m, n, k)


class SolverSpan:
    """A live LAPACK-tier solver span (``solver_begin`` ..
    ``solver_end``).  While it is the innermost open span, every BLAS
    call the runtime records is stamped with its ``span_id``
    (``"<solver>#<seq>"``), and the factor buffer handed to
    :meth:`OffloadRuntime.solver_begin` stays pinned on the device tier
    for the span's lifetime — the ~780x-reuse pattern of the LSMS
    workload (``apps/lsms.py``) made explicit."""

    __slots__ = ("name", "span_id", "factor", "pinned", "t0", "moved0")

    def __init__(self, name: str, span_id: str, factor, pinned: bool,
                 t0: float, moved0: int):
        self.name = name
        self.span_id = span_id
        self.factor = factor
        self.pinned = pinned
        self.t0 = t0
        self.moved0 = moved0

    def __repr__(self) -> str:
        return f"SolverSpan({self.span_id})"


class OffloadRuntime:
    """Placement + dispatch brain behind the intercepted BLAS surface."""

    def __init__(self, config: Optional[OffloadConfig] = None, *,
                 policy: Optional[str] = None,
                 threshold: Optional[float] = None,
                 record_trace: bool = True,
                 sync: Optional[bool] = None,
                 device_bytes: Optional[int] = None,
                 session_id: str = "",
                 pool: Optional[res.SharedDevicePool] = None):
        # the legacy keyword surface resolves to a config with the
        # historical precedence (env SCILIB_POLICY/THRESHOLD over args,
        # explicit sync/device_bytes args over env); an explicit config
        # is taken as-is — no environment is read after this line.
        if config is None:
            config = OffloadConfig.legacy(policy=policy,
                                          threshold=threshold, sync=sync,
                                          device_bytes=device_bytes)
        self.config = config
        # thread safety (PR 7): the dispatch lock serializes whole
        # calls when several threads adopt one session (Session.scope);
        # the stats lock is a leaf guarding counter updates that can
        # arrive on *another* tenant's thread (shared-pool evictions
        # reach this runtime's stores from whichever thread overflowed
        # the pool).  Order: runtime lock -> health -> store -> pool,
        # with the stats lock a leaf acquired under any of them.
        self._lock = threading.RLock()
        self._stats_lock = threading.Lock()
        self.policy: PolicyBase = make_policy(config.policy)
        self.memspace = memspace.install(
            n_devices=config.resolved_devices())
        self.threshold = config.resolved_threshold()
        self.stats = RuntimeStats()
        self.trace: Optional[Trace] = Trace() if record_trace else None
        self.debug = config.debug
        self.sync_mode = bool(config.sync)
        self.dispatch_cache_enabled = config.dispatch_cache
        # per-call-site profiling (cheap fingerprint; callsite=False
        # turns the whole site layer off) and the adaptive per-site mode
        self.callsite_enabled = config.callsite
        self.adaptive = config.adaptive
        self.adaptive_warmup = config.adaptive_warmup
        # the pallas execution venue (SCILIB_KERNELS): off by default so
        # the two-venue pipeline below stays bit-identical
        self.kernel_path = bool(config.kernel_path)
        self.kernel_block = int(config.kernel_block)
        # split-precision emulation (SCILIB_PRECISION): off by default,
        # keeping the dispatch pipeline bit-identical to native fp64
        self.precision = str(config.precision)
        self.precision_rtol = float(config.precision_rtol)
        self.callsites = cs.CallSiteRegistry()
        self.stats.callsites = self.callsites
        # ordered decision stages: first stage to return a decision wins.
        # Later policy PRs extend dispatch by inserting here, not by
        # editing branches inside blas_call.
        self.decision_stages = [self._stage_adaptive,
                                self._stage_cached,
                                self._stage_threshold]
        # keep the blas-level scalar/kernel caches on the same flag even
        # when a runtime is constructed directly (not via install())
        from repro.core import blas
        blas.refresh_cache_flag(config.dispatch_cache)
        self.device_bytes_cap: Optional[int] = config.device_bytes
        # the residency engine: every registry below is one ResidencyStore
        # (repro.core.residency) — the same class the memtier simulator
        # replays, so live and simulated eviction accounting agree.
        self.evict_policy = config.evict
        self.pin_all = config.pin
        # per-call-site dispatch cache: key -> (offload, n_avg)
        self._decisions: Dict[Hashable, Tuple[bool, float]] = {}
        # placement registry: id(src) -> placed device-tier buffer
        self.placements = res.ResidencyStore(
            "placements", cap=self.device_bytes_cap,
            policy=self.evict_policy, pin_new=self.pin_all,
            on_evict=self._on_placement_evict, emit=self._emit_event)
        # multi-device tile scheduler: one block store per device tier,
        # block key -> placed block, plus the round-robin cursor for
        # blocks with no residency anywhere.
        self.n_devices = int(self.memspace.n_devices)
        self.block_stores = [
            res.ResidencyStore(
                f"dev{d}", cap=self.device_bytes_cap,
                policy=self.evict_policy, pin_new=self.pin_all,
                on_evict=self._block_evict_hook(d), emit=self._emit_event)
            for d in range(self.n_devices)]
        self._rr_cursor = 0
        # tiles assigned to each device within the call being scheduled
        # (tie-breaker: replicated blocks score several devices equally)
        self._sched_load: list = [0] * self.n_devices
        # fault tolerance: deterministic injector (SCILIB_FAULTS), the
        # transient-fault retry policy, and the per-device breaker whose
        # trips invalidate block stores and steer the tile scheduler
        self.faults = flt.FaultInjector.from_spec(config.faults)
        self.retry = flt.RetryPolicy(attempts=config.retries,
                                     backoff_ms=config.backoff_ms)
        self.health = flt.HealthTracker(
            self.n_devices, threshold=config.breaker,
            cooldown_ms=config.breaker_cooldown_ms,
            on_quarantine=self._on_quarantine,
            on_recover=self._on_recover)
        self.stats.breaker = self.health
        # transfer faults inject inside memspace (the real movement call
        # sites); the hook is installed by activate(), never here — a
        # merely-constructed runtime must not clobber the active one's
        # async mode: recent in-flight outputs, drained by sync()
        self._pending: "collections.deque[jax.Array]" = collections.deque(
            maxlen=_PENDING_WINDOW)
        # LAPACK-tier solver spans (repro.solvers): innermost-last stack
        # of open spans; the top span stamps every recorded BLAS call
        self._solver_stack: list = []
        self._solver_seq = 0
        # trace-buffer ids: id(arr) -> trace buffer id (uncapped store:
        # entries live exactly as long as their anchor array)
        self._trace_ids = res.ResidencyStore("traceids")
        self._reuse_by_buffer: Dict[int, int] = {}
        # multi-tenancy: join the shared pool (quota from the config),
        # binding the placement + block stores so their residency charges
        # the pool's per-tenant ledger.  An unnamed pooled session gets
        # an auto-assigned tenant id; unpooled unnamed runtimes keep ""
        # (their trace events serialize exactly as before).
        self.pool = pool
        if pool is not None:
            self.session_id = pool.register(session_id,
                                            quota=config.pool_quota)
            pool.attach(self.session_id, self.placements,
                        *self.block_stores)
        else:
            self.session_id = session_id

    def detach_pool(self) -> None:
        """Leave the shared pool (session close): the tenant's usage is
        forgotten, its lifetime counters stay in the pool totals."""
        if self.pool is not None:
            self.pool.unregister(self.session_id)
            self.pool = None

    # ------------------------------------------------------------------ #
    # safe mid-run reconfiguration (Session.reconfigure lands here)       #
    # ------------------------------------------------------------------ #
    def apply_config(self, new: OffloadConfig) -> None:
        """Apply a new (already validated) config to the live runtime.

        Everything that can change safely changes in place; state the
        change invalidates is flushed rather than left stale:

        * the memoized dispatch cache is always cleared (its entries
          encode threshold decisions),
        * a policy / threshold / adaptive change resets adaptive
          per-site locks (and a policy change also discards the probe
          timings, which were measured under the old policy),
        * residency caps, eviction policy and pinning update on every
          store, with an immediate eviction sweep under a tightened cap.

        The device-tier count is topology, fixed at construction:
        changing it raises ``ValueError`` (open a new session instead).
        """
        with self._lock:
            self._apply_config_locked(new)

    def _apply_config_locked(self, new: OffloadConfig) -> None:
        old = self.config
        if new.resolved_devices() != self.n_devices:
            raise ValueError(
                f"devices cannot change on a live runtime "
                f"({self.n_devices} -> {new.resolved_devices()}); "
                f"open a new session")
        old_threshold = self.threshold
        self.config = new
        self.threshold = new.resolved_threshold()
        self.sync_mode = bool(new.sync)
        self.debug = new.debug
        self.dispatch_cache_enabled = new.dispatch_cache
        self.callsite_enabled = new.callsite
        self.adaptive = new.adaptive
        self.adaptive_warmup = new.adaptive_warmup
        from repro.core import blas
        blas.refresh_cache_flag(new.dispatch_cache)
        self._decisions.clear()
        policy_changed = new.policy != old.policy
        if policy_changed:
            self.policy = make_policy(new.policy)
        kernel_changed = new.kernel_path != old.kernel_path
        precision_changed = (new.precision != old.precision
                             or new.precision_rtol != old.precision_rtol)
        if (policy_changed or self.threshold != old_threshold
                or new.adaptive != old.adaptive or kernel_changed
                or precision_changed):
            for prof in self.callsites:
                prof.locked = None
                prof.locked_why = ""
                prof.locked_venue = ""
                prof.locked_precision = ""
                if policy_changed:     # old timings measured a dead path
                    prof.host_timed = prof.device_timed = 0
                    prof.host_seconds = prof.device_seconds = 0.0
                    prof.host_best = prof.device_best = float("inf")
                if policy_changed or kernel_changed:
                    # kernel-venue samples are only comparable within
                    # one (policy, kernel_path) regime
                    prof.kernel_timed = 0
                    prof.kernel_seconds = 0.0
                    prof.kernel_best = float("inf")
                if policy_changed or precision_changed:
                    # split samples timed one (scheme, rtol) regime
                    prof.split_timed = 0
                    prof.split_seconds = 0.0
                    prof.split_best = float("inf")
                    prof.split_scheme = ""
                    prof.split_venue = ""
                    prof.split_bad = False
        self.kernel_path = bool(new.kernel_path)
        self.kernel_block = int(new.kernel_block)
        self.precision = str(new.precision)
        self.precision_rtol = float(new.precision_rtol)
        self.device_bytes_cap = new.device_bytes
        self.evict_policy = new.evict
        pin_changed = new.pin != self.pin_all
        self.pin_all = new.pin
        for store in (self.placements, *self.block_stores):
            store.cap = new.device_bytes
            store.policy = res.make_eviction_policy(new.evict)
            store.pin_new = new.pin
            if pin_changed:
                # pin=True pins existing residents too; pin=False makes
                # them evictable again (entries pinned under pin-all are
                # indistinguishable from explicit pins, and leaving them
                # pinned would render a newly-set cap unenforceable)
                for key in list(store.keys()):
                    (store.pin if new.pin else store.unpin)(key)
            store.evict_over_cap()
        # fault tolerance: a new spec gets a fresh injector (counters and
        # RNG restart — the spec defines the sequence); the breaker keeps
        # per-device state so reconfiguring knobs cannot un-quarantine a
        # sick device (disabling the breaker does re-admit everything)
        self.faults = flt.FaultInjector.from_spec(new.faults)
        self.retry = flt.RetryPolicy(attempts=new.retries,
                                     backoff_ms=new.backoff_ms)
        self.health.reconfigure(threshold=new.breaker,
                                cooldown_ms=new.breaker_cooldown_ms)
        if active() is self:
            memspace.set_fault_hook(self._transfer_fault_hook())
            memspace.set_debug(new.debug)

    # ------------------------------------------------------------------ #
    # the residency engine: event + eviction hooks, pinning               #
    # ------------------------------------------------------------------ #
    def _emit_event(self, kind: str, store: str, nbytes: int) -> None:
        """Mirror one residency transition into the trace and the
        refetch statistics (place/hit/evict/refetch) — and, through the
        same channel, the fault-tolerance transitions
        (fault/retry/fallback/quarantine/recover).  Shared-pool
        pressure can deliver these on another tenant's thread, so the
        counter updates take the leaf stats lock."""
        if kind == "refetch":
            with self._stats_lock:
                self.stats.refetches += 1
                self.stats.refetched_bytes += nbytes
        if self.trace is not None:
            self.trace.record_event(kind, store, nbytes,
                                    session=self.session_id)

    def _on_placement_evict(self, key, placed, nbytes: int) -> None:
        """Cap pressure pushed a placement out: re-tag the buffer
        host-side so the next use re-migrates (and is counted again).
        JAX arrays are immutable: on real-tier backends the HBM itself
        is released once the application's own references die — the
        registry cannot forcibly move a borrowed handle — while the
        simulated tier models the re-migration cost with a real copy."""
        memspace.tag_host(placed)
        with self._stats_lock:
            self.stats.evictions += 1
            self.stats.evicted_bytes += nbytes
        if self.debug >= 1:
            print(f"[scilib] evict {nbytes} B "
                  f"(resident {self.placements.resident_bytes} B)")

    def _block_evict_hook(self, device: int):
        """Per-device eviction callback for the tile-block stores."""
        def _on_evict(key, placed, nbytes, device=device, self=self):
            memspace.tag_host(placed)
            with self._stats_lock:
                dst = self.stats.device(device)
                dst.evictions += 1
                dst.evicted_bytes += nbytes
            if self.debug >= 1:
                print(f"[scilib] dev{device} evict block {nbytes} B "
                      f"(resident "
                      f"{self.block_stores[device].resident_bytes} B)")
        return _on_evict

    # ------------------------------------------------------------------ #
    # fault tolerance: guard, retry, fallback, per-device breaker         #
    # ------------------------------------------------------------------ #
    def _transfer_fault_hook(self):
        """The injector's transfer check, as memspace's hook (None when
        injection is off — the hook test stays one pointer compare)."""
        if self.faults is None:
            return None
        inj = self.faults

        def _hook(device, nbytes):
            inj.check("transfer", device=device, nbytes=nbytes)
        return _hook

    def _guarded(self, site: str, fn, *, device: int, nbytes: int,
                 st: RoutineStats):
        """Run one transfer or kernel *unit* under the fault guard.

        The unit is the smallest retryable operation (one block
        movement, one tile kernel): injection happens at its entry,
        before any state mutates, so a fault absorbed by a retry leaves
        every counter and residency structure bit-identical to an
        unfaulted run.  Transient faults retry with exponential backoff
        (``SCILIB_RETRIES`` / ``SCILIB_BACKOFF_MS``); exhaustion or a
        permanent fault records one breaker failure against ``device``
        and raises — :meth:`_execute` turns that into a host fallback.
        """
        attempt = 0
        while True:
            try:
                if site == "kernel" and self.faults is not None:
                    self.faults.check("kernel", device=device,
                                      nbytes=nbytes)
                out = fn()
            except Exception as raw:
                err = flt.classify(site, raw, device=device,
                                   nbytes=nbytes)
                if err is None:       # a bug, not a device fault
                    raise
                self.stats.faults += 1
                self._emit_event("fault", f"{err.kind}@dev{device}",
                                 nbytes)
                if self.debug >= 1:
                    print(f"[scilib] {site} fault on dev{device} "
                          f"(attempt {attempt}): {err}")
                if err.transient and attempt < self.retry.attempts:
                    self.stats.retries += 1
                    st.retries += 1
                    self._emit_event("retry", f"{site}@dev{device}",
                                     nbytes)
                    self.retry.sleep(attempt)
                    attempt += 1
                    continue
                self.health.failure(device)
                if err is raw:
                    raise
                raise err from raw
            else:
                self.health.ok(device)
                return out

    def _on_quarantine(self, device: int) -> None:
        """Breaker trip: invalidate everything resident on the device
        (evict-style events — the next use re-places on a healthy tier)
        and record the transition.  The tile scheduler and the plan
        stage consult ``health.usable`` and re-shard around it."""
        self.stats.quarantines += 1
        invalidated = self.block_stores[device].evict_all()
        if device == 0:
            # the whole-call placement registry is homed on tier 0
            invalidated += self.placements.evict_all()
        self._emit_event("quarantine", f"dev{device}", 0)
        if self.debug >= 1:
            print(f"[scilib] dev{device} quarantined "
                  f"({invalidated} residents invalidated)")

    def _on_recover(self, device: int) -> None:
        """Half-open probe succeeded: the device is healthy again."""
        self.stats.recoveries += 1
        self._emit_event("recover", f"dev{device}", 0)
        if self.debug >= 1:
            print(f"[scilib] dev{device} recovered")

    def device_usable(self, device: int) -> bool:
        """May the scheduler route work to this device tier now?"""
        return self.health.usable(device)

    def _whole_device(self) -> int:
        """Device-tier index the whole-call (unsharded) offload path is
        attributed to: tier 0, or the first usable tier when 0 is
        quarantined (the logical DEVICE put has no index of its own)."""
        if self.n_devices == 1 or self.health.usable(0):
            return 0
        for d in range(1, self.n_devices):
            if self.health.usable(d):
                return d
        return 0

    def _fallback_host(self, call: CallContext,
                       decision: DispatchDecision, st: RoutineStats,
                       exc: flt.OffloadError) -> jax.Array:
        """Retry exhausted (or a permanent fault): run the call on the
        host path — the same jitted arithmetic on the same operand
        values, so the result is bit-identical to an unoffloaded run —
        and surface the decision as ``fallback:<kind>`` in the IR."""
        decision.offload = False
        decision.plan = None
        decision.why = f"fallback:{exc.kind}"
        decision.precision = ""        # the host rerun is native fp64
        self.stats.fallbacks += 1
        st.fallbacks += 1
        st.on_host += 1
        dev = exc.device if exc.device is not None else 0
        self._emit_event("fallback", f"{exc.kind}@dev{dev}", exc.nbytes)
        if self.debug >= 1:
            print(f"[scilib] {call.routine} falling back to host: {exc}")
        return call.compute(*self._harmonize(call.arrays, st))

    def pin(self, x: jax.Array) -> jax.Array:
        """Pin a buffer on the device tier: place it now if needed and
        mark it never-evictable — it survives arbitrary cap pressure
        until :meth:`unpin` or the buffer dies.  Returns the placed
        device-tier buffer (the pinned residency the next calls hit).
        Pinning is a user-level movement with no fallback path, so it
        opts out of fault injection."""
        with self._lock:
            placed = self.placements.get(id(x))
            if placed is None:
                placed = (x if memspace.tier_of(x) == memspace.DEVICE
                          else memspace.put(x, memspace.DEVICE,
                                            check=False))
                self.placements.put(id(x), placed, placed.nbytes,
                                    anchor=x)
                self.alias_trace_id(x, placed)
            self.placements.pin(id(x))
            return placed

    def unpin(self, x: jax.Array) -> None:
        """Make a pinned buffer evictable again (it stays resident until
        cap pressure actually selects it)."""
        with self._lock:
            self.placements.unpin(id(x))

    def note_uninstrumented(self) -> None:
        """Count one BLAS-shaped call the interceptors saw but could not
        canonicalize (thread-safe: trampolines fire on any thread)."""
        with self._stats_lock:
            self.stats.uninstrumented_calls += 1

    # ------------------------------------------------------------------ #
    # LAPACK-tier solver spans (repro.solvers drives these)               #
    # ------------------------------------------------------------------ #
    def solver_begin(self, name: str, factor=None) -> SolverSpan:
        """Open a solver span: emit the ``solver_begin`` trace event,
        pin the in-place factor buffer for the span's lifetime (the
        factorization re-reads it once per inner BLAS call — the LSMS
        ~780x-reuse pattern), and make the span the stamp for every
        BLAS call recorded until :meth:`solver_end`."""
        with self._lock:
            span_id = f"{name}#{self._solver_seq}"
            self._solver_seq += 1
            nbytes = int(getattr(factor, "nbytes", 0) or 0)
            pinned = False
            if (factor is not None and self.config.policy != "cpu"
                    and isinstance(factor, jax.Array)
                    and not isinstance(factor, jax.core.Tracer)):
                self.pin(factor)
                pinned = True
            self.stats.solver(name).spans += 1
            span = SolverSpan(name, span_id, factor, pinned,
                              time.perf_counter(),
                              self.stats.total_moved_bytes)
            self._solver_stack.append(span)
            self._emit_event("solver_begin", span_id, nbytes)
            return span

    def solver_end(self, span: SolverSpan) -> None:
        """Close a solver span: unpin the factor (it stays resident
        until cap pressure selects it), fold the span's wall time and
        movement delta into the per-solver statistics, and emit the
        ``solver_end`` trace event."""
        with self._lock:
            try:
                self._solver_stack.remove(span)
            except ValueError:
                return                    # already closed (idempotent)
            if span.pinned and span.factor is not None:
                self.unpin(span.factor)
            st = self.stats.solver(span.name)
            st.seconds += time.perf_counter() - span.t0
            st.moved_bytes += max(
                0, self.stats.total_moved_bytes - span.moved0)
            self._emit_event("solver_end", span.span_id, 0)

    def note_panel(self, prec: str, m: int, nb: int, a) -> None:
        """Record one unblocked panel factorization (``getf2`` — the
        host-side work inside a blocked driver).  Panels are recorded
        only inside a solver span: outside the LAPACK tier the drivers
        emit exactly the BLAS stream they always did, keeping
        pre-solver traces and counters byte-identical."""
        with self._lock:
            if not self._solver_stack:
                return
            span = self._solver_stack[-1]
            sst = self.stats.solver(span.name)
            sst.calls += 1
            sst.panel_calls += 1
            rst = self.stats.routine(f"{prec}getf2")
            rst.calls += 1
            rst.on_host += 1
            if self.trace is not None:
                bid = self._trace_id(a, "P")
                el = a.dtype.itemsize
                from repro.core.trace import BlasCall
                self.trace.calls.append(BlasCall(
                    routine=f"{prec}getf2", m=m, n=nb, k=0,
                    operands=(("P", bid, m * nb * el, float(nb), True),),
                    solver_id=span.span_id))

    def resident_bytes(self) -> int:
        return self.placements.resident_bytes

    # ------------------------------------------------------------------ #
    # multi-device block stores + tile scheduler                          #
    # ------------------------------------------------------------------ #
    def next_device(self) -> int:
        """Round-robin cursor for blocks with no residency anywhere.
        Quarantined devices are skipped; with every device quarantined
        the cursor value is returned anyway (callers only reach here
        when the degraded-mode check has already allowed offload)."""
        for _ in range(self.n_devices):
            dev = self._rr_cursor % self.n_devices
            self._rr_cursor += 1
            if self.health.usable(dev):
                return dev
        return dev

    def scheduled_load(self, device: int) -> int:
        """Tiles already assigned to a device in the call being
        scheduled (the affinity tie-breaker)."""
        return self._sched_load[device]

    def device_resident_bytes(self, device: int) -> int:
        return self.block_stores[device].resident_bytes

    def _place_block(self, device: int, op: TileOp,
                     st: RoutineStats) -> Tuple[jax.Array, int, bool]:
        """Materialize one operand block on one device tier.

        Returns (placed block, bytes moved, affinity hit).  Persistent
        policies (DFU/counter/pinned) register the block so later calls
        find it resident; Mem-Copy stages fresh every call.  The actual
        movement runs under the fault guard — a retried block put is a
        perfect no-op (cache hits return above and never see it)."""
        key = op.key()
        store = self.block_stores[device]
        persistent = self.policy.persistent
        if persistent:
            cached = store.get(key)
            if cached is not None:
                return cached, 0, True
        block = op.materialize()
        placed = self._guarded(
            "transfer", lambda: memspace.put_block(block, device),
            device=device, nbytes=op.nbytes, st=st)
        # a no-op put (block already home on this device, e.g. a chained
        # output reused whole) moved nothing — keep the stats honest
        moved = 0 if placed is block else op.nbytes
        if persistent:
            store.put(key, placed, placed.nbytes, anchor=op.parent)
        return placed, moved, False

    def _sharded_call(self, st: RoutineStats, plan: TilePlan,
                      site: Optional[cs.CallSiteProfile] = None,
                      ) -> Tuple[jax.Array, Tuple[int, ...]]:
        """Execute one call as scheduled tiles and gather the output.

        Device choice is the policy's (:meth:`PolicyBase.select_device`):
        affinity first — the device already holding the most operand-block
        bytes — then round-robin.  Output blocks are registered on their
        device so the next call slicing the gathered result at the same
        coordinates reuses them for free (the BLASX chained-call path)."""
        # Phase 1 — schedule every tile against the residency state at
        # call entry, so blocks placed by the first tiles of this call
        # cannot gravitationally pull the rest onto one device.
        self._sched_load = [0] * self.n_devices
        devices = []
        for tile in plan.tiles:
            dev = self.policy.select_device(
                self, [(op.key(), op.nbytes, op.shared) for op in tile.ops])
            self._sched_load[dev] += 1
            devices.append(dev)
        # Phase 2 — place blocks and run the tile kernels.
        outs = []
        for tile, dev in zip(plan.tiles, devices):
            dst = self.stats.device(dev)
            placed = []
            for op in tile.ops:
                arr, moved, hit = self._place_block(dev, op, st)
                st.bytes_in += moved
                dst.moved_bytes += moved
                st.cache_hits += int(hit)
                st.cache_misses += int(not hit)
                dst.affinity_hits += int(hit)
                if site is not None:
                    site.observe_residency(hit)
                placed.append(arr)
            outs.append(self._guarded(
                "kernel", lambda t=tile, p=placed: t.compute(*p),
                device=dev, nbytes=0, st=st))
            dst.tiles += 1
        out = plan.gather(outs)
        if self.policy.persistent:
            for tile, dev, block in zip(plan.tiles, devices, outs):
                self.block_stores[dev].put(
                    (id(out),) + tile.out_coords, block, block.nbytes,
                    anchor=out)
        if self.policy.copy_back:
            st.bytes_out += out.nbytes
            out = memspace.put(out, memspace.HOST)
        else:
            memspace.tag_device(out)
        st.offloaded += 1
        st.sharded += 1
        st.tiles += len(plan.tiles)
        return out, tuple(devices)

    # ------------------------------------------------------------------ #
    # async mode                                                          #
    # ------------------------------------------------------------------ #
    def sync(self) -> "OffloadRuntime":
        """Block until every tracked in-flight result is materialized
        (XLA executes in submission order, so draining the recent window
        fences everything submitted before it).

        Exception-safe: a failed buffer never leaves later buffers
        undrained.  Every pending result is awaited; the first error is
        re-raised with later ones attached as ``__notes__`` (and logged
        under ``SCILIB_DEBUG``) rather than silently dropped."""
        first: Optional[BaseException] = None
        extras: list = []
        with self._lock:
            pending = list(self._pending)
            self._pending.clear()
        for buf in pending:
            try:
                buf.block_until_ready()
            except Exception as exc:
                if first is None:
                    first = exc
                else:
                    extras.append(exc)
        if first is not None:
            for i, exc in enumerate(extras):
                note = f"sync: also failed ({i + 2}/{len(extras) + 1}): " \
                       f"{type(exc).__name__}: {exc}"
                if hasattr(first, "add_note"):   # py3.11+
                    first.add_note(note)
                if self.debug >= 1:
                    print(f"[scilib] {note}")
            raise first
        return self

    # ------------------------------------------------------------------ #
    # trace buffer identity                                               #
    # ------------------------------------------------------------------ #
    def _trace_id(self, x: jax.Array, name: str = "") -> int:
        if self.trace is None:
            return -1
        bid = self._trace_ids.get(id(x))
        if bid is not None:
            return bid
        bid = self.trace.new_buffer(x.nbytes, name)
        self._trace_ids.put(id(x), bid, x.nbytes, anchor=x)
        return bid

    def alias_trace_id(self, src: jax.Array, dst: jax.Array) -> None:
        """Source and its device placement are the same logical buffer."""
        if self.trace is None or id(dst) in self._trace_ids:
            return
        bid = self._trace_ids.get(id(src))
        if bid is None:
            return
        self._trace_ids.put(id(dst), bid, dst.nbytes, anchor=dst)

    # ------------------------------------------------------------------ #
    # the intercepted-call entry point: the staged dispatch pipeline      #
    # ------------------------------------------------------------------ #
    def blas_call(self, routine: str, m: int, n: int, k: int,
                  operands: Sequence[Tuple[str, jax.Array, float, bool]],
                  compute: Callable[..., jax.Array],
                  batch: int = 1,
                  key: Optional[Hashable] = None,
                  shard: Optional[Callable[[int], Optional[TilePlan]]] = None,
                  kernel_compute: Optional[Callable[..., jax.Array]] = None,
                  split_compute: Optional[Callable] = None,
                  split_check: Optional[Callable] = None,
                  ) -> jax.Array:
        """Run one level-3 BLAS call through the dispatch pipeline:

            canonicalize -> decide -> plan -> execute -> record

        ``operands``: (role, array, device_reads_per_elem, written) — the
        same metadata the memtier access-counter model consumes.
        ``compute``: jit-compiled arithmetic taking the placed operand
        arrays in order.
        ``key``: hashable call-shape identity ``(routine, m, n, k, batch,
        dtype, flags)``; when given, the offload decision is memoized in
        the dispatch cache.
        ``shard``: optional tile-plan builder ``n_devices -> TilePlan``;
        consulted only when the call offloads and more than one device
        tier exists, so the single-device fast path never pays for it.
        ``kernel_compute``: the pallas-venue arithmetic (same placed
        operand order as ``compute``); consulted only under
        ``kernel_path`` — None means "no kernel for this routine" and
        the venue resolution falls back to the generic XLA offload.
        ``split_compute``: split-precision factory ``(scheme, venue) ->
        compute`` and ``split_check``: sampled-residual estimator
        ``(out, *arrays) -> rel error``; both consulted only under
        ``SCILIB_PRECISION`` — None means the call has no split
        formulation and always runs native.

        Thread-safe: the whole pipeline runs under the runtime lock, so
        several threads adopting one session (``Session.scope``) issue
        calls atomically — counters never lose updates and the decision
        cache never observes a half-written entry.  The single-threaded
        cost is one uncontended reentrant acquire per call.
        """
        with self._lock:
            return self._blas_call_locked(routine, m, n, k, operands,
                                          compute, batch, key, shard,
                                          kernel_compute, split_compute,
                                          split_check)

    def _blas_call_locked(self, routine, m, n, k, operands, compute,
                          batch, key, shard, kernel_compute,
                          split_compute=None,
                          split_check=None) -> jax.Array:
        st = self.stats.routine(routine)
        st.calls += 1
        arrays = [op[1] for op in operands]

        if any(isinstance(a, jax.core.Tracer) for a in arrays):
            # Inside jit/grad tracing there is no runtime placement to do;
            # the offload decision is static and the compute fn embeds it.
            return compute(*arrays)

        call = self._canonicalize(routine, m, n, k, operands, arrays,
                                  compute, batch, key, shard,
                                  kernel_compute, split_compute,
                                  split_check)
        decision = self._decide(call, st)
        t0 = time.perf_counter()
        self._stage_plan(call, decision)
        out, devices = self._execute(call, decision, st)
        if decision.precision and decision.offload:
            # sampled-residual check + escalation; inside the timed
            # window so probe samples bill what the scheme really costs
            out = self._verify_split(call, decision, st, out)
        if self.sync_mode or decision.timed:
            # adaptive probes always block: path timing needs wall time
            out.block_until_ready()
        else:
            # retire finished results first so the window never pins
            # buffers the application has already dropped
            pend = self._pending
            while pend and pend[0].is_ready():
                pend.popleft()
            pend.append(out)
        dt = time.perf_counter() - t0
        self._record(call, decision, out, devices, dt, st)
        return out

    # ------------------------------------------------------------------ #
    # stage 1 — canonicalize: bundle the call, fingerprint the site       #
    # ------------------------------------------------------------------ #
    def _canonicalize(self, routine, m, n, k, operands, arrays, compute,
                      batch, key, shard, kernel_compute=None,
                      split_compute=None, split_check=None) -> CallContext:
        call = CallContext(routine=routine, m=m, n=n, k=k, batch=batch,
                           operands=operands, arrays=arrays,
                           compute=compute, key=key, shard=shard,
                           kernel_compute=kernel_compute,
                           split_compute=split_compute,
                           split_check=split_check)
        if self.callsite_enabled:
            call.site_id = cs.fingerprint(routine)
            call.site = self.callsites.profile(call.site_id)
        return call

    # ------------------------------------------------------------------ #
    # stage 2 — decide: ordered stages emit the DispatchDecision IR       #
    # ------------------------------------------------------------------ #
    def _decide(self, call: CallContext, st: RoutineStats) -> DispatchDecision:
        decision = None
        for stage in self.decision_stages:
            decision = stage(call, st)
            if decision is not None:
                break
        if decision.offload and not self.policy.offloads:
            decision.offload = False
            decision.why = "policy:host-only"
        if decision.offload and not self.health.any_usable():
            # degraded mode: every device tier quarantined — keep
            # serving on the host path until a half-open probe readmits
            decision.offload = False
            decision.why = "fallback:quarantined"
            self.stats.fallbacks += 1
            st.fallbacks += 1
            self._emit_event("fallback", "quarantined", 0)
        self._resolve_precision(call, decision)
        self._resolve_venue(call, decision)
        return decision

    def _resolve_precision(self, call: CallContext,
                           decision: DispatchDecision) -> None:
        """Stage 2a — precision: which numeric formulation runs the
        decided path.  A no-op with ``SCILIB_PRECISION`` off
        (``precision`` stays ``""``, keeping the classic pipeline
        bit-identical).  Runs before the venue resolution because a
        split call is pallas-eligible where a native fp64 call is not.
        Adaptive decisions arrive with their precision already chosen
        by the probe schedule / lock and are left alone (a site that
        locked native must not be re-split here)."""
        if not self.precision:
            return
        if not decision.offload or call.split_compute is None:
            # host path (incl. policy/health vetoes of a split probe)
            # always runs native fp64
            decision.precision = ""
            return
        if decision.precision or decision.why.startswith("adaptive"):
            return
        scheme = prec.choose(self.precision,
                             thr.base_routine(call.routine),
                             call.k or call.m, self.precision_rtol)
        if scheme:
            decision.precision = scheme
            decision.why += f"+{scheme}"

    def _resolve_venue(self, call: CallContext,
                       decision: DispatchDecision) -> None:
        """Stage 2b — venue: which execution engine runs the decided
        path.  A no-op with ``kernel_path`` off (``venue`` stays ``""``,
        keeping the classic pipeline bit-identical).  Runs after the
        policy/health vetoes so a vetoed call is always ``host``; an
        adaptive decision arrives with its venue already chosen by the
        probe schedule / lock and is left alone."""
        if not self.kernel_path:
            return
        if not decision.offload:
            decision.venue = "host"
            return
        if decision.venue:
            return                      # adaptive stage already chose
        if (call.kernel_compute is not None
                or (decision.precision
                    and call.split_compute is not None)):
            # a split fp64 call is pallas-eligible even though native
            # fp64 has no kernel: its slice passes run the fp32 kernel
            decision.venue = "pallas"
            decision.why += "+kernel"
        else:
            decision.venue = "xla"

    def _stage_adaptive(self, call: CallContext,
                        st: RoutineStats) -> Optional[DispatchDecision]:
        """Per-site adaptive mode (``SCILIB_ADAPTIVE=1``): probe the
        first N calls at each site on both paths, then lock the faster
        decision — the paper's warmup-then-patch behaviour."""
        if not self.adaptive or call.site is None:
            return None
        site = call.site
        # with kernel_path on and a kernel for this routine, the warmup
        # rotates over three venues instead of two; the decision carries
        # the venue so execute/record stay stage-agnostic
        racing = self.kernel_path and call.kernel_compute is not None
        if site.locked is not None:
            # locked fast path: no threshold math, no N_avg derivation —
            # the warmup already captured the site's size distribution
            st.dispatch_hits += 1
            return DispatchDecision(
                site.locked, n_avg=0.0, why="adaptive:locked",
                venue=site.locked_venue if self.kernel_path else "",
                precision=site.locked_precision)
        nav = (thr.n_avg(call.routine, call.m, call.n, call.k)
               * (max(1, call.batch) ** (1.0 / 3.0)))
        if site.probes_done >= self.adaptive_warmup:
            locked = site.lock()
            if self.debug >= 1:
                label = (site.locked_venue if self.kernel_path
                         else ("offload" if locked else "host"))
                if site.locked_precision:
                    label += f"~{site.locked_precision}"
                print(f"[scilib] adaptive lock {site.site}: "
                      f"{label} ({site.locked_why})")
            if self.kernel_path:
                self._emit_event("venue",
                                 f"{site.locked_venue}:{site.site}", 0)
            st.dispatch_hits += 1
            return DispatchDecision(
                locked, n_avg=nav, why="adaptive:locked",
                venue=site.locked_venue if self.kernel_path else "",
                precision=site.locked_precision)
        st.dispatch_misses += 1
        # with SCILIB_PRECISION set and a split formulation available,
        # the warmup additionally races the split variant like a venue
        split_scheme = ""
        if self.precision and call.split_compute is not None:
            split_scheme = prec.choose(
                self.precision, thr.base_routine(call.routine),
                call.k or call.m, self.precision_rtol)
        venue = site.probe_venue(3 if racing else 2,
                                 split=bool(split_scheme))
        if venue == "split":
            # venue stays "" here; _resolve_venue picks xla or pallas
            return DispatchDecision(True, n_avg=nav,
                                    why="adaptive:probe", timed=True,
                                    precision=split_scheme)
        return DispatchDecision(venue != "host", n_avg=nav,
                                why="adaptive:probe", timed=True,
                                venue=venue if self.kernel_path else "")

    def _stage_cached(self, call: CallContext,
                      st: RoutineStats) -> Optional[DispatchDecision]:
        """The memoized dispatch cache (fast path): one threshold
        derivation per call shape, two dict lookups thereafter."""
        if call.key is None or not self.dispatch_cache_enabled:
            return None
        dec = self._decisions.get(call.key)
        if dec is None:
            dec = thr.should_offload(call.routine, call.m, call.n, call.k,
                                     threshold=self.threshold,
                                     batch=call.batch)
            if len(self._decisions) > _DECISION_CACHE_LIMIT:
                self._decisions.clear()   # dynamic-shape churn guard
            self._decisions[call.key] = dec
            st.dispatch_misses += 1
            return DispatchDecision(dec[0], n_avg=dec[1], why="threshold")
        st.dispatch_hits += 1
        return DispatchDecision(dec[0], n_avg=dec[1], why="cache")

    def _stage_threshold(self, call: CallContext,
                         st: RoutineStats) -> DispatchDecision:
        """Terminal stage: derive the threshold rule per call (paper
        §3.3); reached when the key is unhashable or caching is off."""
        st.dispatch_misses += 1
        offload, nav = thr.should_offload(call.routine, call.m, call.n,
                                          call.k, threshold=self.threshold,
                                          batch=call.batch)
        return DispatchDecision(offload, n_avg=nav, why="threshold")

    # ------------------------------------------------------------------ #
    # stage 3 — plan: consult the multi-device tile planner               #
    # ------------------------------------------------------------------ #
    def _stage_plan(self, call: CallContext,
                    decision: DispatchDecision) -> DispatchDecision:
        n_avail = self.health.usable_count()
        if (decision.offload and call.shard is not None
                and n_avail > 1 and self.policy.shardable):
            kw = {}
            if self.kernel_path and decision.venue == "pallas":
                # sharded tiles follow the venue selection too: the tile
                # kernels run the pallas path, under the same _guarded
                # fault units as any tile
                kw["venue"] = "pallas"
            if decision.precision and call.split_compute is not None:
                # split tiles: the same tile geometry, the tile kernels
                # run the split passes (precision-aware shard builders
                # exist exactly when split_compute does)
                kw["precision"] = decision.precision
            decision.plan = (call.shard(n_avail, **kw) if kw
                             else call.shard(n_avail))
        return decision

    # ------------------------------------------------------------------ #
    # stage 4 — execute: host, whole-call offload, or sharded tiles       #
    # ------------------------------------------------------------------ #
    def _execute(self, call: CallContext, decision: DispatchDecision,
                 st: RoutineStats) -> Tuple[jax.Array, Tuple[int, ...]]:
        if not decision.offload:
            out = call.compute(*self._harmonize(call.arrays, st))
            st.on_host += 1
            return out, ()
        try:
            if decision.plan is not None:
                return self._sharded_call(st, decision.plan,
                                          site=call.site)
            return self._offload_whole(call, decision, st), ()
        except flt.OffloadError as exc:
            return self._fallback_host(call, decision, st, exc), ()

    def _offload_whole(self, call: CallContext,
                       decision: DispatchDecision,
                       st: RoutineStats) -> jax.Array:
        """Single-device offload: the policy places every operand.
        Each operand movement and the kernel launch are separate
        guarded units, attributed to the whole-call device tier."""
        site = call.site
        dev = self._whole_device()
        placed, budget_used = [], 0
        ai = self._arith_intensity(call.routine, call.m, call.n, call.k,
                                   call.arrays, call.batch)
        for (role, x, reads, written) in call.operands:
            if isinstance(self.policy, CounterPolicy):
                p = self._guarded(
                    "transfer",
                    lambda x=x, r=reads, w=written, b=budget_used:
                        self.policy.place_operand(
                            self, x, reads_per_elem=r, written=w,
                            ai=ai, budget_used=b),
                    device=dev, nbytes=x.nbytes, st=st)
            else:
                p = self._guarded(
                    "transfer",
                    lambda x=x: self.policy.place_operand(self, x),
                    device=dev, nbytes=x.nbytes, st=st)
            budget_used += p.moved_bytes
            st.bytes_in += p.moved_bytes
            st.cache_hits += int(p.cache_hit)
            st.cache_misses += int(not p.cache_hit)
            if site is not None:
                site.observe_residency(p.cache_hit)
            if p.cache_hit:
                self._count_reuse(x)
            if p.moved_bytes or p.cache_hit:
                self.alias_trace_id(x, p.array)
            placed.append(p.array)
        # harmonize outside the kernel guard: a retried kernel must not
        # re-bill transient streaming bytes
        args = self._harmonize(placed, st)
        # venue selection: the pallas-venue arithmetic replaces the
        # generic jitted compute inside the *same* guarded kernel unit,
        # so injection, retries and breaker trips cover it identically.
        # A split decision swaps in the split formulation the same way
        # (bound to the decided scheme and venue).
        if decision.precision and call.split_compute is not None:
            compute = call.split_compute(decision.precision,
                                         decision.venue)
        elif (decision.venue == "pallas"
                and call.kernel_compute is not None):
            compute = call.kernel_compute
        else:
            compute = call.compute
        out = self._guarded("kernel", lambda: compute(*args),
                            device=dev, nbytes=0, st=st)
        out_p = self._guarded(
            "transfer", lambda: self.policy.place_output(self, out),
            device=dev, nbytes=out.nbytes, st=st)
        st.bytes_out += out_p.moved_bytes
        st.offloaded += 1
        return out_p.array

    def _verify_split(self, call: CallContext,
                      decision: DispatchDecision, st: RoutineStats,
                      out: jax.Array) -> jax.Array:
        """Post-execution escalation check of a split result.

        The sampled residual (one O(n^2) fp64 matvec chain against the
        O(n^3) call) estimates the *forward* relative error; a result
        exceeding ``precision_rtol`` — catastrophic cancellation, an
        ill-conditioned triangle — is discarded and the call reruns
        native fp64, so accuracy degradation is bounded, never silent.
        The check materializes the result (the split path trades the
        async window for the guarantee).  Without ``split_check`` the
        a-priori bound already fit ``rtol`` at resolve time and the
        result stands."""
        if call.split_check is None:
            return out
        rel = float(call.split_check(out, *call.arrays))
        if rel <= self.precision_rtol:
            return out
        st.escalations += 1
        decision.why += "+esc"
        self._emit_event("escalate",
                         f"{decision.precision}:{call.routine}", 0)
        if call.site is not None:
            # a site whose scheme misses its bound must never lock it
            call.site.split_bad = True
        if self.debug >= 1:
            print(f"[scilib] {call.routine} {decision.precision} "
                  f"residual {rel:.2e} > rtol {self.precision_rtol:.2e}"
                  f" -> native fp64")
        return call.compute(*self._harmonize(call.arrays, st))

    # ------------------------------------------------------------------ #
    # stage 5 — record: statistics, site profile, trace                   #
    # ------------------------------------------------------------------ #
    def _record(self, call: CallContext, decision: DispatchDecision,
                out: jax.Array, devices: Tuple[int, ...], dt: float,
                st: RoutineStats) -> None:
        st.seconds += dt
        if decision.offload and decision.venue == "pallas":
            st.kernel_calls += 1
            st.kernel_seconds += dt
        if decision.offload and decision.precision:
            st.split_calls += 1
            st.split_seconds += dt
        site = call.site
        if site is not None:
            if decision.timed:
                site.observe_probe(decision.offload, dt,
                                   venue=decision.venue,
                                   precision=decision.precision)
            site.observe(decision.n_avg,
                         _flops_of(call.routine, call.m, call.n, call.k,
                                   call.batch),
                         dt, decision.offload, venue=decision.venue,
                         precision=decision.precision)
        solver_id = ""
        if self._solver_stack:
            span = self._solver_stack[-1]
            solver_id = span.span_id
            self.stats.solver(span.name).calls += 1
        self._record_trace(call.routine, call.m, call.n, call.k,
                           call.operands, out, call.batch, devices,
                           site_id=call.site_id, seconds=dt,
                           venue=decision.venue,
                           precision=decision.precision,
                           solver_id=solver_id)
        if self.debug >= 2:
            where = "host" if not decision.offload else (
                f"shard[{len(devices)} tiles]" if devices else
                (decision.venue or "offload"))
            print(f"[scilib] {call.routine} m={call.m} n={call.n} "
                  f"k={call.k} navg={decision.n_avg:.0f} {where} "
                  f"({decision.why})")

    # ------------------------------------------------------------------ #
    def _harmonize(self, arrays, st) -> list:
        """Execution-space harmonization: XLA cannot mix memory spaces in
        one op, so operands a policy left host-resident are streamed in
        transiently (GH200's coherent remote read, made explicit). The
        placement registry is untouched — residency stays host."""
        simulated = self.memspace.simulated
        out = []
        for a in arrays:
            if memspace.tier_of(a) != memspace.DEVICE:
                st.transient_bytes += a.nbytes
                if not simulated:
                    # transient streaming, not a placement decision (and
                    # the host fallback path itself runs through here):
                    # never inject faults on it
                    a = memspace.put(a, memspace.DEVICE, check=False)
            out.append(a)
        return out

    # ------------------------------------------------------------------ #
    def _count_reuse(self, x: jax.Array) -> None:
        bid = self._trace_ids.get(id(x))
        if bid is not None:
            self._reuse_by_buffer[bid] = self._reuse_by_buffer.get(bid, 0) + 1

    def mean_buffer_reuse(self) -> float:
        if not self._reuse_by_buffer:
            return 0.0
        return sum(self._reuse_by_buffer.values()) / len(self._reuse_by_buffer)

    @staticmethod
    def _arith_intensity(routine, m, n, k, arrays, batch) -> float:
        nbytes = sum(a.nbytes for a in arrays)
        return _flops_of(routine, m, n, k, batch) / max(1, nbytes)

    def _record_trace(self, routine, m, n, k, operands, out, batch,
                      devices=(), site_id: str = "",
                      seconds: float = 0.0, venue: str = "",
                      precision: str = "", solver_id: str = "") -> None:
        if self.trace is None:
            return
        ops = []
        for (role, x, reads, written) in operands:
            bid = self._trace_id(x, role)
            ops.append((role, bid, x.nbytes // max(1, batch), reads, written))
        # the output aliases the written operand's logical buffer; a
        # fresh output gets its own buffer and is recorded on the call,
        # so replay can account its device-born residency like the live
        # placement store does
        out_buf, out_nbytes = -1, 0
        for (role, x, reads, written) in operands:
            if written:
                self.alias_trace_id(x, out)
                break
        else:
            out_buf = self._trace_id(out, "OUT")
            out_nbytes = out.nbytes
        from repro.core.trace import BlasCall
        self.trace.calls.append(BlasCall(
            routine=routine, m=m, n=n, k=k, batch=batch,
            operands=tuple(ops), devices=tuple(devices),
            callsite_id=site_id, seconds=seconds,
            out_buf=out_buf, out_nbytes=out_nbytes, venue=venue,
            precision=precision, solver_id=solver_id))


# --------------------------------------------------------------------- #
# context-local active runtime (what LD_PRELOAD init/fini manage in C;   #
# context-local so concurrent sessions in different threads each see     #
# their own dispatch target, never a neighbour's)                        #
# --------------------------------------------------------------------- #
_ACTIVE: ContextVar[Optional[OffloadRuntime]] = (
    ContextVar("scilib_active_runtime", default=None))


def activate(runtime: Optional[OffloadRuntime]) -> None:
    """Make ``runtime`` the dispatch target of the *current* context
    (None deactivates).  The session layer drives this; application
    code opens sessions instead.  The memspace fault hook follows the
    active runtime, so a nested session's injector never outlives its
    activation."""
    _ACTIVE.set(runtime)
    if runtime is None:
        memspace.set_fault_hook(None)
        memspace.set_debug(0)
    else:
        memspace.set_fault_hook(runtime._transfer_fault_hook())
        memspace.set_debug(runtime.debug)


def install(policy: Optional[str] = None,
            threshold: Optional[float] = None,
            record_trace: bool = True, sync: Optional[bool] = None,
            device_bytes: Optional[int] = None,
            config: Optional[OffloadConfig] = None) -> OffloadRuntime:
    """`.init_array` analogue, now a shim over an implicit
    :class:`~repro.core.session.Session` (without symbol interception —
    the dlsym-mode surface).  Behavior-identical to the pre-session
    global: env knobs are honored through
    :meth:`OffloadConfig.legacy`, and the created runtime becomes the
    active dispatch target.  An explicit ``config`` bypasses the legacy
    resolution (and the environment) entirely."""
    from repro.core import session as ses
    if config is None:
        config = OffloadConfig.legacy(policy=policy, threshold=threshold,
                                      sync=sync, device_bytes=device_bytes)
    return ses.open_legacy(config, record_trace=record_trace,
                           intercept=False).runtime


def uninstall() -> Optional[RuntimeStats]:
    """`.fini_array` analogue: drain in-flight work, deactivate, and
    return final statistics.  With ``SCILIB_TRACE=/path.json`` set (or
    ``config.trace_path``), the recorded trace is dumped — traces for
    the autotuner need no code changes, mirroring the paper tool's
    no-recompile ethos."""
    from repro.core import session as ses
    return ses.close_legacy()


def active() -> Optional[OffloadRuntime]:
    return _ACTIVE.get()


def pin(x: jax.Array) -> jax.Array:
    """Pin a buffer on the active runtime's device tier (no-op when no
    runtime is installed).  See :meth:`OffloadRuntime.pin`."""
    rt = _ACTIVE.get()
    return x if rt is None else rt.pin(x)


def unpin(x: jax.Array) -> None:
    """Release a :func:`pin` (no-op when no runtime is installed)."""
    rt = _ACTIVE.get()
    if rt is not None:
        rt.unpin(x)

"""LAPACK-tier solver subsystem (paper §4.2's real workload shape).

The paper's headline wins come from applications whose hot loops are
*LAPACK* calls — MuST/LSMS's ``zgetrf``/``zgetrs``, Cholesky, dense
eigensolves — whose panel updates are exactly the gemm/trsm/syr2k
stream SCILIB-Accel offloads.  This package makes that tier a
first-class citizen of the runtime:

* :mod:`repro.solvers.drivers` — span-wrapped factorization/solve
  drivers over :mod:`repro.core.lapack` (getrf/getrs/gesv/potrf/potrs)
  and :mod:`repro.solvers.eigen` (syev).  Each driver opens a *solver
  span* on the active runtime: the in-place factor buffer is pinned on
  the device tier for the span's lifetime (the ~780x-reuse pattern),
  every inner BLAS call is stamped with the span's ``solver_id``, and
  per-solver statistics (calls, panel fraction, moved bytes, seconds)
  accumulate in the runtime report.
* :mod:`repro.solvers.eigen` — blocked one-stage Hermitian
  tridiagonalization (sytrd: latrd panels + syr2k/her2k trailing
  updates), a small host tridiagonal eigensolve, and a compact-WY
  blocked back-transform.
* :mod:`repro.solvers.intercept` — trampolines over
  ``jnp.linalg.cholesky/solve`` (+ ``lu`` where present) and
  ``jax.scipy.linalg.lu_factor/lu_solve/cho_factor/cho_solve/
  solve_triangular/eigh``, gated exactly like the matmul interception
  (eager super-threshold arrays under an active runtime).  Enabled per
  session by ``OffloadConfig.lapack`` (``SCILIB_LAPACK=1``); block
  size via ``lapack_nb`` (``SCILIB_LAPACK_NB``).
"""
from repro.solvers.drivers import (gesv, getrf, getrs, potrf, potrs,
                                   syev)

__all__ = ["getrf", "getrs", "gesv", "potrf", "potrs", "syev"]

"""Blocked Hermitian eigensolver (syev/heev) on the intercepted BLAS.

LAPACK's one-stage ``?sytrd``/``?hetrd`` structure: latrd panels build
``kb`` Householder reflectors at a time (each column costs one big
symmetric/Hermitian matvec through :mod:`repro.core.blas` plus small
V/W corrections), the trailing submatrix is updated with one rank-2k
``syr2k``/``her2k`` per panel — the level-3 call the offload runtime
feeds on — the resulting real tridiagonal system is solved on the host
(it is O(n) data, far below any offload threshold), and eigenvectors
are back-transformed panel-by-panel with compact-WY gemms.

Only the lower triangle of the working matrix is referenced and
updated throughout (``uplo="U"`` inputs are mirrored up front), exactly
the storage discipline of the LAPACK routines this reproduces.
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blas
from repro.core.lapack import DEFAULT_NB


def _hermitize(a: jax.Array, uplo: str) -> jax.Array:
    """Full Hermitian matrix from the referenced triangle (the other
    triangle of a LAPACK-convention input may hold garbage)."""
    tri = jnp.triu(a, 1) if uplo == "U" else jnp.tril(a, -1)
    dg = jnp.real(jnp.diagonal(a)).astype(a.dtype)
    return tri + jnp.conj(tri.T) + jnp.diag(dg)


def _larfg(alpha, x: jax.Array, dtype) -> Tuple[float, jax.Array, complex]:
    """Elementary reflector (zlarfg): returns ``(beta, v, tau)`` with
    ``beta`` real, ``v[0] == 1``, and
    ``(I - tau v v^H)^H [alpha; x] = [beta; 0]``."""
    iscomplex = jnp.issubdtype(dtype, jnp.complexfloating)
    a = complex(alpha)
    xnorm = float(jnp.linalg.norm(x)) if x.size else 0.0
    one = jnp.ones((1,), dtype=dtype)
    if xnorm == 0.0 and a.imag == 0.0:
        # already tridiagonal-real here: H = I
        return a.real, jnp.concatenate([one, x]), 0j if iscomplex else 0.0
    beta = -math.copysign(
        math.sqrt(a.real * a.real + a.imag * a.imag + xnorm * xnorm),
        a.real)
    tau = (beta - a) / beta
    scale = 1.0 / (a - beta)
    if not iscomplex:             # exact: a.imag == 0 on the real path
        tau, scale = tau.real, scale.real
    v = jnp.concatenate([one, x * scale])
    return beta, v, tau


def _sytrd(a: jax.Array, nb: int
           ) -> Tuple[np.ndarray, np.ndarray, List[tuple]]:
    """Blocked lower tridiagonalization ``A = Q T Q^H``.

    Returns ``(d, e, panels)``: the real tridiagonal (host numpy), and
    per-panel ``(k0, V, taus)`` reflector storage for the
    back-transform.  ``A`` is consumed lower-triangle-only: the latrd
    matvec reads the (not yet updated) trailing block through
    ``symm``/``hemm`` and the deferred rank-2k update writes the lower
    triangle via ``syr2k``/``her2k`` — one level-3 call per panel.
    """
    n = a.shape[0]
    dtype = a.dtype
    iscomplex = jnp.issubdtype(dtype, jnp.complexfloating)
    matvec = blas.hemm if iscomplex else blas.symm
    rank2 = blas.her2k if iscomplex else blas.syr2k
    d = np.zeros(n)
    e = np.zeros(max(0, n - 1))
    panels: List[tuple] = []
    A = a
    k0 = 0
    while n - k0 > 1:
        m = n - k0
        kb = min(nb, m - 1)
        A2 = A[k0:, k0:]
        V = jnp.zeros((m, kb), dtype=dtype)
        W = jnp.zeros((m, kb), dtype=dtype)
        taus: List[complex] = []
        for j in range(kb):
            # column j under the panel's previous reflectors (deferred
            # update: A - V W^H - W V^H); rows < j are never read
            col = (A2[:, j] - V @ jnp.conj(W[j, :])
                   - W @ jnp.conj(V[j, :]))
            d[k0 + j] = float(jnp.real(col[j]))
            beta, v, tau = _larfg(col[j + 1], col[j + 2:], dtype)
            e[k0 + j] = beta
            taus.append(tau)
            V = V.at[j + 1:, j].set(v)
            vfull = jnp.zeros(m, dtype=dtype).at[j + 1:].set(v)
            # w = tau (A v - V(W^H v) - W(V^H v)) - (tau/2)(w^H v) v:
            # the big matvec runs on the pre-panel trailing block (rows
            # <= j of the product are discarded by the masking below)
            p = matvec(A2, vfull[:, None], side="L", uplo="L")[:, 0]
            p = (p - V @ (jnp.conj(W.T) @ vfull)
                 - W @ (jnp.conj(V.T) @ vfull))
            w = (tau * p).at[:j + 1].set(0)
            w = w + (-0.5 * tau * (jnp.conj(w) @ vfull)) * vfull
            W = W.at[:, j].set(w)
        panels.append((k0, V, taus))
        if k0 + kb < n:
            # the deferred rank-2k trailing update: the panel's one
            # level-3 call, and the offload runtime's hot spot here
            upd = rank2(V[kb:, :], W[kb:, :], A[k0 + kb:, k0 + kb:],
                        uplo="L", trans="N", alpha=-1.0, beta=1.0)
            A = A.at[k0 + kb:, k0 + kb:].set(upd)
        k0 += kb
    if k0 < n:
        d[n - 1] = float(jnp.real(A[n - 1, n - 1]))
    return d, e, panels


def _tridiag_eigh(d: np.ndarray, e: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Host eigensolve of the real tridiagonal (O(n) data: far below
    any offload threshold, exactly where LAPACK keeps it too)."""
    try:
        from scipy.linalg import eigh_tridiagonal
        return eigh_tridiagonal(d, e)
    except ImportError:                        # pragma: no cover
        t = np.diag(d)
        if e.size:
            t = t + np.diag(e, 1) + np.diag(e, -1)
        return np.linalg.eigh(t)


def _larft(V: jax.Array, taus: List[complex]) -> np.ndarray:
    """Compact-WY triangular factor for the forward product
    ``H_0 H_1 ... H_{kb-1} = I - V T V^H`` (larft, forward/columnwise;
    kb x kb — built on the host)."""
    kb = len(taus)
    Vn = np.asarray(V)
    T = np.zeros((kb, kb), dtype=Vn.dtype)
    for j, tau in enumerate(taus):
        if j > 0:
            T[:j, j] = -tau * (T[:j, :j]
                               @ (Vn[:, :j].conj().T @ Vn[:, j]))
        T[j, j] = tau
    return T


def _apply_q(panels: List[tuple], z: np.ndarray, dtype) -> jax.Array:
    """Back-transform ``S = Q Z``: apply the panel products in reverse
    order, each as two big gemms around a small T application."""
    s = jnp.asarray(z, dtype=dtype)
    for k0, V, taus in reversed(panels):
        T = jnp.asarray(_larft(V, taus), dtype=dtype)
        s2 = s[k0:, :]
        x = blas.gemm(V, s2, trans_a="C")       # V^H S
        x = T @ x                               # small kb x kb apply
        s2 = blas.gemm(V, x, s2, alpha=-1.0, beta=1.0)
        s = s.at[k0:, :].set(s2)
    return s


def syev(a: jax.Array, nb: int = DEFAULT_NB, *,
         uplo: str = "L") -> Tuple[jax.Array, jax.Array]:
    """Eigendecomposition of a Hermitian matrix: ``A = S diag(w) S^H``.

    Returns ``(w, S)`` with ``w`` real ascending and ``S`` the
    eigenvector columns, matching ``scipy.linalg.eigh``.
    """
    n = a.shape[0]
    dtype = a.dtype
    rdtype = np.zeros(0, dtype=np.dtype(dtype)).real.dtype
    if n == 0:
        return (jnp.zeros(0, dtype=rdtype),
                jnp.zeros((0, 0), dtype=dtype))
    if n == 1:
        return (jnp.real(a[0, 0]).astype(rdtype).reshape(1),
                jnp.ones((1, 1), dtype=dtype))
    full = _hermitize(a, uplo)
    d, e, panels = _sytrd(full, nb=max(1, nb))
    w, z = _tridiag_eigh(d, e)
    s = _apply_q(panels, z, dtype)
    return jnp.asarray(w, dtype=rdtype), s

"""Solver-symbol interception: the LAPACK half of the DBI analogue.

The paper's tool patches LAPACK entry points (``zgetrf_``, ``zpotrf_``,
``zheev_`` ...) exactly like BLAS ones; the JAX equivalents are the
public factorization/solve symbols application code actually calls:
``jnp.linalg.cholesky``/``solve`` (+ ``lu`` where the jax version has
one) and ``jax.scipy.linalg.lu_factor``/``lu_solve``/``cho_factor``/
``cho_solve``/``solve_triangular``/``eigh``.  The trampolines route
eager, super-threshold, float/complex square systems onto the
span-wrapped blocked drivers (:mod:`repro.solvers.drivers`) — same
gating discipline as the matmul trampolines in
:mod:`repro.core.intercept` — and fall through to the originals for
everything else (sub-threshold sizes, tracers, batched inputs, kwargs
the drivers do not model).

Patching is refcounted and owned per session: ``OffloadConfig.lapack``
(``SCILIB_LAPACK=1``) makes an intercepting session take a reference on
open and release it on close, so with the flag unset these symbols are
never touched and behavior is bit-identical to the BLAS-only runtime.

Pivot convention: the patched ``lu_factor`` returns the *absolute row
permutation* (``A[piv] == L @ U``, the composed form of LAPACK's
sequential ipiv swaps), and the patched ``lu_solve`` consumes the same
— the pair is self-consistent, but a ``lu_factor`` result produced
while patched must not be fed to an unpatched ``lu_solve``.
"""
from __future__ import annotations

import threading
from typing import Dict

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.core import blas
from repro.core import callsite
from repro.core import runtime as rt
from repro.solvers import drivers

callsite.register_machinery(__file__)

_ORIG: Dict[str, callable] = {}
_PATCHED = 0
_PATCH_LOCK = threading.Lock()

_TRANS = {0: "N", 1: "T", 2: "C", "N": "N", "T": "T", "C": "C"}


def _is_eager_array(x) -> bool:
    return isinstance(x, jax.Array) and not isinstance(x, jax.core.Tracer)


def _solvable(*arrays) -> bool:
    """The solver-tier gate: an active runtime, eager float/complex
    2-D operands, and a leading square system at or above the
    threshold (sub-threshold factorizations stay on the native path —
    the blocked Python drivers only pay off where offload does)."""
    r = rt.active()
    if r is None:
        return False
    for x in arrays:
        if not _is_eager_array(x):
            return False
        if not (jnp.issubdtype(x.dtype, jnp.floating)
                or jnp.issubdtype(x.dtype, jnp.complexfloating)):
            return False
    a = arrays[0]
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        return False
    return a.shape[0] >= r.config.resolved_threshold()


def _fall(name, *args, **kw):
    r = rt.active()
    if r is not None:
        r.note_uninstrumented()
    return _ORIG[name](*args, **kw)


# --------------------------------------------------------------------- #
# trampolines                                                            #
# --------------------------------------------------------------------- #
def _cholesky(a, *, upper=False):
    if _solvable(a):
        f = drivers.potrf(a, uplo="U" if upper else "L")
        return f
    return _fall("cholesky", a, upper=upper)


def _solve(a, b):
    if (_solvable(a) and _is_eager_array(b)
            and b.ndim in (1, 2) and b.shape[0] == a.shape[0]):
        return drivers.gesv(a, b)
    return _fall("solve", a, b)


def _lu(a):                                    # pragma: no cover - no
    if _solvable(a):                           # jnp.linalg.lu on 0.4.x
        lu, piv = drivers.getrf(a)
        return piv, jnp.tril(lu, -1) + jnp.eye(a.shape[0], dtype=a.dtype), \
            jnp.triu(lu)
    return _fall("lu", a)


def _lu_factor(a, overwrite_a=False, check_finite=True):
    if _solvable(a):
        return drivers.getrf(a)
    return _fall("lu_factor", a, overwrite_a=overwrite_a,
                 check_finite=check_finite)


def _lu_solve(lu_and_piv, b, trans=0, overwrite_b=False,
              check_finite=True):
    lu, piv = lu_and_piv
    if (trans == 0 and _solvable(lu) and _is_eager_array(b)
            and b.ndim in (1, 2) and b.shape[0] == lu.shape[0]):
        return drivers.getrs(lu, piv, b)
    return _fall("lu_solve", lu_and_piv, b, trans,
                 overwrite_b=overwrite_b, check_finite=check_finite)


def _cho_factor(a, lower=False, overwrite_a=False, check_finite=True):
    if _solvable(a):
        return drivers.potrf(a, uplo="L" if lower else "U"), lower
    return _fall("cho_factor", a, lower=lower, overwrite_a=overwrite_a,
                 check_finite=check_finite)


def _cho_solve(c_and_lower, b, overwrite_b=False, check_finite=True):
    c, lower = c_and_lower
    if (_solvable(c) and _is_eager_array(b)
            and b.ndim in (1, 2) and b.shape[0] == c.shape[0]):
        return drivers.potrs(c, b, uplo="L" if lower else "U")
    return _fall("cho_solve", c_and_lower, b, overwrite_b=overwrite_b,
                 check_finite=check_finite)


def _solve_triangular(a, b, trans=0, lower=False, unit_diagonal=False,
                      overwrite_b=False, debug=None, check_finite=True):
    if (trans in _TRANS and _solvable(a) and _is_eager_array(b)
            and b.ndim in (1, 2) and b.shape[0] == a.shape[0]):
        b2 = b[:, None] if b.ndim == 1 else b
        x = blas.trsm(a, b2, side="L", uplo="L" if lower else "U",
                      trans=_TRANS[trans],
                      diag="U" if unit_diagonal else "N")
        return x[:, 0] if b.ndim == 1 else x
    return _fall("solve_triangular", a, b, trans, lower=lower,
                 unit_diagonal=unit_diagonal, overwrite_b=overwrite_b,
                 debug=debug, check_finite=check_finite)


def _eigh(a, b=None, lower=True, eigvals_only=False, overwrite_a=False,
          overwrite_b=False, turbo=True, eigvals=None, type=1,
          check_finite=True):
    if (b is None and eigvals is None and type == 1 and _solvable(a)):
        w, v = drivers.syev(a, uplo="L" if lower else "U")
        return w if eigvals_only else (w, v)
    return _fall("eigh", a, b, lower=lower, eigvals_only=eigvals_only,
                 overwrite_a=overwrite_a, overwrite_b=overwrite_b,
                 turbo=turbo, eigvals=eigvals, type=type,
                 check_finite=check_finite)


# --------------------------------------------------------------------- #
# symbol patching (refcounted, same discipline as core.intercept)        #
# --------------------------------------------------------------------- #
_SYMBOLS = (
    (jnp.linalg, "cholesky", _cholesky),
    (jnp.linalg, "solve", _solve),
    (jsl, "lu_factor", _lu_factor),
    (jsl, "lu_solve", _lu_solve),
    (jsl, "cho_factor", _cho_factor),
    (jsl, "cho_solve", _cho_solve),
    (jsl, "solve_triangular", _solve_triangular),
    (jsl, "eigh", _eigh),
) + ((jnp.linalg, "lu", _lu),) * hasattr(jnp.linalg, "lu")


def patch_symbols() -> None:
    """Install the solver trampolines (refcounted: nested
    ``SCILIB_LAPACK`` sessions share one patch)."""
    global _PATCHED
    with _PATCH_LOCK:
        _PATCHED += 1
        if not _ORIG:
            for mod, name, wrapper in _SYMBOLS:
                _ORIG[name] = getattr(mod, name)
                setattr(mod, name, wrapper)


def unpatch_symbols() -> None:
    """Release one patch reference; restore the originals at zero."""
    global _PATCHED
    with _PATCH_LOCK:
        _PATCHED = max(0, _PATCHED - 1)
        if _PATCHED == 0 and _ORIG:
            for mod, name, _ in _SYMBOLS:
                setattr(mod, name, _ORIG.pop(name))

"""Span-wrapped LAPACK drivers: each call is one solver *span*.

A span is the runtime's unit of solver work: ``solver_begin`` pins the
in-place factor buffer on the device tier (it is re-read by every panel
update — the ~780x-reuse pattern ``apps/lsms.py`` documents), stamps
every inner BLAS call with the span's ``solver_id``, and emits a
``solver_begin``/``solver_end`` event pair into the trace so the
memtier simulator can replay per-solver counters count-for-count.
Without an active runtime the drivers degrade to plain
:mod:`repro.core.lapack` / :mod:`repro.solvers.eigen` calls.
"""
from __future__ import annotations

import contextlib
from typing import Tuple

import jax

from repro.core import lapack
from repro.core import runtime as rtm
from repro.solvers import eigen as _eigen


def _resolve_nb(nb: int) -> int:
    """Explicit ``nb`` wins; else the active session's ``lapack_nb``
    (``SCILIB_LAPACK_NB``); else the driver default."""
    if nb:
        return nb
    rt = rtm.active()
    if rt is not None and rt.config.lapack_nb:
        return rt.config.lapack_nb
    return lapack.DEFAULT_NB


@contextlib.contextmanager
def _span(name: str, factor=None):
    rt = rtm.active()
    if rt is None:
        yield None
        return
    span = rt.solver_begin(name, factor)
    try:
        yield span
    finally:
        rt.solver_end(span)


# --------------------------------------------------------------------- #
# LU tier                                                                #
# --------------------------------------------------------------------- #
def getrf(a: jax.Array, nb: int = 0) -> Tuple[jax.Array, jax.Array]:
    """Blocked LU with partial pivoting, as one solver span."""
    with _span("getrf", a):
        return lapack.getrf(a, nb=_resolve_nb(nb))


def getrs(lu: jax.Array, piv: jax.Array, b: jax.Array) -> jax.Array:
    """Solve from getrf output (laswp + two trsms), as one span."""
    with _span("getrs", lu):
        return lapack.getrs(lu, piv, b)


def gesv(a: jax.Array, b: jax.Array, nb: int = 0) -> jax.Array:
    """Factor-and-solve (the zgetrf+zgetrs pair MuST calls) — one span
    covering both phases, so the LU factor stays pinned through the
    triangular solves that re-read it."""
    with _span("gesv", a):
        nbv = _resolve_nb(nb)
        lu, piv = lapack.getrf(a, nb=nbv)
        return lapack.getrs(lu, piv, b)


# --------------------------------------------------------------------- #
# Cholesky tier                                                          #
# --------------------------------------------------------------------- #
def potrf(a: jax.Array, nb: int = 0, *, uplo: str = "L") -> jax.Array:
    """Blocked Cholesky (real-symmetric or complex-Hermitian)."""
    with _span("potrf", a):
        return lapack.potrf(a, _resolve_nb(nb), uplo=uplo)


def potrs(f: jax.Array, b: jax.Array, *, uplo: str = "L") -> jax.Array:
    """Solve from potrf output (two triangular solves)."""
    with _span("potrs", f):
        return lapack.potrs(f, b, uplo=uplo)


# --------------------------------------------------------------------- #
# eigensolver tier                                                       #
# --------------------------------------------------------------------- #
def syev(a: jax.Array, nb: int = 0, *,
         uplo: str = "L") -> Tuple[jax.Array, jax.Array]:
    """Hermitian eigensolve: blocked tridiagonalization + host
    tridiagonal solve + blocked back-transform, as one span."""
    with _span("syev", a):
        return _eigen.syev(a, nb=_resolve_nb(nb), uplo=uplo)

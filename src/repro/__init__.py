"""SCILIB-Accel reproduction: automatic BLAS offload as a library.

The public surface is the session API:

    import repro
    from repro import OffloadConfig

    with repro.session(OffloadConfig.preset("throughput")) as s:
        ...                       # jnp.dot/matmul/einsum intercepted
        print(s.report())

``repro.session(...)`` opens a :class:`repro.core.session.Session` —
a first-class object owning its runtime, interceptors, statistics and
trace, configured by a typed :class:`repro.core.config.OffloadConfig`
instead of ambient ``SCILIB_*`` env vars (which remain supported: they
layer over the defaults through ``OffloadConfig.from_env()``, the one
env-ingestion boundary).  Sessions nest; the legacy
``install()``/``uninstall()``/``offload()`` surface is a shim over an
implicit default session.

Attributes are resolved lazily so ``import repro`` stays cheap: nothing
(including jax) is imported until the first attribute access.
"""
from typing import TYPE_CHECKING

__all__ = ["OffloadConfig", "Session", "session", "active_session",
           "install", "uninstall", "offload", "core"]

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.config import OffloadConfig
    from repro.core.session import Session, active_session, session

_CONFIG_NAMES = ("OffloadConfig",)
_SESSION_NAMES = ("Session", "session", "active_session")
_LEGACY_NAMES = ("install", "uninstall", "offload")


def __getattr__(name: str):
    import importlib
    if name in _CONFIG_NAMES:
        return getattr(importlib.import_module("repro.core.config"), name)
    if name in _SESSION_NAMES:
        return getattr(importlib.import_module("repro.core.session"), name)
    if name in _LEGACY_NAMES:
        from repro.core import intercept as _intercept
        return getattr(_intercept, name)
    if name == "core":
        import repro.core as _core
        return _core
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))

"""Pallas TPU kernels for the paper's compute hot spots.

``gemm``/``trsm``/``syrk`` are the level-3 BLAS bodies SCILIB-Accel
offloads; ``attention`` is the LM-framework hot spot. ``ops`` is the
dispatch wrapper (Pallas on TPU, XLA reference elsewhere); ``ref`` holds
the pure-jnp oracles every kernel is tested against.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]

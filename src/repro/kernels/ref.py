"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the shape/dtype sweep tests: each kernel's
output is ``assert_allclose``-checked against the function of the same
name here.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul(a: jax.Array, b: jax.Array,
           out_dtype=None) -> jax.Array:
    """C = A @ B with f32 accumulation (MXU convention)."""
    acc_t = jnp.float32
    if a.dtype in (jnp.float64, jnp.complex64, jnp.complex128):
        acc_t = a.dtype
    out = jnp.matmul(a, b, preferred_element_type=acc_t)
    return out.astype(out_dtype or a.dtype)


def trsm(a: jax.Array, b: jax.Array, *, side: str = "L", uplo: str = "L",
         trans: str = "N", diag: str = "N") -> jax.Array:
    """Solve op(A) X = B (side=L) or X op(A) = B (side=R)."""
    lower = uplo == "L"
    unit = diag == "U"
    ta = {"N": 0, "T": 1, "C": 2}[trans]
    return jax.lax.linalg.triangular_solve(
        a, b, left_side=(side == "L"), lower=lower,
        transpose_a=(ta != 0), conjugate_a=(ta == 2),
        unit_diagonal=unit)


def syrk(a: jax.Array, *, uplo: str = "L", trans: str = "N") -> jax.Array:
    """C = op(A) op(A)^T, only the ``uplo`` triangle populated."""
    opa = a if trans == "N" else jnp.swapaxes(a, -1, -2)
    full = matmul(opa, jnp.swapaxes(opa, -1, -2))
    return jnp.tril(full) if uplo == "L" else jnp.triu(full)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              softcap: float = 0.0, scale: Optional[float] = None,
              kv_len: Optional[jax.Array] = None,
              out_dtype=None) -> jax.Array:
    """Reference attention. q: [B,Hq,Tq,D]; k,v: [B,Hkv,Tk,D].

    GQA is expressed by Hq a multiple of Hkv. ``window`` > 0 restricts each
    query to the last ``window`` keys (gemma2 local layers); ``softcap``
    applies tanh logit soft-capping (gemma2). ``kv_len`` masks a
    pre-allocated decode cache: only keys < kv_len are live, and queries
    sit right-aligned at positions ``kv_len - Tq .. kv_len - 1``.
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    s = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * s
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    tk = k.shape[2]
    live_len = kv_len if kv_len is not None else tk
    qpos = jnp.arange(tq)[:, None] + (live_len - tq)  # right-aligned
    kpos = jnp.arange(tk)[None, :]
    mask = kpos < live_len
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(out_dtype or q.dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      chunk_q: int, softcap: float = 0.0,
                      scale: Optional[float] = None,
                      out_dtype=None) -> jax.Array:
    """Causal attention in query chunks with causally-sliced keys.

    XLA-expressible flash-style saving: query chunk i only multiplies
    against keys [0, (i+1)*chunk_q) — static shapes per chunk, so the
    masked upper triangle is never computed or materialized. FLOPs and
    logits memory drop to ~(n+1)/2n of the full T^2 formulation.
    Gradients flow through each chunk independently (exact).
    """
    b, hq, t, d = q.shape
    assert t % chunk_q == 0, (t, chunk_q)
    outs = []
    for i in range(t // chunk_q):
        qs = q[:, :, i * chunk_q:(i + 1) * chunk_q]
        klen = (i + 1) * chunk_q
        outs.append(attention(qs, k[:, :, :klen], v[:, :, :klen],
                              causal=True, softcap=softcap, scale=scale,
                              out_dtype=out_dtype))
    return jnp.concatenate(outs, axis=2)

"""Pallas TPU decode attention (single new token vs a long KV cache).

The decode cells are the worst roofline rows in EXPERIMENTS.md §Roofline:
one token against a 32k-entry cache is pure HBM streaming, and the XLA
path re-reads the padded cache with masking applied afterwards. This
kernel streams the cache once, block-by-block, with online softmax and
``kv_len`` masking fused in, and skips dead blocks entirely
(``pl.when`` on the block index) — so a cache filled to 25 % costs 25 %.

Grid ``(B, Hkv, Tk/bk)``: one program per (batch row, KV head, key
block); the GQA query group (Hq/Hkv rows) rides the sublane dimension of
a ``(group, bk)`` logit tile. f32 running max/denominator/accumulator
live in VMEM scratch across the key-block sweep.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import compat
from repro.kernels.compat import pl, pltpu

NEG_INF = -1e30


def _decode_kernel(lenref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, bk: int, k_steps: int, scale: float,
                   softcap: float):
    s = pl.program_id(2)
    kv_len = lenref[0]

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # live if any key position in this block is < kv_len
    @pl.when(s * bk < kv_len)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)          # (group, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
        logits = jnp.dot(q, k.T,
                         preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            logits = jnp.tanh(logits / softcap) * softcap
        kpos = s * bk + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        logits = jnp.where(kpos < kv_len, logits, NEG_INF)

        m_prev = m_ref[...]                          # (group, 1)
        m_new = jnp.maximum(m_prev, logits.max(-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(s == k_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "scale", "bk",
                                             "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, *, softcap: float = 0.0,
                     scale: Optional[float] = None, bk: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: [B,Hq,1,D]; k,v: [B,Hkv,S,D]; kv_len: scalar live length.

    Returns [B,Hq,1,D]. Equivalent to ``ref.attention(..., causal=True,
    kv_len=kv_len)`` for a single right-aligned query token.
    """
    b, hq, tq, d = q.shape
    _, hkv, s, _ = k.shape
    assert tq == 1, "decode kernel is single-token"
    group = hq // hkv
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))
    bk_ = min(bk, s)
    pad = (-s) % bk_
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    sp = k.shape[2]
    qg = q.reshape(b, hkv, group, d)
    grid = (b, hkv, sp // bk_)
    lenvec = jnp.asarray(kv_len, jnp.int32).reshape(1)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, bk=bk_, k_steps=grid[2],
                          scale=scale, softcap=softcap),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, d), lambda bb, h, s_: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, bk_, d), lambda bb, h, s_: (bb, h, s_, 0)),
            pl.BlockSpec((1, 1, bk_, d), lambda bb, h, s_: (bb, h, s_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda bb, h, s_: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lenvec, qg, k, v)
    return out.reshape(b, hq, 1, d)

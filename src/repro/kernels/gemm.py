"""Pallas TPU GEMM kernel — the compute hot spot of the whole paper.

Classic MXU-tiled matmul: grid ``(M/bm, N/bn, K/bk)`` with a float32 VMEM
accumulator revisited along the K axis. Block shapes default to
``(256, 512, 256)`` — multiples of the 128x128 MXU systolic tile, sized so
A-, B- and accumulator blocks together stay well under the ~16 MB/core
VMEM budget:

    bm*bk*2B + bk*bn*2B + bm*bn*4B = 256K*2 + 512*256*2 + 256^2*4
                                   = 0.25 + 0.25 + 0.25 MB per step (bf16)

leaving room for double-buffered pipelining of the HBM->VMEM streams.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import compat
from repro.kernels.compat import pl, pltpu


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jax.Array, mult: Tuple[int, int]) -> jax.Array:
    m, n = x.shape
    pm = (-m) % mult[0]
    pn = (-n) % mult[1]
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "out_dtype",
                                             "interpret"))
def gemm(a: jax.Array, b: jax.Array, *, bm: int = 256, bk: int = 256,
         bn: int = 256, out_dtype=None, interpret: bool = False
         ) -> jax.Array:
    """C = A @ B via the Pallas kernel. 2-D operands; wrapper handles
    padding to block multiples and unpadding of the result."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else jnp.float32

    ap = _pad_to(a, (bm, bk))
    bp = _pad_to(b, (bk, bn))
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]

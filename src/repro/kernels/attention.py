"""Pallas TPU flash attention (prefill/train path).

Online-softmax attention tiled for VMEM: grid ``(batch*q_heads, Tq/bq,
Tk/bk)``, f32 running max/denominator/accumulator in VMEM scratch. GQA is
native — the K/V BlockSpec index maps divide the head id by the group
size, so K/V are never materialized per-q-head. Supports causal masking,
sliding-window locality and tanh logit soft-capping (gemma2's local/global
layers), and skips fully-masked key blocks (``pl.when`` on block ids) so
causal prefill does ~half the MXU work.

Decode (Tq=1) uses the XLA reference path — a 1-row MXU tile would waste
127/128 of the systolic array; XLA's fused GEMV path is the right tool.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import compat
from repro.kernels.compat import pl, pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  k_steps: int, bq: int, bk: int, scale: float,
                  causal: bool, window: int, softcap: float,
                  q_offset: int):
    iq, s = pl.program_id(1), pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions (right-aligned when Tq < Tk, e.g. chunked prefill)
    q_start = iq * bq + q_offset
    k_start = s * bk

    # skip key blocks that are entirely masked out
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + bq - 1
    if window > 0:
        live &= k_start + bk - 1 >= q_start - window + 1

    @pl.when(live)
    def _update():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            logits = jnp.tanh(logits / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=bool)
        if causal:
            mask &= qpos >= kpos
        if window > 0:
            mask &= qpos - kpos < window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]                                   # (bq, 1)
        m_new = jnp.maximum(m_prev, logits.max(-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)                       # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(s == k_steps - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: Optional[float] = None,
                    bq: int = 256, bk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: [B,Hq,Tq,D]; k,v: [B,Hkv,Tk,D] with Hq % Hkv == 0."""
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    group = hq // hkv
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))

    bq_ = min(bq, tq)
    bk_ = min(bk, tk)
    pad_q, pad_k = (-tq) % bq_, (-tk) % bk_
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else v

    qf = qp.reshape(b * hq, qp.shape[2], d)
    kf = kp.reshape(b * hkv, kp.shape[2], d)
    vf = vp.reshape(b * hkv, vp.shape[2], d)
    grid = (b * hq, qf.shape[1] // bq_, kf.shape[1] // bk_)
    q_offset = tk - tq  # right-aligned query positions

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, k_steps=grid[2], bq=bq_, bk=bk_, scale=scale,
            causal=causal, window=window, softcap=softcap,
            q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda h, i, s: (h, i, 0)),
            pl.BlockSpec((1, bk_, d),
                         lambda h, i, s, g=group: (h // g, s, 0)),
            pl.BlockSpec((1, bk_, d),
                         lambda h, i, s, g=group: (h // g, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda h, i, s: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(qf.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, hq, qf.shape[1], d)
    return out[:, :, :tq] if pad_q else out

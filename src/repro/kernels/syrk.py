"""Pallas TPU SYRK: rank-k update writing only one triangle.

C = A @ A^T touches only n(n+1)/2 output blocks; the kernel skips the MXU
work for blocks strictly on the wrong side of the diagonal (``pl.when`` on
block ids — the TPU equivalent of cuBLAS's triangle-restricted tile
scheduling), halving compute vs. a full GEMM. Off-triangle blocks are
zero-filled so the result composes with the full-storage BLAS semantics
in ``repro.core.blas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import compat
from repro.kernels.compat import pl, pltpu


def _syrk_kernel(a_ref, at_ref, o_ref, acc_ref, *, k_steps: int,
                 lower: bool):
    i, j = pl.program_id(0), pl.program_id(1)
    s = pl.program_id(2)
    on_tri = (j <= i) if lower else (j >= i)

    @pl.when(s == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(on_tri)
    def _update():
        acc_ref[...] += jnp.dot(a_ref[...], at_ref[...],
                                preferred_element_type=acc_ref.dtype)

    @pl.when(s == k_steps - 1)
    def _store():
        # blocks straddling the diagonal get masked at the wrapper
        o_ref[...] = jnp.where(on_tri, acc_ref[...], 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("uplo", "trans", "bm", "bk",
                                             "interpret"))
def syrk(a: jax.Array, *, uplo: str = "L", trans: str = "N", bm: int = 256,
         bk: int = 256, interpret: bool = False) -> jax.Array:
    """C = op(A) op(A)^T, only the ``uplo`` triangle populated."""
    opa = a if trans == "N" else a.mT
    n, k = opa.shape
    pad_n, pad_k = (-n) % bm, (-k) % bk
    if pad_n or pad_k:
        opa = jnp.pad(opa, ((0, pad_n), (0, pad_k)))
    npad, kpad = opa.shape
    grid = (npad // bm, npad // bm, kpad // bk)
    acc_dtype = jnp.float64 if a.dtype == jnp.float64 else jnp.float32

    out = pl.pallas_call(
        functools.partial(_syrk_kernel, k_steps=grid[2],
                          lower=(uplo == "L")),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bm), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, npad), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bm), acc_dtype)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(opa, opa.mT)[:n, :n]
    # exact triangle mask for blocks that straddle the diagonal
    return jnp.tril(out) if uplo == "L" else jnp.triu(out)

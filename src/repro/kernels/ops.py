"""Dispatch layer: Pallas kernels on TPU, XLA reference elsewhere.

``matmul``/``trsm``/``attention`` are what the BLAS surface and the model
stack call. Backend selection:

* TPU backend -> Pallas kernels (compiled), except dtypes the MXU lacks.
* CPU backend -> XLA reference by default (the Pallas kernels are TPU
  programs; they execute on CPU only under ``interpret=True``, which is
  for correctness tests, not speed). Set ``SCILIB_PALLAS=1`` to force the
  interpreted kernels everywhere (used by the test suite).

Precision mapping for the TPU target (DESIGN.md): BLAS ``s/c`` run native
(f32/c64 — complex decomposes onto real MXU gemms); ``d/z`` have no MXU
equivalent and stay on the XLA path (host BLAS in the offload picture).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import compat, ref
from repro.kernels.attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.gemm import gemm as pallas_gemm
from repro.kernels.syrk import syrk as pallas_syrk
from repro.kernels.trsm import trsm as pallas_trsm


def _backend() -> str:
    return jax.default_backend()


def use_pallas() -> bool:
    env = os.environ.get("SCILIB_PALLAS", "")
    if env == "0":
        return False
    want = env == "1" or _backend() == "tpu"
    if want and not compat.HAVE_PALLAS:
        compat.warn_missing()       # degrade to ref, once per process
        return False
    return want


def _interpret() -> bool:
    return _backend() != "tpu"


def _mxu_dtype(dtype) -> bool:
    return jnp.dtype(dtype) in (jnp.dtype(jnp.float32),
                                jnp.dtype(jnp.bfloat16),
                                jnp.dtype(jnp.float16),
                                jnp.dtype(jnp.float64))
    # f64 allowed only under interpret (CPU); the TPU check is below.


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B. Complex decomposes into real Pallas GEMMs (zgemm on the
    MXU via its real/imaginary planes — 4M algorithm)."""
    if not use_pallas():
        return ref.matmul(a, b)
    interp = _interpret()
    if jnp.issubdtype(a.dtype, jnp.complexfloating):
        ar, ai = jnp.real(a), jnp.imag(a)
        br, bi = jnp.real(b), jnp.imag(b)
        f = functools.partial(_mm2d, interpret=interp)
        rr = _batched(f, ar, br)
        ii = _batched(f, ai, bi)
        ri = _batched(f, ar, bi)
        ir = _batched(f, ai, br)
        return jax.lax.complex(rr - ii, ri + ir).astype(a.dtype)
    if a.dtype == jnp.float64 and not interp:
        return ref.matmul(a, b)      # no f64 MXU path
    return _batched(functools.partial(_mm2d, interpret=interp), a, b)


def _mm2d(a, b, interpret):
    return pallas_gemm(a, b, interpret=interpret)


def _batched(f, a, b):
    if a.ndim == 2 and b.ndim == 2:
        return f(a, b)
    # normalize leading batch dims then vmap the 2-D kernel
    bshape = jnp.broadcast_shapes(a.shape[:-2], b.shape[:-2])
    a = jnp.broadcast_to(a, bshape + a.shape[-2:])
    b = jnp.broadcast_to(b, bshape + b.shape[-2:])
    af = a.reshape((-1,) + a.shape[-2:])
    bf = b.reshape((-1,) + b.shape[-2:])
    out = jax.vmap(f)(af, bf)
    return out.reshape(bshape + out.shape[-2:])


def trsm(a: jax.Array, b: jax.Array, *, side: str = "L", uplo: str = "L",
         trans: str = "N", diag: str = "N") -> jax.Array:
    if not use_pallas() or jnp.issubdtype(a.dtype, jnp.complexfloating):
        # complex substitution needs complex VPU ops: XLA path (DESIGN.md)
        return ref.trsm(a, b, side=side, uplo=uplo, trans=trans, diag=diag)
    return pallas_trsm(a, b, side=side, uplo=uplo, trans=trans, diag=diag,
                       interpret=_interpret())


def syrk(a: jax.Array, *, uplo: str = "L", trans: str = "N") -> jax.Array:
    if not use_pallas() or jnp.issubdtype(a.dtype, jnp.complexfloating):
        return ref.syrk(a, uplo=uplo, trans=trans)
    return pallas_syrk(a, uplo=uplo, trans=trans, interpret=_interpret())


def attention(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
              kv_len=None, chunk_q=0):
    """Attention entry point for the model stack. The flash kernel handles
    prefill/train (Tq > 8, fully-live cache); decode rows and partial
    caches fall back to the XLA path. ``chunk_q`` selects the causal
    query-chunked XLA formulation (flash-style flop/memory saving that
    also compiles for the CPU dry-run)."""
    tq = q.shape[-2]
    if (use_pallas() and tq == 1 and kv_len is not None and causal
            and window == 0):
        return decode_attention(q, k, v, kv_len, softcap=softcap,
                                scale=scale, interpret=_interpret())
    if use_pallas() and tq >= 8 and kv_len is None:
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               interpret=_interpret())
    if (chunk_q and causal and window == 0 and kv_len is None
            and tq > chunk_q and tq % chunk_q == 0):
        return ref.attention_chunked(q, k, v, chunk_q=chunk_q,
                                     softcap=softcap, scale=scale)
    return ref.attention(q, k, v, causal=causal, window=window,
                         softcap=softcap, scale=scale, kv_len=kv_len)


# ---------------------------------------------------------------------------
# The `pallas` dispatch venue (OffloadConfig.kernel_path / SCILIB_KERNELS)
# ---------------------------------------------------------------------------
# `kernel_*` are the entry points behind the runtime's third execution
# venue: on the TPU backend they run the Pallas kernels compiled, with the
# block edge taken from OffloadConfig.kernel_block; on every other backend
# they run the direct XLA formulation (interpret-mode Pallas is a
# correctness harness, orders of magnitude off), so the venue's remaining
# edge there is the epilogue-free closures built in repro.core.blas.

#: BLAS bases the `pallas` venue can execute; everything else stays on the
#: generic XLA offload path.
KERNEL_BASES = ("gemm", "syrk", "trsm")

#: Bases with a split-precision formulation (repro.kernels.split_gemm,
#: SCILIB_PRECISION): fp64 decomposed onto fp32/bf16 slice passes.  This
#: is the only fp64 gemm path the venue has — the MXU itself has no f64
#: mode.
SPLIT_KERNEL_BASES = ("gemm", "syrk", "trsm")


def kernel_available(base: str, dtype, precision: str = "") -> bool:
    """Capability test for the `pallas` venue: does `base` at `dtype` have
    a kernel? Complex syrk/trsm need complex VPU ops the kernels lack;
    complex gemm decomposes onto real MXU gemms (4M).

    fp64 gemm has no MXU path, so it is only available when a split
    scheme is active (``precision``, via repro.kernels.split_gemm) —
    never silently through the reference matmul: a True here must mean
    the venue executes something other than the plain XLA formulation,
    or the venue prober times the wrong path and can mis-lock."""
    if base not in KERNEL_BASES:
        return False
    if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
        return base == "gemm"
    if jnp.dtype(dtype) == jnp.float64 and base == "gemm":
        return bool(precision) and base in SPLIT_KERNEL_BASES
    return True


def _kernel_compiled() -> bool:
    return _backend() == "tpu" and compat.HAVE_PALLAS


def _block_kw(block: int, names=("bm", "bk", "bn")):
    b = int(block)
    return {n: b for n in names} if b > 0 else {}


def _split_matmul(a: jax.Array, b: jax.Array, precision: str,
                  block: int) -> jax.Array:
    from repro.kernels import split_gemm   # lazy: split_gemm pulls in core
    f = functools.partial(split_gemm.matmul, scheme=precision, block=block)
    return _batched(f, a, b)


def kernel_matmul(a: jax.Array, b: jax.Array, *, block: int = 0,
                  precision: str = "") -> jax.Array:
    """C = A @ B on the `pallas` venue. A zero-length contraction (k = 0)
    skips the kernel outright — its K grid axis would launch nothing and
    leave the accumulator unwritten.

    fp64 runs only with a split ``precision`` scheme (slice passes on
    the fp32 kernel); without one this venue has no f64 kernel — the
    reference fallback below mirrors what ``kernel_available`` already
    refuses, it is not a secret second path."""
    if a.dtype == jnp.float64 and precision and a.shape[-1]:
        return _split_matmul(a, b, precision, block)
    if a.shape[-1] == 0 or not _kernel_compiled():
        return ref.matmul(a, b)
    f = functools.partial(pallas_gemm, **_block_kw(block))
    if jnp.issubdtype(a.dtype, jnp.complexfloating):
        ar, ai = jnp.real(a), jnp.imag(a)
        br, bi = jnp.real(b), jnp.imag(b)
        rr = _batched(f, ar, br)
        ii = _batched(f, ai, bi)
        ri = _batched(f, ar, bi)
        ir = _batched(f, ai, br)
        return jax.lax.complex(rr - ii, ri + ir).astype(a.dtype)
    if a.dtype == jnp.float64:
        return ref.matmul(a, b)      # kernel_available(f64) is False
    return _batched(f, a, b)


def kernel_syrk(a: jax.Array, *, uplo: str = "L", trans: str = "N",
                block: int = 0) -> jax.Array:
    if not _kernel_compiled() or jnp.issubdtype(a.dtype,
                                                jnp.complexfloating):
        return ref.syrk(a, uplo=uplo, trans=trans)
    return pallas_syrk(a, uplo=uplo, trans=trans,
                       **_block_kw(block, ("bm", "bk")))


def kernel_trsm(a: jax.Array, b: jax.Array, *, side: str = "L",
                uplo: str = "L", trans: str = "N", diag: str = "N",
                block: int = 0) -> jax.Array:
    del block   # the recursion's base edge is fixed (trsm.BASE)
    if not _kernel_compiled() or jnp.issubdtype(a.dtype,
                                                jnp.complexfloating):
        return ref.trsm(a, b, side=side, uplo=uplo, trans=trans, diag=diag)
    return pallas_trsm(a, b, side=side, uplo=uplo, trans=trans, diag=diag)

"""Pallas TPU TRSM: triangular solve with a MXU-friendly decomposition.

CUDA trsm implementations are warp-synchronous substitution engines; that
mechanism has no TPU analogue, so this is a *re-design* for the MXU
(DESIGN.md hardware-adaptation): a divide-and-conquer blocked solve

    [A11  0 ] [X1]   [B1]      X1 = trsm(A11, B1)
    [A21 A22] [X2] = [B2]  =>  X2 = trsm(A22, B2 - A21 @ X1)

where all the heavy FLOPs are the ``A21 @ X1`` updates executed by the
Pallas GEMM kernel (exactly how cuBLAS reduces trsm to gemm), and only the
``base``-sized diagonal blocks run a row-substitution Pallas kernel on the
VPU. Total FLOPs match textbook trsm (m^2 n), with log2(m/base) recursion
levels of pure MXU work.

All eight (side, uplo, trans) variants canonicalize to lower-left-N via
conjugation/transpose/flip identities in :func:`trsm`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.compat import pl
from repro.kernels.gemm import gemm

BASE = 128


def _trsm_base_kernel(l_ref, b_ref, x_ref, *, nb: int, unit: bool):
    """Solve L x = b for one (nb x nb) lower block and (nb x bn) panel.

    Sequential row substitution; the panel dimension is vectorized on the
    VPU. Rows >= i of the scratch still hold unsolved values, so the dot
    masks columns >= i.
    """
    x_ref[...] = b_ref[...]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, nb), 1)

    def body(i, _):
        l_row = pl.load(l_ref, (pl.dslice(i, 1), slice(None)))   # (1, nb)
        l_masked = jnp.where(col < i, l_row, 0.0).astype(x_ref.dtype)
        partial = jnp.dot(l_masked, x_ref[...],
                          preferred_element_type=x_ref.dtype)     # (1, bn)
        b_row = pl.load(x_ref, (pl.dslice(i, 1), slice(None)))
        upd = b_row - partial
        if not unit:
            diag = pl.load(l_ref, (pl.dslice(i, 1), pl.dslice(i, 1)))
            upd = upd / diag[0, 0]
        pl.store(x_ref, (pl.dslice(i, 1), slice(None)), upd)
        return 0

    jax.lax.fori_loop(0, nb, body, 0)


@functools.partial(jax.jit, static_argnames=("unit", "bn", "interpret"))
def _trsm_base(l: jax.Array, b: jax.Array, *, unit: bool, bn: int = 256,
               interpret: bool = False) -> jax.Array:
    """Base-case solve via the Pallas substitution kernel."""
    nb, n = l.shape[0], b.shape[1]
    pad_n = (-n) % bn
    bp = jnp.pad(b, ((0, 0), (0, pad_n))) if pad_n else b
    grid = (bp.shape[1] // bn,)
    out = pl.pallas_call(
        functools.partial(_trsm_base_kernel, nb=nb, unit=unit),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb, nb), lambda j: (0, 0)),
            pl.BlockSpec((nb, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((nb, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct(bp.shape, b.dtype),
        interpret=interpret,
    )(l, bp)
    return out[:, :n]


def _solve_lower(l: jax.Array, b: jax.Array, *, unit: bool,
                 interpret: bool) -> jax.Array:
    """Recursive lower-left-N solve; shapes are static so the recursion
    unrolls at trace time into a log-depth chain of Pallas GEMMs."""
    m = l.shape[0]
    if m <= BASE:
        return _trsm_base(l, b, unit=unit, interpret=interpret)
    # split at the largest power-of-two half for aligned gemm shapes
    half = max(BASE, 1 << (m - 1).bit_length() - 1)
    if half >= m:
        half = m // 2
    a11, a21, a22 = l[:half, :half], l[half:, :half], l[half:, half:]
    x1 = _solve_lower(a11, b[:half], unit=unit, interpret=interpret)
    upd = gemm(a21, x1, interpret=interpret) if not jnp.issubdtype(
        l.dtype, jnp.complexfloating) else a21 @ x1
    x2 = _solve_lower(a22, b[half:] - upd, unit=unit, interpret=interpret)
    return jnp.concatenate([x1, x2], axis=0)


@functools.partial(jax.jit, static_argnames=("side", "uplo", "trans",
                                             "diag", "interpret"))
def trsm(a: jax.Array, b: jax.Array, *, side: str = "L", uplo: str = "L",
         trans: str = "N", diag: str = "N",
         interpret: bool = False) -> jax.Array:
    """Solve op(A) X = B (side=L) or X op(A) = B (side=R)."""
    unit = diag == "U"
    if side == "R":
        # X op(A) = B  <=>  op(A)^T X^T = B^T
        flip_t = {"N": "T", "T": "N", "C": "N"}[trans]
        a_ = jnp.conj(a) if trans == "C" else a
        out = trsm(a_, b.mT, side="L", uplo=uplo, trans=flip_t,
                   diag=diag, interpret=interpret)
        return out.mT
    if trans != "N":
        # op(A) X = B with A lower  <=>  solve with upper A^(T|H)
        a_ = jnp.conj(a.mT) if trans == "C" else a.mT
        new_uplo = "U" if uplo == "L" else "L"
        return trsm(a_, b, side="L", uplo=new_uplo, trans="N", diag=diag,
                    interpret=interpret)
    if uplo == "U":
        # U X = B  <=>  (J U J)(J X) = (J B), J = index reversal
        lj = jnp.flip(a, axis=(-2, -1))
        bj = jnp.flip(b, axis=-2)
        xj = trsm(lj, bj, side="L", uplo="L", trans="N", diag=diag,
                  interpret=interpret)
        return jnp.flip(xj, axis=-2)
    if a.ndim > 2:  # batched: vmap the canonical solve
        f = functools.partial(trsm, side="L", uplo="L", trans="N",
                              diag=diag, interpret=interpret)
        return jax.vmap(f)(a, b)
    return _solve_lower(a, b, unit=unit, interpret=interpret)

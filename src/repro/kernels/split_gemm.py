"""Split-precision fp64 GEMM for the `pallas` dispatch venue.

:mod:`repro.core.precision` owns the decomposition math (slices, cross
passes, error bounds); this module binds its injectable fp32 pass
primitive to the Pallas GEMM kernel, which is what finally gives fp64
a real path onto the MXU: the f64 operands never reach the systolic
array — their fp32/bf16 slices do, and the fp64 re-accumulation runs
on the VPU/XLA side.

On backends without compiled Pallas the pass primitive degrades to the
plain XLA fp32 matmul, exactly like every other `kernel_*` entry point
in :mod:`repro.kernels.ops` — so the venue's split path runs anywhere
tier-1 does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import precision
from repro.kernels import compat
from repro.kernels.gemm import gemm as pallas_gemm


def _kernel_compiled() -> bool:
    return jax.default_backend() == "tpu" and compat.HAVE_PALLAS


def pass_mm(block: int = 0) -> precision.MatMul:
    """The fp32 slice-product primitive for the `pallas` venue."""
    if not _kernel_compiled():
        return lambda a, b: jnp.matmul(a, b,
                                       preferred_element_type=jnp.float32)
    kw = {n: int(block) for n in ("bm", "bk", "bn")} if block > 0 else {}
    kern = functools.partial(pallas_gemm, out_dtype=jnp.float32, **kw)

    def mm(a, b):
        if a.shape[-1] == 0:    # empty contraction: no K grid axis
            return jnp.zeros(a.shape[:-1] + b.shape[-1:], jnp.float32)
        return kern(a, b)

    return mm


def matmul(a: jax.Array, b: jax.Array, scheme: str, *,
           block: int = 0) -> jax.Array:
    """fp64 ``A @ B`` via split slices on the Pallas GEMM kernel."""
    return precision.matmul(a, b, scheme, mm=pass_mm(block))


def syrk(a: jax.Array, scheme: str, *, trans: bool = False,
         block: int = 0) -> jax.Array:
    return precision.syrk(a, scheme, trans=trans, mm=pass_mm(block))


def trsm(a: jax.Array, b: jax.Array, scheme: str, *, left_side: bool = True,
         lower: bool = True, trans_a: bool = False, unit_diag: bool = False,
         block: int = 0) -> jax.Array:
    return precision.trsm(a, b, scheme, left_side=left_side, lower=lower,
                          trans_a=trans_a, unit_diag=unit_diag,
                          mm=pass_mm(block))

"""Pallas availability + API compatibility for the pinned JAX.

Two jobs:

* Export ``pl``/``pltpu`` (or ``None``) so the kernel modules import
  cleanly on containers whose jaxlib ships without Pallas — requesting a
  Pallas kernel there degrades to the ``kernels/ref.py`` XLA path with a
  single warning instead of an import-time crash.
* Paper over the one API rename the kernels touch: the pinned JAX
  (0.4.x) names the TPU compiler params ``pltpu.TPUCompilerParams``;
  newer releases renamed it to ``pltpu.CompilerParams``. The pinned name
  is tried first; everything else the kernels use is stable across both.
"""
from __future__ import annotations

import warnings

try:
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    HAVE_PALLAS = True
except ImportError:           # pragma: no cover - jaxlib without Pallas
    pl = None
    pltpu = None
    HAVE_PALLAS = False

CompilerParams = (getattr(pltpu, "TPUCompilerParams", None)
                  or getattr(pltpu, "CompilerParams", None)
                  ) if HAVE_PALLAS else None

_warned = False


def warn_missing() -> None:
    """One warning per process when Pallas was requested but is absent."""
    global _warned
    if not _warned:
        _warned = True
        warnings.warn(
            "Pallas is unavailable in this jaxlib; kernels degrade to the "
            "XLA reference path (repro.kernels.ref)", RuntimeWarning,
            stacklevel=3)


def tpu_compiler_params(**kwargs):
    """Build TPU compiler params under whichever name this JAX exposes."""
    if CompilerParams is None:
        raise RuntimeError("Pallas is unavailable in this jaxlib")
    return CompilerParams(**kwargs)

"""Pallas API compatibility across JAX versions.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer JAX releases; the kernels must compile against both (the dev
container pins an older jaxlib than the TPU fleet runs).
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build TPU compiler params under whichever name this JAX exposes."""
    return CompilerParams(**kwargs)

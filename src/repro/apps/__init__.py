"""Application proxies: the paper's two evaluation codes.

``lsms`` (MuST) and ``dft`` (PARSEC) each provide (a) a *runnable* CPU
mini-app whose BLAS stream flows through the interception layer, and (b)
a *trace generator* reproducing the production-scale BLAS call structure
(sizes, counts, buffer-reuse topology) for the memtier replay that backs
the paper-table benchmarks.
"""
from repro.apps import dft, lsms

__all__ = ["lsms", "dft"]

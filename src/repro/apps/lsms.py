"""MuST / LSMS proxy (paper §4.2).

LSMS solves the Kohn-Sham equation via multiple-scattering Green's
functions: per atom, per energy-grid point, per SCF iteration, build the
KKR matrix ``M = I - t·G`` over the local interaction zone and solve
``M tau = t`` — in production via zgetrf/zgetrs, whose panel updates are
the zgemm/ztrsm stream that is 80 %+ of runtime.

``run_mini`` executes the real numerics at laptop scale through the
public ``jax.scipy.linalg`` solve symbols — under ``SCILIB_LAPACK=1``
these are the intercepted solver tier (:mod:`repro.solvers`), so the
runtime sees a genuine LAPACK-shaped BLAS stream wrapped in solver
spans; without it they are the native path. ``production_trace`` emits the 50-node-scale
call structure of Table 3 — one resident KKR buffer per atom reused
across all (energy x SCF) solves, which is precisely the reuse pattern
(~780x) Device First-Use exploits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core.trace import Trace

# Production workload (paper): 5600 atoms over 50 nodes, 32 energies,
# 3 SCF steps. KKR matrix n ~ LIZ x (lmax+1)^2 x spin; n=6912 calibrated
# so the CPU-policy replay reproduces Table 3's 2080 s of zgemm+ztrsm on
# the Grace-Grace node. nb=256 is the production LU blocking (also sets
# Mem-Copy's per-call staging volume, paper: 291.7 s).
PROD = dict(atoms_per_node=112, energies=32, scf=3, n=6912, nb=256,
            nrhs=32)


@dataclasses.dataclass
class LsmsResult:
    energy: float
    n_solves: int
    trace: Trace


def _getrf_stream(t: Trace, tau: int, tmat: int, n: int, nb: int,
                  nrhs: int) -> None:
    """BLAS stream of one blocked zgetrf + zgetrs on buffer ``tau``.

    Fortran LU factors in place: every panel/trailing-matrix call reads
    and writes regions of the SAME allocation — so all calls reference
    one buffer id, exactly what the DBI interceptor observes.
    """
    for j0 in range(0, n - nb, nb):
        rem = n - j0 - nb
        # panel factor stays on the CPU (getf2 is not level-3 BLAS)
        t.panel("z", n - j0, nb, tau)
        # U12 = L11^{-1} A12
        t.trsm("z", nb, rem, tau, tau)
        # A22 -= L21 @ U12   (the hot zgemm)
        t.gemm("z", rem, rem, nb, tau, tau, tau)
    # zgetrs: forward + back substitution against the t-matrix RHS
    t.trsm("z", n, nrhs, tau, tmat)
    t.trsm("z", n, nrhs, tau, tmat)


def production_trace(atoms_per_node: int = PROD["atoms_per_node"],
                     energies: int = PROD["energies"],
                     scf: int = PROD["scf"], n: int = PROD["n"],
                     nb: int = PROD["nb"],
                     nrhs: int = PROD["nrhs"]) -> Trace:
    """One Grace-Hopper node's BLAS stream for the Table 3 workload."""
    t = Trace()
    el = 16  # complex128
    taus = [t.new_buffer(n * n * el, f"tau_atom{a}")
            for a in range(atoms_per_node)]
    tmats = [t.new_buffer(n * nrhs * el, f"t_atom{a}")
             for a in range(atoms_per_node)]
    for _ in range(scf):
        for _e in range(energies):
            for a in range(atoms_per_node):
                _getrf_stream(t, taus[a], tmats[a], n, nb, nrhs)
    return t


# ----------------------------------------------------------------------- #
# runnable mini-app (real numerics through the interception layer)         #
# ----------------------------------------------------------------------- #
def run_mini(atoms: int = 4, energies: int = 4, scf: int = 2,
             n: int = 192, nb: int = 64, seed: int = 0,
             dtype="complex128") -> Dict[str, float]:
    """Tiny LSMS: real KKR-style solves with verification.

    Returns the total energy proxy and residual so tests can assert the
    physics loop is numerically sound under every offload policy.
    ``nb`` is kept for callers, but when the solver tier is patched the
    blocked LU takes its block size from the session's ``lapack_nb``
    (``SCILIB_LAPACK_NB``); the native path ignores it entirely.
    """
    import jax
    import jax.numpy as jnp
    import jax.scipy.linalg as jsl

    from repro.core.policy import host_array

    rng = np.random.default_rng(seed)
    # structure "constants" G per atom: fixed across SCF; host-first-
    # touched like Fortran allocations, reused across all solves
    gmats = [host_array(jnp.asarray(
        (rng.standard_normal((n, n)) + 1j * rng.standard_normal((n, n)))
        / (2 * n), dtype)) for _ in range(atoms)]
    energy = 0.0
    max_resid = 0.0
    n_solves = 0
    tmat_scale = 1.0
    for it in range(scf):
        for e in range(energies):
            z = 0.1 + 0.05 * e + 0.02j
            for a in range(atoms):
                tm = jnp.asarray(
                    tmat_scale * (np.eye(n)
                                  + 0.01 * rng.standard_normal((n, n))),
                    dtype)
                tg = jnp.matmul(tm, gmats[a])    # intercepted zgemm
                # the KKR build stays in the intercepted stream (no
                # host round-trip), and the solve goes through the
                # public scipy symbols: with SCILIB_LAPACK=1 these are
                # the patched solver tier, without it the native path
                m = jnp.eye(n, dtype=tg.dtype) - z * tg
                lu_piv = jsl.lu_factor(m)
                tau = jsl.lu_solve(lu_piv, tm)
                # verification on the host side (numpy): not BLAS stream
                resid = float(np.max(np.abs(
                    np.asarray(m) @ np.asarray(tau) - np.asarray(tm))))
                max_resid = max(max_resid, resid)
                energy += float(np.real(np.trace(np.asarray(tau)))) / n
                n_solves += 1
        tmat_scale *= 0.98  # SCF mixing proxy
    return {"energy": energy, "max_resid": max_resid,
            "n_solves": n_solves}

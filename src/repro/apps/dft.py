"""PARSEC proxy (paper §4.3).

PARSEC does real-space DFT: Chebyshev-filtered subspace iteration over a
finite-difference Hamiltonian on ~93k grid points. The ScaLAPACK layer
reduces to *extremely tall-skinny* dgemms — the paper's canonical shape
is ``transA='T', M=32, N=2400, K=93536``: a 24 MB block of the wavefront
against the 1.8 GB wavefunction panel, an operand mix that defeats both
per-call Mem-Copy (Table 5: 220 s of cudaMemcpy) and the hardware
access counter (Table 6: the 1.8 GB panel never migrates).

``production_trace`` reproduces that stream for the Table 5 replay;
``run_mini`` runs a real (downscaled) subspace iteration through the
interception layer, with a Rayleigh-Ritz step whose eigenvalues are
verifiable against dense numpy.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.trace import Trace

# Production shape (paper §4.3 / Table 6 row 4)
PROD = dict(ngrid=93536, nstates=2400, nblock=32, scf=2, filt_per_scf=9)


def production_trace(ngrid: int = PROD["ngrid"],
                     nstates: int = PROD["nstates"],
                     nblock: int = PROD["nblock"],
                     scf: int = PROD["scf"],
                     filt_per_scf: int = PROD["filt_per_scf"]) -> Trace:
    """Single-node PARSEC BLAS stream (Table 5 workload).

    Per filter sweep, each of the nstates/nblock wavefront blocks hits
    the resident wavefunction panel: dgemm^T (nblock x nstates x ngrid).
    The panel buffer (1.8 GB) is reused by every call — the ~570x reuse
    the paper measures — while block operands rotate through a small
    working set.
    """
    t = Trace()
    el = 8
    psi = t.new_buffer(ngrid * nstates * el, "psi_panel")      # 1.8 GB
    nblocks = max(1, nstates // nblock)
    work = [t.new_buffer(ngrid * nblock * el, f"hpsi_blk{i}")  # 24 MB
            for i in range(nblocks)]
    outs = [t.new_buffer(nblock * nstates * el, f"s_blk{i}")   # 0.6 MB
            for i in range(nblocks)]
    for _ in range(scf):
        for _f in range(filt_per_scf):
            # one filter+Rayleigh-Ritz sweep touches every wavefront
            # block against the resident panel
            for blk in range(nblocks):
                for _r in range(46):   # orthogonalization sub-iterations
                    # S_blk = Hpsi_blk^T @ Psi  (M=32, N=2400, K=93536)
                    t.gemm("d", nblock, nstates, ngrid,
                           work[blk], psi, outs[blk])
    return t


# ----------------------------------------------------------------------- #
# runnable mini-app                                                        #
# ----------------------------------------------------------------------- #
def run_mini(ngrid: int = 2048, nstates: int = 48, cheb_order: int = 10,
             scf: int = 8, seed: int = 0) -> Dict[str, float]:
    """Downscaled Chebyshev-filtered subspace iteration (CheFSI).

    H = 1-D Laplacian + random potential (real spectrum). The filter
    window [lo, hi] brackets the UNWANTED upper spectrum and adapts each
    pass from the Ritz values, as in PARSEC. Verifies the converged Ritz
    values against dense eigh.
    """
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    # finite-difference H: tridiagonal Laplacian + potential
    pot = 0.5 * rng.standard_normal(ngrid)
    h = (np.diag(2.0 + pot) + np.diag(-np.ones(ngrid - 1), 1)
         + np.diag(-np.ones(ngrid - 1), -1))
    hj = jnp.asarray(h)

    psi = jnp.asarray(rng.standard_normal((ngrid, nstates)))
    psi, _ = jnp.linalg.qr(psi)
    hi = float(2.0 + np.max(pot) + 2.0) + 0.5    # Gershgorin upper bound

    def rayleigh_ritz(p):
        hpsi = jnp.matmul(hj, p)                  # (ngrid, nstates)
        s = jnp.einsum("gi,gj->ij", p, hpsi)      # skinny^T x panel
        evals, vecs = jnp.linalg.eigh((s + s.T) / 2.0)
        return jnp.matmul(p, vecs), evals

    psi, ritz = rayleigh_ritz(psi)                # bootstrap the window
    for _ in range(scf):
        lo = min(float(ritz[-1]) + 0.2, hi - 1.0)  # damp above block
        c, e = (hi + lo) / 2.0, (hi - lo) / 2.0
        t0 = psi
        t1 = (jnp.matmul(hj, psi) - c * psi) / e
        for _k in range(cheb_order - 1):
            t0, t1 = t1, 2.0 * (jnp.matmul(hj, t1) - c * t1) / e - t0
        psi, _ = jnp.linalg.qr(t1)
        psi, ritz = rayleigh_ritz(psi)
    exact = np.linalg.eigvalsh(h)[:nstates]
    err = float(np.max(np.abs(np.asarray(ritz)[:nstates // 2]
                              - exact[:nstates // 2])))
    return {"ritz_min": float(ritz[0]), "exact_min": float(exact[0]),
            "max_err_low_half": err}

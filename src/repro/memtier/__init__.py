"""Tiered-memory hardware model (paper §2.1, §4.4).

This package models a unified-memory superchip as two NUMA domains —
host-resident memory (NUMA 0) and device-resident memory (NUMA 1) — with
asymmetric access bandwidths, a cache-coherent interconnect, page tables,
``move_pages``-style migration, and the hardware access-counter migration
whose behaviour the paper measures in §4.4.1.

Two calibrated specs ship: ``GH200`` (the paper's machine, used to validate
the paper's claims) and ``TPU_V5E`` (the adaptation target used for the
roofline analysis).
"""
from repro.memtier.spec import HardwareSpec, GH200, TPU_V5E, GH200_4K, MemKind
from repro.memtier.pagetable import PageTable, Buffer
from repro.memtier.simulator import (
    MemTierSimulator,
    PolicyReport,
    replay_trace,
)

__all__ = [
    "HardwareSpec",
    "GH200",
    "GH200_4K",
    "TPU_V5E",
    "MemKind",
    "PageTable",
    "Buffer",
    "MemTierSimulator",
    "PolicyReport",
    "replay_trace",
]

"""Virtual-memory page table with NUMA placement and ``move_pages``.

Models Figure 2 of the paper: a buffer is a range of virtual pages whose
physical pages can be re-homed between the host NUMA domain and the device
NUMA domain *without changing the virtual addresses the application sees*.
That property is what makes the Device First-Use policy implementable under
an unmodified binary, and here it is what lets the simulator account
byte-exactly for which accesses hit which memory.

Granularity note (DESIGN.md §2): the production JAX runtime migrates whole
buffers; this page-level model exists to reproduce the paper's page-size,
alignment and partial-migration studies (Tables 6-8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.memtier.spec import HardwareSpec, MemKind


@dataclasses.dataclass
class Buffer:
    """A virtual allocation: contiguous range of pages + bookkeeping."""

    buf_id: int
    name: str
    base: int                  # virtual byte address
    size: int                  # bytes
    page_size: int
    aligned: bool              # base % page_size == 0
    # Physical placement per page (MemKind values).
    numa: np.ndarray = dataclasses.field(repr=False, default=None)
    # Device read-access counter per page (models Hopper's access counter).
    dev_reads: np.ndarray = dataclasses.field(repr=False, default=None)
    # Statistics for the paper's reuse analysis (§4.2: "reused 780 times").
    device_uses: int = 0       # kernel uses while fully device-resident
    migrations: int = 0        # page-migration events (any direction)
    bytes_migrated: int = 0

    # O(1) residency bookkeeping (updated by PageTable.move_pages)
    dev_pages: int = 0

    def __post_init__(self):
        if self.numa is None:
            self.numa = np.full(self.n_pages, MemKind.HOST, dtype=np.int8)
        if self.dev_reads is None:
            self.dev_reads = np.zeros(self.n_pages, dtype=np.int64)

    @property
    def n_pages(self) -> int:
        first = self.base - (self.base % self.page_size)
        last = self.base + self.size
        return int(-(-(last - first) // self.page_size))

    def resident_bytes(self, kind: MemKind) -> int:
        frac = self.dev_pages / max(1, self.n_pages)
        if kind == MemKind.HOST:
            frac = 1.0 - frac
        return int(round(frac * self.size))

    def fully_on(self, kind: MemKind) -> bool:
        if kind == MemKind.DEVICE:
            return self.dev_pages == self.n_pages
        return self.dev_pages == 0


class PageTable:
    """Tracks buffers, placement and NUMA capacity for one superchip."""

    def __init__(self, spec: HardwareSpec):
        self.spec = spec
        self.buffers: Dict[int, Buffer] = {}
        self._next_id = 1
        self._brk = spec.page_size  # bump allocator virtual cursor
        self.used: Dict[MemKind, int] = {MemKind.HOST: 0, MemKind.DEVICE: 0}

    # ------------------------------------------------------------------ #
    # allocation                                                          #
    # ------------------------------------------------------------------ #
    def malloc(self, size: int, name: str = "", *,
               align_to_page: Optional[bool] = None) -> Buffer:
        """Allocate on the host NUMA domain (malloc is a CPU-side call).

        glibc malloc page-aligns big allocations via mmap but offsets them
        by a header; the paper's Table 8 shows that offset costs ~40 % on
        device kernels. ``align_to_page`` defaults to False to model plain
        malloc; the aligned case models posix_memalign.
        """
        ps = self.spec.page_size
        if align_to_page is None:
            align_to_page = False
        base = -(-self._brk // ps) * ps
        if not align_to_page:
            base += 16  # malloc header offset -> not page aligned
        buf = Buffer(self._next_id, name or f"buf{self._next_id}",
                     base, size, ps, aligned=(base % ps == 0))
        self._next_id += 1
        self._brk = base + size + ps
        self.buffers[buf.buf_id] = buf
        self.used[MemKind.HOST] += buf.n_pages * ps
        return buf

    # ------------------------------------------------------------------ #
    # migration                                                           #
    # ------------------------------------------------------------------ #
    def move_pages(self, buf: Buffer, target: MemKind,
                   pages: Optional[np.ndarray] = None) -> Tuple[int, float]:
        """Re-home pages; returns (bytes_moved, seconds).

        Mirrors Linux ``move_pages(2)``: physical copy over the link plus
        per-page kernel bookkeeping; virtual addresses are untouched.
        """
        spec = self.spec
        # fast path: whole-buffer moves with O(1) counters
        if pages is None and buf.fully_on(target):
            return 0, 0.0
        mask = (buf.numa != int(target))
        if pages is not None:
            sel = np.zeros_like(mask)
            sel[pages] = True
            mask &= sel
        n = int(np.count_nonzero(mask))
        if n == 0:
            return 0, 0.0
        moved_bytes = n * buf.page_size
        src = MemKind.HOST if target == MemKind.DEVICE else MemKind.DEVICE
        self.used[src] -= moved_bytes
        self.used[target] += moved_bytes
        buf.numa[mask] = int(target)
        buf.dev_pages = int(np.count_nonzero(buf.numa == int(MemKind.DEVICE)))
        buf.migrations += 1
        buf.bytes_migrated += moved_bytes
        secs = moved_bytes / spec.effective_migrate_bw() \
            + n * spec.migrate_page_s
        return moved_bytes, secs

    # ------------------------------------------------------------------ #
    # access accounting                                                   #
    # ------------------------------------------------------------------ #
    def stream_time(self, buf: Buffer, bytes_touched: int, *,
                    accessor: str) -> float:
        """Seconds to stream ``bytes_touched`` of ``buf`` for an accessor.

        Splits the traffic by current page residency and charges each slice
        at the measured bandwidth for that (accessor, location) pair.
        """
        spec = self.spec
        dev_frac = buf.resident_bytes(MemKind.DEVICE) / max(1, buf.size)
        dev_bytes = bytes_touched * dev_frac
        host_bytes = bytes_touched - dev_bytes
        if accessor == "gpu":
            t = dev_bytes / spec.gpu_local_bw + host_bytes / spec.gpu_remote_bw
        elif accessor == "cpu":
            remote = spec.cpu_remote_bw
            if spec.page_size >= 64 * 1024:
                remote = remote / spec.cpu_remote_64k_penalty
            t = host_bytes / spec.cpu_local_bw + dev_bytes / remote
        else:
            raise ValueError(f"unknown accessor {accessor!r}")
        return t

    def record_device_reads(self, buf: Buffer, reads_per_elem: float) -> None:
        """Bump the Hopper-style access counters on host-resident pages."""
        if buf.dev_pages == buf.n_pages:
            return
        # O(1) summary counter; the per-page array is only materialized
        # for buffers that stay partially resident (none in our traces)
        buf.dev_reads[0] += max(1, int(reads_per_elem))

    # ------------------------------------------------------------------ #
    # stats                                                               #
    # ------------------------------------------------------------------ #
    def device_bytes_used(self) -> int:
        return self.used[MemKind.DEVICE]

    def reuse_report(self) -> Dict[str, float]:
        migrated = [b for b in self.buffers.values() if b.bytes_migrated > 0]
        if not migrated:
            return {"n_migrated_buffers": 0, "mean_reuse": 0.0}
        uses = [b.device_uses for b in migrated]
        return {
            "n_migrated_buffers": len(migrated),
            "mean_reuse": float(np.mean(uses)),
            "max_reuse": float(np.max(uses)),
            "total_bytes_migrated": float(sum(b.bytes_migrated
                                              for b in migrated)),
        }

"""Trace-driven simulation of the paper's data-movement policies (§3.2).

Replays a level-3 BLAS trace (``repro.core.trace.Trace``) against the page
table + bandwidth model and produces the same accounting the paper reports
in Tables 3 and 5: total time, BLAS time, data-movement time, and per-buffer
reuse counts.

Policies:

* ``cpu``      — baseline: everything on host BLAS (paper's NVPL runs).
* ``memcopy``  — Strategy 1: stage operands to device memory around every
                 offloaded call (what LIBSCI_ACC/NVBLAS-style tools do).
* ``counter``  — Strategy 2: pass host pointers; a model of the Hopper
                 access-counter migration decides page movement (§4.4.1).
* ``dfu``      — Strategy 3, the paper's contribution: Device First-Use.
                 move_pages() the operand buffers to device residency on
                 first device use; they stay resident thereafter.
* ``pinned``   — `numactl -m 1`: allocate everything device-resident.

The access-counter model is a *reconstruction*: NVIDIA's criteria are
undocumented ("details of the migration criteria are unknown", §4.4.1). The
rules below reproduce every row of the paper's Table 6, including the
counter-intuitive refusal to migrate the 1.8 GB B matrix of the PARSEC
shape, and the run-to-run instability of the 200 MB row:

  R1. read operands migrate iff their per-element device read multiplicity
      is >= ``counter_reuse_min`` (B in the skinny dgemm is re-read only
      M=32 times per element -> stays), subject to
  R2. a per-call migrated-byte budget ``counter_byte_budget`` (second
      3.2 GB operand of the 20000^3 dgemm -> stays), and
  R3. written operands migrate only when small and the kernel is compute
      bound (C of 1000^3 migrates; C of the skinny shape never does).
  R4. mid-size buffers (>=100 MB) migrate with one-call delay on a seeded
      coin flip (the "yes?" rows).

**Multi-device replay** (``n_devices > 1``): the DFU policy models the
runtime's BLASX-style tile scheduler — super-threshold calls split into a
2-D tile grid executed concurrently across N devices, buffers assigned to
a device round-robin on first use and staying put thereafter (affinity),
each device with its own HBM capacity and H2D accounting
(``per_device_h2d``).  Read operands replicate along one grid axis (the
tile-communication amplification of 2-D decompositions); migration links
to different devices run in parallel.

**Residency accounting** is the live runtime's own engine: one
:class:`repro.core.residency.ResidencyStore` per device tier tracks
which buffers are device-resident, under two admission semantics —

* ``spec.device_capacity`` is the *HBM* limit: a migration that cannot
  fit is refused and the buffer stays remote (``evict_lru=True``
  restores residents to host to make room, the pre-engine behaviour);
* ``device_bytes`` models the runtime's ``SCILIB_DEVICE_BYTES`` registry
  cap: admissions always succeed and the eviction policy (``evict`` —
  ``lru``/``lfu``/``refetch``) pushes other residents back to host,
  exactly like the live store.  Fresh outputs of offloaded calls
  (``BlasCall.out_buf``) are born device-resident and occupy cap bytes,
  again like the live run — which is what makes the replayed eviction
  and refetch counts comparable, count-for-count, with a live capped
  run's trace events.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.residency import ResidencyStore
from repro.core.trace import BlasCall, Trace
from repro.memtier.pagetable import Buffer, PageTable
from repro.memtier.spec import GH200, HardwareSpec, MemKind

POLICIES = ("cpu", "memcopy", "counter", "dfu", "pinned")


@dataclasses.dataclass
class PolicyReport:
    """Accounting identical in structure to the paper's Tables 3/5 rows."""

    policy: str
    spec: str
    threshold: float
    n_devices: int = 1
    # residency-engine configuration + counters of this replay
    device_bytes: Optional[int] = None   # SCILIB_DEVICE_BYTES cap model
    evict: str = "lru"                   # SCILIB_EVICT policy model
    evictions: int = 0                   # cap-pressure evictions
    refetches: int = 0                   # evicted entries placed again
    refetched_bytes: int = 0
    # fault-tolerance counters replayed off the trace's fault events
    # (repro.core.faults): a faulted live run and its replay agree on
    # these exactly — the trace records where the run degraded
    faults: int = 0
    retries: int = 0
    fallbacks: int = 0
    quarantines: int = 0
    recoveries: int = 0
    #: tenant this replay is scoped to ("" = whole trace); session-tagged
    #: traces from a shared-pool run reconcile per-tenant this way
    session: str = ""
    # kernel-path replay (OffloadConfig.kernel_path): offloaded calls the
    # recording run executed on the pallas venue, and the per-routine
    # pallas/xla speed ratios calibrated from its probe timings.  Both
    # stay at their defaults replaying a venue-free (default-off) trace.
    kernel_calls: int = 0
    venue_ratio: Dict[str, float] = dataclasses.field(default_factory=dict)
    # precision replay (OffloadConfig.precision): offloaded calls the
    # recording run executed under a split scheme, escalations its
    # residual checks fired, and the per-routine split/native cost
    # ratios calibrated from its own timings.  All stay at defaults
    # replaying a precision-free (default-off) trace.
    split_calls: int = 0
    escalations: int = 0
    precision_ratio: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # solver-span replay (repro.solvers): spans tallied off the trace's
    # ``solver_begin`` events, per-solver call/panel counters off each
    # call's ``solver_id`` tag — a live LAPACK-tier run and its replay
    # agree on these exactly.  Both stay at their defaults replaying a
    # span-free (default-off) trace.
    solver_spans: int = 0
    per_solver: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)
    total_s: float = 0.0
    blas_device_s: float = 0.0
    blas_host_s: float = 0.0
    movement_s: float = 0.0          # reported separately, like the paper
    bytes_host_to_dev: int = 0
    bytes_dev_to_host: int = 0
    offloaded_calls: int = 0
    host_calls: int = 0
    per_routine_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    mean_reuse: float = 0.0
    max_reuse: float = 0.0
    n_migrated_buffers: int = 0
    device_bytes_peak: int = 0
    # multi-device replay: H2D bytes landing on each device tier
    per_device_h2d: Dict[int, int] = dataclasses.field(default_factory=dict)
    # per-call-site time, keyed by BlasCall.callsite_id (traces recorded
    # before call-site identity existed simply leave this empty)
    per_site_s: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def moved_bytes(self) -> int:
        """Total link traffic, both directions (the autotuner's second
        objective after predicted time)."""
        return self.bytes_host_to_dev + self.bytes_dev_to_host

    def row(self) -> Dict[str, float]:
        return {
            "policy": self.policy,
            "total_s": round(self.total_s, 3),
            "blas_s": round(self.blas_device_s + self.blas_host_s, 3),
            "movement_s": round(self.movement_s, 3),
            "offloaded": self.offloaded_calls,
            "on_host": self.host_calls,
            "mean_reuse": round(self.mean_reuse, 1),
        }


class MemTierSimulator:
    """One application run under one policy on one hardware spec."""

    # Access-counter model constants (see module docstring).
    counter_reuse_min: float = 100.0
    counter_byte_budget: float = 3.4e9
    counter_c_small: float = 16e6
    counter_ai_min: float = 30.0
    counter_delay_prob: float = 0.35

    def __init__(self, spec: HardwareSpec = GH200, *, policy: str = "dfu",
                 threshold: float = 500.0, aligned_alloc: bool = False,
                 seed: int = 0, evict_lru: bool = False,
                 n_devices: int = 1,
                 device_bytes: Optional[int] = None,
                 evict: str = "lru",
                 session: str = "",
                 kernel_path: bool = False,
                 precision: str = ""):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}")
        self.spec = spec
        self.policy = policy
        self.threshold = threshold
        self.aligned_alloc = aligned_alloc
        self.pt = PageTable(spec)
        self.rng = np.random.default_rng(seed)
        self.evict_lru = evict_lru
        self.n_devices = max(1, int(n_devices))
        self.device_bytes = device_bytes if device_bytes else None
        self.session = session
        # kernel-path replay: calls the live run tagged venue="pallas"
        # execute under a per-routine speed ratio calibrated from the
        # trace's own probe timings (see _calibrate_venues).  Off by
        # default — a kernel-off replay multiplies nothing and stays
        # float-identical to the pre-venue model.
        self.kernel_path = bool(kernel_path)
        self._kmult = 1.0
        self._venue_ratio: Dict[str, float] = {}
        # precision replay: calls the live run tagged with a split
        # scheme execute under a per-routine split/native cost ratio
        # calibrated from the trace (_calibrate_precision).  Off by
        # default — a precision-off replay multiplies nothing and stays
        # float-identical to the pre-precision model.
        self.precision = str(precision)
        self._pmult = 1.0
        self._precision_ratio: Dict[str, float] = {}
        self.report = PolicyReport(policy=policy, spec=spec.name,
                                   threshold=threshold,
                                   n_devices=self.n_devices,
                                   device_bytes=self.device_bytes,
                                   evict=evict,
                                   session=session)
        self._bufs: Dict[int, Buffer] = {}       # trace buf id -> Buffer
        self._delayed: Dict[int, int] = {}       # counter: deferred once
        self._denied: set = set()                # counter: budget-refused
        # the residency engine, one store per device tier: the same
        # ResidencyStore class the live runtime's registries use, so
        # capacity checks, cap evictions, refetch detection, LRU order
        # and the counters all share one implementation.
        self._stores = [
            ResidencyStore(f"dev{d}" if self.n_devices > 1
                           else "placements",
                           cap=self.device_bytes, policy=evict,
                           on_evict=self._evict_to_host(d))
            for d in range(self.n_devices)]
        # multi-device DFU: buffer -> assigned device (round-robin with
        # affinity — first placement sticks)
        self._dev_of: Dict[int, int] = {}
        self._rr_dev = 0
        self._out_seq = 0            # synthetic keys for aliased outputs

    @classmethod
    def from_config(cls, config, spec: HardwareSpec = GH200,
                    **kw) -> "MemTierSimulator":
        """A simulator modeling one :class:`repro.core.config.
        OffloadConfig` — the replay side of the tune->deploy loop: the
        autotuner emits a config file, and this constructor predicts
        what a session running that config will do (same policy,
        resolved threshold, device-tier count, cap and eviction)."""
        return cls(spec, policy=config.policy,
                   threshold=config.resolved_threshold(),
                   n_devices=config.resolved_devices(),
                   device_bytes=config.device_bytes,
                   evict=config.evict,
                   kernel_path=config.kernel_path,
                   precision=config.precision, **kw)

    def _evict_to_host(self, dev: int):
        """Cap pressure on one device store: bounce the victim's pages
        back to host and bill the link, like the live store re-tagging
        plus the next refetch the evicted buffer will pay."""
        def _on_evict(key, buf, nbytes):
            # one Buffer can back two entries — the operand placement
            # and its aliased-output twin, like the live registry's
            # id(c)/id(out) pair.  Only the last entry standing moves
            # the pages; evicting a twin bills the link without
            # un-homing the still-resident sibling.
            if any(s.entry(k).payload is buf
                   for s in self._stores for k in s.keys()):
                spec = self.spec
                self.report.movement_s += nbytes / spec.effective_migrate_bw()
                self.report.bytes_dev_to_host += nbytes
                return
            moved, secs = self.pt.move_pages(buf, MemKind.HOST)
            self.report.movement_s += secs
            self.report.bytes_dev_to_host += moved
        return _on_evict

    def _assign_dev(self, bid: int) -> int:
        """The device tier a buffer belongs to (round-robin assignment
        on first device use, sticky thereafter — the affinity rule)."""
        if self.n_devices == 1:
            return 0
        dev = self._dev_of.get(bid)
        if dev is None:
            dev = self._rr_dev % self.n_devices
            self._rr_dev += 1
            self._dev_of[bid] = dev
        return dev

    # ------------------------------------------------------------------ #
    def _buffer(self, trace: Trace, bid: int) -> Buffer:
        if bid not in self._bufs:
            buf = self.pt.malloc(trace.buffer_sizes[bid],
                                 trace.buffer_names[bid],
                                 align_to_page=self.aligned_alloc)
            if self.policy == "pinned":
                moved, _ = self.pt.move_pages(buf, MemKind.DEVICE)
                # numactl binding happens at allocation: free placement.
                buf.migrations = 0
                buf.bytes_migrated = 0
                # pinned entries survive any cap: numactl bindings are
                # not evictable, and the store knows it
                self._stores[self._assign_dev(bid)].put(
                    bid, buf, buf.size, pinned=True)
            self._bufs[bid] = buf
        return self._bufs[bid]

    # ------------------------------------------------------------------ #
    # per-call cost model                                                 #
    # ------------------------------------------------------------------ #
    def _host_call(self, call: BlasCall, bufs: List[Buffer]) -> float:
        t_mem = sum(self.pt.stream_time(b, nb * call.batch, accessor="cpu")
                    for b, (_, _, nb, _, _) in zip(bufs, call.operands))
        eff = self.spec.eff("cpu", call.routine)
        t = max(call.flops / (self.spec.cpu_flops * eff), t_mem)
        self.report.blas_host_s += t
        self.report.host_calls += 1
        return t

    def _device_kernel(self, call: BlasCall, bufs: List[Buffer]) -> float:
        """Device BLAS on operands wherever their pages currently live."""
        spec = self.spec
        t_mem = sum(self.pt.stream_time(b, nb * call.batch, accessor="gpu")
                    for b, (_, _, nb, _, _) in zip(bufs, call.operands))
        # §4.4.3 pathology: system-allocated device memory is slower for the
        # device unless page-aligned; memory-bound paths suffer most.
        on_dev = [b for b in bufs if b.resident_bytes(MemKind.DEVICE) > 0]
        sysmalloc = bool(on_dev) and self.policy != "memcopy"
        if sysmalloc and any(not b.aligned for b in on_dev):
            mem_pen, comp_pen = spec.unaligned_penalty, spec.sysmalloc_penalty
        elif sysmalloc:
            mem_pen = comp_pen = 1.0    # aligned matches cudaMalloc (T.8)
        else:
            mem_pen = comp_pen = 1.0
        eff = spec.eff("gpu", call.routine)
        t = max(call.flops / (spec.gpu_flops * eff) * comp_pen,
                t_mem * mem_pen)
        if self._kmult != 1.0:          # pallas-venue calibrated ratio
            t *= self._kmult
        if self._pmult != 1.0:          # split-scheme calibrated ratio
            t *= self._pmult
        t += spec.kernel_launch_s
        self.report.blas_device_s += t
        self.report.offloaded_calls += 1
        for b in bufs:
            if b.fully_on(MemKind.DEVICE):
                b.device_uses += 1
        return t

    # ------------------------------------------------------------------ #
    # policies                                                            #
    # ------------------------------------------------------------------ #
    def _memcopy(self, call: BlasCall, bufs: List[Buffer]) -> float:
        spec, t_move = self.spec, 0.0
        for b, (_, _, nb, _, written) in zip(bufs, call.operands):
            nbytes = nb * call.batch
            t_move += nbytes / spec.link_bw            # H->D stage in
            self.report.bytes_host_to_dev += nbytes
            if written:
                t_move += nbytes / spec.link_bw        # D->H result out
                self.report.bytes_dev_to_host += nbytes
        # kernel runs on cudaMalloc staging: fully local, no malloc penalty
        t_mem = call.bytes_touched / spec.gpu_local_bw
        eff = spec.eff("gpu", call.routine)
        t_k = max(call.flops / (spec.gpu_flops * eff), t_mem)
        if self._kmult != 1.0:          # pallas-venue calibrated ratio
            t_k *= self._kmult
        if self._pmult != 1.0:          # split-scheme calibrated ratio
            t_k *= self._pmult
        t_k += spec.kernel_launch_s
        self.report.blas_device_s += t_k
        self.report.offloaded_calls += 1
        self.report.movement_s += t_move
        return t_k + t_move

    def _dfu(self, call: BlasCall, bufs: List[Buffer]) -> float:
        """Device First-Use: move_pages() everything on first device use.

        The residency store is the arbiter: a hit is a free reuse, a
        miss migrates (HBM capacity permitting) and registers — under a
        ``device_bytes`` cap the registration itself may evict other
        residents, exactly like the live placement store.
        """
        t_move = 0.0
        store = self._stores[0]
        for b in bufs:
            if store.get(b.buf_id) is not None:
                continue                        # resident: reuse is free
            if not b.fully_on(MemKind.DEVICE):
                if not store.reserve(b.size,
                                     limit=self.spec.device_capacity,
                                     evict=self.evict_lru):
                    continue                    # HBM full: stay remote
                moved, secs = self.pt.move_pages(b, MemKind.DEVICE)
                t_move += secs
                self.report.bytes_host_to_dev += moved
            store.put(b.buf_id, b, b.size)
        self.report.movement_s += t_move
        return self._device_kernel(call, bufs) + t_move

    def _dfu_multi(self, call: BlasCall, bufs: List[Buffer]) -> float:
        """N-device DFU: the runtime's tile scheduler under the cost model.

        Buffers are dealt to devices round-robin on first device use and
        stay put (affinity); the call executes as a gm x gn tile grid,
        one tile round per device concurrently.  Read operands replicate
        along one grid axis — the communication amplification every 2-D
        decomposition pays — while the written operand splits per tile.
        """
        spec, n_dev = self.spec, self.n_devices
        t_move_dev: Dict[int, float] = {}
        for b in bufs:
            dev = self._assign_dev(b.buf_id)
            store = self._stores[dev]
            if store.get(b.buf_id) is not None:
                continue
            if not b.fully_on(MemKind.DEVICE):
                if not store.reserve(b.size,
                                     limit=spec.device_capacity,
                                     evict=self.evict_lru):
                    continue
                moved, secs = self.pt.move_pages(b, MemKind.DEVICE)
                self.report.per_device_h2d[dev] = (
                    self.report.per_device_h2d.get(dev, 0) + moved)
                self.report.bytes_host_to_dev += moved
                t_move_dev[dev] = t_move_dev.get(dev, 0.0) + secs
            store.put(b.buf_id, b, b.size)
        # links to distinct devices run in parallel: the slowest one gates
        t_move = max(t_move_dev.values(), default=0.0)
        self.report.movement_s += t_move
        gm = max(1, math.isqrt(n_dev))
        gn = -(-n_dev // gm)
        tiles = gm * gn
        axis_frac = (1.0 / gm, 1.0 / gn)
        t_mem, nread = 0.0, 0
        for b, (_, _, nb, _, written) in zip(bufs, call.operands):
            if written:
                frac = 1.0 / tiles
            else:
                frac = axis_frac[min(nread, 1)]
                nread += 1
            t_mem += self.pt.stream_time(b, int(nb * call.batch * frac),
                                         accessor="gpu")
        on_dev = [b for b in bufs if b.resident_bytes(MemKind.DEVICE) > 0]
        if on_dev and any(not b.aligned for b in on_dev):
            mem_pen, comp_pen = spec.unaligned_penalty, spec.sysmalloc_penalty
        else:
            mem_pen = comp_pen = 1.0
        eff = spec.eff("gpu", call.routine)
        per_tile = max(call.flops / tiles / (spec.gpu_flops * eff) * comp_pen,
                       t_mem * mem_pen)
        if self._kmult != 1.0:          # pallas-venue calibrated ratio
            per_tile *= self._kmult
        if self._pmult != 1.0:          # split-scheme calibrated ratio
            per_tile *= self._pmult
        per_tile += spec.kernel_launch_s
        t_k = per_tile * (-(-tiles // n_dev))   # tile rounds per device
        self.report.blas_device_s += t_k
        self.report.offloaded_calls += 1
        for b in bufs:
            if b.fully_on(MemKind.DEVICE):
                b.device_uses += 1
        return t_k + t_move

    def _counter(self, call: BlasCall, bufs: List[Buffer]) -> float:
        """Model of Hopper's access-counter migration (§4.4.1, Table 6)."""
        spec = self.spec
        store = self._stores[0]
        migrated_this_call = 0
        t_mig = 0.0
        ai = call.flops / max(1, call.bytes_touched)   # arithmetic intensity
        for b, (_, _, nb, reads, written) in zip(bufs, call.operands):
            nbytes = nb * call.batch
            if store.get(b.buf_id) is not None:
                continue                         # resident: recency touch
            if b.fully_on(MemKind.DEVICE):
                continue
            self.pt.record_device_reads(b, reads)
            if written:                                         # rule R3
                ok = nbytes <= self.counter_c_small and ai >= self.counter_ai_min
            elif b.buf_id in self._denied:
                ok = False               # budget refusals are sticky (T.6)
            elif reads < self.counter_reuse_min:                # rule R1
                ok = False
            elif migrated_this_call + nbytes > self.counter_byte_budget:
                ok = False                                      # rule R2
                self._denied.add(b.buf_id)
            else:
                ok = True
            if ok and 100e6 <= nbytes < 1e9:                    # rule R4
                seen = self._delayed.get(b.buf_id, 0)
                self._delayed[b.buf_id] = seen + 1
                if seen == 0 and self.rng.random() < self.counter_delay_prob:
                    ok = False
            if ok and store.reserve(b.size, limit=spec.device_capacity,
                                    evict=self.evict_lru):
                moved, secs = self.pt.move_pages(b, MemKind.DEVICE)
                t_mig += secs
                migrated_this_call += moved
                self.report.bytes_host_to_dev += moved
                store.put(b.buf_id, b, b.size)
        # counter migration happens behind the kernel: its cost is billed
        # to BLAS time, exactly how the paper reports it ("included").
        t_k = self._device_kernel(call, bufs)
        self.report.blas_device_s += t_mig
        return t_k + t_mig

    # ------------------------------------------------------------------ #
    def _born_on_device(self, buf: Buffer) -> None:
        """Mark a fresh output buffer device-resident with no link cost
        and no migration event: offloaded outputs are device-born, the
        exact analogue of the live runtime's ``place_output``."""
        mask = buf.numa != int(MemKind.DEVICE)
        n = int(np.count_nonzero(mask))
        if n == 0:
            return
        self.pt.used[MemKind.HOST] -= n * buf.page_size
        self.pt.used[MemKind.DEVICE] += n * buf.page_size
        buf.numa[mask] = int(MemKind.DEVICE)
        buf.dev_pages = buf.n_pages

    def _register_output(self, trace: Trace, call: BlasCall) -> None:
        """DFU only: the live runtime registers *every* offloaded
        output, so the replay must too or capped eviction counts drift.

        A fresh output (no written operand) carries its own trace
        buffer (``out_buf``).  An output that aliases a written operand
        shares that operand's trace buffer — but the live registry
        still holds two entries (the operand's placed copy under
        ``id(c)`` and the output under ``id(out)``; the caller's old C
        stays valid and cached), so the replay adds a synthetic twin
        entry of the same size backed by the same Buffer."""
        if call.out_buf >= 0 and call.out_buf in trace.buffer_sizes:
            buf = self._buffer(trace, call.out_buf)
            self._born_on_device(buf)
            dev = self._assign_dev(call.out_buf)
            self._stores[dev].put(call.out_buf, buf,
                                  call.out_nbytes or buf.size)
            return
        for _, bid, nb, _, written in call.operands:
            if written:
                buf = self._buffer(trace, bid)
                self._born_on_device(buf)
                dev = self._assign_dev(bid)
                self._out_seq += 1
                self._stores[dev].put(("out", self._out_seq), buf,
                                      nb * call.batch)
                return

    # ------------------------------------------------------------------ #
    def _calibrate_venues(self, trace: Trace) -> Dict[str, float]:
        """Per-routine pallas/xla speed ratio from the trace's own
        measured per-call wall times (the adaptive probe timings a
        kernel-path run records in ``BlasCall.seconds``/``venue``).

        Best-sample per venue, like ``CallSiteProfile.lock`` — the first
        call on each venue pays jit compilation and the minimum is
        robust to it.  A routine seen on only one venue gets no ratio
        (the generic model applies, ratio 1.0); ratios clamp to
        [0.1, 10] so one mistimed probe cannot distort the replay."""
        best: Dict[tuple, float] = {}
        for call in trace:
            if call.venue in ("xla", "pallas") and call.seconds > 0:
                k = (call.routine, call.venue)
                if call.seconds < best.get(k, float("inf")):
                    best[k] = call.seconds
        ratios: Dict[str, float] = {}
        for (routine, venue) in best:
            if venue != "pallas":
                continue
            xla = best.get((routine, "xla"))
            if xla:
                r = best[(routine, "pallas")] / xla
                ratios[routine] = min(10.0, max(0.1, r))
        return ratios

    # ------------------------------------------------------------------ #
    def _calibrate_precision(self, trace: Trace) -> Dict[str, float]:
        """Per-routine split/native cost ratio from the trace's own
        measured per-call wall times, exactly like
        :meth:`_calibrate_venues` — best sample per side (robust to the
        one-off jit cost of the first call), clamped to [0.1, 10].  A
        routine seen only split (or only native) gets no ratio and the
        generic model applies unchanged."""
        best: Dict[tuple, float] = {}
        for call in trace:
            if call.seconds > 0 and call.venue != "host":
                k = (call.routine,
                     "split" if call.precision else "native")
                if call.seconds < best.get(k, float("inf")):
                    best[k] = call.seconds
        ratios: Dict[str, float] = {}
        for (routine, kind) in best:
            if kind != "split":
                continue
            native = best.get((routine, "native"))
            if native:
                r = best[(routine, "split")] / native
                ratios[routine] = min(10.0, max(0.1, r))
        return ratios

    # ------------------------------------------------------------------ #
    def run(self, trace: Trace) -> PolicyReport:
        # fault replay: a call the live run fell back to host (retry
        # exhaustion or total quarantine) is host-bound here too — the
        # fallback events carry the call index they interleaved at
        forced_host = {e.call_index for e in trace.events
                       if e.kind == "fallback"
                       and (not self.session
                            or e.session == self.session)}
        if self.kernel_path:
            self._venue_ratio = self._calibrate_venues(trace)
            self.report.venue_ratio = dict(self._venue_ratio)
        if self.precision:
            self._precision_ratio = self._calibrate_precision(trace)
            self.report.precision_ratio = dict(self._precision_ratio)
        for i, call in enumerate(trace):
            bufs = [self._buffer(trace, bid)
                    for _, bid, _, _, _ in call.operands]
            # panel factorization (getf2) is not level-3: never offloaded,
            # it serializes on the host between the device BLAS calls
            offload = (self.policy != "cpu"
                       and not call.routine.endswith("getf2")
                       and call.n_avg > self.threshold
                       and i not in forced_host)
            # venue replay: a call the live run executed on the pallas
            # venue runs under its routine's calibrated ratio here, and
            # counts — so a live kernel-path run replays to the same
            # kernel_calls the runtime report shows
            if self.kernel_path and offload and call.venue == "pallas":
                self._kmult = self._venue_ratio.get(call.routine, 1.0)
                self.report.kernel_calls += 1
            else:
                self._kmult = 1.0
            # precision replay: a call the live run dispatched split
            # runs under its routine's calibrated split/native ratio
            # and counts — a precision run replays to the same
            # split_calls the runtime report shows
            if self.precision and offload and call.precision:
                self._pmult = self._precision_ratio.get(call.routine, 1.0)
                self.report.split_calls += 1
            else:
                self._pmult = 1.0
            if not offload:
                t = self._host_call(call, bufs)
            elif self.policy == "memcopy":
                t = self._memcopy(call, bufs)
            elif self.policy == "dfu":
                t = (self._dfu(call, bufs) if self.n_devices == 1
                     else self._dfu_multi(call, bufs))
                self._register_output(trace, call)
            elif self.policy == "counter":
                t = self._counter(call, bufs)
            else:                                   # pinned
                t = self._device_kernel(call, bufs)
            self.report.total_s += t
            key = call.routine
            self.report.per_routine_s[key] = (
                self.report.per_routine_s.get(key, 0.0) + t)
            if call.callsite_id:
                self.report.per_site_s[call.callsite_id] = (
                    self.report.per_site_s.get(call.callsite_id, 0.0) + t)
            self.report.device_bytes_peak = max(
                self.report.device_bytes_peak, self.pt.device_bytes_used())
        reuse = self.pt.reuse_report()
        self.report.mean_reuse = reuse.get("mean_reuse", 0.0)
        self.report.max_reuse = reuse.get("max_reuse", 0.0)
        self.report.n_migrated_buffers = int(
            reuse.get("n_migrated_buffers", 0))
        # residency-engine counters, straight off the shared stores
        self.report.evictions = sum(s.evictions for s in self._stores)
        self.report.refetches = sum(s.refetches for s in self._stores)
        self.report.refetched_bytes = sum(s.refetched_bytes
                                          for s in self._stores)
        # fault counters come straight off the recorded events — the
        # injector is deterministic, so live == replay by construction
        ses = self.session or None
        self.report.faults = trace.event_count("fault", session=ses)
        self.report.retries = trace.event_count("retry", session=ses)
        self.report.fallbacks = trace.event_count("fallback", session=ses)
        self.report.quarantines = trace.event_count("quarantine",
                                                    session=ses)
        self.report.recoveries = trace.event_count("recover", session=ses)
        # escalation counters come straight off the recorded events —
        # the residual checks already ran live, so live == replay
        self.report.escalations = trace.event_count("escalate",
                                                    session=ses)
        # solver spans come straight off the recorded events and the
        # per-call solver_id tags — the drivers already ran live, so a
        # LAPACK-tier run replays to its exact per-solver counters
        for ev in trace.events:
            if ev.kind == "solver_begin" and (
                    not self.session or ev.session == self.session):
                slot = self.report.per_solver.setdefault(
                    ev.store.split("#", 1)[0],
                    {"spans": 0, "calls": 0, "panel_calls": 0})
                slot["spans"] += 1
                self.report.solver_spans += 1
        for call in trace:
            if call.solver_id:
                slot = self.report.per_solver.setdefault(
                    call.solver,
                    {"spans": 0, "calls": 0, "panel_calls": 0})
                slot["calls"] += 1
                if call.routine.endswith("getf2"):
                    slot["panel_calls"] += 1
        return self.report

    # convenience: residency of a trace buffer after the run
    def residency(self, bid: int) -> Optional[str]:
        b = self._bufs.get(bid)
        if b is None:
            return None
        if b.fully_on(MemKind.DEVICE):
            return "device"
        if b.fully_on(MemKind.HOST):
            return "host"
        return "mixed"


def replay_trace(trace: Trace, *, spec: HardwareSpec = GH200,
                 policies=POLICIES, threshold: float = 500.0,
                 aligned_alloc: bool = False,
                 evict_lru: bool = False,
                 n_devices: int = 1,
                 device_bytes: Optional[int] = None,
                 evict: str = "lru",
                 kernel_path: bool = False,
                 precision: str = "") -> Dict[str, PolicyReport]:
    """Run one trace under several policies (the paper's Tables 3/5)."""
    out = {}
    for p in policies:
        sim = MemTierSimulator(spec, policy=p, threshold=threshold,
                               aligned_alloc=aligned_alloc,
                               evict_lru=evict_lru, n_devices=n_devices,
                               device_bytes=device_bytes, evict=evict,
                               kernel_path=kernel_path,
                               precision=precision)
        out[p] = sim.run(trace)
    return out

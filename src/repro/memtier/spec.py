"""Hardware specifications for tiered-memory systems.

Constants for GH200 come from the paper's own measurements:

* Table 1 (STREAM): CPU->LPDDR5X 418-446 GB/s, CPU->HBM3 ~142 GB/s,
  GPU->HBM3 3.36-3.68 TB/s, GPU->LPDDR5X 407-610 GB/s.
* NVLink-C2C: 450 GB/s per direction (paper §2.1).
* Table 8: cublasDgemm on unaligned system-malloc HBM is ~1.35-1.47x slower
  than page-aligned; Table 3 shows the same effect at application level
  (DFU zgemm+ztrsm 580 s vs Mem-Copy-on-cudaMalloc 439.8 s ~= 1.32x).

TPU v5e constants are the roofline constants mandated for this repo:
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI; host link is
PCIe-class.
"""
from __future__ import annotations

import dataclasses
import enum


class MemKind(enum.IntEnum):
    """NUMA domain of a page/buffer (paper §2.1: two NUMA domains)."""

    HOST = 0    # CPU-resident (LPDDR5X on GH200; host DRAM for TPU)
    DEVICE = 1  # device-resident (HBM3 on GH200; HBM on TPU)


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Bandwidths (bytes/s), compute rates (FLOP/s) and page parameters.

    ``*_bw`` names read as ``<accessor>_<location>``: e.g. ``gpu_remote_bw``
    is the device engine streaming operands that still reside in host memory
    (over the coherent link).
    """

    name: str

    # --- streaming bandwidths (bytes/s) -------------------------------
    cpu_local_bw: float      # CPU <- host memory
    cpu_remote_bw: float     # CPU <- device memory (slow path, Table 1)
    gpu_local_bw: float      # device <- HBM
    gpu_remote_bw: float     # device <- host memory over coherent link
    link_bw: float           # explicit copy/migration engine, per direction

    # --- compute (FLOP/s, achievable not peak) -------------------------
    cpu_flops: float         # host BLAS (e.g. NVPL dgemm on 72c Grace)
    gpu_flops: float         # device BLAS (cuBLAS dgemm on H100 / MXU)
    # Per-routine efficiency at production (mid-size, mixed-shape) calls.
    # Calibrated so Table 3's cudaMalloc zgemm+ztrsm time reproduces:
    # LU-stream gemms run well below peak (decreasing trailing sizes,
    # launch gaps), trsm panels far below, and the CPU panel factor
    # (getf2, never offloaded) is memory-bound rank-1 work.
    gpu_eff: tuple = (("gemm", 0.55), ("trsm", 0.25), ("syrk", 0.5),
                      ("symm", 0.55), ("trmm", 0.4), ("getf2", 0.0))
    cpu_eff: tuple = (("gemm", 0.85), ("trsm", 0.6), ("getf2", 0.25))

    # --- overheads ------------------------------------------------------
    kernel_launch_s: float = 4.0e-6   # per device-kernel launch
    migrate_page_s: float = 1.2e-6    # per-page move_pages() bookkeeping
    migrate_bw: float = 0.0           # effective move_pages throughput;
                                      # defaults to link_bw when 0

    # --- memory geometry -------------------------------------------------
    page_size: int = 64 * 1024        # 64 KB default on GH200 (paper §4.4.2)
    host_capacity: int = 120 << 30
    device_capacity: int = 96 << 30

    # --- pathologies measured by the paper ------------------------------
    # §4.4.3 / Table 8: device kernels on system-malloc'd, non-page-aligned
    # device memory run ~1.35-1.47x slower than on page-aligned memory.
    unaligned_penalty: float = 1.40
    # Residual penalty for system-allocated device memory even when the
    # allocator page-aligns large blocks (Table 3: 580 s vs 439.8 s).
    sysmalloc_penalty: float = 1.30
    # §4.4.2 Table 7: CPU access to device memory degrades further at 64K
    # pages (15.5 ms vs 10.9 ms -> ~1.4x applied to cpu_remote paths).
    cpu_remote_64k_penalty: float = 1.40

    def effective_migrate_bw(self) -> float:
        return self.migrate_bw if self.migrate_bw > 0 else self.link_bw

    def eff(self, accessor: str, routine: str) -> float:
        base = routine.lstrip("sdcz")
        table = dict(self.gpu_eff if accessor == "gpu" else self.cpu_eff)
        return table.get(base, 1.0)

    def with_(self, **kw) -> "HardwareSpec":
        return dataclasses.replace(self, **kw)


GB = 1.0e9
TB = 1.0e12

# The paper's machine: Vista GH200 node (120 GB LPDDR5X Grace + 96 GB H100).
GH200 = HardwareSpec(
    name="gh200",
    cpu_local_bw=418.22 * GB,     # Table 1 CPU triad on LPDDR5X
    cpu_remote_bw=141.94 * GB,    # Table 1 CPU triad on HBM3
    gpu_local_bw=3679.50 * GB,    # Table 1 GPU triad on HBM3
    gpu_remote_bw=610.43 * GB,    # Table 1 GPU triad on LPDDR5X via C2C
    link_bw=450.0 * GB,           # NVLink-C2C per direction (§2.1)
    # CPU baseline = Grace-Grace NODE (144 cores, Table 3's comparison
    # unit): ~6.2 TF/s peak FP64, per-routine eff applied on top.
    cpu_flops=6.2e12,
    # H100 cuBLAS dgemm sustained FP64 (tensor core): ~55 TF/s.
    gpu_flops=55.0e12,
    migrate_bw=300.0 * GB,        # move_pages sustained < raw C2C
    page_size=64 * 1024,
)

# Same machine booted with 4 KB base pages (paper §4.4.2 tests both).
GH200_4K = GH200.with_(name="gh200-4k", page_size=4 * 1024,
                       cpu_remote_64k_penalty=1.0)

# Adaptation target for the LM framework rooflines.
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    cpu_local_bw=200.0 * GB,
    cpu_remote_bw=16.0 * GB,      # host reads of HBM are indirect
    gpu_local_bw=819.0 * GB,      # HBM bw per chip (mandated constant)
    gpu_remote_bw=32.0 * GB,      # PCIe-class host link: no coherent C2C
    link_bw=32.0 * GB,
    cpu_flops=2.0e12,
    gpu_flops=197.0e12,           # bf16 MXU (mandated constant)
    page_size=32 * 1024,          # model granule: one VMEM tile row
    host_capacity=512 << 30,
    device_capacity=16 << 30,
    # No coherent-malloc pathology on TPU; placement is always explicit.
    unaligned_penalty=1.0,
    sysmalloc_penalty=1.0,
    cpu_remote_64k_penalty=1.0,
)

SPECS = {s.name: s for s in (GH200, GH200_4K, TPU_V5E)}

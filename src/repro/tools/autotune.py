"""Trace-replay autotuner: tune ``SCILIB_*`` knobs from a recorded workload.

The paper notes the optimal offload threshold is device- and
workload-dependent (§3.3) — there is no constant that is right for both a
reuse-heavy LSMS run and a movement-bound skinny-gemm stream.  This tool
closes the loop without touching application code, mirroring the paper
tool's no-recompile ethos:

1. record a trace from any run (``SCILIB_TRACE=/path.json``, dumped
   automatically at ``uninstall()``),
2. replay it through the memtier N-device DFU simulator across a
   threshold x policy x device-count x device-bytes-cap x
   eviction-policy grid,
3. print the grid, the recommended ``SCILIB_*`` settings, and the
   predicted time/moved-bytes deltas against the paper-default baseline.

Command line::

    python -m repro.tools.autotune trace.json
    python -m repro.tools.autotune trace.json --spec tpu-v5e \
        --policies dfu,memcopy --thresholds 300,500,1000 --devices 1,2,4 \
        --device-bytes auto --evict lru,lfu,refetch

The threshold grid defaults to :func:`repro.core.threshold.threshold_grid`
over the trace's observed N_avg values — only thresholds that flip at
least one call's decision are worth simulating.  The device-bytes grid
defaults to ``auto``: fractions of the uncapped replay's peak device
residency, because both the live runtime and the simulator now run the
same :class:`repro.core.residency.ResidencyStore`, a capped replay's
eviction/refetch counts are directly comparable to a live capped run —
so the tool can recommend a *cap* (how much HBM the workload actually
needs), not just a threshold.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import threshold as thr
from repro.core.trace import Trace
from repro.memtier.simulator import MemTierSimulator, PolicyReport
from repro.memtier.spec import SPECS, HardwareSpec

#: policies the grid sweeps by default; ``pinned`` is a capacity bracket,
#: not a deployable setting, and ``cpu`` is implied by a huge threshold.
DEFAULT_POLICIES = ("dfu", "memcopy", "counter")
DEFAULT_DEVICE_COUNTS = (1, 2, 4)
#: eviction policies swept at each capped point (lru alone is pointless
#: to sweep uncapped: no cap, no eviction, identical replay).
DEFAULT_EVICTS = ("lru", "lfu", "refetch")

#: the comparison point: the paper's conservative default configuration
#: (policy, threshold, n_devices, device_bytes cap, eviction policy,
#: kernel path, precision scheme, LAPACK block size).
BASELINE = ("dfu", thr.DEFAULT_THRESHOLD, 1, None, "lru", False, "", 0)

#: LU block sizes swept when the trace carries solver spans (0 = as
#: recorded, i.e. whatever ``nb`` the run factored with).
DEFAULT_LAPACK_NBS = (0, 64, 128, 256)


def _fmt_threshold(t: float) -> str:
    return str(int(t)) if float(t).is_integer() else f"{t:.1f}"


def _fmt_cap(cap: Optional[int]) -> str:
    if cap is None:
        return "-"
    if cap >= 1 << 30:
        return f"{cap / (1 << 30):.1f}G"
    return f"{cap / (1 << 20):.0f}M"


@dataclasses.dataclass
class GridPoint:
    """One simulated (policy, threshold, n_devices, cap, evict, kernel,
    precision, lapack_nb) config."""

    policy: str
    threshold: float
    n_devices: int
    report: PolicyReport
    device_bytes: Optional[int] = None
    evict: str = "lru"
    kernel: bool = False    # SCILIB_KERNELS: the pallas dispatch venue
    precision: str = ""     # SCILIB_PRECISION: the split-emulation scheme
    lapack_nb: int = 0      # SCILIB_LAPACK_NB: LU block size (0 = as run)

    @property
    def config(self) -> Tuple:
        return (self.policy, self.threshold, self.n_devices,
                self.device_bytes, self.evict, self.kernel,
                self.precision, self.lapack_nb)

    @property
    def total_s(self) -> float:
        return self.report.total_s

    @property
    def moved_bytes(self) -> int:
        return self.report.moved_bytes

    def env(self) -> Dict[str, str]:
        """The ``SCILIB_*`` settings that realize this point."""
        settings = {"SCILIB_POLICY": self.policy,
                    "SCILIB_THRESHOLD": _fmt_threshold(self.threshold)}
        if self.n_devices > 1:
            settings["SCILIB_DEVICES"] = str(self.n_devices)
        if self.device_bytes is not None:
            settings["SCILIB_DEVICE_BYTES"] = str(self.device_bytes)
        if self.evict != "lru":
            settings["SCILIB_EVICT"] = self.evict
        if self.kernel:
            settings["SCILIB_KERNELS"] = "1"
        if self.precision:
            settings["SCILIB_PRECISION"] = self.precision
        if self.lapack_nb:
            settings["SCILIB_LAPACK"] = "1"
            settings["SCILIB_LAPACK_NB"] = str(self.lapack_nb)
        return settings

    def to_config(self):
        """The typed :class:`~repro.core.config.OffloadConfig` that
        realizes this point — what ``--emit-config`` writes, and what
        ``repro.session(OffloadConfig.load(...))`` runs directly.
        ``devices`` is always explicit (``None`` would re-resolve to
        the deploy host's device count, which is not what was tuned)."""
        from repro.core.config import OffloadConfig
        return OffloadConfig(
            policy=self.policy, threshold=self.threshold,
            devices=self.n_devices,
            device_bytes=self.device_bytes, evict=self.evict,
            kernel_path=self.kernel, precision=self.precision,
            lapack=bool(self.lapack_nb), lapack_nb=self.lapack_nb)


@dataclasses.dataclass
class AutotuneResult:
    """Everything :func:`autotune` learned from one trace."""

    points: List[GridPoint]
    baseline: GridPoint
    best: GridPoint

    @property
    def speedup(self) -> float:
        return self.baseline.total_s / max(1e-12, self.best.total_s)

    @property
    def moved_delta(self) -> int:
        """Moved-byte change of the recommendation (negative = less)."""
        return self.best.moved_bytes - self.baseline.moved_bytes

    def recommended_cap(self) -> Optional[GridPoint]:
        """The tightest swept ``SCILIB_DEVICE_BYTES`` that keeps the
        best configuration within 2% of its uncapped predicted time —
        how much device residency this workload actually needs.  None
        when no capped point stays near (or none was swept)."""
        twin = [p for p in self.points
                if p.device_bytes is not None
                and (p.policy, p.threshold, p.n_devices, p.kernel,
                     p.precision, p.lapack_nb) ==
                    (self.best.policy, self.best.threshold,
                     self.best.n_devices, self.best.kernel,
                     self.best.precision, self.best.lapack_nb)
                and p.total_s <= self.best.total_s * 1.02]
        if not twin:
            return None
        return min(twin, key=lambda p: (p.device_bytes, p.total_s))


def _simulate(trace: Trace, spec: HardwareSpec, policy: str,
              threshold: float, n_devices: int,
              device_bytes: Optional[int] = None,
              evict: str = "lru", kernel: bool = False,
              precision: str = "", lapack_nb: int = 0) -> GridPoint:
    # lapack_nb is a label only: the caller hands in the already-retiled
    # trace (retile_lapack), the simulator itself is nb-oblivious.
    sim = MemTierSimulator(spec, policy=policy, threshold=threshold,
                           n_devices=n_devices, device_bytes=device_bytes,
                           evict=evict, kernel_path=kernel,
                           precision=precision)
    return GridPoint(policy, threshold, n_devices, sim.run(trace),
                     device_bytes, evict, kernel, precision, lapack_nb)


def _is_lu_span(call) -> bool:
    return bool(call.solver_id) and call.solver in ("getrf", "gesv")


def retile_lapack(trace: Trace, nb: int) -> Trace:
    """Re-tile the trace's LU solver spans at block size ``nb``.

    The blocked-LU call structure is fully determined by (n, nb): per
    block a ``getf2`` panel, a ``trsm`` row-swap/solve of the panel's
    U12, and the trailing ``gemm`` — so a recorded span can be
    regenerated at any candidate ``nb`` without re-running the solver.
    Factor-phase calls of each ``getrf``/``gesv`` span are replaced by
    the re-tiled stream against the same factor buffer (preserving the
    cross-span buffer reuse DFU feeds on); solve-phase trsms (their
    ``m`` equals the matrix order — the factor trsms' ``m`` is the
    block size) are nb-independent and copied through, as are
    ``getrs``-only spans, non-solver calls, buffers and events.
    ``nb == 0`` (or a span-free trace) returns the trace unchanged.
    """
    if not nb:
        return trace
    lu_spans: Dict[str, List] = {}
    for c in trace:
        if _is_lu_span(c):
            lu_spans.setdefault(c.solver_id, []).append(c)
    if not lu_spans:
        return trace
    out = Trace()
    out.buffer_sizes = dict(trace.buffer_sizes)
    out.buffer_names = dict(trace.buffer_names)
    out._next_buf = trace._next_buf
    out.events = list(trace.events)
    emitted = set()
    for c in trace:
        sid = c.solver_id
        if sid not in lu_spans:
            out.calls.append(c)
            continue
        if sid in emitted:
            continue
        emitted.add(sid)
        span = lu_spans[sid]
        first = next(x for x in span if x.routine.endswith("getf2"))
        prec = first.routine[0]
        n = first.m                     # first panel spans all n rows
        fbuf = first.operands[0][1]
        for j0 in range(0, n, nb):
            jb = min(nb, n - j0)
            out.panel(prec, n - j0, jb, fbuf, solver=sid)
            rem = n - j0 - jb
            if rem > 0:
                out.trsm(prec, jb, rem, fbuf, fbuf, solver=sid)
                out.gemm(prec, rem, rem, jb, fbuf, fbuf, fbuf,
                         solver=sid)
        for x in span:
            if x.routine.endswith("trsm") and x.m == n:
                out.calls.append(x)     # getrs phase: nb-independent
    return out


def _cap_grid(device_bytes, baseline: GridPoint) -> List[Optional[int]]:
    """Resolve the device-bytes sweep.  ``"auto"`` derives candidates
    from the uncapped baseline replay's peak device residency — the only
    caps that change anything are the ones below what DFU would use."""
    if device_bytes is None:
        return [None]
    if device_bytes == "auto":
        peak = baseline.report.device_bytes_peak
        if not peak:
            return [None]
        return [None, peak // 2, peak // 4]
    caps: List[Optional[int]] = []
    for c in device_bytes:
        caps.append(None if not c else int(c))
    return caps or [None]


def autotune(trace: Trace, *, spec: HardwareSpec = SPECS["gh200"],
             policies: Sequence[str] = DEFAULT_POLICIES,
             thresholds: Optional[Sequence[float]] = None,
             device_counts: Sequence[int] = DEFAULT_DEVICE_COUNTS,
             device_bytes="auto",
             evicts: Sequence[str] = DEFAULT_EVICTS,
             kernels: Optional[Sequence[bool]] = None,
             precisions: Optional[Sequence[str]] = None,
             lapack_nbs: Optional[Sequence[int]] = None,
             ) -> AutotuneResult:
    """Sweep the grid and pick the fastest point (moved bytes break ties).

    Multi-device replay only exists for the ``dfu`` policy (the runtime's
    tile scheduler never shards the others), so non-dfu policies are
    swept at one device only.  Likewise the device-bytes cap and the
    eviction policy model the runtime's DFU residency store, so only
    ``dfu`` sweeps them (and eviction policies only matter under a cap).

    The kernel dimension (``SCILIB_KERNELS``) defaults to auto: it is
    swept only when the trace carries venue tags — a venue-free trace
    has no probe timings to calibrate the pallas cost model from, so
    both kernel settings would replay identically and the sweep would
    only double the grid.  Kernel-off points precede their kernel-on
    twins, so an exact tie recommends the simpler configuration.

    The precision dimension (``SCILIB_PRECISION``) is gated the same
    way: swept only when the trace carries split-scheme tags to
    calibrate the split/native cost ratio from, and — the recommendation
    guard — only when the recorded run's escalation rate stayed under
    10% of its split calls.  A workload whose residual checks keep
    escalating pays for the split passes *and* the native reruns; its
    trace is evidence the scheme does not fit, so the tuner refuses to
    recommend it.

    The LAPACK block-size dimension (``SCILIB_LAPACK_NB``) is gated on
    solver spans: only a trace whose LU factorizations were recorded
    through the solver tier (``SCILIB_LAPACK=1``) can be re-tiled —
    each candidate ``nb`` replays a :func:`retile_lapack` variant of
    the trace, trading panel count against trailing-gemm size.
    ``nb == 0`` (the baseline) replays the trace exactly as recorded.
    """
    if thresholds is None:
        thresholds = thr.threshold_grid(c.n_avg for c in trace)
    if kernels is None:
        kernels = ((False, True) if any(c.venue for c in trace)
                   else (False,))
    if precisions is None:
        schemes = sorted({c.precision for c in trace if c.precision})
        tagged = sum(1 for c in trace if c.precision)
        esc = trace.event_count("escalate")
        if schemes and esc <= 0.1 * tagged:
            precisions = ("",) + tuple(schemes)
        else:
            precisions = ("",)
    if lapack_nbs is None:
        lapack_nbs = (DEFAULT_LAPACK_NBS
                      if any(_is_lu_span(c) for c in trace) else (0,))
    retiled = {lnb: retile_lapack(trace, lnb) for lnb in set(lapack_nbs)}
    baseline = _simulate(trace, spec, *BASELINE)
    caps = _cap_grid(device_bytes, baseline)
    points: List[GridPoint] = [baseline]
    for policy in policies:
        for t in thresholds:
            for nd in device_counts:
                if nd > 1 and policy != "dfu":
                    continue
                for cap in (caps if policy == "dfu" else [None]):
                    for ev in (evicts if cap is not None else ["lru"]):
                        for kern in kernels:
                            for prc in precisions:
                                for lnb in lapack_nbs:
                                    cfg = (policy, float(t), nd, cap, ev,
                                           bool(kern), str(prc),
                                           int(lnb))
                                    if cfg == BASELINE:
                                        continue    # already simulated
                                    points.append(_simulate(
                                        retiled[lnb], spec, *cfg))
    # fastest first; among points within 2% of it, least movement wins —
    # a config that moves gigabytes for a sub-noise predicted gain is
    # not a recommendation.  Uncapped points precede capped twins in the
    # list, so an exact tie recommends the simpler configuration.
    fastest = min(p.total_s for p in points)
    near = [p for p in points if p.total_s <= fastest * 1.02]
    best = min(near, key=lambda p: (p.moved_bytes, p.total_s))
    return AutotuneResult(points=points, baseline=baseline, best=best)


# --------------------------------------------------------------------- #
# presentation                                                           #
# --------------------------------------------------------------------- #
def _grid_row(p: GridPoint, mark: str = "") -> str:
    return (f"{p.policy:<9}{_fmt_threshold(p.threshold):>10}"
            f"{p.n_devices:>6}{_fmt_cap(p.device_bytes):>8}"
            f"{p.evict:>9}{('on' if p.kernel else '-'):>6}"
            f"{(p.precision or '-'):>8}"
            f"{(str(p.lapack_nb) if p.lapack_nb else '-'):>5}"
            f"{p.total_s:>10.4f}"
            f"{p.moved_bytes / 1e9:>10.3f}"
            f"{p.report.offloaded_calls:>9}"
            f"{p.report.evictions:>7}{mark}")


def format_grid(result: AutotuneResult, top: int = 12) -> str:
    lines = [f"{'policy':<9}{'threshold':>10}{'ndev':>6}{'cap':>8}"
             f"{'evict':>9}{'kern':>6}{'prec':>8}{'nb':>5}{'pred_s':>10}"
             f"{'moved_GB':>10}{'offload':>9}{'evict#':>7}"]
    ranked = sorted(result.points,
                    key=lambda p: (p.total_s, p.moved_bytes))[:top]
    for p in ranked:
        mark = " <- baseline" if p is result.baseline else (
            " <- best" if p is result.best else "")
        lines.append(_grid_row(p, mark))
    # the two rows the operator must be able to cross-check are always
    # shown, even when they rank below the top-N cut
    for p, mark in ((result.best, " <- best"),
                    (result.baseline, " <- baseline")):
        if p not in ranked:
            lines.append(_grid_row(p, mark))
            ranked.append(p)
    return "\n".join(lines)


def format_sites(trace: Trace, result: AutotuneResult,
                 top: int = 6) -> str:
    """Per-site baseline vs recommended predicted seconds (needs a trace
    recorded after call-site identity existed; silent otherwise)."""
    flops: Dict[str, float] = {}
    calls: Dict[str, int] = {}
    for c in trace:
        if not c.callsite_id:
            continue
        flops[c.callsite_id] = flops.get(c.callsite_id, 0.0) + c.flops
        calls[c.callsite_id] = calls.get(c.callsite_id, 0) + 1
    if not flops:
        return ""
    base_s = result.baseline.report.per_site_s
    best_s = result.best.report.per_site_s
    lines = ["call sites (predicted seconds, baseline -> recommended)",
             f"{'site':<44}{'calls':>7}{'GFLOP':>9}{'base_s':>9}"
             f"{'best_s':>9}"]
    for site in sorted(flops, key=lambda s: -flops[s])[:top]:
        label = site if len(site) <= 43 else site[:40] + "..."
        lines.append(f"{label:<44}{calls[site]:>7}"
                     f"{flops[site] / 1e9:>9.2f}"
                     f"{base_s.get(site, 0.0):>9.4f}"
                     f"{best_s.get(site, 0.0):>9.4f}")
    return "\n".join(lines)


def format_recommendation(result: AutotuneResult) -> str:
    env = " ".join(f"{k}={v}" for k, v in result.best.env().items())
    if result.baseline.moved_bytes > 0:
        delta = (f"({100.0 * result.moved_delta / result.baseline.moved_bytes:+.0f}%)")
    else:
        delta = f"({result.moved_delta / 1e9:+.3f} GB)"
    lines = [
        f"baseline  (dfu @ {_fmt_threshold(result.baseline.threshold)}, "
        f"1 device): {result.baseline.total_s:.4f} s predicted, "
        f"{result.baseline.moved_bytes / 1e9:.3f} GB moved",
        f"recommended: {env}",
        f"  predicted {result.best.total_s:.4f} s "
        f"({result.speedup:.2f}x vs baseline), "
        f"{result.best.moved_bytes / 1e9:.3f} GB moved {delta}",
    ]
    cap = result.recommended_cap()
    if cap is not None:
        lines.append(
            f"  cap: SCILIB_DEVICE_BYTES={cap.device_bytes} "
            f"(SCILIB_EVICT={cap.evict}) stays within 2% — "
            f"{cap.report.evictions} evictions, "
            f"{cap.report.refetched_bytes / 1e9:.3f} GB refetched; "
            f"the workload needs no more device memory than this")
    if result.best is result.baseline:
        lines.append("  the default configuration is already optimal "
                     "for this workload")
    return "\n".join(lines)


# --------------------------------------------------------------------- #
# CLI                                                                    #
# --------------------------------------------------------------------- #
def _parse_floats(raw: str) -> Tuple[float, ...]:
    return tuple(float(v) for v in raw.split(",") if v)


def _parse_ints(raw: str) -> Tuple[int, ...]:
    return tuple(int(v) for v in raw.split(",") if v)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.autotune",
        description="Replay a recorded BLAS trace across a threshold x "
                    "policy x device grid and recommend SCILIB_* settings.")
    ap.add_argument("trace", help="trace JSON (SCILIB_TRACE=... dump)")
    ap.add_argument("--spec", default="gh200", choices=sorted(SPECS),
                    help="hardware spec to simulate (default: gh200)")
    ap.add_argument("--policies", default=",".join(DEFAULT_POLICIES),
                    help="comma list of policies to sweep")
    ap.add_argument("--thresholds", default="",
                    help="comma list of thresholds (default: derived "
                         "from the trace's N_avg distribution)")
    ap.add_argument("--devices", default=",".join(
        str(d) for d in DEFAULT_DEVICE_COUNTS),
        help="comma list of device counts (dfu only beyond 1)")
    ap.add_argument("--device-bytes", default="auto",
                    help="comma list of SCILIB_DEVICE_BYTES caps to "
                         "sweep (0 = uncapped), or 'auto' to derive "
                         "fractions of the uncapped replay's peak "
                         "device residency (dfu only)")
    ap.add_argument("--evict", default=",".join(DEFAULT_EVICTS),
                    help="comma list of eviction policies to sweep at "
                         "each capped point (lru, lfu, refetch)")
    ap.add_argument("--kernels", default="auto",
                    choices=("auto", "off", "on", "both"),
                    help="sweep the SCILIB_KERNELS (pallas venue) "
                         "dimension; 'auto' sweeps it only when the "
                         "trace carries venue tags to calibrate from")
    ap.add_argument("--precision", default="auto",
                    help="sweep the SCILIB_PRECISION (split-emulation) "
                         "dimension: 'auto' sweeps the schemes the "
                         "trace was recorded under (refused when its "
                         "escalation rate exceeded 10%%), 'off' pins "
                         "native, or a comma list of schemes (e.g. "
                         "split2,split3)")
    ap.add_argument("--lapack-nb", default="auto",
                    help="sweep the SCILIB_LAPACK_NB (LU block size) "
                         "dimension: 'auto' sweeps "
                         f"{','.join(str(v) for v in DEFAULT_LAPACK_NBS if v)} "
                         "when the trace carries solver spans, 'off' "
                         "pins the recorded tiling, or a comma list of "
                         "block sizes (0 = as recorded)")
    ap.add_argument("--top", type=int, default=12,
                    help="grid rows to print")
    ap.add_argument("--emit-config", metavar="PATH", default="",
                    help="write the recommendation as a typed "
                         "OffloadConfig JSON file: the tune->deploy "
                         "artifact repro.session(OffloadConfig.load("
                         "PATH)) runs directly")
    args = ap.parse_args(argv)

    trace = Trace.load(args.trace)
    thresholds = _parse_floats(args.thresholds) or None
    device_bytes = (args.device_bytes if args.device_bytes == "auto"
                    else _parse_ints(args.device_bytes))
    kernels = {"auto": None, "off": (False,), "on": (True,),
               "both": (False, True)}[args.kernels]
    if args.precision == "auto":
        precisions = None
    elif args.precision == "off":
        precisions = ("",)
    else:
        precisions = ("",) + tuple(
            p for p in args.precision.split(",") if p and p != "native")
    if args.lapack_nb == "auto":
        lapack_nbs = None
    elif args.lapack_nb == "off":
        lapack_nbs = (0,)
    else:
        lapack_nbs = (0,) + tuple(
            v for v in _parse_ints(args.lapack_nb) if v)
    result = autotune(trace, spec=SPECS[args.spec],
                      policies=tuple(args.policies.split(",")),
                      thresholds=thresholds,
                      device_counts=_parse_ints(args.devices),
                      device_bytes=device_bytes,
                      evicts=tuple(args.evict.split(",")),
                      kernels=kernels, precisions=precisions,
                      lapack_nbs=lapack_nbs)
    n_sites = len({c.callsite_id for c in trace if c.callsite_id})
    print(f"autotune: {len(result.points)}-point grid, spec={args.spec}, "
          f"{len(trace)} calls, {n_sites} sites, "
          f"{trace.total_flops / 1e9:.2f} GFLOP")
    print(format_grid(result, top=args.top))
    sites = format_sites(trace, result)
    if sites:
        print(sites)
    print(format_recommendation(result))
    if args.emit_config:
        result.best.to_config().save(args.emit_config)
        print(f"config written to {args.emit_config} — run it with "
              f"repro.session(OffloadConfig.load({args.emit_config!r}))")
    return 0


if __name__ == "__main__":
    sys.exit(main())

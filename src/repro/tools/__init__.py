"""Operator-facing tools built on the runtime's traces and simulator.

* :mod:`repro.tools.autotune` — trace-replay autotuner: grid-search the
  ``SCILIB_*`` knobs against the memtier simulator and print recommended
  settings (``python -m repro.tools.autotune trace.json``).
"""

"""Serving driver: batched decoding with offload-policy state placement.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2_1_3b \
        --reduced --batch 4 --prompt-len 32 --gen 64 --policy dfu

``--offload-config tuned.json`` additionally opens a BLAS-offload
session for the whole serve (the autotuner's ``--emit-config``
artifact, loaded via ``OffloadConfig.load``): eager BLAS around the
jitted decode step is intercepted under the tuned settings and the
session report prints at exit.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--policy", default="dfu",
                    choices=["dfu", "memcopy", "pinned"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--offload-config", default="",
                    help="OffloadConfig JSON (e.g. from "
                         "repro.tools.autotune --emit-config): serve "
                         "inside a session running these settings")
    args = ap.parse_args()

    from repro.models import get_config
    from repro.models.registry import Model
    from repro.train import Server, ServeConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model.from_config(cfg)
    params = model.init(jax.random.PRNGKey(0))

    scfg = ServeConfig(max_len=args.prompt_len + args.gen,
                       temperature=args.temperature,
                       offload_policy=args.policy,
                       cache_dtype=jnp.dtype(cfg.dtype))
    srv = Server(model, params, scfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len),
                                0, cfg.vocab)
    extra = None
    if cfg.family == "encdec":
        extra = {"frames": jnp.ones(
            (args.batch, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.dtype))}
    session = None
    if args.offload_config:
        from repro.core.config import OffloadConfig
        from repro.core.session import Session
        session = Session(
            OffloadConfig.load(args.offload_config)).open()
    try:
        out = srv.generate(prompt, args.gen, extra)
    finally:
        if session is not None:
            print(session.report())
            session.close()
    s = srv.stats
    tps = s.tokens / max(1e-9, s.decode_s)
    print(f"arch={cfg.name} policy={args.policy}")
    print(f"generated {out.shape} prefill={s.prefill_s:.3f}s "
          f"decode={s.decode_s:.3f}s ({tps:.1f} tok/s)")
    print(f"state moved: h->d {s.bytes_host_to_dev/1e6:.2f} MB, "
          f"d->h {s.bytes_dev_to_host/1e6:.2f} MB, "
          f"migrations={s.migrations}, cache reuses={s.cache_reuses}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

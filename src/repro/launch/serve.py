"""Serving driver: batched decoding with offload-policy state placement.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2_1_3b \
        --reduced --batch 4 --prompt-len 32 --gen 64 --policy dfu

``--offload-config tuned.json`` additionally opens a BLAS-offload
session for the whole serve (the autotuner's ``--emit-config``
artifact, loaded via ``OffloadConfig.load``): eager BLAS around the
jitted decode step is intercepted under the tuned settings and the
session report prints at exit.

``--streams N`` serves N concurrent request streams, one worker thread
+ one offload session per stream, all drawing on a single shared device
pool (``--pool-mb``) — the multi-tenant serving shape.  The per-tenant
pool report prints at exit.
"""
from __future__ import annotations

import argparse
import threading

import jax
import jax.numpy as jnp


def _serve_streams(args, cfg, model, params, scfg, prompt, extra) -> int:
    """N concurrent streams: per-stream Server + Session over one
    shared pool; aggregate throughput plus the per-tenant report."""
    from repro.core import residency as res
    from repro.core import session as ses
    from repro.core.config import OffloadConfig

    if args.offload_config:
        ocfg = OffloadConfig.load(args.offload_config)
    else:
        ocfg = OffloadConfig(policy="dfu")
    pool = res.SharedDevicePool(args.pool_mb << 20, name="serve")
    from repro.train import Server

    outs = [None] * args.streams
    tenants = [None] * args.streams
    errors = []

    def worker(idx: int) -> None:
        try:
            with ses.session(ocfg, record_trace=False, intercept=False,
                             name=f"stream-{idx}", pool=pool) as s:
                srv = Server(model, params, scfg)
                outs[idx] = srv.generate(prompt, args.gen, extra)
                tenants[idx] = pool.tenant_stats().get(s.name)
        except BaseException as exc:
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,),
                                name=f"serve-stream-{i}")
               for i in range(args.streams)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    if errors:
        raise errors[0]
    print(f"arch={cfg.name} policy={args.policy} "
          f"streams={args.streams} pool={args.pool_mb}MB")
    print(pool.report())
    for idx, row in enumerate(tenants):
        if row is not None:
            print(f"  stream-{idx}: " + " ".join(
                f"{k}={v}" for k, v in sorted(row.items())))
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--policy", default="dfu",
                    choices=["dfu", "memcopy", "pinned"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--offload-config", default="",
                    help="OffloadConfig JSON (e.g. from "
                         "repro.tools.autotune --emit-config): serve "
                         "inside a session running these settings")
    ap.add_argument("--streams", type=int, default=1,
                    help="concurrent request streams, one session per "
                         "stream over a shared device pool")
    ap.add_argument("--pool-mb", type=int, default=256,
                    help="shared pool capacity for --streams > 1")
    args = ap.parse_args()

    from repro.models import get_config
    from repro.models.registry import Model
    from repro.train import Server, ServeConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model.from_config(cfg)
    params = model.init(jax.random.PRNGKey(0))

    scfg = ServeConfig(max_len=args.prompt_len + args.gen,
                       temperature=args.temperature,
                       offload_policy=args.policy,
                       cache_dtype=jnp.dtype(cfg.dtype))
    srv = Server(model, params, scfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len),
                                0, cfg.vocab)
    extra = None
    if cfg.family == "encdec":
        extra = {"frames": jnp.ones(
            (args.batch, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.dtype))}
    if args.streams > 1:
        return _serve_streams(args, cfg, model, params, scfg,
                              prompt, extra)
    session = None
    if args.offload_config:
        from repro.core.config import OffloadConfig
        from repro.core.session import Session
        session = Session(
            OffloadConfig.load(args.offload_config)).open()
    try:
        out = srv.generate(prompt, args.gen, extra)
    finally:
        if session is not None:
            print(session.report())
            session.close()
    s = srv.stats
    tps = s.tokens / max(1e-9, s.decode_s)
    print(f"arch={cfg.name} policy={args.policy}")
    print(f"generated {out.shape} prefill={s.prefill_s:.3f}s "
          f"decode={s.decode_s:.3f}s ({tps:.1f} tok/s)")
    print(f"state moved: h->d {s.bytes_host_to_dev/1e6:.2f} MB, "
          f"d->h {s.bytes_dev_to_host/1e6:.2f} MB, "
          f"migrations={s.migrations}, cache reuses={s.cache_reuses}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods x 256
chips as (pod=2, data=16, model=16) — the ``pod`` axis composes with
``data`` for batch sharding and carries the (slower, compressible)
inter-pod gradient reduction. Defined as functions so importing this
module never touches jax device state (the dry-run must set XLA_FLAGS
before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 8, model: int = 2):
    """Small mesh over however many (fake) devices a test session has."""
    data = n_devices // model
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)

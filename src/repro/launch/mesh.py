"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods x 256
chips as (pod=2, data=16, model=16) — the ``pod`` axis composes with
``data`` for batch sharding and carries the (slower, compressible)
inter-pod gradient reduction. Defined as functions so importing this
module never touches jax device state (the dry-run must set XLA_FLAGS
before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 8, model: int = 2):
    """Small mesh over however many (fake) devices a test session has."""
    data = n_devices // model
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# --------------------------------------------------------------------- #
# BLAS-offload device set (the multi-device tile scheduler's view)       #
# --------------------------------------------------------------------- #
def offload_devices():
    """Real devices backing the offload runtime's logical device tiers.

    The runtime enumerates N device tiers (``SCILIB_DEVICES`` or
    ``len(jax.devices())``, see ``repro.core.memspace``); tier *i* maps to
    real device ``i % len(jax.devices())`` — with more tiers than
    hardware (the CPU container's simulated layout) tiers wrap onto the
    same physical device, exactly like :func:`memspace.put_block`.
    """
    from repro.core import memspace
    devs = jax.devices()
    return [devs[i % len(devs)] for i in range(memspace.active().n_devices)]


def make_offload_mesh():
    """1-D ``('blas',)`` mesh over the sharded-dispatch device set, for
    model code that wants its collectives co-located with the BLAS tiles
    the offload runtime schedules."""
    import numpy as np
    seen, unique = set(), []
    for d in offload_devices():
        if d.id not in seen:
            seen.add(d.id)
            unique.append(d)
    return jax.sharding.Mesh(np.array(unique), ("blas",))

"""End-to-end training driver.

CPU-sized run (the example driver):
    PYTHONPATH=src python -m repro.launch.train --arch qwen1_5_4b \
        --reduced --steps 50 --batch 8 --seq 128

Production mesh (with real TPUs this is the full launcher; on CPU use
DRYRUN_DEVICES and --dry-compile to validate without executing):
    DRYRUN_DEVICES=512 PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2_5_32b --mesh multi --dry-compile
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "dots", "full"])
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    choices=[None, "single", "multi"])
    ap.add_argument("--dry-compile", action="store_true",
                    help="lower+compile the sharded step, do not run")
    ap.add_argument("--offload-config", default="",
                    help="OffloadConfig JSON (e.g. from "
                         "repro.tools.autotune --emit-config): train "
                         "inside a BLAS-offload session running these "
                         "settings; the session report prints at exit")
    args = ap.parse_args()

    if args.mesh and args.dry_compile:
        os.environ.setdefault("DRYRUN_DEVICES", "512")
        from repro.launch.dryrun import run_cell
        rec = run_cell(args.arch, "train_4k", args.mesh == "multi",
                       remat=args.remat, n_micro=args.n_micro,
                       grad_compress=args.grad_compress, out_dir=None)
        return 0 if rec["status"] == "ok" else 1

    import jax.numpy as jnp
    from repro.data import DataConfig, TokenPipeline
    from repro.models import get_config
    from repro.models.registry import Model
    from repro.train import Trainer, TrainConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model.from_config(cfg)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch))
    extra_fn = None
    if cfg.family == "encdec":
        def extra_fn(step):
            return {"frames": jnp.ones(
                (args.batch, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.dtype))}
    elif cfg.family == "vlm" and cfg.patch_prefix:
        def extra_fn(step):
            return {"patch_embeds": jnp.ones(
                (args.batch, cfg.patch_prefix, cfg.d_model),
                jnp.dtype(cfg.dtype))}

    tcfg = TrainConfig(steps=args.steps, peak_lr=args.lr,
                       n_micro=args.n_micro, remat=args.remat,
                       grad_compress=args.grad_compress,
                       ckpt_every=args.ckpt_every,
                       moe_impl="dense" if args.reduced else "scatter")
    trainer = Trainer(model, pipe, tcfg, ckpt_dir=args.ckpt_dir)
    session = None
    if args.offload_config:
        from repro.core.config import OffloadConfig
        from repro.core.session import Session
        session = Session(
            OffloadConfig.load(args.offload_config)).open()
    try:
        hist = trainer.fit()
    finally:
        if session is not None:
            print(session.report())
            session.close()
    print(f"final loss {hist[-1]['loss']:.4f} after {trainer.step} steps; "
          f"straggler events: {trainer.straggler_events}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Parameter/batch/cache sharding rules for the production meshes.

Megatron-style tensor parallelism on the ``model`` axis: column-parallel
QKV/gate/up projections, row-parallel O/down projections (one psum per
block), vocab-parallel embedding, expert-parallel MoE weights, and
head-sharded SSD state. Batch spans ``data`` (and ``pod`` when present).
Optimizer state inherits the parameter rules; ``zero=True`` additionally
shards the largest dim of every moment tensor over ``data`` (ZeRO-1).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_axes

# ----------------------------------------------------------------------- #
# parameter rules                                                          #
# ----------------------------------------------------------------------- #
_COL = ("wq", "wk", "wv", "wg", "wu", "w1", "in_proj")     # d -> sharded out
_ROW = ("wo", "wd", "w2", "out_proj")                      # sharded in -> d
_VEC_MODEL = ("bq", "bk", "bv", "b1", "a_log", "dt_bias", "d_skip",
              "norm_w", "conv_b")


def _path_names(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


def param_spec(path, leaf, cfg: ModelConfig) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    nd = leaf.ndim
    in_moe = "moe" in names
    in_conv = name.startswith("conv_w")

    def lead(spec_tail):
        """Pad with None for stacked leading dims (layer groups, experts)."""
        pad = nd - len(spec_tail)
        return P(*([None] * pad + list(spec_tail)))

    if name == "table":                       # (V, d): vocab-parallel
        return P("model", None)
    if name == "unembed":                     # (d, V)
        return P(None, "model")
    if name == "pos_dec":
        return P(None, None)
    if name == "router":                      # replicated: tiny + hot
        return lead([None, None])
    if in_moe and name in ("wg", "wu"):       # (..., E, d, ffe): EP
        return lead(["model", None, None])
    if in_moe and name == "wd":               # (..., E, ffe, d): EP
        return lead(["model", None, None])
    if in_conv:                               # (..., k, conv_dim)
        return lead([None, "model"])
    if name in _COL and nd >= 2:
        return lead([None, "model"])
    if name in _ROW and nd >= 2:
        return lead(["model", None])
    if name in _VEC_MODEL and nd >= 1:
        return lead(["model"])
    return P(*([None] * nd))                  # norms, biases: replicated


def sanitize(spec: P, shape, mesh: Mesh, *, fallbacks: dict = None) -> P:
    """Drop (or re-home) spec dims the shape cannot divide evenly.

    ``fallbacks`` maps dim index -> alternative dim index: if the spec'd
    axis does not divide dim i but divides dim j, the axis moves there
    (e.g. KV-head sharding falling back to head_dim when Hkv < mesh)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    fallbacks = fallbacks or {}

    def axsize(ax):
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        return mesh.shape[ax]

    for i in range(len(dims)):
        ax = dims[i]
        if ax is None:
            continue
        if shape[i] % axsize(ax) != 0:
            j = fallbacks.get(i)
            if (j is not None and dims[j] is None
                    and shape[j] % axsize(ax) == 0):
                dims[j] = ax
            dims[i] = None
    return P(*dims)


def param_shardings(params: Any, mesh: Mesh, cfg: ModelConfig):
    def one(path, leaf):
        spec = sanitize(param_spec(path, leaf, cfg), leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_shardings(opt_state: Any, mesh: Mesh, cfg: ModelConfig, *,
                  zero: bool = False):
    """Moments follow the params; ZeRO-1 also slices over ``data``."""

    def one(path, leaf):
        spec = sanitize(param_spec(path, leaf, cfg), leaf.shape, mesh)
        if zero and leaf.ndim >= 2:
            dims = list(spec)
            dims += [None] * (leaf.ndim - len(dims))
            # shard the largest still-unsharded dim over data
            free = [i for i, d in enumerate(dims) if d is None]
            if free:
                big = max(free, key=lambda i: leaf.shape[i])
                if leaf.shape[big] % mesh.shape["data"] == 0:
                    dims[big] = "data"
            spec = P(*dims)
        return NamedSharding(mesh, spec)

    # step counter and other scalars: replicated
    def dispatch(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return one(path, leaf)

    return jax.tree_util.tree_map_with_path(dispatch, opt_state)


# ----------------------------------------------------------------------- #
# batch / cache rules                                                      #
# ----------------------------------------------------------------------- #
def batch_spec(mesh) -> P:
    ba = batch_axes(mesh)
    return P(ba if len(ba) > 1 else ba[0])


def batch_shardings(batch_shapes: dict, mesh: Mesh):
    """tokens/labels: (B, T) -> batch over data(+pod); stub embeddings:
    (B, S, d) likewise — the leading dim is always the global batch."""
    ba = batch_axes(mesh)
    lead = ba[0] if len(ba) == 1 else tuple(ba)

    def one(shape_dtype):
        nd = len(shape_dtype.shape)
        spec = sanitize(P(*([lead] + [None] * (nd - 1))),
                        shape_dtype.shape, mesh)
        return NamedSharding(mesh, spec)

    return {k: one(v) for k, v in batch_shapes.items()}


def cache_spec(mesh, kind: str, ndim: int, *, seq_shard: bool = False) -> P:
    """Decode-state shardings.

    kind "kv": (G, B, Hkv, S, D) — batch over data, heads over model;
    ``seq_shard`` (long-context, batch=1) moves data-sharding to S.
    kind "ssm": (G, B, H, S, P) state — heads over model, batch over data.
    kind "conv": (G, B, K-1, C) — channels over model.
    """
    ba = batch_axes(mesh)
    b = ba[0] if len(ba) == 1 else tuple(ba)
    if kind == "kv":
        if seq_shard:
            return P(None, None, "model", "data", None)
        return P(None, b, "model", None, None)
    if kind == "ssm":
        return P(None, b, "model", None, None)
    if kind == "conv":
        return P(None, b, None, "model")
    raise ValueError(kind)
